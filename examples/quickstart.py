"""Quickstart: the paper's primitives in 60 lines.

1. MRD Allreduce for a non-power-of-two group (sim executor).
2. The non-blocking statechart: one stage per call, overlap with 'compute'.
3. Exact (snapshot-certified) convergence detection of an asynchronous
   Jacobi solve of the paper's 1-D boundary-value problem.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import async_engine, mrd, nonblocking, solvers
from repro.core.topology import paper_message_count, paper_step_count

# --- 1. modified recursive doubling, p = 6 (non-power-of-two) --------------
p = 6
x = jnp.arange(p * 4, dtype=jnp.float32).reshape(p, 4)
out = mrd.sim_allreduce(x, op="sum")
np.testing.assert_allclose(np.asarray(out[0]), np.asarray(x.sum(0)))
print(f"MRD allreduce p={p}: {paper_step_count(p)} steps, "
      f"{paper_message_count(p)} messages (paper: log2(p0)+2, p0*log2(p0)+2(p-p0))")

# --- 2. non-blocking statechart (paper Fig. 4) ------------------------------
vals = jnp.asarray([3.0, 1.0, 4.0, 1.0, 5.0, 9.0])
st = nonblocking.init(vals)
calls = 0
while True:
    st = nonblocking.step(st, vals, p=p, op="max")
    calls += 1
    # << the application computes here while the reduction is in flight >>
    if bool(st["flag"]):
        break
print(f"staged allreduce: max={float(st['result'][0])} after {calls} "
      f"non-blocking calls (= cycle length {nonblocking.cycle_length(p)})")

# --- 3. async iterations + exact convergence detection ----------------------
fp = solvers.poisson_1d(n=96, omega=1.0, shift=0.5, seed=0)
cfg = async_engine.AsyncConfig(p=4, detection="exact", eps=1e-5, max_ticks=50000)
res = async_engine.run(fp, cfg)
print(f"exact detector fired at tick {res.det_tick}: certified residual "
      f"{res.res_glb:.2e}, TRUE residual {res.true_res:.2e} < eps — "
      f"the snapshot solution is genuinely terminal")
assert res.true_res < cfg.eps
print("quickstart OK")
