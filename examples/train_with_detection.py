"""End-to-end training driver example: train a ~small LM for a few hundred
steps with the paper's machinery as first-class features:

- gradient sync = MRD-ZeRO-1 (reduce-scatter/all-gather built from the
  paper's butterfly; works on non-power-of-two DP groups),
- convergence detection = the non-blocking staged MRD Allreduce of per-worker
  losses (paper Algorithm 1), which stops training without ever blocking a
  step.

Run:  PYTHONPATH=src python examples/train_with_detection.py
(single-device CPU demo; multi-device via XLA_FLAGS=--xla_force_host_platform_device_count=8)
"""

from repro.launch.train import main as train_main

if __name__ == "__main__":
    train_main([
        "--arch", "llama3.2-1b",
        "--smoke",
        "--steps", "300",
        "--batch", "8",
        "--seq", "64",
        "--lr", "3e-3",
        "--grad-sync", "mrd_zero1",
        "--schedule", "wsd",
        "--monitor-threshold", "1.5",
        "--monitor-mode", "inexact",
        "--log-every", "20",
    ])
