"""Serving example: batched greedy decoding with KV/state caches, on an SSM
arch (recurrent cache) to show the cache machinery beyond transformers.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main([
        "--arch", "falcon-mamba-7b",
        "--smoke",
        "--batch", "4",
        "--prompt-len", "12",
        "--gen", "24",
    ])
