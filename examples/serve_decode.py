"""Continuous-batching serving demo on the ``ServeEngine`` API
(``repro.serving``, DESIGN.md S13), on an SSM arch (recurrent cache) to
show the slot machinery beyond transformer KV caches.

Requests with mixed prompt lengths and generation budgets arrive over
time; the pool admits each one by offset-prefilling it into a free (or
recycled) slot while every other slot keeps decoding, and the
``eos_maxlen`` termination protocol retires slots through the paper's
non-blocking agreement reduction.  Each request's tokens are identical to
decoding it alone (tests/test_serving.py proves bit-equality).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import numpy as np

from repro.configs import registry
from repro.launch.train import build_mesh
from repro.serving import Request, ServeConfig, ServeEngine, make_workload


def main():
    cfg = registry.get_smoke_config("falcon-mamba-7b")
    mesh = build_mesh(1, 1)
    workload = make_workload(
        "llm_decode", cfg=cfg, mesh=mesh,
        slots=3, max_len=40, max_prompt_len=12, seed=0,
    )
    engine = ServeEngine(workload, ServeConfig(
        scheduler="fcfs", termination="eos_maxlen",
    ))

    # 8 requests over 3 slots: mixed prompt lengths (3..12), mixed budgets
    # (4..16), staggered arrivals -> admissions recycle retired slots
    rng = np.random.default_rng(0)
    requests = [
        Request(
            id=i,
            arrival=int(rng.integers(0, 10)),
            prompt=rng.integers(0, cfg.vocab, size=int(rng.integers(3, 13))),
            max_new=int(rng.integers(4, 17)),
        )
        for i in range(8)
    ]
    results = engine.run(requests)

    for i in sorted(results):
        r = results[i]
        print(
            f"req {r.id}: arrival t={r.arrival:>2}  admitted t={r.admit_tick:>2}  "
            f"retired t={r.retire_tick:>2}  {r.n_tokens:>2} tokens  "
            f"head {r.output[:6].tolist()}"
        )
    s = engine.summary()
    print(
        f"\n{s['completed']} requests, {s['ticks']} ticks: "
        f"{s['throughput_tok_s']:.1f} tok/s, occupancy {s['occupancy']:.2f}, "
        f"TTFT p50 {s['ttft_p50_ms']:.1f} ms, TPOT p50 {s['tpot_p50_ms']:.2f} ms"
    )


if __name__ == "__main__":
    main()
