"""The paper's S4 experiment: asynchronous relaxation of a 1-D two-point
boundary-value problem, comparing detection protocols and environments.

Reproduces the Fig. 5 qualitative result: in a 'concentrated' (low-delay)
environment the asynchronous iteration count tracks the synchronous one,
while message counts are strictly higher — the regime where the paper
concludes synchronous iterations remain competitive.

Run:  PYTHONPATH=src python examples/solve_poisson_async.py
"""

from repro.configs.paper_poisson1d import CONFIG as PAPER
from repro.core import async_engine as ae
from repro.core import solvers

N = 512  # (paper: 10000 with shift=0 — slow contraction; see bench notes)

print(f"{'p':>3} {'mode':>9} {'ticks':>7} {'iters(min..max)':>16} "
      f"{'msgs':>9} {'certified':>10} {'true res':>10}")
for p in (2, 4, 8):
    fp = solvers.poisson_1d(N, omega=1.0, shift=PAPER.shift, seed=0)
    for mode in ("sync", "exact", "inexact"):
        cfg = ae.AsyncConfig(
            p=p, detection=mode, eps=PAPER.eps, max_ticks=60000,
            max_delay=PAPER.max_delay, activity=PAPER.activity, seed=p,
        )
        r = ae.run(fp, cfg)
        print(f"{p:>3} {mode:>9} {r.ticks:>7} "
              f"{str(r.kiter.min()) + '..' + str(r.kiter.max()):>16} "
              f"{r.messages_p2p + r.messages_coll:>9} "
              f"{r.res_glb:>10.2e} {r.true_res:>10.2e}")

print("\nNote: 'exact' certifies ||f(x̄)-x̄|| < eps on a consistent snapshot "
      "(always true at detection); 'inexact' may stop early (paper Alg. 1).")
