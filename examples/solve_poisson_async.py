"""The paper's S4 experiment on the registry-backed asynchrony runtime
(``repro.asynchrony``, DESIGN.md S11): asynchronous relaxation of a 1-D
two-point boundary-value problem, comparing detection protocols
(``DETECTION_PROTOCOLS``) and delay environments (``DELAY_MODELS``).

Reproduces the Fig. 5 qualitative result: in a 'concentrated' (low-delay)
environment the asynchronous iteration count tracks the synchronous one,
while message counts are strictly higher — the regime where the paper
concludes synchronous iterations remain competitive.  The closing sweep
shows the new engine's headline: seeds x delay-model parameters batched
into ONE jitted dispatch via ``sweep()`` (vmapped while_loop) instead of a
Python loop of runs.

Run:  PYTHONPATH=src python examples/solve_poisson_async.py
"""

import jax.numpy as jnp

from repro.asynchrony import AsyncConfig, make_solver, run, sweep
from repro.configs.paper_poisson1d import CONFIG as PAPER

N = 512  # (paper: 10000 with shift=0 — slow contraction; see bench notes)

print(f"{'p':>3} {'mode':>9} {'ticks':>7} {'iters(min..max)':>16} "
      f"{'msgs':>9} {'certified':>10} {'true res':>10}")
for p in (2, 4, 8):
    fp = make_solver("poisson1d", n=N, omega=1.0, shift=PAPER.shift, seed=0)
    for mode in ("sync", "exact", "inexact", "interval"):
        cfg = AsyncConfig(
            p=p, detection=mode, eps=PAPER.eps, max_ticks=60000,
            max_delay=PAPER.max_delay, activity=PAPER.activity, seed=p,
        )
        r = run(fp, cfg)
        print(f"{p:>3} {mode:>9} {r.ticks:>7} "
              f"{str(r.kiter.min()) + '..' + str(r.kiter.max()):>16} "
              f"{r.messages_p2p + r.messages_coll:>9} "
              f"{r.res_glb:>10.2e} {r.true_res:>10.2e}")

print("\nNote: 'exact' certifies ||f(x̄)-x̄|| < eps on a consistent snapshot "
      "(always true at detection); 'inexact' may stop early (paper Alg. 1); "
      "'interval' certifies a whole window of small updates.")

# --- one-dispatch sweep: seeds x bernoulli activity grid --------------------
fp = make_solver("poisson1d", n=128, omega=1.0, shift=PAPER.shift, seed=0)
cfg = AsyncConfig(p=4, detection="exact", eps=PAPER.eps, max_ticks=60000,
                  max_delay=PAPER.max_delay)
grid = {"activity": jnp.asarray([0.3, 0.6, 0.95], jnp.float32)}
sw = sweep(fp, cfg, seeds=jnp.arange(8), delay_params=grid)
print("\nsweep(): 3 activity levels x 8 seeds in one vmapped dispatch")
for gi, act in enumerate(grid["activity"]):
    print(f"  activity={float(act):.2f}: mean ticks {sw.ticks[gi].mean():7.1f}, "
          f"all certified: {bool(sw.detected[gi].all())}, "
          f"worst true res {sw.true_res[gi].max():.2e}")
