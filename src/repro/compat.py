"""JAX version compatibility shims.

The repo targets current JAX (``jax.shard_map``, ``jax.lax.axis_size``,
``jax.make_mesh(axis_types=...)``) but must also run on older 0.4.x
installs where those live under ``jax.experimental`` or don't exist.
Everything that touches the manual-collective surface goes through this
module so the rest of the codebase is version-agnostic.
"""

from __future__ import annotations

from typing import Any, Iterable, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh


def axis_size(axis_name) -> int:
    """Static size of a named mesh axis inside a manual region.

    ``jax.lax.psum`` of a Python constant folds to a static int on every
    JAX version, so this works where ``jax.lax.axis_size`` is missing.
    Accepts a tuple of names (returns the product).
    """
    if isinstance(axis_name, (tuple, list)):
        out = 1
        for a in axis_name:
            out *= axis_size(a)
        return out
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(
    f,
    *,
    mesh: Mesh,
    in_specs,
    out_specs,
    axis_names: Optional[Iterable[str]] = None,
    check_vma: bool = False,
):
    """``jax.shard_map`` when available, else the experimental one.

    ``axis_names`` selects the *manual* axes (new-API semantics); on the
    experimental API the complement becomes ``auto=``.  ``check_vma``
    maps to ``check_rep`` on old versions.
    """
    if hasattr(jax, "shard_map"):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma, **kw,
        )
    from jax.experimental.shard_map import shard_map as _sm

    # Old JAX: partial-manual (auto=) lowering hits unsupported PartitionId
    # ops on CPU, so run fully manual.  Axes outside ``axis_names`` then see
    # replicated data instead of auto-sharded data — correct (sharding
    # constraints inside the body degrade to no-ops), just less parallel.
    return _sm(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


def partial_manual_shard_map() -> bool:
    """True when shard_map supports auto (non-manual) axes alongside manual
    ones (``jax.shard_map`` era).  The experimental fallback runs fully
    manual instead."""
    return hasattr(jax, "shard_map")


def make_mesh(
    shape: Sequence[int],
    axis_names: Sequence[str],
    *,
    devices: Optional[Sequence[Any]] = None,
    axis_types: Any = None,
) -> Mesh:
    """Build a Mesh portably.  ``axis_types`` (AxisType.Auto/...) is applied
    only on JAX versions that have it; older versions ignore it (the
    auto/manual split is then carried by :func:`shard_map`'s axis_names)."""
    if devices is None:
        n = int(np.prod(shape))
        devices = jax.devices()[:n]
    arr = np.asarray(devices, dtype=object).reshape(tuple(shape))
    if axis_types is not None and hasattr(jax.sharding, "AxisType"):
        return Mesh(arr, tuple(axis_names), axis_types=axis_types)
    return Mesh(arr, tuple(axis_names))


def default_axis_types(n: int):
    """(AxisType.Auto,) * n on new JAX, None on old."""
    if hasattr(jax.sharding, "AxisType"):
        return (jax.sharding.AxisType.Auto,) * n
    return None


def pvary(x, axis_names):
    """``jax.lax.pvary`` or identity where VMA tracking doesn't exist."""
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis_names)
    return x
