"""Layer 3 of the asynchrony subsystem: *detection protocols*
(``DETECTION_PROTOCOLS``).

A protocol is an ``init / tick / finalize`` object layered over a
:class:`repro.collectives.plans.CollectivePlan` (sim executor in the engine,
device executor in the training-loop :class:`ConvergenceMonitor` — both are
built from this registry, so sim and device training share protocol code):

- ``init(p, m, cfg)``: the protocol's carried state pytree; always contains
  ``res_norm`` (the certified value, latched at :data:`RES_INIT`) and
  ``detected``.
- ``tick(state, obs)``: advance one engine tick; returns ``(state,
  coll_msgs)`` where ``coll_msgs`` is this tick's collective message count
  (paper S2 accounting).  ``obs`` is an :class:`Obs` snapshot of the
  engine's tick.
- ``finalize(state, x)``: the solution the protocol certifies (``x̄`` for
  the snapshot-exact protocol, the live iterate otherwise) — vmappable, so
  :func:`repro.asynchrony.engine.sweep` can finalize whole batches.

Registered protocols: ``inexact`` (paper Alg. 1), ``exact`` (paper Alg. 2,
Chandy–Lamport snapshot), ``oracle`` (physically unrealizable ground truth),
``sync`` (classic synchronous iteration + blocking allreduce; the engine
reads ``synchronous=True`` and pins full activity / zero delays), and
``interval`` (Alg. 1 hardened: each worker contributes the *max over a
sliding window* of its update magnitudes, so a single momentarily-small
update cannot certify — the window default covers the staleness bound).

Protocols that support the training loop also define ``monitor_init`` /
``monitor_contribution`` — the per-step latching policy the
:class:`ConvergenceMonitor` composes with a device plan.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.collectives import plans
from repro.core import snapshot

# Public finite 'infinity' for residual latches (was detection._BIG).
RES_INIT = 1e30


@dataclasses.dataclass(frozen=True)
class Obs:
    """One engine tick as seen by a protocol (all leaves traced)."""

    x: Any  # [p, m] current blocks
    update_mag: Any  # [p] last local update magnitude per worker
    tick: Any  # scalar int32
    key: Any  # per-tick PRNG key (snapshot marker delays)
    fp: Any  # the FixedPoint being solved (static)
    eps: float
    max_delay: int
    msg_table: Any  # [S] messages sent at MRD stage s
    coll_cycle_msgs: Any  # messages of one full blocking cycle


def _sim_plan(p: int) -> plans.CollectivePlan:
    return plans.allreduce_plan(schedule="mrd", p=p, op="max")


def _take_ranks(arr, keep, fill, axis: int = 0):
    """Select worker rows along ``axis`` per the resize ``keep`` map.

    ``keep[i]`` is the old rank now at new rank ``i`` (None = a joined
    worker, which gets ``fill``).  Works for any rank-axis position —
    ``res_loc [p]``, ``win [W, p]``, stacked monitor rows ``[dp, ...]``.
    """
    parts = []
    for k in keep:
        if k is None:
            parts.append(jnp.full_like(jnp.take(arr, 0, axis=axis), fill))
        else:
            parts.append(jnp.take(arr, int(k), axis=axis))
    return jnp.stack(parts, axis=axis)


def _stage_msgs(msg_table, stage):
    return msg_table[jnp.minimum(stage, msg_table.shape[0] - 1)]


DETECTION_PROTOCOLS: Dict[str, Any] = {}


def register_protocol(name: str):
    def deco(cls):
        DETECTION_PROTOCOLS[name] = cls()
        return cls

    return deco


def get_protocol(name: str):
    try:
        return DETECTION_PROTOCOLS[name]
    except KeyError:
        raise ValueError(
            f"unknown detection protocol {name!r}; "
            f"registered: {sorted(DETECTION_PROTOCOLS)}"
        ) from None


class _ProtocolBase:
    """Default surfaces shared by the registered protocols."""

    synchronous = False

    def finalize(self, state, x):
        """Solution to report at termination (default: the live iterate)."""
        return x.reshape(x.shape[:-2] + (-1,)) if x.ndim > 2 else x.reshape(-1)

    # -- elastic resize (DESIGN.md S12) --------------------------------------

    def migrate(self, state, keep, new_p: int, m: int, cfg):
        """Re-lay-out protocol state after the worker set changes.

        ``keep[i]`` = old rank now at new rank ``i`` (None = joined).
        The in-flight non-blocking reduction is abandoned — its stage
        counter and partial combines are meaningless at the new extent,
        and the MRD plan at ``new_p`` has a different cycle length — while
        everything certified so far (``res_norm``, ``detected``) and
        per-worker latches survive.  Subclasses extend this for their
        extra per-worker state.
        """
        new = self.init(new_p, m, cfg)
        for k_ in ("res_norm", "detected"):
            if k_ in new and k_ in state:
                new[k_] = state[k_]
        return new

    # -- training-loop policy (optional) ------------------------------------

    def monitor_init(self, metric0):
        raise NotImplementedError(
            f"protocol {type(self).__name__} has no training-loop policy"
        )

    def monitor_contribution(self, mstate, metric, step_idx, cycle_length):
        raise NotImplementedError


@register_protocol("inexact")
@dataclasses.dataclass(frozen=True)
class InexactProtocol(_ProtocolBase):
    """Paper Algorithm 1: non-blocking Allreduce of local update magnitudes.

    Each cycle re-latches the worker's *current* ``res_loc``; contributions
    mix different local iterations (hence inexact), but the detector never
    blocks an iteration.
    """

    name: str = "inexact"

    def init(self, p: int, m: int, cfg):
        return {
            "nb": _sim_plan(p).init(jnp.full((p,), RES_INIT, jnp.float32)),
            "res_loc": jnp.full((p,), RES_INIT, jnp.float32),
            "res_norm": jnp.full((), RES_INIT, jnp.float32),
            "detected": jnp.zeros((), jnp.bool_),
        }

    def tick(self, st, obs: Obs):
        p = obs.update_mag.shape[0]
        msgs = _stage_msgs(obs.msg_table, st["nb"]["stage"])
        nb = _sim_plan(p).step(st["nb"], st["res_loc"])
        flag = nb["flag"]
        res_norm = jnp.where(flag, jnp.max(nb["result"]), st["res_norm"])
        res_loc = jnp.where(flag, obs.update_mag, st["res_loc"])
        detected = st["detected"] | (flag & (res_norm < obs.eps))
        return {
            "nb": nb, "res_loc": res_loc,
            "res_norm": res_norm, "detected": detected,
        }, msgs

    def migrate(self, state, keep, new_p, m, cfg):
        new = super().migrate(state, keep, new_p, m, cfg)
        # surviving workers re-latch their last contribution on the next
        # cycle start; joiners start at the RES_INIT sentinel
        new["res_loc"] = _take_ranks(state["res_loc"], keep, RES_INIT)
        return new

    def monitor_init(self, metric0):
        return {}

    def monitor_contribution(self, mstate, metric, step_idx, cycle_length):
        return mstate, metric


@register_protocol("exact")
@dataclasses.dataclass(frozen=True)
class ExactProtocol(_ProtocolBase):
    """Paper Algorithm 2: Chandy–Lamport snapshot -> residual on the frozen
    x̄ -> non-blocking Allreduce.  Certification is exact for the returned
    x̄; a failed certification starts a new snapshot."""

    name: str = "exact"

    def init(self, p: int, m: int, cfg):
        return {
            "snap": snapshot.init(p, m),
            "nb": _sim_plan(p).init(jnp.full((p,), RES_INIT, jnp.float32)),
            "res_loc": jnp.full((p,), RES_INIT, jnp.float32),
            "res_norm": jnp.full((), RES_INIT, jnp.float32),
            "mode": jnp.zeros((), jnp.int32),  # 0 = snapshot, 1 = reduce
            "xbar": jnp.zeros((p * m,), jnp.float32),
            "detected": jnp.zeros((), jnp.bool_),
        }

    def tick(self, st, obs: Obs):
        p, m = obs.x.shape

        def snapshot_phase(d):
            snap = d["snap"]
            fresh = ~snap["in_progress"]
            started = snapshot.start(snap, obs.tick, obs.key, obs.max_delay)
            snap = jax.tree.map(lambda a, b: jnp.where(fresh, a, b), started, snap)
            snap = snapshot.tick(snap, obs.x, obs.tick)
            fin = snapshot.done(snap, obs.tick)
            xbar = snapshot.assembled(snap)
            fx = obs.fp.full_map(xbar)
            res_blocks = jnp.max(jnp.abs(fx - xbar).reshape(p, m), axis=1)
            return {
                **d,
                "snap": {**snap, "in_progress": snap["in_progress"] & ~fin},
                "res_loc": jnp.where(fin, res_blocks, d["res_loc"]),
                "xbar": jnp.where(fin, xbar, d["xbar"]),
                "mode": jnp.where(fin, 1, d["mode"]),
            }

        def reduce_phase(d):
            nb = _sim_plan(p).step(d["nb"], d["res_loc"])
            flag = nb["flag"]
            res_norm = jnp.where(flag, jnp.max(nb["result"]), d["res_norm"])
            det_now = flag & (res_norm < obs.eps)
            return {
                **d,
                "nb": nb,
                "res_norm": res_norm,
                "detected": d["detected"] | det_now,
                "mode": jnp.where(flag & ~det_now, 0, d["mode"]),
            }

        in_reduce = st["mode"] == 1
        # snapshot markers + data replies (all-to-all) when a snapshot starts
        started = (~in_reduce) & ~st["snap"]["in_progress"]
        msgs = jnp.where(
            in_reduce, _stage_msgs(obs.msg_table, st["nb"]["stage"]), 0
        ) + jnp.where(started, 2 * p * (p - 1), 0)
        new = jax.lax.cond(in_reduce, reduce_phase, snapshot_phase, st)
        return new, msgs

    def finalize(self, state, x):
        return state["xbar"]

    def migrate(self, state, keep, new_p, m, cfg):
        new = super().migrate(state, keep, new_p, m, cfg)
        # an in-progress snapshot is a cut of the *old* worker set —
        # discard it (a fresh one starts next tick); the last certified
        # x̄ carries over when the global problem size is unchanged
        if state["xbar"].shape == new["xbar"].shape:
            new["xbar"] = state["xbar"]
        return new

    def monitor_init(self, metric0):
        return {"latched": metric0}

    def monitor_contribution(self, mstate, metric, step_idx, cycle_length):
        latch_now = (step_idx % cycle_length) == 0
        latched = jnp.where(latch_now, metric, mstate["latched"])
        return {"latched": latched}, latched


@register_protocol("interval")
@dataclasses.dataclass(frozen=True)
class IntervalProtocol(_ProtocolBase):
    """Windowed Algorithm 1: each worker's contribution is the max of its
    update magnitudes over the last ``window`` ticks, so certification means
    updates stayed below eps across a whole window (default
    ``max_delay + 2`` — covering the staleness bound), not at one instant.
    Same message cost as ``inexact``."""

    name: str = "interval"

    def _window(self, cfg) -> int:
        w = getattr(cfg, "window", 0)
        return int(w) if w else int(cfg.max_delay) + 2

    def init(self, p: int, m: int, cfg):
        W = self._window(cfg)
        return {
            "nb": _sim_plan(p).init(jnp.full((p,), RES_INIT, jnp.float32)),
            "win": jnp.full((W, p), RES_INIT, jnp.float32),
            "res_loc": jnp.full((p,), RES_INIT, jnp.float32),
            "res_norm": jnp.full((), RES_INIT, jnp.float32),
            "detected": jnp.zeros((), jnp.bool_),
        }

    def tick(self, st, obs: Obs):
        p = obs.update_mag.shape[0]
        W = st["win"].shape[0]
        win = st["win"].at[jnp.mod(obs.tick, W)].set(obs.update_mag)
        msgs = _stage_msgs(obs.msg_table, st["nb"]["stage"])
        nb = _sim_plan(p).step(st["nb"], st["res_loc"])
        flag = nb["flag"]
        res_norm = jnp.where(flag, jnp.max(nb["result"]), st["res_norm"])
        res_loc = jnp.where(flag, jnp.max(win, axis=0), st["res_loc"])
        detected = st["detected"] | (flag & (res_norm < obs.eps))
        return {
            "nb": nb, "win": win, "res_loc": res_loc,
            "res_norm": res_norm, "detected": detected,
        }, msgs

    def migrate(self, state, keep, new_p, m, cfg):
        new = super().migrate(state, keep, new_p, m, cfg)
        new["res_loc"] = _take_ranks(state["res_loc"], keep, RES_INIT)
        # per-worker window columns follow their workers; joiners start
        # saturated so they cannot certify before filling a whole window
        new["win"] = _take_ranks(state["win"], keep, RES_INIT, axis=1)
        return new

    def monitor_init(self, metric0, window: int = 8):
        return {"win": jnp.broadcast_to(metric0, (window,)).astype(jnp.float32)}

    def monitor_contribution(self, mstate, metric, step_idx, cycle_length):
        win = mstate["win"]
        win = win.at[jnp.mod(step_idx, win.shape[0])].set(metric)
        return {"win": win}, jnp.max(win)


@register_protocol("oracle")
@dataclasses.dataclass(frozen=True)
class OracleProtocol(_ProtocolBase):
    """Ground truth (physically unrealizable): the true residual of the
    *current* global iterate, free of charge.  The baseline every realizable
    protocol's detection delay is measured against."""

    name: str = "oracle"

    def init(self, p: int, m: int, cfg):
        return {
            "res_norm": jnp.full((), RES_INIT, jnp.float32),
            "detected": jnp.zeros((), jnp.bool_),
        }

    def tick(self, st, obs: Obs):
        res = obs.fp.residual_norm(obs.x.reshape(-1))
        return {"res_norm": res, "detected": res < obs.eps}, jnp.zeros((), jnp.int32)


@register_protocol("sync")
@dataclasses.dataclass(frozen=True)
class SyncProtocol(_ProtocolBase):
    """Classic synchronous iteration: full activity, zero delays (the engine
    honors ``synchronous``), blocking Allreduce of update magnitudes every
    iteration — the paper's Fig. 5 comparison arm."""

    name: str = "sync"
    synchronous = True

    def init(self, p: int, m: int, cfg):
        return {
            "res_norm": jnp.full((), RES_INIT, jnp.float32),
            "detected": jnp.zeros((), jnp.bool_),
        }

    def tick(self, st, obs: Obs):
        res = jnp.max(obs.update_mag)
        return {"res_norm": res, "detected": res < obs.eps}, obs.coll_cycle_msgs


# ---------------------------------------------------------------------------
# Training-loop monitor (device executor) — built from the same registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ConvergenceMonitor:
    """Paper's detection embedded in a training step, over the DP mesh axes.

    ``mode`` names any :data:`DETECTION_PROTOCOLS` entry with a
    training-loop policy (``inexact``, ``exact``, ``interval``); the policy
    decides what each rank contributes per step, and the reduction itself is
    the same staged MRD plan the sim engine drives — one scalar ppermute per
    step, never blocking.

    ``mode='inexact'``: each cycle latches the worker's *current* metric
    (e.g. local grad-norm or loss delta); the certified global value lags by
    ``cycle_length`` steps and may mix step indices across workers — exactly
    the paper's Algorithm 1 trade-off.

    ``mode='exact'``: contributions are latched only from steps where
    ``step_idx % cycle_length == 0``; all workers therefore reduce metrics
    from the *same* global step (a consistent cut — the BSP analogue of the
    snapshot), so the certified value is exact for that step.

    ``mode='interval'``: each rank contributes the max of its last
    ``window`` metrics, certifying a whole window of small values.

    ``axis_name`` may be a single mesh axis or a tuple (e.g. a multi-pod
    ``("pod", "data")`` DP domain): the underlying plan chains the per-axis
    MRD schedules into one stage list, so detection over a product of axes
    costs one scalar ppermute per step exactly like the single-axis case.

    Use inside shard_map/jit: ``state, done, value = monitor.step(state,
    metric, step_idx)``.
    """

    axis_name: Any  # str or tuple of axis names (e.g. ("pod","data"))
    threshold: float
    mode: str = "inexact"  # any DETECTION_PROTOCOLS entry with a monitor policy
    op: str = "max"
    window: int = 8  # 'interval' mode: metrics per certified window

    def _axes(self) -> tuple[str, ...]:
        if isinstance(self.axis_name, str):
            return (self.axis_name,)
        return tuple(self.axis_name)

    def _plan(self) -> plans.CollectivePlan:
        return plans.allreduce_plan(schedule="mrd", axes=self._axes(), op=self.op)

    def _protocol(self):
        proto = get_protocol(self.mode)
        if type(proto).monitor_init is _ProtocolBase.monitor_init:
            raise ValueError(
                f"protocol {self.mode!r} has no training-loop policy; "
                "use one of "
                + str(sorted(
                    n for n, pr in DETECTION_PROTOCOLS.items()
                    if type(pr).monitor_init is not _ProtocolBase.monitor_init
                ))
            )
        return proto

    def _monitor_init(self, proto, metric0):
        if self.mode == "interval":
            return proto.monitor_init(metric0, window=self.window)
        return proto.monitor_init(metric0)

    def init(self, varying: bool = True) -> dict[str, Any]:
        """``varying=True`` when called *inside* a shard_map region with VMA
        checking on (marks state as varying over the manual axes so it can be
        carried through scan/while).  Use ``varying=False`` when building the
        global state outside shard_map (e.g. replicated-then-sharded train
        state)."""
        proto = self._protocol()
        metric0 = jnp.full((), RES_INIT, jnp.float32)
        state = {
            "nb": plans.allreduce_plan(schedule="mrd", p=1).init(metric0),
            "m": self._monitor_init(proto, metric0),
            "value": metric0,
            "done": jnp.zeros((), jnp.bool_),
        }
        if not varying:
            return state
        return jax.tree.map(lambda x: compat.pvary(x, self._axes()), state)

    def migrate_rows(self, rows, keep):
        """Elastic resize of replicated-then-sharded monitor state.

        ``rows`` is the ``[dp, ...]``-leaved pytree built by
        ``monitor_rows_init``; ``keep[i]`` is the old DP rank now at new
        rank ``i`` (None = joined worker, which gets a fresh row).  The
        per-rank policy state (``m`` — the exact-mode latch, the interval
        window), the certified ``value`` and the ``done`` latch follow
        their workers; the staged non-blocking reduction restarts from
        stage 0 because the MRD cycle length at the new extent differs
        and a mid-cycle partial combine would mix extents.
        """
        fresh = self.init(varying=False)

        def sel(rows_leaf, fresh_leaf):
            parts = [
                rows_leaf[k] if k is not None else fresh_leaf for k in keep
            ]
            return jnp.stack([jnp.asarray(x) for x in parts])

        migrated = jax.tree.map(sel, rows, fresh)
        migrated["nb"] = jax.tree.map(
            lambda f: jnp.broadcast_to(f, (len(keep),) + f.shape),
            fresh["nb"],
        )
        return migrated

    def step(self, state, local_metric, step_idx):
        local_metric = local_metric.astype(jnp.float32)
        proto = self._protocol()
        plan = self._plan()
        mstate, contribution = proto.monitor_contribution(
            state["m"], local_metric, step_idx, plan.cycle_length()
        )
        nb = plan.step(state["nb"], contribution)
        value = jnp.where(nb["flag"], nb["result"], state["value"])
        done = state["done"] | (nb["flag"] & (value < self.threshold))
        return (
            {"nb": nb, "m": mstate, "value": value, "done": done},
            done,
            value,
        )
