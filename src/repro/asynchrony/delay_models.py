"""Layer 2 of the asynchrony subsystem: *delay models* (``DELAY_MODELS``).

A delay model decides, per global tick, which workers iterate and how stale
each worker's view of every other block is — the ``(active, delays)`` pair
the bounded-delay simulator (paper S1) consumes.  Every model is a frozen
dataclass registered by name:

- ``default_params(cfg, p)``: the model's parameter pytree (plain jnp
  scalars/arrays, so :func:`repro.asynchrony.engine.sweep` can ``vmap``
  whole experiments over a stacked grid of them);
- ``init_state(p)``: the carried state pytree (empty for memoryless models);
- ``sample(params, state, tick, key, last_active, *, p, max_delay,
  force_every)``: one tick's ``(active [p] bool, delays [p, p] int32,
  state)``.

Every model ends with :func:`apply_fairness`, which enforces the paper's two
fairness conditions *by construction*: a worker inactive for ``force_every``
ticks is forced active (first condition: every worker iterates infinitely
often), and delays are clipped to ``[0, max_delay]`` (second condition:
bounded retards, tau -> infinity).  Fairness takes precedence over a model's
own story — e.g. a ``bursty`` outage cannot starve a worker past the bound.

Registered models: ``bernoulli`` (iid activity + uniform delays — the
original engine behavior), ``straggler`` (a fixed slow subset with
heavy-tailed delays), ``heterogeneous`` (per-worker activity/delay rates),
``bursty`` (correlated outage windows), ``trace`` (replay a recorded delay
matrix; :func:`record_trace` records one from any other model).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp


def apply_fairness(active, delays, tick, last_active, *, max_delay: int, force_every: int):
    """Clamp any model's raw sample to the paper's fairness conditions."""
    active = active | (tick - last_active >= force_every)
    delays = jnp.clip(delays, 0, max_delay).astype(jnp.int32)
    return active, delays


DELAY_MODELS: Dict[str, Any] = {}


def register_delay_model(name: str):
    def deco(cls):
        DELAY_MODELS[name] = cls()
        return cls

    return deco


def get_delay_model(name: str):
    try:
        return DELAY_MODELS[name]
    except KeyError:
        raise ValueError(
            f"unknown delay model {name!r}; registered: {sorted(DELAY_MODELS)}"
        ) from None


def _uniform_delays(key, p: int, max_delay: int):
    return jax.random.randint(key, (p, p), 0, max_delay + 1)


@register_delay_model("bernoulli")
@dataclasses.dataclass(frozen=True)
class BernoulliModel:
    """iid Bernoulli activity + iid uniform delays (the original engine)."""

    name: str = "bernoulli"

    def default_params(self, cfg, p: int):
        return {"activity": jnp.float32(cfg.activity)}

    def init_state(self, p: int):
        return {}

    def sample(self, params, state, tick, key, last_active, *, p, max_delay, force_every):
        k_act, k_delay = jax.random.split(key)
        active = jax.random.bernoulli(k_act, params["activity"], (p,))
        delays = _uniform_delays(k_delay, p, max_delay)
        active, delays = apply_fairness(
            active, delays, tick, last_active,
            max_delay=max_delay, force_every=force_every,
        )
        return active, delays, state


@register_delay_model("straggler")
@dataclasses.dataclass(frozen=True)
class StragglerModel:
    """A fixed slow subset (workers ``0..n_slow-1``) iterates rarely, and
    *its* blocks reach everyone else with heavy-tailed (truncated-geometric)
    delays — the classic one-bad-host cluster."""

    name: str = "straggler"

    def default_params(self, cfg, p: int):
        return {
            "n_slow": jnp.int32(max(1, p // 4)),
            "slow_activity": jnp.float32(0.15),
            "fast_activity": jnp.float32(cfg.activity),
            # mean of the heavy tail (in ticks), before truncation
            "tail_scale": jnp.float32(max(1.0, 0.75 * cfg.max_delay)),
        }

    def init_state(self, p: int):
        return {}

    def sample(self, params, state, tick, key, last_active, *, p, max_delay, force_every):
        k_act, k_fast, k_tail = jax.random.split(key, 3)
        slow = jnp.arange(p) < params["n_slow"]
        prob = jnp.where(slow, params["slow_activity"], params["fast_activity"])
        active = jax.random.bernoulli(k_act, prob, (p,))
        base = _uniform_delays(k_fast, p, max_delay)
        u = jax.random.uniform(k_tail, (p, p), minval=1e-6, maxval=1.0)
        heavy = jnp.floor(-params["tail_scale"] * jnp.log(u)).astype(jnp.int32)
        # column j = staleness of worker j's block as seen by everyone
        delays = jnp.where(slow[None, :], heavy, base)
        active, delays = apply_fairness(
            active, delays, tick, last_active,
            max_delay=max_delay, force_every=force_every,
        )
        return active, delays, state


@register_delay_model("heterogeneous")
@dataclasses.dataclass(frozen=True)
class HeterogeneousModel:
    """Per-worker activity rates and per-source delay ceilings — a cluster of
    unequal hosts (params are length-``p`` arrays, sweepable)."""

    name: str = "heterogeneous"

    def default_params(self, cfg, p: int):
        return {
            "activity": jnp.linspace(0.4, 1.0, p, dtype=jnp.float32)
            * jnp.float32(cfg.activity),
            "dmax": jnp.linspace(0.0, cfg.max_delay, p, dtype=jnp.float32),
        }

    def init_state(self, p: int):
        return {}

    def sample(self, params, state, tick, key, last_active, *, p, max_delay, force_every):
        k_act, k_delay = jax.random.split(key)
        active = jax.random.bernoulli(k_act, params["activity"], (p,))
        u = jax.random.uniform(k_delay, (p, p))
        # delays[i, j] ~ U{0 .. dmax_j}: source j's network quality
        delays = jnp.floor(u * (params["dmax"][None, :] + 1.0)).astype(jnp.int32)
        active, delays = apply_fairness(
            active, delays, tick, last_active,
            max_delay=max_delay, force_every=force_every,
        )
        return active, delays, state


@register_delay_model("bursty")
@dataclasses.dataclass(frozen=True)
class BurstyModel:
    """Correlated outage windows: with rate ``outage_rate`` per tick an
    outage starts, knocking a random ``affected`` fraction of workers out for
    ``outage_len`` ticks (inactive, blocks maximally stale).  Carries
    ``outage_until`` across ticks — the only stateful built-in model."""

    name: str = "bursty"

    def default_params(self, cfg, p: int):
        return {
            "activity": jnp.float32(cfg.activity),
            "outage_rate": jnp.float32(0.05),
            "outage_len": jnp.float32(3 * cfg.force_every),
            "affected": jnp.float32(0.5),
        }

    def init_state(self, p: int):
        return {"outage_until": jnp.zeros((p,), jnp.int32)}

    def sample(self, params, state, tick, key, last_active, *, p, max_delay, force_every):
        k_act, k_delay, k_start, k_who = jax.random.split(key, 4)
        start = jax.random.bernoulli(k_start, params["outage_rate"])
        who = jax.random.bernoulli(k_who, params["affected"], (p,))
        until = jnp.where(
            start & who,
            tick + params["outage_len"].astype(jnp.int32),
            state["outage_until"],
        )
        out = tick < until
        active = jax.random.bernoulli(k_act, params["activity"], (p,)) & ~out
        delays = _uniform_delays(k_delay, p, max_delay)
        delays = jnp.where(out[None, :], max_delay, delays)
        # fairness wins over the outage: a starved worker is forced active
        active, delays = apply_fairness(
            active, delays, tick, last_active,
            max_delay=max_delay, force_every=force_every,
        )
        return active, delays, {"outage_until": until}


_DEFAULT_TRACE_CACHE: dict = {}


@register_delay_model("trace")
@dataclasses.dataclass(frozen=True)
class TraceModel:
    """Replay a recorded delay matrix: params are ``{"active": [T, p] bool,
    "delays": [T, p, p] int32}``, indexed by ``(tick - 1) % T``.  Use
    :func:`record_trace` to capture a trace from any other model (or load a
    measured one), making runs exactly reproducible across engines."""

    name: str = "trace"

    def default_params(self, cfg, p: int):
        # recording is an eager 256-tick Python loop — memoize it so
        # repeated run()/sweep() calls under the same cfg don't re-record
        key = (p, cfg.max_delay, cfg.force_every, cfg.activity, cfg.seed)
        if key not in _DEFAULT_TRACE_CACHE:
            _DEFAULT_TRACE_CACHE[key] = record_trace(cfg, p, ticks=256)
        return _DEFAULT_TRACE_CACHE[key]

    def init_state(self, p: int):
        return {}

    def sample(self, params, state, tick, key, last_active, *, p, max_delay, force_every):
        idx = jnp.mod(tick - 1, params["active"].shape[0])
        active = params["active"][idx]
        delays = params["delays"][idx]
        active, delays = apply_fairness(
            active, delays, tick, last_active,
            max_delay=max_delay, force_every=force_every,
        )
        return active, delays, state


def record_trace(cfg, p: int, *, ticks: int = 256, source: str = "bernoulli",
                 source_params=None, seed=None):
    """Run ``source`` for ``ticks`` ticks and return its fairness-clamped
    ``(active, delays)`` history as ``trace`` params."""
    model = get_delay_model(source)
    params = source_params if source_params is not None else model.default_params(cfg, p)
    state = model.init_state(p)
    base = jax.random.PRNGKey(cfg.seed if seed is None else seed)
    last_active = jnp.zeros((p,), jnp.int32)
    actives, delays = [], []
    for t in range(1, ticks + 1):
        k_model, _ = jax.random.split(jax.random.fold_in(base, t))
        a, d, state = model.sample(
            params, state, jnp.int32(t), k_model, last_active,
            p=p, max_delay=cfg.max_delay, force_every=cfg.force_every,
        )
        last_active = jnp.where(a, t, last_active)
        actives.append(a)
        delays.append(d)
    return {"active": jnp.stack(actives), "delays": jnp.stack(delays)}
