"""Layer 4 of the asynchrony subsystem: the *engine* — a thin composition of
solver x delay model x detection protocol (paper S1 + S3).

``p`` virtual workers each own one block of the iterate.  Per global tick:

1. the configured delay model emits ``(active, delays)`` under the paper's
   two fairness conditions (``repro.asynchrony.delay_models``);
2. each active worker applies its block map to a *stale view* of the global
   vector assembled from a ring-buffer history with per-(i,j) delays bounded
   by ``max_delay``;
3. the configured detection protocol advances one tick
   (``repro.asynchrony.protocols``) — the non-blocking MRD Allreduce
   advances exactly one stage per tick, so communication progresses while
   workers compute (the point of the paper's statechart).

Everything is a single ``lax.while_loop`` whose carry is a flat pytree of
arrays, which is what makes :func:`sweep` possible: whole experiments
``jax.vmap`` over seeds x delay-model parameter grids into **one** jitted
dispatch (the paper's Fig. 5-style comparisons stop being a Python loop of
runs).  ``sweep`` is bit-identical to per-seed :func:`run` calls — vmapped
``while_loop`` lanes freeze once their own predicate clears.

Message accounting follows the paper: point-to-point ``Send(x_i)`` to all
dependent neighbors (all-to-all assumption) plus per-stage collective
messages from the schedule, attributed by the protocol.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.asynchrony.delay_models import get_delay_model
from repro.asynchrony.protocols import RES_INIT, Obs, get_protocol
from repro.asynchrony.solvers import FixedPoint
from repro.core import topology


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    p: int
    max_delay: int = 3
    activity: float = 0.7
    force_every: int = 5
    # any name in repro.asynchrony.DETECTION_PROTOCOLS
    detection: str = "exact"
    # any name in repro.asynchrony.DELAY_MODELS
    delay_model: str = "bernoulli"
    eps: float = 1e-6
    max_ticks: int = 20000
    seed: int = 0
    window: int = 0  # 'interval' protocol: 0 -> max_delay + 2


@dataclasses.dataclass
class AsyncResult:
    detected: bool
    ticks: int  # tick at which the loop stopped (detection or budget)
    res_glb: float  # detector's certified value at detection
    true_res: float  # ground-truth ||f(.)-.||_inf of the returned solution
    kiter: np.ndarray  # per-worker local iteration counts
    messages_p2p: int
    messages_coll: int
    x: np.ndarray  # returned solution (x̄ for 'exact', current x otherwise)

    @property
    def det_tick(self) -> int:
        """Deprecated alias of ``ticks`` (they were always equal)."""
        return self.ticks


@dataclasses.dataclass
class SweepResult:
    """Stacked :class:`AsyncResult` fields: leading axes are ``[S]`` (seeds)
    or ``[G, S]`` (delay-param grid x seeds)."""

    detected: np.ndarray
    ticks: np.ndarray
    res_glb: np.ndarray
    true_res: np.ndarray
    kiter: np.ndarray
    messages_p2p: np.ndarray
    messages_coll: np.ndarray
    x: np.ndarray


def _stage_message_table(p: int) -> jnp.ndarray:
    """messages sent at stage s of the MRD allreduce cycle."""
    sched = topology.allreduce_schedule(p)
    if not sched:
        return jnp.zeros((1,), jnp.int32)
    return jnp.asarray([len(st.pairs) for st in sched], jnp.int32)


def _build_core(fp: FixedPoint, cfg: AsyncConfig):
    """``core(seed, delay_params) -> final carry`` — the one traced function
    both :func:`run` and :func:`sweep` execute (sweep vmaps it)."""
    p = cfg.p
    if fp.n % p:
        raise ValueError(f"n={fp.n} must be divisible by p={p}")
    m = fp.n // p
    H = cfg.max_delay + 2  # ring-buffer depth (delays in [0, max_delay])
    model = get_delay_model(cfg.delay_model)
    proto = get_protocol(cfg.detection)
    msg_table = _stage_message_table(p)
    coll_cycle_msgs = jnp.int32(topology.paper_message_count(p))

    def cond(c):
        return (~c["det"]["detected"]) & (c["tick"] < cfg.max_ticks)

    def body(c):
        tick = c["tick"]
        key = jax.random.fold_in(c["base_key"], tick)
        k_model, k_proto = jax.random.split(key)

        if proto.synchronous:
            active = jnp.ones((p,), jnp.bool_)
            delays = jnp.zeros((p, p), jnp.int32)
            dm = c["dm"]
        else:
            active, delays, dm = model.sample(
                c["params"], c["dm"], tick, k_model, c["last_active"],
                p=p, max_delay=cfg.max_delay, force_every=cfg.force_every,
            )

        # Assemble stale views: worker i sees block j from `delays[i,j]` ticks
        # ago (its own block is always current).
        idx = jnp.mod(tick - 1 - delays, H)  # [p, p]
        views = c["hist"][idx, jnp.arange(p)[None, :]]  # [p, p, m]
        views = views.at[jnp.arange(p), jnp.arange(p)].set(c["x"])
        xnew = fp.block_views_update(views.reshape(p, p * m))  # [p, m]

        x = jnp.where(active[:, None], xnew, c["x"])
        upd = jnp.max(jnp.abs(x - c["x"]), axis=1)
        update_mag = jnp.where(active, upd, c["update_mag"])
        hist = c["hist"].at[jnp.mod(tick, H)].set(x)

        obs = Obs(
            x=x, update_mag=update_mag, tick=tick, key=k_proto, fp=fp,
            eps=cfg.eps, max_delay=cfg.max_delay,
            msg_table=msg_table, coll_cycle_msgs=coll_cycle_msgs,
        )
        det, coll_msgs = proto.tick(c["det"], obs)

        n_active = jnp.sum(active.astype(jnp.int32))
        return {
            **c,
            "tick": tick + 1,
            "x": x,
            "hist": hist,
            "update_mag": update_mag,
            "kiter": c["kiter"] + active.astype(jnp.int32),
            "last_active": jnp.where(active, tick, c["last_active"]),
            "dm": dm,
            "det": det,
            "messages_p2p": c["messages_p2p"] + n_active * (p - 1),
            "messages_coll": c["messages_coll"] + coll_msgs,
        }

    def core(seed, delay_params):
        x0 = jnp.zeros((p, m), jnp.float32)
        carry = {
            "tick": jnp.ones((), jnp.int32),
            "base_key": jax.random.PRNGKey(seed),
            "params": delay_params,
            "x": x0,
            "hist": jnp.broadcast_to(x0, (H, p, m)).astype(jnp.float32),
            "update_mag": jnp.full((p,), RES_INIT, jnp.float32),
            "kiter": jnp.zeros((p,), jnp.int32),
            "last_active": jnp.zeros((p,), jnp.int32),
            "dm": model.init_state(p),
            "det": proto.init(p, m, cfg),
            "messages_p2p": jnp.zeros((), jnp.int32),
            "messages_coll": jnp.zeros((), jnp.int32),
        }
        return jax.lax.while_loop(cond, body, carry)

    return core, proto, model


def resolve_delay_params(fp: FixedPoint, cfg: AsyncConfig, delay_params=None):
    """The delay-model parameter pytree a run will use (model defaults
    unless overridden)."""
    model = get_delay_model(cfg.delay_model)
    if delay_params is None:
        return model.default_params(cfg, cfg.p)
    return delay_params


def run(fp: FixedPoint, cfg: AsyncConfig, *, delay_params=None) -> AsyncResult:
    """One asynchronous solve under ``cfg`` (blocking; jitted while_loop)."""
    core, proto, _ = _build_core(fp, cfg)
    params = resolve_delay_params(fp, cfg, delay_params)
    # Per-tick protocol events live inside the while_loop (traced) — the
    # host-visible telemetry is the run span + the certify instant below.
    with obs.span(
        "async.run", protocol=cfg.detection, delay_model=cfg.delay_model, p=cfg.p
    ):
        final = jax.jit(core)(jnp.int32(cfg.seed), params)
        if obs.enabled():
            jax.block_until_ready(final["tick"])

    x_out = np.asarray(proto.finalize(final["det"], final["x"]))
    true_res = float(fp.residual_norm(jnp.asarray(x_out)))
    result = AsyncResult(
        detected=bool(final["det"]["detected"]),
        ticks=int(final["tick"]) - 1,
        res_glb=float(final["det"]["res_norm"]),
        true_res=true_res,
        kiter=np.asarray(final["kiter"]),
        messages_p2p=int(final["messages_p2p"]),
        messages_coll=int(final["messages_coll"]),
        x=x_out,
    )
    if obs.enabled():
        obs.instant(
            "protocol.certify",
            protocol=cfg.detection,
            detected=result.detected,
            tick=result.ticks,
            res_glb=result.res_glb,
            true_res=result.true_res,
        )
        obs.counter("async.messages_p2p", protocol=cfg.detection).add(
            result.messages_p2p
        )
        obs.counter("async.messages_coll", protocol=cfg.detection).add(
            result.messages_coll
        )
        obs.gauge("async.detect.ticks", protocol=cfg.detection).set(result.ticks)
    return result


def sweep(
    fp: FixedPoint,
    cfg: AsyncConfig,
    seeds,
    *,
    delay_params=None,
) -> SweepResult:
    """Batch of solves in **one** jitted dispatch.

    ``seeds``: ``[S]`` ints — vmapped over.  ``delay_params``: optional
    pytree whose leaves carry a leading grid axis ``[G, ...]`` (stack the
    per-point parameter pytrees of ``cfg.delay_model``); when given, the
    result axes are ``[G, S]``.  Per lane the math is exactly :func:`run`
    (vmapped ``while_loop`` lanes freeze once their own predicate clears),
    so results are bit-identical to per-seed ``run`` calls — tested for the
    ``bernoulli`` model.
    """
    seeds = jnp.asarray(seeds, jnp.int32)
    core, proto, _ = _build_core(fp, cfg)

    with obs.span(
        "async.sweep",
        protocol=cfg.detection,
        delay_model=cfg.delay_model,
        p=cfg.p,
        n_seeds=int(seeds.shape[0]),
        gridded=delay_params is not None,
    ):
        if delay_params is None:
            params = resolve_delay_params(fp, cfg)
            batched = jax.vmap(core, in_axes=(0, None))
            final = jax.jit(batched)(seeds, params)
            nbatch = 1
        else:
            over_seeds = jax.vmap(core, in_axes=(0, None))
            over_grid = jax.vmap(
                lambda prm, s: over_seeds(s, prm), in_axes=(0, None)
            )
            final = jax.jit(over_grid)(delay_params, seeds)
            nbatch = 2
        if obs.enabled():
            jax.block_until_ready(final["tick"])

    fin = proto.finalize
    res = jax.vmap(fp.residual_norm)
    for _ in range(nbatch - 1):
        fin = jax.vmap(fin)
        res = jax.vmap(res)
    xs = jax.vmap(fin)(final["det"], final["x"])
    true_res = res(xs)

    return SweepResult(
        detected=np.asarray(final["det"]["detected"]),
        ticks=np.asarray(final["tick"]) - 1,
        res_glb=np.asarray(final["det"]["res_norm"]),
        true_res=np.asarray(true_res),
        kiter=np.asarray(final["kiter"]),
        messages_p2p=np.asarray(final["messages_p2p"]),
        messages_coll=np.asarray(final["messages_coll"]),
        x=np.asarray(xs),
    )


def record_detection_delay(protocol: str, ticks, oracle_ticks) -> None:
    """Detection-delay-vs-oracle telemetry, for callers that ran both a
    detecting protocol and the ``oracle`` reference on the same scenario
    (bench_async does; per-run this is unobservable without the oracle)."""
    if obs.enabled():
        delay = float(np.mean(np.asarray(ticks) - np.asarray(oracle_ticks)))
        obs.gauge("async.detect.delay_vs_oracle", protocol=protocol).set(delay)
