"""Layer 1 of the asynchrony subsystem: fixed-point *solvers* (``SOLVERS``).

The paper's setting: ``Ax = b``, splitting ``A = M - N``, iteration
``x <- Tx + c`` with ``T = M^{-1}N``.  The engine (``repro.asynchrony.engine``)
only needs the fixed-point map ``f`` and a block partitioning; every solver
here is a registered factory ``SOLVERS[name](**kwargs) -> FixedPoint`` so
examples, benchmarks, and sweeps select workloads by name exactly like
schedules/executors/transforms in ``repro.collectives``:

- ``poisson1d`` — the paper's S4 experiment (1-D two-point BVP, finite
  differences, weighted Jacobi).
- ``poisson2d`` — 5-point Laplacian on an ``nx x ny`` grid (the natural
  next-dimension workload; same Jacobi splitting).
- ``jacobi_dense`` / ``richardson`` — dense variants for tests (default to
  a random strictly diagonally dominant system).
- ``d_iteration`` — sparse diffusion fixed point (Hong & Mathieu,
  arXiv:1301.3007 / arXiv:1202.3108): ``f(x) = d·P x + (1-d)·v`` with a
  column-stochastic ``P``; contraction factor is the damping ``d`` itself,
  so it is asynchronously convergent for any ``d < 1``.  The PageRank-style
  example config lives in ``repro.configs.pagerank_diffusion``.

Asynchronous convergence requires rho(|T|) < 1 (contraction in a weighted max
norm [4,2]); ``spectral_radius_abs_T`` estimates it for test matrices, and
``FixedPoint.contraction`` carries the model-derived factor the protocol
soundness tests bound certified residuals with.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FixedPoint:
    """A fixed-point problem f(x) = x partitioned into p equal blocks.

    ``contraction``: an upper bound on rho(|T|) when the constructor knows
    one (None otherwise) — the model-derived quantity protocol soundness
    bounds are stated against.
    """

    n: int
    full_map: Callable  # [n] -> [n], the map f
    name: str = "fixed-point"
    contraction: Optional[float] = None

    def residual_norm(self, x):
        """||f(x) - x||_inf — the paper's termination functional."""
        return jnp.max(jnp.abs(self.full_map(x) - x))

    def block_views_update(self, views):
        """views: [p, n] (worker i's possibly-stale global view).
        Returns [p, m]: worker i's new block = f(view_i) restricted to block i."""
        p = views.shape[0]
        m = self.n // p
        full = jax.vmap(self.full_map)(views)  # [p, n]
        return full.reshape(p, p, m)[jnp.arange(p), jnp.arange(p)]


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

SOLVERS: Dict[str, Callable[..., FixedPoint]] = {}


def register_solver(name: str):
    """Decorator: register a ``(**kwargs) -> FixedPoint`` factory."""

    def deco(fn: Callable[..., FixedPoint]) -> Callable[..., FixedPoint]:
        SOLVERS[name] = fn
        return fn

    return deco


def get_solver(name: str) -> Callable[..., FixedPoint]:
    try:
        return SOLVERS[name]
    except KeyError:
        raise ValueError(
            f"unknown solver {name!r}; registered: {sorted(SOLVERS)}"
        ) from None


def make_solver(name: str, **kwargs) -> FixedPoint:
    return get_solver(name)(**kwargs)


# ---------------------------------------------------------------------------
# The paper's S4 problem + dense test variants
# ---------------------------------------------------------------------------


@register_solver("poisson1d")
def poisson_1d(
    n: int,
    *,
    omega: float = 1.0,
    shift: float = 0.0,
    rhs: jnp.ndarray | None = None,
    seed: int = 0,
    rhs_scale: float = 10.0,
) -> FixedPoint:
    """The paper's S4 problem: 1-D two-point BVP, finite differences.

    A = tridiag(-1, 2+shift, -1) (n x n), b ~ U[-rhs_scale, rhs_scale] (paper:
    n = 10000, b in [-10, 10], shift = 0).  Weighted-Jacobi fixed point:
    ``f(x) = x + (omega/diag) * (b - Ax)``.  ``shift > 0`` makes A strictly
    diagonally dominant (rho(|T|) <= 2/(2+shift) < 1), giving fast asynchronous
    contraction for protocol benchmarks; shift = 0 is the paper's exact (slow,
    rho ~ 1 - O(1/n^2)) problem.
    """
    if rhs is None:
        rhs = jax.random.uniform(
            jax.random.PRNGKey(seed), (n,), minval=-rhs_scale, maxval=rhs_scale
        )
    diag = 2.0 + shift

    def apply_A(x):
        up = jnp.concatenate([x[1:], jnp.zeros((1,), x.dtype)])
        down = jnp.concatenate([jnp.zeros((1,), x.dtype), x[:-1]])
        return diag * x - up - down

    def f(x):
        return x + (omega / diag) * (rhs - apply_A(x))

    contraction = min(2.0 / (2.0 + shift), 1.0) if omega == 1.0 else None
    return FixedPoint(
        n=n,
        full_map=f,
        name=f"poisson1d(n={n},omega={omega},shift={shift})",
        contraction=contraction,
    )


@register_solver("poisson2d")
def poisson_2d(
    nx: int,
    ny: Optional[int] = None,
    *,
    omega: float = 1.0,
    shift: float = 0.0,
    seed: int = 0,
    rhs_scale: float = 10.0,
) -> FixedPoint:
    """2-D Poisson: 5-point Laplacian on an ``nx x ny`` grid, weighted Jacobi.

    A = diag(4+shift) - (N/S/E/W neighbors); the flat iterate is the
    row-major raveling of the grid, so a ``p``-block partition hands each
    worker a band of grid rows.  rho(|T|) <= 4/(4+shift).
    """
    ny = nx if ny is None else ny
    n = nx * ny
    rhs = jax.random.uniform(
        jax.random.PRNGKey(seed), (n,), minval=-rhs_scale, maxval=rhs_scale
    )
    diag = 4.0 + shift

    def f(x):
        g = x.reshape(nx, ny)
        z = jnp.zeros_like(g)
        nbrs = (
            jnp.concatenate([g[1:], z[:1]], axis=0)
            + jnp.concatenate([z[:1], g[:-1]], axis=0)
            + jnp.concatenate([g[:, 1:], z[:, :1]], axis=1)
            + jnp.concatenate([z[:, :1], g[:, :-1]], axis=1)
        )
        ax = diag * g - nbrs
        return (x.reshape(nx, ny) + (omega / diag) * (rhs.reshape(nx, ny) - ax)).reshape(-1)

    contraction = min(4.0 / (4.0 + shift), 1.0) if omega == 1.0 else None
    return FixedPoint(
        n=n,
        full_map=f,
        name=f"poisson2d({nx}x{ny},omega={omega},shift={shift})",
        contraction=contraction,
    )


@register_solver("jacobi_dense")
def jacobi_dense(
    A: jnp.ndarray | None = None,
    b: jnp.ndarray | None = None,
    *,
    omega: float = 1.0,
    n: int = 64,
    seed: int = 0,
    dominance: float = 2.0,
) -> FixedPoint:
    """Weighted Jacobi on a dense system (tests): f(x) = x + omega*D^-1(b-Ax).

    With no ``A``/``b`` given, a random strictly diagonally dominant system
    is generated (rho(|T|) <= 1/dominance)."""
    contraction = None
    if A is None:
        A, b = random_dd_system(n, seed=seed, dominance=dominance)
        A, b = jnp.asarray(A, jnp.float32), jnp.asarray(b, jnp.float32)
        if omega == 1.0:
            contraction = 1.0 / dominance
    n = A.shape[0]
    dinv = 1.0 / jnp.diag(A)

    def f(x):
        return x + omega * dinv * (b - A @ x)

    return FixedPoint(
        n=n, full_map=f, name=f"jacobi_dense(n={n})", contraction=contraction
    )


@register_solver("richardson")
def richardson_dense(
    A: jnp.ndarray | None = None,
    b: jnp.ndarray | None = None,
    *,
    alpha: float = 0.1,
    n: int = 64,
    seed: int = 0,
) -> FixedPoint:
    """Richardson iteration (a 'gradient method' in the paper's sense):
    f(x) = x + alpha*(b - Ax)."""
    if A is None:
        A, b = random_dd_system(n, seed=seed)
        # normalize so alpha*A is a contraction on the default system
        A = jnp.asarray(A / np.abs(A).sum(axis=1, keepdims=True), jnp.float32)
        b = jnp.asarray(b / np.abs(np.asarray(b)).max(), jnp.float32)
    n = A.shape[0]

    def f(x):
        return x + alpha * (b - A @ x)

    return FixedPoint(n=n, full_map=f, name=f"richardson(n={n})")


@register_solver("d_iteration")
def d_iteration(
    n: int = 64,
    *,
    damping: float = 0.85,
    out_degree: int = 4,
    seed: int = 0,
    v: jnp.ndarray | None = None,
) -> FixedPoint:
    """Sparse diffusion fixed point (the D-iteration family, arXiv:1301.3007).

    ``f(x) = damping * P x + (1 - damping) * v`` with ``P`` column-stochastic
    (each node diffuses its mass to ``out_degree`` random successors plus a
    ring edge so the graph is strongly connected).  ``|T| = damping * P`` has
    rho = damping < 1, so the iteration is asynchronously convergent and its
    fixed point is the damped diffusion (PageRank-style) vector.  The async
    engine's block partition assigns each worker a contiguous node range —
    the per-node/partial-diffusion scheduling of the D-iteration papers maps
    onto the engine's activity subsets.
    """
    rng = np.random.default_rng(seed)
    cols = np.zeros((n, n), np.float32)
    for j in range(n):
        succ = set(rng.choice(n, size=min(out_degree, n), replace=False).tolist())
        succ.add((j + 1) % n)  # ring edge: strong connectivity
        succ.discard(j)
        w = 1.0 / len(succ)
        for i in succ:
            cols[i, j] = w
    P = jnp.asarray(cols)
    if v is None:
        v = jnp.ones((n,), jnp.float32) / n
    v = jnp.asarray(v, jnp.float32)

    def f(x):
        return damping * (P @ x) + (1.0 - damping) * v

    return FixedPoint(
        n=n,
        full_map=f,
        name=f"d_iteration(n={n},d={damping})",
        contraction=damping,
    )


# ---------------------------------------------------------------------------
# Test-matrix helpers
# ---------------------------------------------------------------------------


def random_dd_system(n: int, *, seed: int = 0, dominance: float = 2.0):
    """Random strictly diagonally dominant system (async-convergent Jacobi:
    rho(|T|) <= 1/dominance < 1).  Returns (A, b) as numpy arrays."""
    rng = np.random.default_rng(seed)
    A = rng.uniform(-1.0, 1.0, size=(n, n))
    np.fill_diagonal(A, 0.0)
    rowsum = np.abs(A).sum(axis=1)
    np.fill_diagonal(A, dominance * rowsum + 1e-3)
    b = rng.uniform(-10.0, 10.0, size=(n,))
    return A, b


def spectral_radius_abs_T(A: np.ndarray, iters: int = 200) -> float:
    """Power-iteration estimate of rho(|T|) for Jacobi T = I - D^-1 A
    (asynchronous convergence criterion [4])."""
    D = np.diag(A)
    T = np.abs(np.eye(A.shape[0]) - A / D[:, None])
    v = np.ones(A.shape[0]) / np.sqrt(A.shape[0])
    lam = 0.0
    for _ in range(iters):
        w = T @ v
        lam = float(np.linalg.norm(w))
        if lam == 0.0:
            return 0.0
        v = w / lam
    return lam
