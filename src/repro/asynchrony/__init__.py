"""Registry-backed asynchronous-iterations runtime (DESIGN.md S11).

Mirrors the collectives architecture: four layers, each a registry, one
engine composing them —

| layer | module | registry |
|---|---|---|
| solvers | ``asynchrony/solvers.py`` | ``SOLVERS`` |
| delay models | ``asynchrony/delay_models.py`` | ``DELAY_MODELS`` |
| detection protocols | ``asynchrony/protocols.py`` | ``DETECTION_PROTOCOLS`` |
| engine | ``asynchrony/engine.py`` | composes the three + ``sweep`` |

``repro.core.{async_engine,solvers,detection}`` remain import-compatible
shims over this package.
"""

from repro.asynchrony.delay_models import (  # noqa: F401
    DELAY_MODELS,
    apply_fairness,
    get_delay_model,
    record_trace,
    register_delay_model,
)
from repro.asynchrony.engine import (  # noqa: F401
    AsyncConfig,
    AsyncResult,
    SweepResult,
    resolve_delay_params,
    run,
    sweep,
)
from repro.asynchrony.protocols import (  # noqa: F401
    DETECTION_PROTOCOLS,
    RES_INIT,
    ConvergenceMonitor,
    get_protocol,
    register_protocol,
)
from repro.asynchrony.solvers import (  # noqa: F401
    SOLVERS,
    FixedPoint,
    get_solver,
    make_solver,
    register_solver,
)
