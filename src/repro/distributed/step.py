"""Train/serve step builders: sharding, microbatching, remat, grad sync,
and the paper's convergence monitor — all wired per (arch x mesh x mode).

Grad-sync strategies (DESIGN.md S2):

- ``gspmd``: pure pjit.  Params FSDP+TP sharded; XLA inserts the DP
  all-reduce in backward.  This is the baseline every MRD mode is measured
  against.
- ``mrd_zero1``: the paper's butterfly as a ZeRO-1 distributed optimizer —
  inside ``shard_map`` (manual over the DP axes, auto over "model"):
  chained recursive-halving **reduce-scatter** of the flat fp32 gradient over
  each DP axis, shard-local AdamW on the fp32 master shard, then chained
  recursive-doubling **all-gather** of the bf16 params.  Works for
  non-power-of-two DP groups (the paper's headline case) — the elasticity
  path uses exactly this.
- ``compressed``: mrd_zero1 with int8-quantized reduce-scatter payloads +
  error feedback.
- Hierarchy is implicit: with mesh axes ("pod","data"), the chained RS/AG
  (data first, then pod) reduces inter-pod bytes by 1/p0(data) — the
  'hierarchical allreduce' of DESIGN.md.

The ConvergenceMonitor (paper Alg. 1/2 over the DP axis) advances one MRD
stage per train step; it costs one scalar ppermute per step and never blocks.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import mrd
from repro.core.detection import ConvergenceMonitor
from repro.core.topology import pivot
from repro.distributed import sharding as shd
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.layers import dtype_of
from repro.optim import optimizer as opt_lib

REMAT_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    remat: str = "full"  # 'none' | 'full' | 'dots'
    # 'gspmd' | 'mrd_paper' (paper-faithful RD-butterfly allreduce, flat)
    # | 'mrd_leaf' (butterfly on TP-sharded grad leaves: no flatten/reshard)
    # | 'mrd_zero1' (beyond-paper RS+AG ZeRO-1) | 'compressed' | 'local_sgd'
    grad_sync: str = "gspmd"
    local_sync_every: int = 8  # local_sgd: MRD param-average period (staleness bound)
    monitor: bool = True
    monitor_mode: str = "inexact"  # paper Alg.1 ('inexact') / Alg.2 ('exact')
    monitor_threshold: float = 1e-3
    optimizer: opt_lib.OptimizerConfig = dataclasses.field(
        default_factory=opt_lib.OptimizerConfig
    )
    fsdp: bool = True  # weight sharding over "data" (gspmd mode)


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, rules: shd.ShardingRules, batch: Any):
    """PartitionSpecs for a train batch pytree (batch dim over DP axes)."""

    def spec(leaf):
        b = rules.batch_axes(leaf.shape[0])
        return P(b, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(spec, batch)


def _microbatched_grads(params, batch, cfg, remat_policy, microbatches: int):
    """Gradient accumulation over microbatches via lax.scan (fp32 accum).
    Returns (grads_fp32, mean_loss, metrics_last)."""

    def loss_fn(p, mb):
        return transformer.forward_train(p, mb, cfg, remat_policy)

    if microbatches == 1:
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return jax.tree.map(lambda x: x.astype(jnp.float32), g), loss, metrics

    def reshape_mb(x):
        return x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:])

    mbs = jax.tree.map(
        lambda x: shd.constrain(reshape_mb(x), "mb_batch"), batch
    )
    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(carry, mb):
        g_acc, loss_acc = carry
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
        return (g_acc, loss_acc + loss), metrics

    (g, loss_sum), metrics = jax.lax.scan(body, (g0, 0.0), mbs, unroll=cfg.scan_unroll)
    g = jax.tree.map(lambda x: x / microbatches, g)
    metrics = jax.tree.map(lambda x: x[-1], metrics)
    return g, loss_sum / microbatches, metrics


def _monitor_tick(monitor: Optional[ConvergenceMonitor], mon_state, metric, step):
    if monitor is None:
        return mon_state, jnp.zeros((), jnp.bool_), jnp.zeros((), jnp.float32)
    return monitor.step(mon_state, metric, step)


# ---------------------------------------------------------------------------
# gspmd train step
# ---------------------------------------------------------------------------


def make_train_step_gspmd(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig):
    """Returns (jitted step, init_state_fn, state_shardings_fn)."""
    rules = shd.make_rules(cfg, mesh, fsdp=tcfg.fsdp)
    remat_policy = REMAT_POLICIES[tcfg.remat]
    pdt = dtype_of(cfg.param_dtype)
    monitor = (
        ConvergenceMonitor(
            axis_name=rules.dp_axes if len(rules.dp_axes) > 1 else rules.dp_axes[0],
            threshold=tcfg.monitor_threshold,
            mode=tcfg.monitor_mode,
        )
        if tcfg.monitor
        else None
    )
    dp = rules.dp

    def init_state(key):
        params = transformer.init_params(cfg, key)
        state = {
            "params": params,
            "opt": opt_lib.init_opt_state(params),
            "step": jnp.zeros((), jnp.int32),
        }
        if monitor is not None:
            mon = monitor.init(varying=False)
            state["monitor"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (dp,) + x.shape), mon
            )
        return state

    def state_specs(state):
        pspecs = shd.param_specs(cfg, rules, state["params"])
        specs = {
            "params": pspecs,
            "opt": {
                "master": pspecs,
                "mu": pspecs,
                "nu": pspecs,
            },
            "step": P(),
        }
        if monitor is not None:
            specs["monitor"] = jax.tree.map(
                lambda x: P(rules.dp_axes), state["monitor"]
            )
        return specs

    def train_step(state, batch):
        with shd.sharding_ctx(cfg, rules):
            grads, loss, metrics = _microbatched_grads(
                state["params"], batch, cfg, remat_policy, tcfg.microbatches
            )
        grads, gnorm = opt_lib.clip_by_global_norm(grads, tcfg.optimizer.grad_clip)
        params, opt = opt_lib.apply_update(
            grads, state["opt"], tcfg.optimizer, state["step"], pdt
        )
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        out_metrics = {"loss": loss, "grad_norm": gnorm}

        if monitor is not None:
            # per-DP-shard local loss feeds the paper's staged detection
            def mon_fn(mon_st, per_ex, step):
                local = jax.tree.map(lambda x: x[0], mon_st)
                m = per_ex.mean()
                new, done, val = monitor.step(local, m, step)
                return (
                    jax.tree.map(lambda x: x[None], new),
                    done[None],
                    val[None],
                )

            # per_example is [B/microbatches]; when that no longer divides
            # the DP extent (large mb on the multi-pod mesh), feed it
            # replicated — each worker then monitors the same global mean,
            # which stays sound (the staged reduction just becomes uniform).
            pe_spec = P(rules.batch_axes(metrics["per_example"].shape[0]))
            mon_new, done, val = jax.shard_map(
                mon_fn,
                mesh=mesh,
                in_specs=(
                    jax.tree.map(lambda _: P(rules.dp_axes), state["monitor"]),
                    pe_spec,
                    P(),
                ),
                out_specs=(
                    jax.tree.map(lambda _: P(rules.dp_axes), state["monitor"]),
                    P(rules.dp_axes),
                    P(rules.dp_axes),
                ),
                axis_names=set(rules.dp_axes),
                check_vma=False,
            )(state["monitor"], metrics["per_example"], state["step"])
            new_state["monitor"] = mon_new
            out_metrics["converged"] = done[0]
            out_metrics["monitor_value"] = val[0]
        return new_state, out_metrics

    return train_step, init_state, state_specs, rules


# ---------------------------------------------------------------------------
# MRD-ZeRO-1 train step (paper butterfly as the distributed optimizer)
# ---------------------------------------------------------------------------


def _chained_rs(vec, axes, *, compressed=False):
    for ax in axes:
        if compressed:
            vec = mrd.compressed_reduce_scatter(vec, ax)
        else:
            vec = mrd.reduce_scatter(vec, ax)
    return vec


def _chained_ag(vec, axes):
    for ax in reversed(axes):
        vec = mrd.allgather(vec, ax)
    return vec


def zero1_shard_len(n_params: int, mesh: Mesh, dp_axes, block: int = 256) -> tuple[int, int]:
    """(padded_total, shard_len) for the chained RS over dp_axes."""
    prod_p0 = 1
    for ax in dp_axes:
        p0, _, _ = pivot(mesh.shape[ax])
        prod_p0 *= p0
    quantum = prod_p0 * block
    padded = ((n_params + quantum - 1) // quantum) * quantum
    return padded, padded // prod_p0


def zero1_owner_segments(mesh: Mesh, dp_axes) -> list:
    """For each flattened DP rank (axis-major order), the natural-order global
    segment index it owns after the chained RS, or None (non-pivot rank of a
    non-power-of-two axis)."""
    sizes = [mesh.shape[ax] for ax in dp_axes]
    p0s = [pivot(sz)[0] for sz in sizes]
    owners = []
    for flat_rank in range(int(np.prod(sizes))):
        idxs = list(np.unravel_index(flat_rank, sizes))
        if any(i >= q for i, q in zip(idxs, p0s)):
            owners.append(None)
        else:
            seg = 0
            for i, q in zip(idxs, p0s):
                seg = seg * q + i
            owners.append(seg)
    return owners


def make_train_step_mrd(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig):
    """MRD-ZeRO-1 (grad_sync = 'mrd_zero1' | 'compressed').

    Params: TP-sharded (auto "model" axis), replicated across DP (manual).
    Opt state: flat fp32 shards owned per DP rank, global shape [dp, m].
    Global grad-norm clipping uses the paper's MRD allreduce on the scalar.
    """
    rules = shd.make_rules(cfg, mesh, fsdp=False)  # DP-replicated params
    remat_policy = REMAT_POLICIES[tcfg.remat]
    pdt = dtype_of(cfg.param_dtype)
    compressed = tcfg.grad_sync == "compressed"
    # paper-faithful mode: pure recursive-doubling allreduce of the full
    # gradient (paper S2) + replicated optimizer; no RS/AG, no opt sharding.
    paper_mode = tcfg.grad_sync == "mrd_paper"
    dp_axes = rules.dp_axes
    dp = rules.dp
    monitor = (
        ConvergenceMonitor(
            axis_name=dp_axes if len(dp_axes) > 1 else dp_axes[0],
            threshold=tcfg.monitor_threshold,
            mode=tcfg.monitor_mode,
        )
        if tcfg.monitor
        else None
    )

    pshape = jax.eval_shape(lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(pshape))
    padded, shard_len = zero1_shard_len(n_params, mesh, dp_axes)
    if paper_mode:
        shard_len = padded  # every rank owns (a replica of) the full vector
    owners = zero1_owner_segments(mesh, dp_axes)

    def _is_owner():
        """Inside the manual region: does this rank own a live segment?"""
        ok = jnp.ones((), jnp.bool_)
        for ax in dp_axes:
            p0, _, _ = pivot(mesh.shape[ax])
            ok &= jax.lax.axis_index(ax) < p0
        return ok

    def init_state(key):
        params = transformer.init_params(cfg, key)
        flat, _ = jax.flatten_util.ravel_pytree(
            jax.tree.map(lambda x: x.astype(jnp.float32), params)
        )
        flat = jnp.pad(flat, (0, padded - flat.shape[0]))
        if paper_mode:
            masters = jnp.broadcast_to(flat, (dp, shard_len))
        else:
            segs = flat.reshape(-1, shard_len)  # [prod_p0, m]
            rows = [
                segs[o] if o is not None else jnp.zeros((shard_len,), jnp.float32)
                for o in owners
            ]
            masters = jnp.stack(rows)  # [dp, m]
        state = {
            "params": params,
            "opt": {
                "master": masters,
                "mu": jnp.zeros((dp, shard_len), jnp.float32),
                "nu": jnp.zeros((dp, shard_len), jnp.float32),
            },
            "step": jnp.zeros((), jnp.int32),
        }
        if monitor is not None:
            mon = monitor.init(varying=False)
            state["monitor"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (dp,) + x.shape), mon
            )
        return state

    def state_specs(state):
        pspecs = shd.param_specs(cfg, rules, state["params"])
        dpP = P(dp_axes)
        specs = {
            "params": pspecs,
            "opt": {"master": dpP, "mu": dpP, "nu": dpP},
            "step": P(),
        }
        if monitor is not None:
            specs["monitor"] = jax.tree.map(lambda _: dpP, state["monitor"])
        return specs

    def train_step(state, batch):
        _, unravel = jax.flatten_util.ravel_pytree(
            jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), pshape)
        )

        def local_step(params, opt, step, mon_state, local_batch):
            with shd.sharding_ctx(cfg, rules.manual_region()):
                grads, loss, metrics = _microbatched_grads(
                    params, local_batch, cfg, remat_policy, tcfg.microbatches
                )
            flat, _ = jax.flatten_util.ravel_pytree(
                jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            )
            flat = jnp.pad(flat, (0, padded - flat.shape[0]))
            if paper_mode:
                # the paper's Allreduce: full-buffer XOR butterfly per DP axis
                gshard = flat
                for ax in dp_axes:
                    gshard = mrd.allreduce(gshard, ax, op="sum")
                gshard = gshard / dp
                gnorm = jnp.sqrt(jnp.sum(gshard * gshard))
            else:
                # beyond-paper: chained RS over DP axes -> mean segment
                gshard = _chained_rs(flat, dp_axes, compressed=compressed) / dp
                # global grad norm via the paper's MRD allreduce on a scalar
                own = _is_owner()
                sq = jnp.where(own, jnp.sum(gshard * gshard), 0.0)
                for ax in dp_axes:
                    sq = mrd.allreduce(sq, ax, op="sum")
                gnorm = jnp.sqrt(sq)
            if tcfg.optimizer.grad_clip > 0:
                scale = jnp.minimum(
                    1.0, tcfg.optimizer.grad_clip / jnp.maximum(gnorm, 1e-12)
                )
                gshard = gshard * scale
            master, new_opt = opt_lib.apply_update_vector(
                gshard,
                {"master": opt["master"][0], "mu": opt["mu"][0], "nu": opt["nu"][0]},
                tcfg.optimizer,
                step,
            )
            if paper_mode:
                new_flat = master.astype(pdt)  # already full-length
            else:
                # recursive-doubling all-gather of updated bf16 params
                new_flat = _chained_ag(master.astype(pdt), dp_axes)
            new_params = unravel(new_flat[:n_params].astype(jnp.float32))
            new_params = jax.tree.map(
                lambda a, b: a.astype(b.dtype), new_params, params
            )

            if monitor is not None:
                local_mon = jax.tree.map(lambda x: x[0], mon_state)
                new_mon, done, val = monitor.step(
                    local_mon, metrics["per_example"].mean(), step
                )
                mon_out = jax.tree.map(lambda x: x[None], new_mon)
            else:
                mon_out = mon_state
                done = jnp.zeros((), jnp.bool_)
                val = jnp.zeros((), jnp.float32)
            opt_out = jax.tree.map(lambda x: x[None], new_opt)
            return (
                new_params,
                opt_out,
                mon_out,
                loss[None],
                gnorm[None],
                done[None],
                val[None],
            )

        dpP = P(dp_axes)
        bspecs = batch_specs(cfg, rules, batch)
        if monitor is not None:
            mon_state_in = state["monitor"]
            mon_spec = jax.tree.map(lambda _: dpP, state["monitor"])
        else:
            mon_state_in = jnp.zeros((dp, 1), jnp.float32)
            mon_spec = dpP
        out = jax.shard_map(
            local_step,
            mesh=mesh,
            in_specs=(
                jax.tree.map(lambda _: P(), state["params"]),
                {"master": dpP, "mu": dpP, "nu": dpP},
                P(),
                mon_spec,
                bspecs,
            ),
            out_specs=(
                jax.tree.map(lambda _: P(), state["params"]),
                {"master": dpP, "mu": dpP, "nu": dpP},
                mon_spec,
                dpP,
                dpP,
                dpP,
                dpP,
            ),
            axis_names=set(dp_axes),
            check_vma=False,
        )(state["params"], state["opt"], state["step"], mon_state_in, batch)
        params, opt, mon, loss, gnorm, done, val = out
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        if monitor is not None:
            new_state["monitor"] = mon
        metrics = {
            "loss": loss.mean(),
            "grad_norm": gnorm[0],
            "converged": done[0],
            "monitor_value": val[0],
        }
        return new_state, metrics

    return train_step, init_state, state_specs, rules


def make_train_step_mrd_leaf(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig):
    """Leaf-wise MRD butterfly gradient allreduce (beyond-paper iteration on
    'mrd_paper'): the butterfly runs per gradient leaf, which stays TP-sharded
    over the auto "model" axis — ppermute moves 1/tp of each leaf per device
    and no flatten/reshard collectives appear.  Optimizer: fp32 tree, TP-
    sharded, DP-replicated (memory ~ 16 B/param / tp)."""
    rules = shd.make_rules(cfg, mesh, fsdp=False)
    remat_policy = REMAT_POLICIES[tcfg.remat]
    pdt = dtype_of(cfg.param_dtype)
    dp_axes = rules.dp_axes
    dp = rules.dp
    monitor = (
        ConvergenceMonitor(
            axis_name=dp_axes if len(dp_axes) > 1 else dp_axes[0],
            threshold=tcfg.monitor_threshold,
            mode=tcfg.monitor_mode,
        )
        if tcfg.monitor
        else None
    )

    def init_state(key):
        params = transformer.init_params(cfg, key)
        state = {
            "params": params,
            "opt": opt_lib.init_opt_state(params),
            "step": jnp.zeros((), jnp.int32),
        }
        if monitor is not None:
            mon = monitor.init(varying=False)
            state["monitor"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (dp,) + x.shape), mon
            )
        return state

    def state_specs(state):
        pspecs = shd.param_specs(cfg, rules, state["params"])
        specs = {
            "params": pspecs,
            "opt": {"master": pspecs, "mu": pspecs, "nu": pspecs},
            "step": P(),
        }
        if monitor is not None:
            specs["monitor"] = jax.tree.map(lambda _: P(dp_axes), state["monitor"])
        return specs

    def train_step(state, batch):
        def local_step(params, opt, step, mon_state, local_batch):
            with shd.sharding_ctx(cfg, rules.manual_region()):
                grads, loss, metrics = _microbatched_grads(
                    params, local_batch, cfg, remat_policy, tcfg.microbatches
                )
            # the paper's butterfly, leaf-wise over TP-sharded grads
            for ax in dp_axes:
                grads = mrd.allreduce(grads, ax, op="sum")
            grads = jax.tree.map(lambda g: g / dp, grads)
            grads, gnorm = opt_lib.clip_by_global_norm(grads, tcfg.optimizer.grad_clip)
            params, opt = opt_lib.apply_update(
                grads, opt, tcfg.optimizer, step, pdt
            )
            if monitor is not None:
                local_mon = jax.tree.map(lambda x: x[0], mon_state)
                new_mon, done, val = monitor.step(
                    local_mon, metrics["per_example"].mean(), step
                )
                mon_out = jax.tree.map(lambda x: x[None], new_mon)
            else:
                mon_out = mon_state
                done = jnp.zeros((), jnp.bool_)
                val = jnp.zeros((), jnp.float32)
            return params, opt, mon_out, loss[None], gnorm[None], done[None], val[None]

        dpP = P(dp_axes)
        bspecs = batch_specs(cfg, rules, batch)
        if monitor is not None:
            mon_state_in = state["monitor"]
            mon_spec = jax.tree.map(lambda _: dpP, state["monitor"])
        else:
            mon_state_in = jnp.zeros((dp, 1), jnp.float32)
            mon_spec = dpP
        rep = lambda t: jax.tree.map(lambda _: P(), t)
        out = jax.shard_map(
            local_step,
            mesh=mesh,
            in_specs=(rep(state["params"]), rep(state["opt"]), P(), mon_spec, bspecs),
            out_specs=(rep(state["params"]), rep(state["opt"]), mon_spec, dpP, dpP, dpP, dpP),
            axis_names=set(dp_axes),
            check_vma=False,
        )(state["params"], state["opt"], state["step"], mon_state_in, batch)
        params, opt, mon, loss, gnorm, done, val = out
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        if monitor is not None:
            new_state["monitor"] = mon
        return new_state, {
            "loss": loss.mean(),
            "grad_norm": gnorm[0],
            "converged": done[0],
            "monitor_value": val[0],
        }

    return train_step, init_state, state_specs, rules


def make_train_step_local_sgd(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig):
    """Bounded-staleness local SGD (asynchronous-iterations-inspired;
    DESIGN.md §9): each DP worker trains its own replica with purely local
    gradients for ``local_sync_every`` steps, then replicas are averaged by
    the paper's collectives (Rabenseifner RS+AG over the flat vector).
    Stragglers never block intermediate steps; the staleness bound plays the
    role of the paper's bounded retards.  Per-replica state costs dp x the
    replicated-params memory — pair with TP for larger models."""
    rules = shd.make_rules(cfg, mesh, fsdp=False)
    remat_policy = REMAT_POLICIES[tcfg.remat]
    pdt = dtype_of(cfg.param_dtype)
    dp_axes = rules.dp_axes
    dp = rules.dp
    H = max(tcfg.local_sync_every, 1)

    def init_state(key):
        params = transformer.init_params(cfg, key)
        rep = lambda x: jnp.broadcast_to(x[None], (dp,) + x.shape)
        return {
            "params": jax.tree.map(rep, params),
            "opt": jax.tree.map(rep, opt_lib.init_opt_state(params)),
            "step": jnp.zeros((), jnp.int32),
        }

    def state_specs(state):
        dpP_tree = lambda t: jax.tree.map(lambda _: P(dp_axes), t)
        return {
            "params": dpP_tree(state["params"]),
            "opt": dpP_tree(state["opt"]),
            "step": P(),
        }

    def train_step(state, batch):
        def local_step(params_s, opt_s, step, local_batch):
            params = jax.tree.map(lambda x: x[0], params_s)
            opt = jax.tree.map(lambda x: x[0], opt_s)
            with shd.sharding_ctx(cfg, rules.manual_region()):
                grads, loss, metrics = _microbatched_grads(
                    params, local_batch, cfg, remat_policy, tcfg.microbatches
                )
            grads, gnorm = opt_lib.clip_by_global_norm(grads, tcfg.optimizer.grad_clip)
            params, opt = opt_lib.apply_update(
                grads, opt, tcfg.optimizer, step, pdt
            )

            def sync(ps):
                # paper's butterfly: average the replicas (flat, RS+AG)
                avg = mrd.tree_allreduce_flat(
                    jax.tree.map(lambda x: x.astype(jnp.float32), ps),
                    dp_axes[-1] if len(dp_axes) == 1 else dp_axes[-1],
                )
                if len(dp_axes) > 1:  # chain over outer axes (pod)
                    for ax in dp_axes[:-1]:
                        avg = mrd.tree_allreduce_flat(avg, ax)
                return jax.tree.map(
                    lambda a, b: (a / dp).astype(b.dtype), avg, ps
                )

            do_sync = (step + 1) % H == 0
            params = jax.lax.cond(do_sync, sync, lambda q: q, params)
            add1 = lambda t: jax.tree.map(lambda x: x[None], t)
            return add1(params), add1(opt), loss[None], gnorm[None]

        dpP = P(dp_axes)
        dpP_tree = lambda t: jax.tree.map(lambda _: dpP, t)
        bspecs = batch_specs(cfg, rules, batch)
        params_s, opt_s, loss, gnorm = jax.shard_map(
            local_step,
            mesh=mesh,
            in_specs=(dpP_tree(state["params"]), dpP_tree(state["opt"]), P(), bspecs),
            out_specs=(dpP_tree(state["params"]), dpP_tree(state["opt"]), dpP, dpP),
            axis_names=set(dp_axes),
            check_vma=False,
        )(state["params"], state["opt"], state["step"], batch)
        new_state = {"params": params_s, "opt": opt_s, "step": state["step"] + 1}
        return new_state, {
            "loss": loss.mean(),
            "grad_norm": gnorm.mean(),
            "converged": jnp.zeros((), jnp.bool_),
            "monitor_value": jnp.zeros((), jnp.float32),
        }

    return train_step, init_state, state_specs, rules


def make_train_step(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig):
    if tcfg.grad_sync == "gspmd":
        return make_train_step_gspmd(cfg, mesh, tcfg)
    if tcfg.grad_sync in ("mrd_zero1", "compressed", "mrd_paper"):
        return make_train_step_mrd(cfg, mesh, tcfg)
    if tcfg.grad_sync == "mrd_leaf":
        return make_train_step_mrd_leaf(cfg, mesh, tcfg)
    if tcfg.grad_sync == "local_sgd":
        return make_train_step_local_sgd(cfg, mesh, tcfg)
    raise ValueError(f"unknown grad_sync {tcfg.grad_sync!r}")


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, rules: shd.ShardingRules, cache: Any):
    """PartitionSpecs for a decode cache pytree."""

    def spec(path, leaf):
        name = None
        for e in reversed(path):
            if isinstance(e, jax.tree_util.DictKey):
                name = str(e.key)
                break
        shape = leaf.shape
        if name in ("k", "v", "local_k", "local_v", "global_k", "global_v", "attn_k", "attn_v"):
            lead = len(shape) - 4  # [..., B, W, KV, hd]
            b = rules.batch_axes(shape[lead])
            if rules.kv_heads_sharded:
                tail = (b, None, rules.tp_axis, None)
            else:
                tail = (b, rules.tp_axis if shape[lead + 1] % rules.tp == 0 else None, None, None)
            return P(*([None] * lead), *tail)
        if name in ("k_scale", "v_scale"):  # [L, B, W, KV]
            lead = len(shape) - 3
            b = rules.batch_axes(shape[lead])
            sdim = rules.tp_axis if (not rules.kv_heads_sharded and shape[lead + 1] % rules.tp == 0) else None
            return P(*([None] * lead), b, sdim, None)
        if name == "h":  # [L, B, di, st]
            return P(None, rules.batch_axes(shape[1]), rules.tp_if(shape[2]), None)
        if name == "conv":  # [L, B, K-1, di]
            return P(None, rules.batch_axes(shape[1]), None, rules.tp_if(shape[3]))
        if name == "m_h":  # [G, k, B, nh, hp, st]
            return P(None, None, rules.batch_axes(shape[2]), rules.tp_if(shape[3]), None, None)
        if name == "m_conv":  # [G, k, B, K-1, convdim]
            return P(None, None, rules.batch_axes(shape[2]), None, None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(spec, cache)


def _serve_needs_fsdp(cfg: ModelConfig, mesh: Mesh) -> bool:
    """bf16 weights sharded over "model" alone must fit in ~half the HBM."""
    tp = mesh.shape.get("model", 1)
    return cfg.n_params() * 2 / tp > 8e9


def make_serve_step(cfg: ModelConfig, mesh: Mesh):
    """Decode step: (params, tokens [B], cache, cache_len) -> (logits, cache)."""
    rules = shd.make_rules(cfg, mesh, fsdp=_serve_needs_fsdp(cfg, mesh))

    def serve_step(params, tokens, cache, cache_len):
        with shd.sharding_ctx(cfg, rules):
            return transformer.forward_decode(params, tokens, cache, cache_len, cfg)

    return serve_step, rules


def make_prefill_step(cfg: ModelConfig, mesh: Mesh):
    """Prefill: full forward, returns last-position logits [B, V]."""
    rules = shd.make_rules(cfg, mesh, fsdp=_serve_needs_fsdp(cfg, mesh))

    def prefill_step(params, batch):
        with shd.sharding_ctx(cfg, rules):
            x, _ = transformer._embed_inputs(params, batch, cfg)
            x = shd.constrain(x.astype(dtype_of(cfg.compute_dtype)), "tokens")
            S = x.shape[1]
            pos = jnp.arange(S)[None, :]
            x, _ = transformer._run_stack(params, x, cfg, pos)
            from repro.models.layers import rmsnorm

            x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
            return transformer._logits(params, x, cfg)[:, 0]

    return prefill_step, rules
