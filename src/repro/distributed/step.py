"""Train/serve step wiring — thin facade over the layered subsystems.

The actual machinery lives in:

- ``repro.distributed.gradsync``  — the grad-sync strategy registry
  (DESIGN.md S2): one module per mode (``gspmd`` | ``mrd_paper`` |
  ``mrd_leaf`` | ``mrd_zero1`` | ``compressed`` | ``local_sgd``), each
  composing the shared monitor/optimizer/microbatching pieces in
  ``gradsync.common`` with its own gradient-crossing plan;
- ``repro.collectives``           — schedules x executors x transforms x
  plans (DESIGN.md S1); every collective any strategy issues runs
  through a single :class:`repro.collectives.plans.CollectivePlan`;
- ``repro.distributed.serve``     — decode/prefill steps + cache specs.

The ConvergenceMonitor (paper Alg. 1/2 over the DP axes) advances one MRD
stage per train step; it costs one scalar ppermute per step and never
blocks.  This module keeps the historical import surface
(``repro.distributed.step``) stable for launchers, benchmarks, and tests.
"""

from __future__ import annotations

from jax.sharding import Mesh

from repro.distributed.gradsync import (  # noqa: F401
    GRAD_SYNC,
    available as available_grad_sync,
    make_step_factory,
)
from repro.distributed.gradsync import make_train_step as _registry_make_train_step
from repro.distributed.gradsync.common import (  # noqa: F401
    REMAT_POLICIES,
    TrainConfig,
    batch_specs,
    build_monitor,
    microbatched_grads as _microbatched_grads,
)
from repro.distributed.gradsync.mrd_zero1 import (  # noqa: F401
    zero1_layout,
    zero1_masters_from_params,
    zero1_owner_segments,
    zero1_shard_len,
)
from repro.distributed.gradsync.overlap import (  # noqa: F401
    segmented_grads,
)
from repro.distributed.serve import (  # noqa: F401
    cache_specs,
    make_cached_prefill_step,
    make_prefill_step,
    make_serve_step,
)
from repro.models.config import ModelConfig


def make_train_step(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig):
    """Resolve ``tcfg.grad_sync`` in the registry and build
    ``(train_step, init_state, state_specs, rules)``."""
    return _registry_make_train_step(cfg, mesh, tcfg)


# --- deprecated aliases (pre-registry entry points) ------------------------


def make_train_step_gspmd(cfg, mesh, tcfg):
    from repro.distributed.gradsync import gspmd

    return gspmd.make(cfg, mesh, tcfg)


def make_train_step_mrd(cfg, mesh, tcfg):
    from repro.distributed.gradsync.mrd_zero1 import make_zero1

    return make_zero1(
        cfg, mesh, tcfg,
        transform="int8" if tcfg.grad_sync == "compressed" else "identity",
        paper_mode=tcfg.grad_sync == "mrd_paper",
    )


def make_train_step_mrd_leaf(cfg, mesh, tcfg):
    from repro.distributed.gradsync import mrd_leaf

    return mrd_leaf.make(cfg, mesh, tcfg)


def make_train_step_local_sgd(cfg, mesh, tcfg):
    from repro.distributed.gradsync import local_sgd

    return local_sgd.make(cfg, mesh, tcfg)
