"""Serving step builders: decode/prefill wiring + decode-cache sharding."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.layers import dtype_of


def cache_specs(cfg: ModelConfig, rules: shd.ShardingRules, cache: Any):
    """PartitionSpecs for a decode cache pytree."""

    def spec(path, leaf):
        name = None
        for e in reversed(path):
            if isinstance(e, jax.tree_util.DictKey):
                name = str(e.key)
                break
        shape = leaf.shape
        if name in ("k", "v", "local_k", "local_v", "global_k", "global_v", "attn_k", "attn_v"):
            lead = len(shape) - 4  # [..., B, W, KV, hd]
            b = rules.batch_axes(shape[lead])
            if rules.kv_heads_sharded:
                tail = (b, None, rules.tp_axis, None)
            else:
                tail = (b, rules.tp_axis if shape[lead + 1] % rules.tp == 0 else None, None, None)
            return P(*([None] * lead), *tail)
        if name in ("k_scale", "v_scale"):  # [L, B, W, KV]
            lead = len(shape) - 3
            b = rules.batch_axes(shape[lead])
            sdim = rules.tp_axis if (not rules.kv_heads_sharded and shape[lead + 1] % rules.tp == 0) else None
            return P(*([None] * lead), b, sdim, None)
        if name == "h":  # [L, B, di, st]
            return P(None, rules.batch_axes(shape[1]), rules.tp_if(shape[2]), None)
        if name == "conv":  # [L, B, K-1, di]
            return P(None, rules.batch_axes(shape[1]), None, rules.tp_if(shape[3]))
        if name == "m_h":  # [G, k, B, nh, hp, st]
            return P(None, None, rules.batch_axes(shape[2]), rules.tp_if(shape[3]), None, None)
        if name == "m_conv":  # [G, k, B, K-1, convdim]
            return P(None, None, rules.batch_axes(shape[2]), None, None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(spec, cache)


def _serve_needs_fsdp(cfg: ModelConfig, mesh: Mesh) -> bool:
    """bf16 weights sharded over "model" alone must fit in ~half the HBM."""
    tp = mesh.shape.get("model", 1)
    return cfg.n_params() * 2 / tp > 8e9


def make_serve_step(cfg: ModelConfig, mesh: Mesh):
    """Decode step: (params, tokens [B], cache, cache_len) -> (logits, cache)."""
    rules = shd.make_rules(cfg, mesh, fsdp=_serve_needs_fsdp(cfg, mesh))

    def serve_step(params, tokens, cache, cache_len):
        with shd.sharding_ctx(cfg, rules):
            return transformer.forward_decode(params, tokens, cache, cache_len, cfg)

    return serve_step, rules


def make_cached_prefill_step(cfg: ModelConfig, mesh: Mesh):
    """Single-dispatch prefill that fills the decode cache.

    Scans the decode step over the prompt inside one jitted program —
    replacing the launcher's historical per-token Python loop (one XLA
    dispatch *per prompt token*) with a single ``lax.scan`` dispatch.
    Token-for-token the math is the decode step's own, so the resulting
    cache and last-position logits match the per-token loop.

    Returns ``prefill_step(params, prompt [B, S], cache) ->
    (last_logits [B, V], cache)``.
    """
    serve_step, rules = make_serve_step(cfg, mesh)

    def prefill_step(params, prompt, cache):
        S = prompt.shape[1]

        def body(c, xs):
            tok, i = xs
            logits, c = serve_step(params, tok, c, i)
            return c, logits

        cache_out, logits = jax.lax.scan(
            body, cache,
            (prompt.T, jnp.arange(S, dtype=jnp.int32)),
            unroll=1,
        )
        return logits[-1], cache_out

    return prefill_step, rules


# ---------------------------------------------------------------------------
# Continuous-batching pool steps (repro.serving, DESIGN.md S13)
# ---------------------------------------------------------------------------

# Leaf-name -> batch-axis index for every cache family built by
# ``transformer.init_cache`` (the slot dimension of a decode pool).
_CACHE_BATCH_AXIS = {
    "k": 1, "v": 1, "k_scale": 1, "v_scale": 1,          # dense/moe/vlm [L,B,...]
    "global_k": 1, "global_v": 1,                        # gemma3 [G,B,...]
    "local_k": 2, "local_v": 2,                          # gemma3 [G,P,B,...]
    "h": 1, "conv": 1,                                   # ssm [L,B,...]
    "m_h": 2, "m_conv": 2,                               # hybrid [G,k,B,...]
    "attn_k": 1, "attn_v": 1,                            # hybrid [G,B,...]
}


def _leaf_name(path) -> str:
    for e in reversed(path):
        if isinstance(e, jax.tree_util.DictKey):
            return str(e.key)
    raise KeyError(f"no dict key in path {path}")


def cache_batch_axes(cache: Any):
    """Pytree matching ``cache`` whose leaves are the batch (slot) axis index."""
    return jax.tree_util.tree_map_with_path(
        lambda p, _: _CACHE_BATCH_AXIS[_leaf_name(p)], cache
    )


def select_slots(mask, cache_new: Any, cache_old: Any):
    """Per-slot select between two caches: slot ``s`` takes ``cache_new``
    where ``mask[s]``, else ``cache_old`` (leaves keep their layout)."""

    def sel(path, new, old):
        ax = _CACHE_BATCH_AXIS[_leaf_name(path)]
        shape = [1] * new.ndim
        shape[ax] = mask.shape[0]
        return jnp.where(mask.reshape(shape), new, old)

    return jax.tree_util.tree_map_with_path(sel, cache_new, cache_old)


def _expand_slot(cache: Any):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: jnp.expand_dims(l, _CACHE_BATCH_AXIS[_leaf_name(p)]), cache
    )


def _squeeze_slot(cache: Any):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: jnp.squeeze(l, _CACHE_BATCH_AXIS[_leaf_name(p)]), cache
    )


def make_pool_decode_step(cfg: ModelConfig, mesh: Mesh):
    """Decode step with a *per-slot* cache length (continuous batching).

    ``pool_step(params, tokens [S], cache, lengths [S]) -> (logits [S,V],
    cache)`` — a ``vmap`` of the single-sequence decode step over the slot
    dimension, so every slot advances at its own position/write offset.
    Slot math is independent (vmap adds no cross-slot terms), which is what
    makes continuous batching bit-equal to solo decode per request.
    """
    rules = shd.make_rules(cfg, mesh, fsdp=_serve_needs_fsdp(cfg, mesh))

    def pool_step(params, tokens, cache, lengths):
        axes = cache_batch_axes(cache)

        def one(tok, cslot, length):
            logits, c2 = transformer.forward_decode(
                params, tok[None], _expand_slot(cslot), length, cfg
            )
            return logits[0], _squeeze_slot(c2)

        with shd.sharding_ctx(cfg, rules):
            return jax.vmap(one, in_axes=(0, axes, 0), out_axes=(0, axes))(
                tokens, cache, lengths
            )

    return pool_step, rules


def _prefill_scan(cfg, rules, params, prompt, plen, cslot, max_prompt_len: int):
    """Scan the decode step over a padded prompt into a one-slot cache view.

    Shared by the contiguous and paged offset-prefill builders so both
    admission paths trace the exact same jaxpr (bit-exactness discipline).
    Positions ``>= plen`` run but are masked out of the carried cache; the
    last live position's logits are latched.
    """

    def body(carry, xs):
        c, last = carry
        tok, i = xs
        with shd.sharding_ctx(cfg, rules):
            logits, c2 = transformer.forward_decode(
                params, tok[None], c, i, cfg
            )
        live = i < plen
        c = jax.tree.map(lambda a, b: jnp.where(live, a, b), c2, c)
        last = jnp.where(i == plen - 1, logits[0], last)
        return (c, last), None

    (cslot, last_logits), _ = jax.lax.scan(
        body,
        (cslot, jnp.zeros((cfg.vocab,), jnp.float32)),
        (prompt[:max_prompt_len], jnp.arange(max_prompt_len, dtype=jnp.int32)),
        unroll=1,
    )
    return cslot, last_logits


def make_slot_prefill_step(cfg: ModelConfig, mesh: Mesh, max_prompt_len: int):
    """Offset-prefill into a live cache slot (slot recycling).

    ``slot_prefill(params, prompt [Lmax], plen, cache, slot) ->
    (last_logits [V], cache)``: the retired slot's cache slice is zeroed
    (recurrent SSM/conv state must not leak between requests; attention
    positions beyond the new length are masked anyway) and the decode step
    is scanned over the padded prompt, masking positions ``>= plen`` — one
    jitted dispatch per admission, shapes fixed by ``max_prompt_len``, so
    admission never recompiles.  The rest of the pool is untouched, so live
    slots keep decoding across admissions.
    """
    rules = shd.make_rules(cfg, mesh, fsdp=_serve_needs_fsdp(cfg, mesh))

    def slot_prefill(params, prompt, plen, cache, slot):
        cslot = jax.tree_util.tree_map_with_path(
            lambda p, l: jnp.zeros_like(
                jax.lax.dynamic_index_in_dim(
                    l, slot, axis=_CACHE_BATCH_AXIS[_leaf_name(p)], keepdims=True
                )
            ),
            cache,
        )
        cslot, last_logits = _prefill_scan(
            cfg, rules, params, prompt, plen, cslot, max_prompt_len
        )
        cache = jax.tree_util.tree_map_with_path(
            lambda p, l, s: jax.lax.dynamic_update_index_in_dim(
                l, jnp.squeeze(s, _CACHE_BATCH_AXIS[_leaf_name(p)]), slot,
                axis=_CACHE_BATCH_AXIS[_leaf_name(p)],
            ),
            cache, cslot,
        )
        return last_logits, cache

    return slot_prefill, rules


# ---------------------------------------------------------------------------
# Block-paged pool steps (repro.serving.paged, DESIGN.md S14)
# ---------------------------------------------------------------------------

# Cache leaves whose (slot, seq) slab is paged into fixed-size blocks of a
# shared physical pool.  Everything else ("slot leaves": recurrent SSM/conv
# state, rolling local windows) stays per-slot.  Every paged leaf has batch
# (slot) axis 1 and sequence axis 2 in its contiguous layout.
PAGED_LEAVES = ("k", "v", "k_scale", "v_scale", "attn_k", "attn_v")


def split_paged_cache(cache):
    """Split a decode cache dict into (paged leaves, per-slot leaves)."""
    paged = {n: l for n, l in cache.items() if n in PAGED_LEAVES}
    slot = {n: l for n, l in cache.items() if n not in PAGED_LEAVES}
    return paged, slot


def validate_pageable(cfg: ModelConfig, max_len: int) -> None:
    """Raise unless this config's decode cache can be block-paged.

    Pageable: dense/moe/vlm full-attention caches (no rolling sliding
    window — a modular write index breaks the position->block mapping) and
    hybrid attention caches (the Mamba h/conv state stays per-slot).
    """
    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.pattern_local:
            raise ValueError(
                f"{cfg.name}: local-attention layers write their cache "
                "modulo the window; rolling windows are not pageable — "
                "use the contiguous pool"
            )
        if cfg.sliding_window and cfg.sliding_window < max_len:
            raise ValueError(
                f"{cfg.name}: sliding_window={cfg.sliding_window} < "
                f"max_len={max_len} writes the cache modulo the window; "
                "rolling windows are not pageable — use the contiguous pool"
            )
        return
    if cfg.family == "hybrid":
        return
    raise ValueError(
        f"family {cfg.family!r} has no pageable KV cache (recurrent state "
        "is O(1) per slot already) — use the contiguous pool"
    )


def init_paged_pool(cfg: ModelConfig, max_len: int, num_blocks: int,
                    block_size: int):
    """Physical block pools for every paged cache leaf.

    A contiguous leaf ``[D0, B, W, *tail]`` becomes a pool
    ``[D0, num_blocks, block_size, *tail]`` shared by all slots; per-slot
    block tables map logical block ``j`` (positions ``[j*bs, (j+1)*bs)``)
    to a physical block.  Block 0 is reserved as the *trash* block —
    device-side writes for inactive/masked slots are redirected there so
    the fused tick never branches on host allocator state.
    """
    validate_pageable(cfg, max_len)
    tmpl, _ = split_paged_cache(transformer.init_cache(cfg, 1, max_len))
    pool = {}
    for n, l in tmpl.items():
        if l.shape[2] != max_len:
            raise ValueError(f"paged leaf {n}: seq dim {l.shape[2]} != max_len")
        pool[n] = jnp.zeros(
            l.shape[:1] + (num_blocks, block_size) + l.shape[3:], l.dtype
        )
    return pool


def paged_pool_specs(cfg: ModelConfig, rules: shd.ShardingRules, pool: Any):
    """PartitionSpecs for a paged block pool.

    Head/hd tail axes shard exactly like the contiguous cache leaf; the
    (num_blocks, block_size) axes are replicated — blocks must move between
    slots without resharding.
    """

    def spec(path, leaf):
        name = _leaf_name(path)
        if name in ("k_scale", "v_scale"):  # [L, N, bs, KV]
            sdim = (
                rules.tp_axis
                if (not rules.kv_heads_sharded and leaf.shape[3] % rules.tp == 0)
                else None
            )
            return P(None, None, None, sdim)
        # [D0, N, bs, KV, hd]
        if rules.kv_heads_sharded:
            return P(None, None, None, rules.tp_axis, None)
        return P(
            None, None, None,
            rules.tp_axis if leaf.shape[3] % rules.tp == 0 else None, None,
        )

    return jax.tree_util.tree_map_with_path(spec, pool)


def gather_block_views(pool_leaf, tables):
    """Assemble per-slot contiguous views through the block tables.

    ``pool_leaf [D0, N, bs, *tail]`` + ``tables [S, nb]`` ->
    ``[D0, S, nb*bs, *tail]`` — exactly the contiguous cache layout, so the
    unchanged per-slot decode vmap consumes it and its math (shapes,
    reduction orders) is bit-identical to the contiguous pool step.
    Positions beyond a slot's allocation read the trash block; attention
    masks them with NEG_INF before the softmax max, so they contribute an
    exact 0.0 either way.
    """
    g = jnp.take(pool_leaf, tables, axis=1)  # [D0, S, nb, bs, *tail]
    return g.reshape(
        g.shape[0], g.shape[1], g.shape[2] * g.shape[3], *g.shape[4:]
    )


def make_paged_pool_decode_step(cfg: ModelConfig, mesh: Mesh, block_size: int,
                                attn: str = "gather"):
    """Paged decode step: gather views -> contiguous pool step -> row scatter.

    ``pool_step(params, tokens [S], pages, tables [S,nb], slot_state,
    lengths [S], write_ok [S]) -> (logits [S,V], pages, slot_state)``.

    ``attn="gather"`` (default) runs the *unchanged* contiguous per-slot
    decode vmap over block-table-gathered views — bit-exact with the
    contiguous pool by construction — then scatters the single written row
    per slot back into its physical (block, offset).  ``attn="pallas"``
    dispatches :func:`repro.models.transformer.forward_decode_paged`, which
    reads K/V through the block table *inside* the Pallas paged-attention
    kernel (no materialized views; the TPU hot path).  ``write_ok`` masks
    slots whose write is redirected to the trash block (inactive slots stay
    one fused dispatch without host branching).
    """
    if attn == "pallas":
        rules = shd.make_rules(cfg, mesh, fsdp=_serve_needs_fsdp(cfg, mesh))

        def pool_step_pallas(params, tokens, pages, tables, slot_state,
                             lengths, write_ok):
            with shd.sharding_ctx(cfg, rules):
                return transformer.forward_decode_paged(
                    params, tokens, pages, tables, slot_state, lengths, cfg,
                    block_size=block_size, write_ok=write_ok,
                )

        return pool_step_pallas, rules
    if attn != "gather":
        raise ValueError(f"attn must be 'gather' or 'pallas', got {attn!r}")

    contiguous_step, rules = make_pool_decode_step(cfg, mesh)

    def pool_step(params, tokens, pages, tables, slot_state, lengths, write_ok):
        view = {n: gather_block_views(pages[n], tables) for n in pages}
        logits, cache2 = contiguous_step(
            params, tokens, {**view, **slot_state}, lengths
        )
        # physical (block, offset) of the one row each slot wrote; masked
        # slots land in the reserved trash block 0
        pb = jnp.take_along_axis(
            tables, (lengths // block_size)[:, None], axis=1
        )[:, 0]
        pb = jnp.where(write_ok, pb, 0)
        off = jnp.where(write_ok, lengths % block_size, 0)
        pages2 = {}
        for n in pages:
            idx = lengths.reshape((1, -1, 1) + (1,) * (cache2[n].ndim - 3))
            row = jnp.squeeze(
                jnp.take_along_axis(cache2[n], idx, axis=2), 2
            )  # [D0, S, *tail]
            pages2[n] = pages[n].at[:, pb, off].set(row)
        slot2 = {n: cache2[n] for n in slot_state}
        return logits, pages2, slot2

    return pool_step, rules


def make_paged_slot_prefill_step(cfg: ModelConfig, mesh: Mesh,
                                 max_prompt_len: int, max_len: int,
                                 block_size: int):
    """Offset-prefill a prompt into a slot's *block table* (paged admission).

    ``slot_prefill(params, prompt [Lmax], plen, pages, tables, slot_state,
    slot, table_row [nb], write_mask [nb]) -> (last_logits [V], pages,
    tables, slot_state)``.

    Runs the shared :func:`_prefill_scan` over a zeroed full-length view
    (same jaxpr as the contiguous admission — bit-exactness), then scatters
    whole blocks into the physical pool: logical block ``j`` goes to
    ``table_row[j]`` where ``write_mask[j]``, else to the trash block —
    shared prefix blocks are *skip-written* (their recomputed content is
    bit-identical by determinism; the registered copy stays untouched).
    Shapes are fixed by ``max_prompt_len``/``nb``, so admission never
    recompiles.
    """
    rules = shd.make_rules(cfg, mesh, fsdp=_serve_needs_fsdp(cfg, mesh))
    tmpl, _ = split_paged_cache(transformer.init_cache(cfg, 1, max_len))
    shapes = {n: (l.shape, l.dtype) for n, l in tmpl.items()}

    def slot_prefill(params, prompt, plen, pages, tables, slot_state, slot,
                     table_row, write_mask):
        view0 = {n: jnp.zeros(sh, dt) for n, (sh, dt) in shapes.items()}
        slot0 = jax.tree_util.tree_map_with_path(
            lambda p, l: jnp.zeros_like(
                jax.lax.dynamic_index_in_dim(
                    l, slot, axis=_CACHE_BATCH_AXIS[_leaf_name(p)], keepdims=True
                )
            ),
            slot_state,
        )
        cslot, last_logits = _prefill_scan(
            cfg, rules, params, prompt, plen, {**view0, **slot0},
            max_prompt_len,
        )
        nb = table_row.shape[0]
        dst = jnp.where(write_mask, table_row, 0)
        pages2 = {}
        for n in pages:
            leaf = jnp.squeeze(cslot[n], 1)  # [D0, W, *tail]
            blocks = leaf.reshape(
                leaf.shape[0], nb, block_size, *leaf.shape[2:]
            )
            pages2[n] = pages[n].at[:, dst].set(blocks)
        slot2 = jax.tree_util.tree_map_with_path(
            lambda p, l, s: jax.lax.dynamic_update_index_in_dim(
                l, jnp.squeeze(s, _CACHE_BATCH_AXIS[_leaf_name(p)]), slot,
                axis=_CACHE_BATCH_AXIS[_leaf_name(p)],
            ),
            slot_state, {n: cslot[n] for n in slot_state},
        )
        tables2 = tables.at[slot].set(table_row)
        return last_logits, pages2, tables2, slot2

    return slot_prefill, rules


def make_prefill_step(cfg: ModelConfig, mesh: Mesh):
    """Prefill: full forward, returns last-position logits [B, V]."""
    rules = shd.make_rules(cfg, mesh, fsdp=_serve_needs_fsdp(cfg, mesh))

    def prefill_step(params, batch):
        with shd.sharding_ctx(cfg, rules):
            x, _ = transformer._embed_inputs(params, batch, cfg)
            x = shd.constrain(x.astype(dtype_of(cfg.compute_dtype)), "tokens")
            S = x.shape[1]
            pos = jnp.arange(S)[None, :]
            x, _ = transformer._run_stack(params, x, cfg, pos)
            from repro.models.layers import rmsnorm

            x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
            return transformer._logits(params, x, cfg)[:, 0]

    return prefill_step, rules


# ---------------------------------------------------------------------------
# Elastic serving: bit-exact state transfer to joining replicas (S15)
# ---------------------------------------------------------------------------


_BCAST_JIT: dict = {}


def mrd_broadcast_stacked(tree, p: int, src: int = 0, dst: int = None):
    """Simulated-replica analogue of ``runtime.elastic.mrd_broadcast``.

    The serving engine's termination agreement runs over *stacked* replicas
    (sim-executor MRD plans over a leading ``[p]`` axis), so the grow path's
    state transfer is the same protocol move at the same extent: rank
    ``src`` contributes the real leaves, every other rank contributes exact
    zeros, and the MRD **sum**-allreduce makes ``x + 0`` bit-exact at every
    stage — the value landing on the joiner (``dst``, default the last,
    newly appended rank) equals the source's bit for bit.  Bool leaves ride
    as uint8; zero-size leaves pass through untouched.  Returns the tree as
    received by ``dst``.

    The whole tree moves through **one** jitted program (cached per
    ``(p, src, dst, structure, shapes)``): a per-leaf eager loop dispatches
    thousands of stage-sized ops for a full model tree, which would
    dominate a live grow.
    """
    from repro.collectives import plans as _plans

    if dst is None:
        dst = p - 1
    if p == 1:
        return jax.tree.map(jnp.asarray, tree)
    leaves, treedef = jax.tree.flatten(tree)
    leaves = [jnp.asarray(l) for l in leaves]
    key = (p, src, dst, treedef,
           tuple((l.shape, str(l.dtype)) for l in leaves))
    fn = _BCAST_JIT.get(key)
    if fn is None:
        plan = _plans.allreduce_plan(schedule="mrd", p=p, op="sum")

        def one(leaf):
            if leaf.size == 0:
                return leaf
            as_bool = leaf.dtype == jnp.bool_
            x = leaf.astype(jnp.uint8) if as_bool else leaf
            stacked = jnp.zeros((p,) + x.shape, x.dtype).at[src].set(x)
            out = plan.run(stacked)[dst]
            return out.astype(jnp.bool_) if as_bool else out

        fn = _BCAST_JIT[key] = jax.jit(lambda ls: [one(l) for l in ls])
    return jax.tree.unflatten(treedef, fn(leaves))
