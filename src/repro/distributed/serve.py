"""Serving step builders: decode/prefill wiring + decode-cache sharding."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.layers import dtype_of


def cache_specs(cfg: ModelConfig, rules: shd.ShardingRules, cache: Any):
    """PartitionSpecs for a decode cache pytree."""

    def spec(path, leaf):
        name = None
        for e in reversed(path):
            if isinstance(e, jax.tree_util.DictKey):
                name = str(e.key)
                break
        shape = leaf.shape
        if name in ("k", "v", "local_k", "local_v", "global_k", "global_v", "attn_k", "attn_v"):
            lead = len(shape) - 4  # [..., B, W, KV, hd]
            b = rules.batch_axes(shape[lead])
            if rules.kv_heads_sharded:
                tail = (b, None, rules.tp_axis, None)
            else:
                tail = (b, rules.tp_axis if shape[lead + 1] % rules.tp == 0 else None, None, None)
            return P(*([None] * lead), *tail)
        if name in ("k_scale", "v_scale"):  # [L, B, W, KV]
            lead = len(shape) - 3
            b = rules.batch_axes(shape[lead])
            sdim = rules.tp_axis if (not rules.kv_heads_sharded and shape[lead + 1] % rules.tp == 0) else None
            return P(*([None] * lead), b, sdim, None)
        if name == "h":  # [L, B, di, st]
            return P(None, rules.batch_axes(shape[1]), rules.tp_if(shape[2]), None)
        if name == "conv":  # [L, B, K-1, di]
            return P(None, rules.batch_axes(shape[1]), None, rules.tp_if(shape[3]))
        if name == "m_h":  # [G, k, B, nh, hp, st]
            return P(None, None, rules.batch_axes(shape[2]), rules.tp_if(shape[3]), None, None)
        if name == "m_conv":  # [G, k, B, K-1, convdim]
            return P(None, None, rules.batch_axes(shape[2]), None, None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(spec, cache)


def _serve_needs_fsdp(cfg: ModelConfig, mesh: Mesh) -> bool:
    """bf16 weights sharded over "model" alone must fit in ~half the HBM."""
    tp = mesh.shape.get("model", 1)
    return cfg.n_params() * 2 / tp > 8e9


def make_serve_step(cfg: ModelConfig, mesh: Mesh):
    """Decode step: (params, tokens [B], cache, cache_len) -> (logits, cache)."""
    rules = shd.make_rules(cfg, mesh, fsdp=_serve_needs_fsdp(cfg, mesh))

    def serve_step(params, tokens, cache, cache_len):
        with shd.sharding_ctx(cfg, rules):
            return transformer.forward_decode(params, tokens, cache, cache_len, cfg)

    return serve_step, rules


def make_cached_prefill_step(cfg: ModelConfig, mesh: Mesh):
    """Single-dispatch prefill that fills the decode cache.

    Scans the decode step over the prompt inside one jitted program —
    replacing the launcher's historical per-token Python loop (one XLA
    dispatch *per prompt token*) with a single ``lax.scan`` dispatch.
    Token-for-token the math is the decode step's own, so the resulting
    cache and last-position logits match the per-token loop.

    Returns ``prefill_step(params, prompt [B, S], cache) ->
    (last_logits [B, V], cache)``.
    """
    serve_step, rules = make_serve_step(cfg, mesh)

    def prefill_step(params, prompt, cache):
        S = prompt.shape[1]

        def body(c, xs):
            tok, i = xs
            logits, c = serve_step(params, tok, c, i)
            return c, logits

        cache_out, logits = jax.lax.scan(
            body, cache,
            (prompt.T, jnp.arange(S, dtype=jnp.int32)),
            unroll=1,
        )
        return logits[-1], cache_out

    return prefill_step, rules


def make_prefill_step(cfg: ModelConfig, mesh: Mesh):
    """Prefill: full forward, returns last-position logits [B, V]."""
    rules = shd.make_rules(cfg, mesh, fsdp=_serve_needs_fsdp(cfg, mesh))

    def prefill_step(params, batch):
        with shd.sharding_ctx(cfg, rules):
            x, _ = transformer._embed_inputs(params, batch, cfg)
            x = shd.constrain(x.astype(dtype_of(cfg.compute_dtype)), "tokens")
            S = x.shape[1]
            pos = jnp.arange(S)[None, :]
            x, _ = transformer._run_stack(params, x, cfg, pos)
            from repro.models.layers import rmsnorm

            x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
            return transformer._logits(params, x, cfg)[:, 0]

    return prefill_step, rules
