"""Serving step builders: decode/prefill wiring + decode-cache sharding."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.layers import dtype_of


def cache_specs(cfg: ModelConfig, rules: shd.ShardingRules, cache: Any):
    """PartitionSpecs for a decode cache pytree."""

    def spec(path, leaf):
        name = None
        for e in reversed(path):
            if isinstance(e, jax.tree_util.DictKey):
                name = str(e.key)
                break
        shape = leaf.shape
        if name in ("k", "v", "local_k", "local_v", "global_k", "global_v", "attn_k", "attn_v"):
            lead = len(shape) - 4  # [..., B, W, KV, hd]
            b = rules.batch_axes(shape[lead])
            if rules.kv_heads_sharded:
                tail = (b, None, rules.tp_axis, None)
            else:
                tail = (b, rules.tp_axis if shape[lead + 1] % rules.tp == 0 else None, None, None)
            return P(*([None] * lead), *tail)
        if name in ("k_scale", "v_scale"):  # [L, B, W, KV]
            lead = len(shape) - 3
            b = rules.batch_axes(shape[lead])
            sdim = rules.tp_axis if (not rules.kv_heads_sharded and shape[lead + 1] % rules.tp == 0) else None
            return P(*([None] * lead), b, sdim, None)
        if name == "h":  # [L, B, di, st]
            return P(None, rules.batch_axes(shape[1]), rules.tp_if(shape[2]), None)
        if name == "conv":  # [L, B, K-1, di]
            return P(None, rules.batch_axes(shape[1]), None, rules.tp_if(shape[3]))
        if name == "m_h":  # [G, k, B, nh, hp, st]
            return P(None, None, rules.batch_axes(shape[2]), rules.tp_if(shape[3]), None, None)
        if name == "m_conv":  # [G, k, B, K-1, convdim]
            return P(None, None, rules.batch_axes(shape[2]), None, None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(spec, cache)


def _serve_needs_fsdp(cfg: ModelConfig, mesh: Mesh) -> bool:
    """bf16 weights sharded over "model" alone must fit in ~half the HBM."""
    tp = mesh.shape.get("model", 1)
    return cfg.n_params() * 2 / tp > 8e9


def make_serve_step(cfg: ModelConfig, mesh: Mesh):
    """Decode step: (params, tokens [B], cache, cache_len) -> (logits, cache)."""
    rules = shd.make_rules(cfg, mesh, fsdp=_serve_needs_fsdp(cfg, mesh))

    def serve_step(params, tokens, cache, cache_len):
        with shd.sharding_ctx(cfg, rules):
            return transformer.forward_decode(params, tokens, cache, cache_len, cfg)

    return serve_step, rules


def make_cached_prefill_step(cfg: ModelConfig, mesh: Mesh):
    """Single-dispatch prefill that fills the decode cache.

    Scans the decode step over the prompt inside one jitted program —
    replacing the launcher's historical per-token Python loop (one XLA
    dispatch *per prompt token*) with a single ``lax.scan`` dispatch.
    Token-for-token the math is the decode step's own, so the resulting
    cache and last-position logits match the per-token loop.

    Returns ``prefill_step(params, prompt [B, S], cache) ->
    (last_logits [B, V], cache)``.
    """
    serve_step, rules = make_serve_step(cfg, mesh)

    def prefill_step(params, prompt, cache):
        S = prompt.shape[1]

        def body(c, xs):
            tok, i = xs
            logits, c = serve_step(params, tok, c, i)
            return c, logits

        cache_out, logits = jax.lax.scan(
            body, cache,
            (prompt.T, jnp.arange(S, dtype=jnp.int32)),
            unroll=1,
        )
        return logits[-1], cache_out

    return prefill_step, rules


# ---------------------------------------------------------------------------
# Continuous-batching pool steps (repro.serving, DESIGN.md S13)
# ---------------------------------------------------------------------------

# Leaf-name -> batch-axis index for every cache family built by
# ``transformer.init_cache`` (the slot dimension of a decode pool).
_CACHE_BATCH_AXIS = {
    "k": 1, "v": 1, "k_scale": 1, "v_scale": 1,          # dense/moe/vlm [L,B,...]
    "global_k": 1, "global_v": 1,                        # gemma3 [G,B,...]
    "local_k": 2, "local_v": 2,                          # gemma3 [G,P,B,...]
    "h": 1, "conv": 1,                                   # ssm [L,B,...]
    "m_h": 2, "m_conv": 2,                               # hybrid [G,k,B,...]
    "attn_k": 1, "attn_v": 1,                            # hybrid [G,B,...]
}


def _leaf_name(path) -> str:
    for e in reversed(path):
        if isinstance(e, jax.tree_util.DictKey):
            return str(e.key)
    raise KeyError(f"no dict key in path {path}")


def cache_batch_axes(cache: Any):
    """Pytree matching ``cache`` whose leaves are the batch (slot) axis index."""
    return jax.tree_util.tree_map_with_path(
        lambda p, _: _CACHE_BATCH_AXIS[_leaf_name(p)], cache
    )


def select_slots(mask, cache_new: Any, cache_old: Any):
    """Per-slot select between two caches: slot ``s`` takes ``cache_new``
    where ``mask[s]``, else ``cache_old`` (leaves keep their layout)."""

    def sel(path, new, old):
        ax = _CACHE_BATCH_AXIS[_leaf_name(path)]
        shape = [1] * new.ndim
        shape[ax] = mask.shape[0]
        return jnp.where(mask.reshape(shape), new, old)

    return jax.tree_util.tree_map_with_path(sel, cache_new, cache_old)


def _expand_slot(cache: Any):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: jnp.expand_dims(l, _CACHE_BATCH_AXIS[_leaf_name(p)]), cache
    )


def _squeeze_slot(cache: Any):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: jnp.squeeze(l, _CACHE_BATCH_AXIS[_leaf_name(p)]), cache
    )


def make_pool_decode_step(cfg: ModelConfig, mesh: Mesh):
    """Decode step with a *per-slot* cache length (continuous batching).

    ``pool_step(params, tokens [S], cache, lengths [S]) -> (logits [S,V],
    cache)`` — a ``vmap`` of the single-sequence decode step over the slot
    dimension, so every slot advances at its own position/write offset.
    Slot math is independent (vmap adds no cross-slot terms), which is what
    makes continuous batching bit-equal to solo decode per request.
    """
    rules = shd.make_rules(cfg, mesh, fsdp=_serve_needs_fsdp(cfg, mesh))

    def pool_step(params, tokens, cache, lengths):
        axes = cache_batch_axes(cache)

        def one(tok, cslot, length):
            logits, c2 = transformer.forward_decode(
                params, tok[None], _expand_slot(cslot), length, cfg
            )
            return logits[0], _squeeze_slot(c2)

        with shd.sharding_ctx(cfg, rules):
            return jax.vmap(one, in_axes=(0, axes, 0), out_axes=(0, axes))(
                tokens, cache, lengths
            )

    return pool_step, rules


def make_slot_prefill_step(cfg: ModelConfig, mesh: Mesh, max_prompt_len: int):
    """Offset-prefill into a live cache slot (slot recycling).

    ``slot_prefill(params, prompt [Lmax], plen, cache, slot) ->
    (last_logits [V], cache)``: the retired slot's cache slice is zeroed
    (recurrent SSM/conv state must not leak between requests; attention
    positions beyond the new length are masked anyway) and the decode step
    is scanned over the padded prompt, masking positions ``>= plen`` — one
    jitted dispatch per admission, shapes fixed by ``max_prompt_len``, so
    admission never recompiles.  The rest of the pool is untouched, so live
    slots keep decoding across admissions.
    """
    rules = shd.make_rules(cfg, mesh, fsdp=_serve_needs_fsdp(cfg, mesh))

    def slot_prefill(params, prompt, plen, cache, slot):
        axes = cache_batch_axes(cache)
        cslot = jax.tree_util.tree_map_with_path(
            lambda p, l: jnp.zeros_like(
                jax.lax.dynamic_index_in_dim(
                    l, slot, axis=_CACHE_BATCH_AXIS[_leaf_name(p)], keepdims=True
                )
            ),
            cache,
        )

        def body(carry, xs):
            c, last = carry
            tok, i = xs
            with shd.sharding_ctx(cfg, rules):
                logits, c2 = transformer.forward_decode(
                    params, tok[None], c, i, cfg
                )
            live = i < plen
            c = jax.tree.map(lambda a, b: jnp.where(live, a, b), c2, c)
            last = jnp.where(i == plen - 1, logits[0], last)
            return (c, last), None

        (cslot, last_logits), _ = jax.lax.scan(
            body,
            (cslot, jnp.zeros((cfg.vocab,), jnp.float32)),
            (prompt[:max_prompt_len], jnp.arange(max_prompt_len, dtype=jnp.int32)),
            unroll=1,
        )
        cache = jax.tree_util.tree_map_with_path(
            lambda p, l, s: jax.lax.dynamic_update_index_in_dim(
                l, jnp.squeeze(s, _CACHE_BATCH_AXIS[_leaf_name(p)]), slot,
                axis=_CACHE_BATCH_AXIS[_leaf_name(p)],
            ),
            cache, cslot,
        )
        return last_logits, cache

    return slot_prefill, rules


def make_prefill_step(cfg: ModelConfig, mesh: Mesh):
    """Prefill: full forward, returns last-position logits [B, V]."""
    rules = shd.make_rules(cfg, mesh, fsdp=_serve_needs_fsdp(cfg, mesh))

    def prefill_step(params, batch):
        with shd.sharding_ctx(cfg, rules):
            x, _ = transformer._embed_inputs(params, batch, cfg)
            x = shd.constrain(x.astype(dtype_of(cfg.compute_dtype)), "tokens")
            S = x.shape[1]
            pos = jnp.arange(S)[None, :]
            x, _ = transformer._run_stack(params, x, cfg, pos)
            from repro.models.layers import rmsnorm

            x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
            return transformer._logits(params, x, cfg)[:, 0]

    return prefill_step, rules
