"""Divisibility-aware sharding rules: logical axes -> mesh axes per arch.

Mesh axes: ``("pod", "data", "model")`` multi-pod or ``("data", "model")``
single-pod.  ``("pod","data")`` form the DP/FSDP domain, ``"model"`` is TP.

Per-arch decisions are *derived*, not hand-written:
- attention activations shard over heads iff ``n_heads % tp == 0`` (else the
  head dims stay replicated and TP lives in the flattened QKV projections +
  MLP; decode caches then shard sequence over "model");
- MoE expert dim shards over the FSDP axis iff ``n_experts %  fsdp == 0``
  (true EP, llama4: 16e/16) else experts replicate and d_ff shards over TP;
- every weight matmul dim shards only when divisible.

Models stay distribution-agnostic: they call :func:`constrain` with logical
axis names; an active :class:`ShardingContext` maps them to mesh axes (no-op
outside a context, e.g. unit tests).
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig

# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    dp_axes: tuple[str, ...]  # ("pod","data") or ("data",)
    fsdp_axis: Optional[str]  # weight/opt-state sharding over DP ("data")
    tp_axis: Optional[str]
    attn_heads_sharded: bool
    kv_heads_sharded: bool
    ep: bool  # expert dim over fsdp axis

    @property
    def tp(self) -> int:
        return self.mesh.shape[self.tp_axis] if self.tp_axis else 1

    @property
    def dp(self) -> int:
        out = 1
        for a in self.dp_axes:
            out *= self.mesh.shape[a]
        return out

    def fsdp_if(self, dim: int):
        if self.fsdp_axis and dim % self.mesh.shape[self.fsdp_axis] == 0:
            return self.fsdp_axis
        return None

    def tp_if(self, dim: int):
        if self.tp_axis is None:
            return None
        return self.tp_axis if dim % self.tp == 0 else None

    def batch_axes(self, batch_dim: int):
        """DP axes for a batch dim, or None when indivisible (e.g. B=1)."""
        if not self.dp_axes:
            return None
        return self.dp_axes if batch_dim % self.dp == 0 else None

    def manual_region(self) -> "ShardingRules":
        """Rules for code running *inside* a shard_map manual over the DP
        axes: batch dims are already local (no DP constraints allowed); TP
        constraints on the auto 'model' axis remain valid."""
        return dataclasses.replace(self, dp_axes=(), fsdp_axis=None)

    def full_manual_region(self) -> "ShardingRules":
        """Rules for a shard_map manual over *every* mesh axis (old-JAX
        fallback, where partial-manual lowering is unavailable): no
        constraint may mention any axis, so TP clears too."""
        return dataclasses.replace(
            self, dp_axes=(), fsdp_axis=None, tp_axis=None
        )


def make_rules(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    fsdp: bool = True,
    tp_axis: str = "model",
) -> ShardingRules:
    axes = list(mesh.axis_names)
    if tp_axis not in axes:
        tp_axis = None  # pure-DP mesh (e.g. elastic non-p2 groups)
    dp_axes = tuple(a for a in axes if a != tp_axis)
    fsdp_axis = "data" if (fsdp and "data" in axes) else None
    tp = mesh.shape[tp_axis] if tp_axis else 1
    heads_ok = tp_axis is not None and cfg.n_heads > 0 and cfg.n_heads % tp == 0
    kv_ok = tp_axis is not None and cfg.n_kv_heads > 0 and cfg.n_kv_heads % tp == 0
    ep = (
        cfg.n_experts > 0
        and fsdp_axis is not None
        and cfg.n_experts % mesh.shape[fsdp_axis] == 0
    )
    return ShardingRules(
        mesh=mesh,
        dp_axes=dp_axes,
        fsdp_axis=fsdp_axis,
        tp_axis=tp_axis,
        attn_heads_sharded=heads_ok,
        kv_heads_sharded=kv_ok,
        ep=ep,
    )


# ---------------------------------------------------------------------------
# Param specs (path-based; stacked leading dims get None)
# ---------------------------------------------------------------------------


def _leaf_spec(name: str, shape, cfg: ModelConfig, r: ShardingRules) -> P:
    """Spec for the logical (unstacked) trailing dims of a param leaf."""
    t, f = r.tp_if, r.fsdp_if

    def pad(spec_tail):
        lead = len(shape) - len(spec_tail)
        return P(*([None] * lead), *spec_tail)

    if name == "embed":
        return pad((t(shape[-2]), None))
    if name == "lm_head":
        return pad((None, t(shape[-1])))
    if name in ("patch_proj", "frame_proj", "router"):
        return pad((None, None))
    if name in ("wq", "wk", "wv"):
        return pad((f(shape[-2]), t(shape[-1])))
    if name in ("bq", "bk", "bv"):
        return pad((t(shape[-1]),))
    if name == "wo":
        return pad((t(shape[-2]), f(shape[-1])))
    if name in ("w1", "w3"):
        if len(shape) >= 3 and cfg.n_experts:  # [.., E, d, f]
            if r.ep:
                return pad((r.fsdp_axis, None, t(shape[-1])))
            return pad((None, f(shape[-2]), t(shape[-1])))
        return pad((f(shape[-2]), t(shape[-1])))
    if name == "w2":
        if len(shape) >= 3 and cfg.n_experts:  # [.., E, f, d]
            if r.ep:
                return pad((r.fsdp_axis, t(shape[-2]), None))
            return pad((None, t(shape[-2]), f(shape[-1])))
        return pad((t(shape[-2]), f(shape[-1])))
    # --- ssm ---
    if name == "in_proj":  # mamba1 [d, 2*di]; split at di is shard-aligned
        return pad((f(shape[-2]), t(shape[-1])))
    if name in ("in_z", "in_x"):
        return pad((f(shape[-2]), t(shape[-1])))
    if name in ("in_bc", "in_dt"):
        return pad((f(shape[-2]), None))
    if name == "x_proj":
        return pad((t(shape[-2]), None))
    if name == "dt_proj":
        return pad((None, t(shape[-1])))
    if name == "out_proj":
        return pad((t(shape[-2]), f(shape[-1])))
    if name == "A_log":
        if len(shape) >= 2 and shape[-1] == cfg.ssm_state:  # mamba1 [di, st]
            return pad((t(shape[-2]), None))
        return pad((None,))  # mamba2 [nh]
    if name in ("conv_w", "conv_b", "dt_bias", "D", "norm_w"):
        # conv weights/small vectors: replicate (mamba2 conv spans mixed dims)
        if name == "D" and len(shape) >= 1 and shape[-1] == cfg.d_inner:
            return pad((t(shape[-1]),))
        return P(*([None] * len(shape)))
    # norms and everything else: replicated
    return P(*([None] * len(shape)))


def param_specs(cfg: ModelConfig, rules: ShardingRules, params: Any):
    """PartitionSpec pytree matching ``params`` (arrays or ShapeDtypeStructs)."""

    def spec_for(path, leaf):
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        return _leaf_spec(name or "", leaf.shape, cfg, rules)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def param_shardings(cfg, rules, params):
    return jax.tree.map(
        lambda s: NamedSharding(rules.mesh, s), param_specs(cfg, rules, params)
    )


# ---------------------------------------------------------------------------
# Activation constraint context (used inside model code via `constrain`)
# ---------------------------------------------------------------------------

_ACTIVE: list["ShardingContext"] = []


@dataclasses.dataclass
class ShardingContext:
    cfg: ModelConfig
    rules: ShardingRules

    def spec_for(self, kind: str, x) -> Optional[P]:
        r = self.rules
        if kind == "tokens":  # [B, S, d]
            return P(r.batch_axes(x.shape[0]), None, None)
        if kind == "mb_batch":  # [mb, B_mb, ...]: shard the per-microbatch batch
            return P(None, r.batch_axes(x.shape[1]), *([None] * (x.ndim - 2)))
        if kind == "q":  # [B, S, H, hd]
            h = r.tp_axis if r.attn_heads_sharded else None
            return P(r.batch_axes(x.shape[0]), None, h, None)
        if kind in ("k", "v"):  # [B, S, KV(_eff), hd] — divisibility on the
            # actual (possibly kv-repeated) head count
            h = r.tp_if(x.shape[2]) if r.attn_heads_sharded else None
            return P(r.batch_axes(x.shape[0]), None, h, None)
        if kind in ("cache_k", "cache_v"):  # [B, W, KV, hd]
            if r.kv_heads_sharded:
                return P(r.batch_axes(x.shape[0]), None, r.tp_axis, None)
            return P(r.batch_axes(x.shape[0]), r.tp_axis, None, None)
        if kind == "ffn":  # [B, S, f]
            return P(r.batch_axes(x.shape[0]), None, r.tp_if(x.shape[-1]))
        if kind == "expert_buf":  # [G, E, C, d]: groups over DP
            return P(r.batch_axes(x.shape[0]), None, None, None)
        if kind == "expert_buf_ep":  # [G, E, C, d]: experts over the EP axis.
            # Resharding expert_buf -> expert_buf_ep is exactly the token
            # all_to_all of true expert parallelism: tokens travel to the
            # expert-owning shards and the (huge) expert weights never move.
            if r.ep and x.shape[1] % r.mesh.shape[r.fsdp_axis] == 0:
                return P(None, r.fsdp_axis, None, None)
            return P(r.batch_axes(x.shape[0]), None, None, None)
        if kind == "ssm_inner":  # [B, S, di] or [B, di, ...]
            return P(r.batch_axes(x.shape[0]), None, r.tp_if(x.shape[2]) if x.ndim > 2 else None)
        if kind == "logits":  # [B, S, V]
            return P(r.batch_axes(x.shape[0]), None, r.tp_if(x.shape[-1]))
        return None


@contextlib.contextmanager
def sharding_ctx(cfg: ModelConfig, rules: ShardingRules):
    ctx = ShardingContext(cfg, rules)
    _ACTIVE.append(ctx)
    try:
        yield ctx
    finally:
        _ACTIVE.pop()


def kv_repeat_factor(H: int, KV: int) -> int:
    """Smallest factor r such that KV*r divides the TP axis cleanly (and r
    divides H/KV), enabling head-sharded GQA when KV < tp.  The (KV, rep)
    grouped reshape otherwise forces GSPMD to replicate attention
    intermediates (a multi-GB transient at 32k prefill)."""
    if not _ACTIVE:
        return 1
    r = _ACTIVE[-1].rules
    if r.tp_axis is None or H == 0 or H % r.tp:
        return 1
    if KV % r.tp == 0:
        return 1
    rep = H // KV
    for f in range(2, rep + 1):
        if rep % f == 0 and (KV * f) % r.tp == 0:
            return f
    return 1


def constrain(x, kind: str):
    """Apply a with_sharding_constraint if a ShardingContext is active."""
    if not _ACTIVE:
        return x
    ctx = _ACTIVE[-1]
    spec = ctx.spec_for(kind, x)
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(ctx.rules.mesh, spec)
        )
    except (TypeError, ValueError):
        # ValueError: indivisible shape for this spec — leave to GSPMD.
        # TypeError: eager (op-by-op) execution outside jit, where the
        # constraint is a no-op hint anyway (dispatch-regime benchmarks).
        return x
