"""Grad-sync strategy ``gspmd``: pure pjit baseline.

Params FSDP+TP sharded; XLA inserts the DP all-reduce in backward.  The
ConvergenceMonitor still advances the paper's staged MRD detection — one
scalar ppermute per step inside a tiny shard_map over the DP axes.

``tcfg.overlap`` is a no-op here: there is no explicit bucketed gradient
path to reorder — XLA's latency-hiding scheduler already interleaves its
own all-reduces with backward compute (DESIGN.md S16).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.distributed import sharding as shd
from repro.distributed.gradsync import common, register, register_resize
from repro.distributed.gradsync.common import TrainConfig
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.layers import dtype_of
from repro.optim import optimizer as opt_lib


@register("gspmd")
def make(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig):
    """Returns (jitted step, init_state_fn, state_shardings_fn, rules)."""
    rules = shd.make_rules(cfg, mesh, fsdp=tcfg.fsdp)
    remat_policy = common.REMAT_POLICIES[tcfg.remat]
    pdt = dtype_of(cfg.param_dtype)
    monitor = common.build_monitor(tcfg, rules)
    dp = rules.dp

    def init_state(key):
        params = transformer.init_params(cfg, key)
        state = {
            "params": params,
            "opt": opt_lib.init_opt_state(params),
            "step": jnp.zeros((), jnp.int32),
        }
        if monitor is not None:
            state["monitor"] = common.monitor_rows_init(monitor, dp)
        return state

    def state_specs(state):
        pspecs = shd.param_specs(cfg, rules, state["params"])
        specs = {
            "params": pspecs,
            "opt": {
                "master": pspecs,
                "mu": pspecs,
                "nu": pspecs,
            },
            "step": P(),
        }
        if monitor is not None:
            specs["monitor"] = jax.tree.map(
                lambda x: P(rules.dp_axes), state["monitor"]
            )
        return specs

    def train_step(state, batch):
        with shd.sharding_ctx(cfg, rules):
            grads, loss, metrics = common.microbatched_grads(
                state["params"], batch, cfg, remat_policy, tcfg.microbatches
            )
        grads, gnorm = opt_lib.clip_by_global_norm(grads, tcfg.optimizer.grad_clip)
        params, opt = opt_lib.apply_update(
            grads, state["opt"], tcfg.optimizer, state["step"], pdt
        )
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        out_metrics = {"loss": loss, "grad_norm": gnorm}

        if monitor is not None:
            # per-DP-shard local loss feeds the paper's staged detection
            def mon_fn(mon_st, per_ex, step):
                return common.local_monitor_tick(
                    monitor, mon_st, per_ex.mean(), step
                )

            # per_example is [B/microbatches]; when that no longer divides
            # the DP extent (large mb on the multi-pod mesh), feed it
            # replicated — each worker then monitors the same global mean,
            # which stays sound (the staged reduction just becomes uniform).
            pe_spec = P(rules.batch_axes(metrics["per_example"].shape[0]))
            mon_new, done, val = compat.shard_map(
                mon_fn,
                mesh=mesh,
                in_specs=(
                    jax.tree.map(lambda _: P(rules.dp_axes), state["monitor"]),
                    pe_spec,
                    P(),
                ),
                out_specs=(
                    jax.tree.map(lambda _: P(rules.dp_axes), state["monitor"]),
                    P(rules.dp_axes),
                    P(rules.dp_axes),
                ),
                axis_names=set(rules.dp_axes),
                check_vma=False,
            )(state["monitor"], metrics["per_example"], state["step"])
            new_state["monitor"] = mon_new
            out_metrics["converged"] = done[0]
            out_metrics["monitor_value"] = val[0]
        return new_state, out_metrics

    return train_step, init_state, state_specs, rules


@register_resize("gspmd")
def resize(cfg, tcfg, old_mesh, new_mesh, state, keep):
    """Elastic resize: params/opt are mesh-shape-independent global arrays
    (XLA re-partitions them under the new mesh's shardings); only the
    per-DP-rank monitor rows need re-laying-out."""
    new_state = dict(state)
    if "monitor" in state:
        rules_n = shd.make_rules(cfg, new_mesh, fsdp=tcfg.fsdp)
        new_state["monitor"] = common.monitor_rows_migrate(
            tcfg, rules_n, state["monitor"], keep
        )
    return new_state
