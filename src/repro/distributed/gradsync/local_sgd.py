"""Grad-sync strategy ``local_sgd``: bounded-staleness local SGD
(asynchronous-iterations-inspired; DESIGN.md S9).

Each DP worker trains its own replica with purely local gradients for
``local_sync_every`` steps, then replicas are averaged by the paper's
collectives (one chained Rabenseifner RS+AG plan over the flat vector).
Stragglers never block intermediate steps; the staleness bound plays the
role of the paper's bounded retards.  Per-replica state costs dp x the
replicated-params memory — pair with TP for larger models.

``tcfg.overlap`` is a no-op here: gradients never cross the DP axes
(only the periodic param average does), so there is no per-step bucketed
reduction to overlap with the backward (DESIGN.md S16).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.collectives import plans
from repro.distributed import sharding as shd
from repro.distributed.gradsync import common, register, register_resize
from repro.distributed.gradsync.common import TrainConfig
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.layers import dtype_of
from repro.optim import optimizer as opt_lib


@register("local_sgd")
def make(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig):
    rules = shd.make_rules(cfg, mesh, fsdp=False)
    remat_policy = common.REMAT_POLICIES[tcfg.remat]
    pdt = dtype_of(cfg.param_dtype)
    executor = common.resolve_executor(tcfg)
    dp_axes = rules.dp_axes
    dp = rules.dp
    H = max(tcfg.local_sync_every, 1)

    def init_state(key):
        params = transformer.init_params(cfg, key)
        rep = lambda x: jnp.broadcast_to(x[None], (dp,) + x.shape)
        return {
            "params": jax.tree.map(rep, params),
            "opt": jax.tree.map(rep, opt_lib.init_opt_state(params)),
            "step": jnp.zeros((), jnp.int32),
        }

    def state_specs(state):
        dpP_tree = lambda t: jax.tree.map(lambda _: P(dp_axes), t)
        return {
            "params": dpP_tree(state["params"]),
            "opt": dpP_tree(state["opt"]),
            "step": P(),
        }

    def train_step(state, batch):
        def local_step(params_s, opt_s, step, local_batch):
            params = jax.tree.map(lambda x: x[0], params_s)
            opt = jax.tree.map(lambda x: x[0], opt_s)
            with shd.sharding_ctx(cfg, common.manual_rules(rules)):
                grads, loss, metrics = common.microbatched_grads(
                    params, local_batch, cfg, remat_policy, tcfg.microbatches
                )
            grads, gnorm = opt_lib.clip_by_global_norm(grads, tcfg.optimizer.grad_clip)
            params, opt = opt_lib.apply_update(
                grads, opt, tcfg.optimizer, step, pdt
            )

            def sync(ps):
                # the paper's collectives: average the replicas across the
                # whole DP domain with a chained RS+AG plan, pipelined over
                # size-capped param buckets (DESIGN.md S10)
                avg = plans.tree_allreduce(
                    jax.tree.map(lambda x: x.astype(jnp.float32), ps),
                    schedule="rabenseifner",
                    axes=dp_axes,
                    executor=executor,
                    bucket_bytes=tcfg.bucket_bytes,
                )
                return jax.tree.map(
                    lambda a, b: (a / dp).astype(b.dtype), avg, ps
                )

            do_sync = (step + 1) % H == 0
            params = jax.lax.cond(do_sync, sync, lambda q: q, params)
            add1 = lambda t: jax.tree.map(lambda x: x[None], t)
            return add1(params), add1(opt), loss[None], gnorm[None]

        dpP = P(dp_axes)
        dpP_tree = lambda t: jax.tree.map(lambda _: dpP, t)
        bspecs = common.batch_specs(cfg, rules, batch)
        params_s, opt_s, loss, gnorm = compat.shard_map(
            local_step,
            mesh=mesh,
            in_specs=(dpP_tree(state["params"]), dpP_tree(state["opt"]), P(), bspecs),
            out_specs=(dpP_tree(state["params"]), dpP_tree(state["opt"]), dpP, dpP),
            axis_names=set(dp_axes),
            check_vma=False,
        )(state["params"], state["opt"], state["step"], batch)
        new_state = {"params": params_s, "opt": opt_s, "step": state["step"] + 1}
        return new_state, {
            "loss": loss.mean(),
            "grad_norm": gnorm.mean(),
            "converged": jnp.zeros((), jnp.bool_),
            "monitor_value": jnp.zeros((), jnp.float32),
        }

    return train_step, init_state, state_specs, rules


@register_resize("local_sgd")
def resize(cfg, tcfg, old_mesh, new_mesh, state, keep):
    """Elastic resize: params/opt are dp-major replica rows.  Surviving
    replicas follow their workers; a joiner clones the first survivor's
    replica (it has no local history of its own — the next
    ``local_sync_every`` boundary folds it into the average anyway)."""
    src = next(k for k in keep if k is not None)

    def sel(rows):
        return jnp.stack([rows[k if k is not None else src] for k in keep])

    new_state = dict(state)
    new_state["params"] = jax.tree.map(sel, state["params"])
    new_state["opt"] = jax.tree.map(sel, state["opt"])
    return new_state
