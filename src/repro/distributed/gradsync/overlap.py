"""Ready-bucket grad-sync overlap (DESIGN.md S16).

The bucketed MRD engine (DESIGN.md S10) pipelines collective stages
*across* buckets, but every strategy still waits for the **full**
backward before packing the first bucket — the classic DDP stall.  This
module extends the stage-major pipelining across the autodiff boundary:

1. :func:`segmented_grads` computes the backward as three manually
   composed VJPs over the model's natural reverse-topological readiness
   groups — **head** (``final_norm``/``lm_head``), **stack** (the
   scanned layer parameters), **embed** (``embed``/``patch_proj``/
   ``frame_proj``) — and *yields* each group's gradients as they
   complete.  Scanned layer leaves are stacked ``[L, ...]`` arrays whose
   gradients only exist once the whole backward scan finishes, so
   top-level-key granularity is the finest readiness the program
   structure admits without changing leaf shapes (which would change
   bucket layouts and break the compressed path's bit-exactness).
2. :func:`drive` consumes that generator, packs each bucket the moment
   all of its slots' gradients exist (same :class:`BucketLayout` as the
   post-backward path — only the *issue order* changes, never element
   offsets), admits it into a
   :class:`repro.collectives.plans.BucketPipeline`, and advances every
   in-flight bucket one stage per readiness group — so the head bucket's
   MRD permutes are in flight while the (dominant) backward scan is
   still running.

Bit-exactness contract: per bucket the stage math is exactly
``run_buffers``'s and each stage touches only that bucket's arrays, so
the reduced buffers — and therefore params, optimizer moments, and the
EF residual — are **bit-identical** to the post-backward bucketed path
for every transform, extent, and dtype.  The differential suite
(tests/test_overlap_differential.py) enforces this.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.collectives import buckets, plans
from repro.distributed import sharding as shd
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.layers import dtype_of

# Readiness groups in backward (reverse-topological) order.  Any top-level
# param key not named here is part of the layer stack (group 1) — every
# family's stacked keys (layers | local_layers+global_layers |
# mamba_groups+shared_attn) land there without per-family tables.
HEAD_KEYS = frozenset({"final_norm", "lm_head"})
EMBED_KEYS = frozenset({"embed", "patch_proj", "frame_proj"})
GROUP_NAMES = ("head", "stack", "embed")
N_GROUPS = len(GROUP_NAMES)


def group_of_key(key: str) -> int:
    if key in HEAD_KEYS:
        return 0
    if key in EMBED_KEYS:
        return 2
    return 1


def _split_params(params):
    """Partition the top-level param dict into (head, stack, embed)."""
    groups: tuple[dict, dict, dict] = ({}, {}, {})
    for k, v in params.items():
        groups[group_of_key(k)][k] = v
    return groups


def key_offsets(pshape) -> dict[str, int]:
    """Global ``jax.tree.leaves`` index of each top-level key's first leaf.

    jax flattens dicts in sorted-key order and subtree leaves contiguously,
    so ``leaves(tree)[off[k] : off[k] + n_k] == leaves(tree[k])``.
    """
    out, off = {}, 0
    for k in sorted(pshape.keys()):
        out[k] = off
        off += len(jax.tree.leaves(pshape[k]))
    return out


def leaf_groups(pshape) -> list[int]:
    """Per-leaf readiness group index, in ``jax.tree.leaves`` order."""
    out: list[int] = []
    for k in sorted(pshape.keys()):
        out.extend([group_of_key(k)] * len(jax.tree.leaves(pshape[k])))
    return out


def bucket_groups(layout: buckets.BucketLayout, lgroups: list[int]) -> list[int]:
    """Readiness group per bucket: a bucket is packable once its *latest*
    slot's group has emitted."""
    return [max(lgroups[s.index] for s in b.slots) for b in layout.buckets]


def _label_offset(batch, cfg: ModelConfig) -> int:
    """Static mirror of :func:`transformer._embed_inputs`'s label_offset."""
    if cfg.frontend == "vision" and "patches" in batch:
        return batch["patches"].shape[1]
    return 0


def _one_batch_segments(params, batch, cfg: ModelConfig, remat_policy):
    """Segmented forward for ONE (micro)batch.

    Returns ``(loss, metrics, backward)`` where ``backward()`` generates
    ``(group_name, grad_piece)`` in readiness order — grad pieces are
    top-level-key dicts in the model's param dtype (cast to fp32 by the
    caller, mirroring ``common.microbatched_grads``).
    """
    ph, ps, pe = _split_params(params)
    cdt = dtype_of(cfg.compute_dtype)
    off = _label_offset(batch, cfg)
    tied = cfg.tie_embeddings and "embed" in pe

    def embed_fn(pe_):
        x, _ = transformer._embed_inputs(pe_, batch, cfg)
        return shd.constrain(x.astype(cdt), "tokens")

    x0, e_vjp = jax.vjp(embed_fn, pe)
    S = x0.shape[1]
    positions = jnp.arange(S)[None, :]

    def stack_fn(ps_, x):
        return transformer._run_stack(ps_, x, cfg, positions, remat_policy)

    (x1, aux), s_vjp = jax.vjp(stack_fn, ps, x0)

    if tied:
        # the tied output head reads params['embed'], which belongs to the
        # *embed* readiness group — take its head-side cotangent as a
        # separate VJP input and fold it into the embed-group gradient
        def head_fn(ph_, embed, x, a):
            return transformer._train_head(
                {**ph_, "embed": embed}, x, a, batch, cfg, off
            )

        loss, h_vjp, metrics = jax.vjp(
            head_fn, ph, pe["embed"], x1, aux, has_aux=True
        )
    else:

        def head_fn(ph_, x, a):
            return transformer._train_head(ph_, x, a, batch, cfg, off)

        loss, h_vjp, metrics = jax.vjp(head_fn, ph, x1, aux, has_aux=True)

    def backward():
        ct = jnp.ones_like(loss)
        if tied:
            gh, g_embed_head, ct_x1, ct_aux = h_vjp(ct)
        else:
            gh, ct_x1, ct_aux = h_vjp(ct)
        yield "head", gh
        gs, ct_x0 = s_vjp((ct_x1, ct_aux))
        yield "stack", gs
        (ge,) = e_vjp(ct_x0)
        if tied:
            # the two cotangent contributions of a fanned-out primal are
            # summed — one commutative add, bitwise identical to the
            # composite backward's accumulation
            ge = dict(ge)
            ge["embed"] = ge["embed"] + g_embed_head
        yield "embed", ge

    return loss, metrics, backward


def segmented_grads(params, batch, cfg: ModelConfig, remat_policy, microbatches: int):
    """Generator form of :func:`common.microbatched_grads`.

    First yields ``(mean_loss, metrics_last)``; then ``(group_name,
    grads_fp32_piece)`` for head → stack → embed, each piece already
    microbatch-accumulated and averaged.  Joint output is bit-identical
    to ``common.microbatched_grads`` on the same inputs: for
    ``microbatches > 1`` the first M-1 microbatches run through the exact
    same fp32 accumulation scan and only the last microbatch's backward
    is segmented, preserving the accumulation association
    ``(((g_0+g_1)+...)+g_{M-1}) / M``.
    """
    if microbatches == 1:
        loss, metrics, backward = _one_batch_segments(
            params, batch, cfg, remat_policy
        )
        yield loss, metrics
        for name, piece in backward():
            yield name, jax.tree.map(lambda g: g.astype(jnp.float32), piece)
        return

    def reshape_mb(x):
        return x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:])

    mbs = jax.tree.map(lambda x: shd.constrain(reshape_mb(x), "mb_batch"), batch)
    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def loss_fn(p, mb):
        return transformer.forward_train(p, mb, cfg, remat_policy)

    def body(carry, mb):
        g_acc, loss_acc = carry
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
        return (g_acc, loss_acc + loss), metrics

    head_mbs = jax.tree.map(lambda x: x[:-1], mbs)
    (g_part, loss_part), _ = jax.lax.scan(
        body, (g0, 0.0), head_mbs, unroll=cfg.scan_unroll
    )
    mb_last = jax.tree.map(lambda x: x[-1], mbs)
    loss_last, metrics, backward = _one_batch_segments(
        params, mb_last, cfg, remat_policy
    )
    yield (loss_part + loss_last) / microbatches, metrics
    for name, piece in backward():
        acc = {k: g_part[k] for k in piece}
        yield name, jax.tree.map(
            lambda a, b: (a + b.astype(jnp.float32)) / microbatches, acc, piece
        )


def drive(
    emitter,
    layout: buckets.BucketLayout,
    koffsets: dict[str, int],
    bgroups: list[int],
    *,
    plan: plans.CollectivePlan,
    wire=None,
):
    """Consume a :func:`segmented_grads` generator, admitting each bucket
    into ``plan``'s :class:`BucketPipeline` the moment its readiness group
    emits, and advancing all in-flight buckets one stage per group.

    ``wire(i, buf) -> (wire_buf, aux)`` optionally maps a packed fp32
    bucket to its wire payload (the EF-SGD round-trip hook); ``aux`` per
    bucket is collected and returned.  Returns ``(loss, metrics,
    reduced_bufs, wire_aux)`` with buffers in bucket order.
    """
    loss, metrics = next(emitter)
    leaves: list = [None] * layout.n_leaves
    pipe = plan.pipeline()
    wire_aux: list = [None] * len(layout.buckets)
    emitted = 0
    for gi, (_name, piece) in enumerate(emitter):
        for k in sorted(piece.keys()):
            base = koffsets[k]
            for j, leaf in enumerate(jax.tree.leaves(piece[k])):
                leaves[base + j] = leaf
        for bi, bg in enumerate(bgroups):
            if bg == gi:
                buf = buckets.pack_bucket(leaves, layout, bi)
                if wire is not None:
                    buf, wire_aux[bi] = wire(bi, buf)
                pipe.admit(bi, buf)
                emitted += 1
        # one stage per in-flight bucket, issued before the next backward
        # segment traces — the overlap point
        pipe.advance()
    if emitted != len(layout.buckets):
        raise ValueError(
            f"emitted {emitted} of {len(layout.buckets)} buckets — "
            "readiness groups do not cover the layout"
        )
    done = pipe.drain()
    bufs = [done[i] for i in range(len(layout.buckets))]
    return loss, metrics, bufs, wire_aux
