"""Grad-sync strategy ``compressed``: mrd_zero1 with int8-quantized
reduce-scatter payloads (the ``int8`` payload transform; wire bytes / 4 vs
fp32).  On TPU the per-stage dequant-accumulate runs through the
``mrd_combine`` Pallas kernel via the ``device_fused`` executor.  Like
``mrd_zero1``, the gradient is bucketed and the RS/AG stages pipeline
across buckets (DESIGN.md S10); buckets stay 256-block aligned so the
quantizer never straddles a bucket boundary.

Quantization noise is bounded per stage (see
``repro.collectives.transforms``) but uncompensated — error feedback
(EF-SGD residual carry across steps) is not implemented yet.
"""

from __future__ import annotations

from jax.sharding import Mesh

from repro.distributed.gradsync import register
from repro.distributed.gradsync.common import TrainConfig
from repro.distributed.gradsync.mrd_zero1 import make_zero1
from repro.models.config import ModelConfig


@register("compressed")
def make(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig):
    return make_zero1(cfg, mesh, tcfg, transform="int8")
