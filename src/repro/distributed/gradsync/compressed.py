"""Grad-sync strategy ``compressed``: mrd_zero1 with int8-quantized
reduce-scatter payloads (the ``int8`` payload transform; wire bytes / 4 vs
fp32).  On TPU the per-stage dequant-accumulate runs through the
``mrd_combine`` Pallas kernel via the ``device_fused`` executor.  Like
``mrd_zero1``, the gradient is bucketed and the RS/AG stages pipeline
across buckets (DESIGN.md S10); buckets stay 256-block aligned so the
quantizer never straddles a bucket boundary.  ``tcfg.overlap`` issues
each bucket (EF round-trip included) as its backward segment completes —
the int8 block grid is keyed to offsets *within* a bucket, which the
overlap never changes, so results stay bit-identical (DESIGN.md S16).

Quantization noise is bounded per stage (see
``repro.collectives.transforms``) and — with ``tcfg.error_feedback``, the
default — first-hop compensated: each rank carries the EF-SGD residual of
what it sent and folds it into the next step's gradient
(:func:`repro.collectives.transforms.ef_roundtrip`), so coordinates
persistently below the quantization step are delayed rather than dropped.
"""

from __future__ import annotations

from jax.sharding import Mesh

from repro.distributed.gradsync import register
from repro.distributed.gradsync.common import TrainConfig
from repro.distributed.gradsync.mrd_zero1 import make_zero1
from repro.models.config import ModelConfig


@register("compressed")
def make(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig):
    return make_zero1(cfg, mesh, tcfg, transform="int8")
