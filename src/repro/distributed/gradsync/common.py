"""Shared building blocks for grad-sync strategies (DESIGN.md S2).

Every strategy module composes the same pieces: microbatched gradient
accumulation, remat policy, the paper's ConvergenceMonitor (advanced one
MRD stage per train step — one scalar ppermute, never blocking), and the
optimizer.  Strategies differ only in *how the gradient crosses the DP
axes and where the optimizer state lives* — that difference is what each
``repro.distributed.gradsync`` module encodes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.asynchrony import ConvergenceMonitor
from repro.distributed import sharding as shd
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.optim import optimizer as opt_lib

REMAT_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    remat: str = "full"  # 'none' | 'full' | 'dots'
    # any name in repro.distributed.gradsync.GRAD_SYNC ('gspmd', 'mrd_paper',
    # 'mrd_leaf', 'mrd_zero1', 'compressed', 'local_sgd', ...)
    grad_sync: str = "gspmd"
    local_sync_every: int = 8  # local_sgd: MRD param-average period (staleness bound)
    monitor: bool = True
    # any repro.asynchrony.DETECTION_PROTOCOLS entry with a training-loop
    # policy: 'inexact' (Alg.1) | 'exact' (Alg.2) | 'interval' (windowed)
    monitor_mode: str = "inexact"
    monitor_threshold: float = 1e-3
    # EF-SGD error feedback for quantized grad sync ('compressed'): carry the
    # per-shard quantization residual and fold it into the next step's
    # gradient.  Ignored by identity-transform modes.
    error_feedback: bool = True
    optimizer: opt_lib.OptimizerConfig = dataclasses.field(
        default_factory=opt_lib.OptimizerConfig
    )
    fsdp: bool = True  # weight sharding over "data" (gspmd mode)
    # collectives executor for the MRD strategies: None = auto ('device';
    # 'device_fused' routes the int8 combine through the Pallas kernel)
    collective_executor: Optional[str] = None
    # cap on each dtype-homogeneous gradient bucket for the pipelined
    # collective engine (repro.collectives.buckets, DESIGN.md S10);
    # None = one unbounded bucket per dtype
    bucket_bytes: Optional[int] = 32 * 2**20
    # ready-bucket grad-sync overlap (DESIGN.md S16): issue each gradient
    # bucket's MRD stages as its backward segment completes instead of
    # after the full backward.  Bit-identical results by construction
    # (same BucketLayout, only issue order changes).  Honored by the
    # gradient-scale modes (mrd_leaf, mrd_paper, mrd_zero1, compressed);
    # gspmd/local_sgd have no bucketed gradient path and ignore it.
    overlap: bool = False


def manual_rules(rules: shd.ShardingRules) -> shd.ShardingRules:
    """Rules for a strategy's shard_map body: TP constraints stay live when
    the runtime supports partial-manual shard_map, otherwise everything is
    manual and constraints must clear."""
    from repro import compat

    if compat.partial_manual_shard_map():
        return rules.manual_region()
    return rules.full_manual_region()


def resolve_executor(tcfg: TrainConfig, *, compressed: bool = False) -> str:
    if tcfg.collective_executor is not None:
        return tcfg.collective_executor
    if compressed and jax.default_backend() == "tpu":
        return "device_fused"
    return "device"


# ---------------------------------------------------------------------------
# Gradients
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, rules: shd.ShardingRules, batch: Any):
    """PartitionSpecs for a train batch pytree (batch dim over DP axes)."""

    def spec(leaf):
        b = rules.batch_axes(leaf.shape[0])
        return P(b, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(spec, batch)


def microbatched_grads(params, batch, cfg, remat_policy, microbatches: int):
    """Gradient accumulation over microbatches via lax.scan (fp32 accum).
    Returns (grads_fp32, mean_loss, metrics_last)."""

    def loss_fn(p, mb):
        return transformer.forward_train(p, mb, cfg, remat_policy)

    if microbatches == 1:
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return jax.tree.map(lambda x: x.astype(jnp.float32), g), loss, metrics

    def reshape_mb(x):
        return x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:])

    mbs = jax.tree.map(
        lambda x: shd.constrain(reshape_mb(x), "mb_batch"), batch
    )
    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(carry, mb):
        g_acc, loss_acc = carry
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
        return (g_acc, loss_acc + loss), metrics

    (g, loss_sum), metrics = jax.lax.scan(body, (g0, 0.0), mbs, unroll=cfg.scan_unroll)
    g = jax.tree.map(lambda x: x / microbatches, g)
    metrics = jax.tree.map(lambda x: x[-1], metrics)
    return g, loss_sum / microbatches, metrics


# ---------------------------------------------------------------------------
# Monitor wiring (identical across strategies)
# ---------------------------------------------------------------------------


def build_monitor(tcfg: TrainConfig, rules: shd.ShardingRules):
    """The paper's staged detector over the DP domain, or None."""
    if not tcfg.monitor:
        return None
    axes = rules.dp_axes
    return ConvergenceMonitor(
        axis_name=axes if len(axes) > 1 else axes[0],
        threshold=tcfg.monitor_threshold,
        mode=tcfg.monitor_mode,
    )


def monitor_rows_init(monitor: Optional[ConvergenceMonitor], dp: int):
    """Replicated-then-sharded monitor state: one row per DP rank."""
    mon = monitor.init(varying=False)
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (dp,) + x.shape), mon)


def monitor_rows_migrate(tcfg: TrainConfig, rules, rows, keep):
    """Elastic resize of the ``state['monitor']`` rows (or None pass-through):
    surviving rows follow their workers, joiners get fresh rows, and the
    staged reduction restarts (see
    :meth:`repro.asynchrony.ConvergenceMonitor.migrate_rows`)."""
    monitor = build_monitor(tcfg, rules)
    if monitor is None or rows is None:
        return rows
    return monitor.migrate_rows(rows, keep)


def local_monitor_tick(monitor, mon_state, metric, step):
    """Inside shard_map: advance this rank's monitor row ([1, ...] leaves).

    Returns (new rows, done [1], value [1]); zeros when monitor is None.
    """
    if monitor is None:
        return mon_state, jnp.zeros((1,), jnp.bool_), jnp.zeros((1,), jnp.float32)
    local = jax.tree.map(lambda x: x[0], mon_state)
    new, done, val = monitor.step(local, metric, step)
    return jax.tree.map(lambda x: x[None], new), done[None], val[None]
