"""Grad-sync strategy registry (DESIGN.md S2).

Each strategy is one module registering a builder
``make(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig) ->
(train_step, init_state, state_specs, rules)`` under its mode name.
Adding a sync mode is a one-file change: drop a module in this package,
call :func:`register`, import it below.

- ``gspmd``: pure pjit.  Params FSDP+TP sharded; XLA inserts the DP
  all-reduce in backward.  The baseline every MRD mode is measured against.
- ``mrd_paper``: the paper's recursive-doubling Allreduce of the full flat
  gradient (paper S2) + replicated optimizer.
- ``mrd_leaf``: the butterfly per gradient leaf (stays TP-sharded; no
  flatten/reshard collectives).
- ``mrd_zero1``: the butterfly as a ZeRO-1 distributed optimizer — chained
  recursive-halving reduce-scatter over the DP axes, shard-local AdamW,
  chained all-gather of the bf16 params.  Non-power-of-two DP groups (the
  paper's headline case) work natively; elasticity uses exactly this.
- ``compressed``: mrd_zero1 with int8-quantized wire payloads (+ the
  ``device_fused`` Pallas-combine executor on TPU); EF-SGD error feedback
  (on by default, ``tcfg.error_feedback``) carries the quantization
  residual across steps.
- ``local_sgd``: bounded-staleness local SGD; replicas averaged by the
  paper's collectives every ``local_sync_every`` steps (DESIGN.md S9).
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence

GRAD_SYNC: Dict[str, Callable] = {}

# Elastic resize hooks (DESIGN.md S12): per-strategy state migration
# ``hook(cfg, tcfg, old_mesh, new_mesh, state, keep) -> new_state`` where
# ``keep[i]`` is the old flattened-DP rank now at new rank ``i`` (None =
# freshly joined worker).  The returned state is host-side (unplaced);
# the elastic controller device_puts it onto the new mesh's shardings.
GRAD_SYNC_RESIZE: Dict[str, Callable] = {}


def register(name: str):
    """Decorator: register a strategy builder under ``name``."""

    def deco(fn: Callable) -> Callable:
        GRAD_SYNC[name] = fn
        return fn

    return deco


def register_resize(name: str):
    """Decorator: register a strategy's elastic resize hook under ``name``."""

    def deco(fn: Callable) -> Callable:
        GRAD_SYNC_RESIZE[name] = fn
        return fn

    return deco


def get(name: str) -> Callable:
    try:
        return GRAD_SYNC[name]
    except KeyError:
        raise ValueError(
            f"unknown grad_sync {name!r}; registered: {sorted(GRAD_SYNC)}"
        ) from None


def available() -> list[str]:
    return sorted(GRAD_SYNC)


def make_train_step(cfg, mesh, tcfg):
    """Build (train_step, init_state, state_specs, rules) for
    ``tcfg.grad_sync`` by composing the registered strategy with the
    monitor + optimizer wiring in ``common``."""
    return get(tcfg.grad_sync)(cfg, mesh, tcfg)


def make_step_factory(cfg, tcfg) -> Callable:
    """``mesh -> (train_step, init_state, state_specs, rules)`` — the shape
    elastic/fault-tolerant controllers rebuild on every topology change."""
    return lambda mesh: make_train_step(cfg, mesh, tcfg)


def migrate_state(
    cfg, tcfg, old_mesh, new_mesh, state, keep: Sequence[Optional[int]]
):
    """Migrate a live train state across a mesh resize **in place** — no
    checkpoint round-trip — by dispatching to ``tcfg.grad_sync``'s
    registered resize hook.

    ``keep`` maps new flattened-DP ranks to old ones (None = joined
    worker).  Every hook re-lays-out whatever its strategy shards over DP
    (the ZeRO-1 master/moment rows, the EF residual carry, monitor rows)
    and leaves replicated leaves untouched; the result is host-side
    arrays ready for ``jax.device_put`` onto the new mesh's shardings.
    """
    name = tcfg.grad_sync
    if name not in GRAD_SYNC_RESIZE:
        raise ValueError(
            f"grad_sync {name!r} has no registered resize hook; "
            f"registered: {sorted(GRAD_SYNC_RESIZE)}"
        )
    return GRAD_SYNC_RESIZE[name](cfg, tcfg, old_mesh, new_mesh, state, keep)


# populate the registry (import order = doc order)
from repro.distributed.gradsync import (  # noqa: E402,F401
    compressed,
    gspmd,
    local_sgd,
    mrd_leaf,
    mrd_paper,
    mrd_zero1,
)
