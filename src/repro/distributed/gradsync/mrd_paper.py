"""Grad-sync strategy ``mrd_paper``: the paper-faithful collective.

Pure modified-recursive-doubling Allreduce of the full fp32 gradient
(paper S2) chained over the DP axes + a replicated optimizer; no RS/AG,
no optimizer-state sharding.  The gradient travels in size-capped buckets
executed stage-major (``repro.collectives.buckets`` +
:meth:`repro.collectives.plans.CollectivePlan.run_buffers`, DESIGN.md
S10) rather than as one monolithic flat vector; with ``tcfg.overlap``
each bucket's butterfly is issued as its backward segment completes
(ready-bucket overlap, DESIGN.md S16 — bit-identical either way).  This
is the reference the beyond-paper modes (``mrd_zero1``, ``compressed``)
are measured against.
"""

from __future__ import annotations

from jax.sharding import Mesh

from repro.distributed.gradsync import register
from repro.distributed.gradsync.common import TrainConfig
from repro.distributed.gradsync.mrd_zero1 import make_zero1
from repro.models.config import ModelConfig


@register("mrd_paper")
def make(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig):
    return make_zero1(cfg, mesh, tcfg, paper_mode=True)
