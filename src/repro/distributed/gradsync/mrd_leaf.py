"""Grad-sync strategy ``mrd_leaf``: bucketed MRD butterfly gradient
allreduce with a tree-shaped optimizer (beyond-paper iteration on
``mrd_paper``).

Historically this mode ran one full schedule cycle *per gradient leaf*,
paying the per-message alpha cost once per tensor.  It now packs the
gradient tree into dtype-homogeneous, size-capped buckets and executes
the butterfly stage-major across them
(:meth:`repro.collectives.plans.CollectivePlan.run_bucketed`,
DESIGN.md S10) — leaf dtypes are preserved end-to-end and the per-leaf
loop is gone.  Trade-off vs the old per-leaf path: packing concatenates
leaves, so on partial-manual runtimes TP-sharded grads are gathered
over the auto "model" axis before the DP butterfly (the per-leaf path
moved 1/tp of each leaf with no reshard); tune ``bucket_bytes`` or
prefer ``mrd_zero1`` when TP resharding dominates.  Optimizer: fp32
tree, TP-sharded via param specs, DP-replicated (memory
~16 B/param / tp).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.collectives import buckets, plans
from repro.distributed import sharding as shd
from repro.distributed.gradsync import common, register, register_resize
from repro.distributed.gradsync import overlap as overlap_lib
from repro.distributed.gradsync.common import TrainConfig
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.layers import dtype_of
from repro.optim import optimizer as opt_lib


@register("mrd_leaf")
def make(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig):
    rules = shd.make_rules(cfg, mesh, fsdp=False)
    remat_policy = common.REMAT_POLICIES[tcfg.remat]
    pdt = dtype_of(cfg.param_dtype)
    executor = common.resolve_executor(tcfg)
    dp_axes = rules.dp_axes
    dp = rules.dp
    monitor = common.build_monitor(tcfg, rules)
    grad_ar = plans.allreduce_plan(
        schedule="mrd", axes=dp_axes, op="sum", executor=executor
    )
    if tcfg.overlap:
        # ready-bucket overlap (DESIGN.md S16): prebuild the same fp32
        # layout run_bucketed would derive from the gradient tree, so the
        # overlapped path is bit-identical by construction
        pshape = jax.eval_shape(
            lambda: transformer.init_params(cfg, jax.random.PRNGKey(0))
        )
        fp32 = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(tuple(l.shape), jnp.float32), pshape
        )
        layout = buckets.build_layout(
            fp32, bucket_bytes=tcfg.bucket_bytes, quantum=grad_ar.pad_quantum()
        )
        koffs = overlap_lib.key_offsets(pshape)
        bgroups = overlap_lib.bucket_groups(
            layout, overlap_lib.leaf_groups(pshape)
        )

    def init_state(key):
        params = transformer.init_params(cfg, key)
        state = {
            "params": params,
            "opt": opt_lib.init_opt_state(params),
            "step": jnp.zeros((), jnp.int32),
        }
        if monitor is not None:
            state["monitor"] = common.monitor_rows_init(monitor, dp)
        return state

    def state_specs(state):
        pspecs = shd.param_specs(cfg, rules, state["params"])
        specs = {
            "params": pspecs,
            "opt": {"master": pspecs, "mu": pspecs, "nu": pspecs},
            "step": P(),
        }
        if monitor is not None:
            specs["monitor"] = jax.tree.map(lambda _: P(dp_axes), state["monitor"])
        return specs

    def train_step(state, batch):
        def local_step(params, opt, step, mon_state, local_batch):
            if tcfg.overlap:
                # segmented backward, ready buckets issued mid-backward
                # through the same butterfly (DESIGN.md S16)
                with shd.sharding_ctx(cfg, common.manual_rules(rules)):
                    emitter = overlap_lib.segmented_grads(
                        params, local_batch, cfg, remat_policy,
                        tcfg.microbatches,
                    )
                    loss, metrics, red, _ = overlap_lib.drive(
                        emitter, layout, koffs, bgroups, plan=grad_ar
                    )
                grads = buckets.unpack(red, layout)
            else:
                with shd.sharding_ctx(cfg, common.manual_rules(rules)):
                    grads, loss, metrics = common.microbatched_grads(
                        params, local_batch, cfg, remat_policy, tcfg.microbatches
                    )
                # the paper's butterfly, pipelined over dtype-homogeneous
                # gradient buckets (stage-major; DESIGN.md S10)
                grads = grad_ar.run_bucketed(grads, bucket_bytes=tcfg.bucket_bytes)
            grads = jax.tree.map(lambda g: g / dp, grads)
            grads, gnorm = opt_lib.clip_by_global_norm(grads, tcfg.optimizer.grad_clip)
            params, opt = opt_lib.apply_update(
                grads, opt, tcfg.optimizer, step, pdt
            )
            mon_out, done, val = common.local_monitor_tick(
                monitor, mon_state, metrics["per_example"].mean(), step
            )
            return params, opt, mon_out, loss[None], gnorm[None], done, val

        dpP = P(dp_axes)
        bspecs = common.batch_specs(cfg, rules, batch)
        if monitor is not None:
            mon_state_in = state["monitor"]
            mon_spec = jax.tree.map(lambda _: dpP, state["monitor"])
        else:
            mon_state_in = jnp.zeros((dp, 1), jnp.float32)
            mon_spec = dpP
        rep = lambda t: jax.tree.map(lambda _: P(), t)
        out = compat.shard_map(
            local_step,
            mesh=mesh,
            in_specs=(rep(state["params"]), rep(state["opt"]), P(), mon_spec, bspecs),
            out_specs=(rep(state["params"]), rep(state["opt"]), mon_spec, dpP, dpP, dpP, dpP),
            axis_names=set(dp_axes),
            check_vma=False,
        )(state["params"], state["opt"], state["step"], mon_state_in, batch)
        params, opt, mon, loss, gnorm, done, val = out
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        if monitor is not None:
            new_state["monitor"] = mon
        return new_state, {
            "loss": loss.mean(),
            "grad_norm": gnorm[0],
            "converged": done[0],
            "monitor_value": val[0],
        }

    return train_step, init_state, state_specs, rules


@register_resize("mrd_leaf")
def resize(cfg, tcfg, old_mesh, new_mesh, state, keep):
    """Elastic resize: the tree-shaped optimizer is DP-replicated, so any
    survivor's copy is the state; only the monitor rows re-lay-out."""
    new_state = dict(state)
    if "monitor" in state:
        rules_n = shd.make_rules(cfg, new_mesh, fsdp=False)
        new_state["monitor"] = common.monitor_rows_migrate(
            tcfg, rules_n, state["monitor"], keep
        )
    return new_state
