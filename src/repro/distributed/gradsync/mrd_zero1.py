"""Grad-sync strategy ``mrd_zero1``: the paper's butterfly as a ZeRO-1
distributed optimizer (beyond-paper).

Inside ``shard_map`` (manual over the DP axes, auto over "model"): chained
recursive-halving **reduce-scatter** of the flat fp32 gradient over each DP
axis, shard-local AdamW on the fp32 master shard, then chained
recursive-doubling **all-gather** of the bf16 params.  Works for
non-power-of-two DP groups (the paper's headline case) — the elasticity
path uses exactly this.  Hierarchy is implicit: with mesh axes
("pod","data"), the chained RS/AG reduces inter-pod bytes by 1/p0(data).

All collectives run through :class:`repro.collectives.plans.CollectivePlan`;
``mrd_paper`` and ``compressed`` reuse this builder with a different
schedule/transform binding.
"""

from __future__ import annotations

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.collectives import plans
from repro.collectives.schedules import pivot
from repro.distributed import sharding as shd
from repro.distributed.gradsync import common, register
from repro.distributed.gradsync.common import TrainConfig
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.layers import dtype_of
from repro.optim import optimizer as opt_lib


def zero1_shard_len(n_params: int, mesh: Mesh, dp_axes, block: int = 256) -> tuple[int, int]:
    """(padded_total, shard_len) for the chained RS over dp_axes."""
    prod_p0 = 1
    for ax in dp_axes:
        p0, _, _ = pivot(mesh.shape[ax])
        prod_p0 *= p0
    quantum = prod_p0 * block
    padded = ((n_params + quantum - 1) // quantum) * quantum
    return padded, padded // prod_p0


def zero1_owner_segments(mesh: Mesh, dp_axes) -> list:
    """For each flattened DP rank (axis-major order), the natural-order global
    segment index it owns after the chained RS, or None (non-pivot rank of a
    non-power-of-two axis)."""
    sizes = [mesh.shape[ax] for ax in dp_axes]
    p0s = [pivot(sz)[0] for sz in sizes]
    owners = []
    for flat_rank in range(int(np.prod(sizes))):
        idxs = list(np.unravel_index(flat_rank, sizes))
        if any(i >= q for i, q in zip(idxs, p0s)):
            owners.append(None)
        else:
            seg = 0
            for i, q in zip(idxs, p0s):
                seg = seg * q + i
            owners.append(seg)
    return owners


def make_zero1(
    cfg: ModelConfig,
    mesh: Mesh,
    tcfg: TrainConfig,
    *,
    transform: str = "identity",
    paper_mode: bool = False,
):
    """Shared builder for the flat-gradient MRD strategies.

    Params: TP-sharded (auto "model" axis), replicated across DP (manual).
    Opt state: flat fp32 shards owned per DP rank, global shape [dp, m]
    (``paper_mode``: every rank owns a full replica, pure RD-butterfly
    allreduce — the paper's S2 collective — and no RS/AG).
    Global grad-norm clipping uses the paper's MRD allreduce on the scalar.
    """
    rules = shd.make_rules(cfg, mesh, fsdp=False)  # DP-replicated params
    remat_policy = common.REMAT_POLICIES[tcfg.remat]
    pdt = dtype_of(cfg.param_dtype)
    executor = common.resolve_executor(tcfg, compressed=transform != "identity")
    dp_axes = rules.dp_axes
    dp = rules.dp
    monitor = common.build_monitor(tcfg, rules)

    # the plan bindings: one code path for plain/compressed, 1/N axes
    rs_plan = plans.reduce_scatter_plan(
        axes=dp_axes, op="sum", transform=transform, executor=executor
    )
    ag_plan = plans.allgather_plan(axes=dp_axes, executor=executor)
    scalar_ar = plans.allreduce_plan(schedule="mrd", axes=dp_axes, op="sum")

    pshape = jax.eval_shape(lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(pshape))
    padded, shard_len = zero1_shard_len(n_params, mesh, dp_axes)
    if paper_mode:
        shard_len = padded  # every rank owns (a replica of) the full vector
    owners = zero1_owner_segments(mesh, dp_axes)

    def init_state(key):
        params = transformer.init_params(cfg, key)
        flat, _ = jax.flatten_util.ravel_pytree(
            jax.tree.map(lambda x: x.astype(jnp.float32), params)
        )
        flat = jnp.pad(flat, (0, padded - flat.shape[0]))
        if paper_mode:
            masters = jnp.broadcast_to(flat, (dp, shard_len))
        else:
            segs = flat.reshape(-1, shard_len)  # [prod_p0, m]
            rows = [
                segs[o] if o is not None else jnp.zeros((shard_len,), jnp.float32)
                for o in owners
            ]
            masters = jnp.stack(rows)  # [dp, m]
        state = {
            "params": params,
            "opt": {
                "master": masters,
                "mu": jnp.zeros((dp, shard_len), jnp.float32),
                "nu": jnp.zeros((dp, shard_len), jnp.float32),
            },
            "step": jnp.zeros((), jnp.int32),
        }
        if monitor is not None:
            state["monitor"] = common.monitor_rows_init(monitor, dp)
        return state

    def state_specs(state):
        pspecs = shd.param_specs(cfg, rules, state["params"])
        dpP = P(dp_axes)
        specs = {
            "params": pspecs,
            "opt": {"master": dpP, "mu": dpP, "nu": dpP},
            "step": P(),
        }
        if monitor is not None:
            specs["monitor"] = jax.tree.map(lambda _: dpP, state["monitor"])
        return specs

    def _is_owner():
        """Inside the manual region: does this rank own a live segment?"""
        ok = jnp.ones((), jnp.bool_)
        for ax in dp_axes:
            p0, _, _ = pivot(mesh.shape[ax])
            ok &= jax.lax.axis_index(ax) < p0
        return ok

    def train_step(state, batch):
        _, unravel = jax.flatten_util.ravel_pytree(
            jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), pshape)
        )

        def local_step(params, opt, step, mon_state, local_batch):
            with shd.sharding_ctx(cfg, common.manual_rules(rules)):
                grads, loss, metrics = common.microbatched_grads(
                    params, local_batch, cfg, remat_policy, tcfg.microbatches
                )
            flat, _ = jax.flatten_util.ravel_pytree(
                jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            )
            flat = jnp.pad(flat, (0, padded - flat.shape[0]))
            if paper_mode:
                # the paper's Allreduce: full-buffer XOR butterfly per DP axis
                gshard = scalar_ar.run(flat) / dp
                gnorm = jnp.sqrt(jnp.sum(gshard * gshard))
            else:
                # beyond-paper: chained RS over DP axes -> mean segment
                gshard = rs_plan.run(flat) / dp
                # global grad norm via the paper's MRD allreduce on a scalar
                own = _is_owner()
                sq = jnp.where(own, jnp.sum(gshard * gshard), 0.0)
                gnorm = jnp.sqrt(scalar_ar.run(sq))
            if tcfg.optimizer.grad_clip > 0:
                scale = jnp.minimum(
                    1.0, tcfg.optimizer.grad_clip / jnp.maximum(gnorm, 1e-12)
                )
                gshard = gshard * scale
            master, new_opt = opt_lib.apply_update_vector(
                gshard,
                {"master": opt["master"][0], "mu": opt["mu"][0], "nu": opt["nu"][0]},
                tcfg.optimizer,
                step,
            )
            if paper_mode:
                new_flat = master.astype(pdt)  # already full-length
            else:
                # recursive-doubling all-gather of updated bf16 params
                new_flat = ag_plan.run(master.astype(pdt))
            new_params = unravel(new_flat[:n_params].astype(jnp.float32))
            new_params = jax.tree.map(
                lambda a, b: a.astype(b.dtype), new_params, params
            )

            mon_out, done, val = common.local_monitor_tick(
                monitor, mon_state, metrics["per_example"].mean(), step
            )
            opt_out = jax.tree.map(lambda x: x[None], new_opt)
            return (
                new_params,
                opt_out,
                mon_out,
                loss[None],
                gnorm[None],
                done,
                val,
            )

        dpP = P(dp_axes)
        bspecs = common.batch_specs(cfg, rules, batch)
        if monitor is not None:
            mon_state_in = state["monitor"]
            mon_spec = jax.tree.map(lambda _: dpP, state["monitor"])
        else:
            mon_state_in = jnp.zeros((dp, 1), jnp.float32)
            mon_spec = dpP
        out = compat.shard_map(
            local_step,
            mesh=mesh,
            in_specs=(
                jax.tree.map(lambda _: P(), state["params"]),
                {"master": dpP, "mu": dpP, "nu": dpP},
                P(),
                mon_spec,
                bspecs,
            ),
            out_specs=(
                jax.tree.map(lambda _: P(), state["params"]),
                {"master": dpP, "mu": dpP, "nu": dpP},
                mon_spec,
                dpP,
                dpP,
                dpP,
                dpP,
            ),
            axis_names=set(dp_axes),
            check_vma=False,
        )(state["params"], state["opt"], state["step"], mon_state_in, batch)
        params, opt, mon, loss, gnorm, done, val = out
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        if monitor is not None:
            new_state["monitor"] = mon
        metrics = {
            "loss": loss.mean(),
            "grad_norm": gnorm[0],
            "converged": done[0],
            "monitor_value": val[0],
        }
        return new_state, metrics

    return train_step, init_state, state_specs, rules


@register("mrd_zero1")
def make(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig):
    return make_zero1(cfg, mesh, tcfg)
