"""Grad-sync strategy ``mrd_zero1``: the paper's butterfly as a ZeRO-1
distributed optimizer (beyond-paper).

Inside ``shard_map`` (manual over the DP axes, auto over "model"): the
flat fp32 gradient is packed into size-capped buckets
(:mod:`repro.collectives.buckets`), each bucket reduce-scattered over the
DP axes with the recursive-halving schedule **stage-major across buckets**
(:meth:`repro.collectives.plans.CollectivePlan.run_buffers`, DESIGN.md
S10) so collective-permute overlaps neighbouring buckets' compute;
shard-local AdamW runs on the concatenated per-bucket fp32 segments, then
the bf16 params all-gather back per bucket on the same pipelined path.
Works for non-power-of-two DP groups (the paper's headline case) — the
elasticity path uses exactly this.  Hierarchy is implicit: with mesh axes
("pod","data"), the chained RS/AG reduces inter-pod bytes by 1/p0(data).

All collectives run through :class:`repro.collectives.plans.CollectivePlan`;
``mrd_paper`` and ``compressed`` reuse this builder with a different
schedule/transform binding.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.collectives import buckets, plans
from repro.collectives.schedules import pivot
from repro.distributed import sharding as shd
from repro.distributed.gradsync import common, register, register_resize
from repro.distributed.gradsync import overlap as overlap_lib
from repro.distributed.gradsync.common import TrainConfig
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.layers import dtype_of
from repro.optim import optimizer as opt_lib


def zero1_prod_p0(mesh: Mesh, dp_axes) -> int:
    """Product of the per-axis pivot sizes (live RS segment count)."""
    prod_p0 = 1
    for ax in dp_axes:
        p0, _, _ = pivot(mesh.shape[ax])
        prod_p0 *= p0
    return prod_p0


def zero1_shard_len(n_params: int, mesh: Mesh, dp_axes, block: int = 256) -> tuple[int, int]:
    """(padded_total, shard_len) for a *single-bucket* chained RS over
    dp_axes (legacy flat layout; the bucketed layout generalizes this
    per bucket — see :func:`zero1_layout`)."""
    prod_p0 = zero1_prod_p0(mesh, dp_axes)
    quantum = prod_p0 * block
    padded = ((n_params + quantum - 1) // quantum) * quantum
    return padded, padded // prod_p0


def zero1_layout(
    pshape,
    mesh: Mesh,
    dp_axes,
    *,
    bucket_bytes: Optional[int] = buckets.DEFAULT_BUCKET_BYTES,
    block: int = 256,
) -> tuple[buckets.BucketLayout, int]:
    """(bucket layout, prod_p0) for the bucketed chained RS over dp_axes.

    The layout is built over the fp32 view of ``pshape`` (gradients are
    accumulated in fp32); every bucket is padded to ``prod_p0 * block``
    elements so each RS phase divides evenly and int8 blocks stay aligned.
    Master/moment rows are the per-bucket owned segments concatenated in
    bucket order — total shard length ``layout.total_padded / prod_p0``.
    """
    prod_p0 = zero1_prod_p0(mesh, dp_axes)
    fp32 = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(tuple(l.shape), jnp.float32), pshape
    )
    layout = buckets.build_layout(
        fp32, bucket_bytes=bucket_bytes, quantum=prod_p0 * block
    )
    return layout, prod_p0


def zero1_masters_from_params(
    params,
    mesh: Mesh,
    dp_axes,
    *,
    bucket_bytes: Optional[int] = buckets.DEFAULT_BUCKET_BYTES,
    paper_mode: bool = False,
) -> jnp.ndarray:
    """``[dp, m]`` fp32 master rows matching :func:`make_zero1`'s bucketed
    shard layout — the elastic restart path re-seeds masters from restored
    params with exactly this (tests/test_fault_tolerance.py)."""
    layout, prod_p0 = zero1_layout(params, mesh, dp_axes, bucket_bytes=bucket_bytes)
    bufs = buckets.pack(
        jax.tree.map(lambda x: x.astype(jnp.float32), params), layout
    )
    dp = int(np.prod([mesh.shape[ax] for ax in dp_axes]))
    if paper_mode:
        flat = jnp.concatenate(bufs) if bufs else jnp.zeros((0,), jnp.float32)
        return jnp.broadcast_to(flat, (dp, flat.shape[0]))
    owners = zero1_owner_segments(mesh, dp_axes)
    seg_bufs = [b.reshape(prod_p0, -1) for b in bufs]
    m = layout.total_padded // prod_p0
    rows = [
        jnp.concatenate([sb[o] for sb in seg_bufs]) if o is not None
        else jnp.zeros((m,), jnp.float32)
        for o in owners
    ]
    return jnp.stack(rows)


def zero1_gather_buckets(rows, layout, owners, prod_p0: int) -> list:
    """Reassemble full per-bucket buffers from owner-sharded ``[dp, m]`` rows
    (each owner row concatenates its per-bucket segments in bucket order) —
    the inverse of the scatter in :func:`zero1_masters_from_params`."""
    rank_of = {seg: r for r, seg in enumerate(owners) if seg is not None}
    bufs, shard_off = [], 0
    for blen in layout.bucket_lengths:
        seg = blen // prod_p0
        bufs.append(
            jnp.concatenate(
                [rows[rank_of[s], shard_off : shard_off + seg]
                 for s in range(prod_p0)]
            )
        )
        shard_off += seg
    return bufs


def zero1_scatter_buckets(bufs, layout, owners, prod_p0: int) -> jnp.ndarray:
    """Shard full per-bucket buffers back into owner rows ``[dp, m]``
    (non-owner ranks of a non-power-of-two extent get zero rows, matching
    :func:`zero1_masters_from_params`)."""
    seg_bufs = [b.reshape(prod_p0, -1) for b in bufs]
    m = layout.total_padded // prod_p0
    dtype = bufs[0].dtype if bufs else jnp.float32
    rows = [
        jnp.concatenate([sb[o] for sb in seg_bufs])
        if o is not None
        else jnp.zeros((m,), dtype)
        for o in owners
    ]
    return jnp.stack(rows)


def zero1_regrid(bufs, layout_old, layout_new) -> list:
    """Re-bucket full flat buffers from one layout to another.

    Both layouts cover the same (fp32 view of the) parameter tree; only
    the per-bucket padding differs (the pad quantum scales with the RS
    pivot product, which changes on resize).  Pad regions carry exact
    zeros throughout training — gradients, moments and EF residuals are
    all zero there by construction — so dropping the old padding and
    re-padding with zeros is bit-exact for every live coordinate.
    """
    return buckets.pack(buckets.unpack(bufs, layout_old), layout_new)


def zero1_owner_segments(mesh: Mesh, dp_axes) -> list:
    """For each flattened DP rank (axis-major order), the natural-order global
    segment index it owns after the chained RS, or None (non-pivot rank of a
    non-power-of-two axis)."""
    sizes = [mesh.shape[ax] for ax in dp_axes]
    p0s = [pivot(sz)[0] for sz in sizes]
    owners = []
    for flat_rank in range(int(np.prod(sizes))):
        idxs = list(np.unravel_index(flat_rank, sizes))
        if any(i >= q for i, q in zip(idxs, p0s)):
            owners.append(None)
        else:
            seg = 0
            for i, q in zip(idxs, p0s):
                seg = seg * q + i
            owners.append(seg)
    return owners


def make_zero1(
    cfg: ModelConfig,
    mesh: Mesh,
    tcfg: TrainConfig,
    *,
    transform: str = "identity",
    paper_mode: bool = False,
):
    """Shared builder for the bucketed flat-gradient MRD strategies.

    Params: TP-sharded (auto "model" axis), replicated across DP (manual).
    Opt state: fp32 shards owned per DP rank, global shape [dp, m] — ``m``
    concatenates the owned segment of every gradient bucket
    (``paper_mode``: every rank owns a full replica, pure RD-butterfly
    allreduce — the paper's S2 collective — and no RS/AG).  All
    gradient-scale collectives run per-bucket, pipelined stage-major
    (DESIGN.md S10).
    Global grad-norm clipping uses the paper's MRD allreduce on the scalar.

    With a lossy ``transform`` and ``tcfg.error_feedback``, each rank
    carries an EF-SGD residual (``opt['ef']``, the full padded gradient
    length): the quantization error of what it sent this step is folded
    into next step's gradient (:func:`repro.collectives.transforms.ef_roundtrip`),
    so persistently-sub-quantum coordinates are delayed, not dropped.
    """
    rules = shd.make_rules(cfg, mesh, fsdp=False)  # DP-replicated params
    remat_policy = common.REMAT_POLICIES[tcfg.remat]
    pdt = dtype_of(cfg.param_dtype)
    executor = common.resolve_executor(tcfg, compressed=transform != "identity")
    dp_axes = rules.dp_axes
    dp = rules.dp
    monitor = common.build_monitor(tcfg, rules)

    # the plan bindings: one code path for plain/compressed, 1/N axes.
    # paper_mode allreduces full buckets; the ZeRO-1 path reduce-scatters
    # them, allreduces the grad-norm scalar, and all-gathers the params.
    if paper_mode:
        full_ar = plans.allreduce_plan(
            schedule="mrd", axes=dp_axes, op="sum", transform=transform,
            executor=executor,
        )
    else:
        rs_plan = plans.reduce_scatter_plan(
            axes=dp_axes, op="sum", transform=transform, executor=executor
        )
        ag_plan = plans.allgather_plan(axes=dp_axes, executor=executor)
        scalar_ar = plans.allreduce_plan(schedule="mrd", axes=dp_axes, op="sum")

    pshape = jax.eval_shape(lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
    layout, prod_p0 = zero1_layout(
        pshape, mesh, dp_axes, bucket_bytes=tcfg.bucket_bytes
    )
    padded = layout.total_padded
    shard_len = padded if paper_mode else padded // prod_p0
    # per-bucket split points of the concatenated shard / full vector
    full_bounds = list(np.cumsum(layout.bucket_lengths)[:-1])
    shard_bounds = [b // prod_p0 for b in full_bounds]
    use_ef = tcfg.error_feedback and transform != "identity"
    # the gradient-reducing plan: full-bucket butterfly (paper) or chained RS
    grad_plan = full_ar if paper_mode else rs_plan
    if tcfg.overlap:
        # ready-bucket overlap (DESIGN.md S16): same layout, same plan —
        # only the bucket *issue order* moves inside the backward
        koffs = overlap_lib.key_offsets(pshape)
        bgroups = overlap_lib.bucket_groups(
            layout, overlap_lib.leaf_groups(pshape)
        )

    def init_state(key):
        params = transformer.init_params(cfg, key)
        masters = zero1_masters_from_params(
            params, mesh, dp_axes,
            bucket_bytes=tcfg.bucket_bytes, paper_mode=paper_mode,
        )
        opt = {
            "master": masters,
            "mu": jnp.zeros((dp, shard_len), jnp.float32),
            "nu": jnp.zeros((dp, shard_len), jnp.float32),
        }
        if use_ef:
            opt["ef"] = jnp.zeros((dp, padded), jnp.float32)
        state = {
            "params": params,
            "opt": opt,
            "step": jnp.zeros((), jnp.int32),
        }
        if monitor is not None:
            state["monitor"] = common.monitor_rows_init(monitor, dp)
        return state

    def state_specs(state):
        pspecs = shd.param_specs(cfg, rules, state["params"])
        dpP = P(dp_axes)
        specs = {
            "params": pspecs,
            "opt": jax.tree.map(lambda _: dpP, state["opt"]),
            "step": P(),
        }
        if monitor is not None:
            specs["monitor"] = jax.tree.map(lambda _: dpP, state["monitor"])
        return specs

    def _is_owner():
        """Inside the manual region: does this rank own a live segment?"""
        ok = jnp.ones((), jnp.bool_)
        for ax in dp_axes:
            p0, _, _ = pivot(mesh.shape[ax])
            ok &= jax.lax.axis_index(ax) < p0
        return ok

    def train_step(state, batch):
        def local_step(params, opt, step, mon_state, local_batch):
            if use_ef:
                # EF-SGD: send the grid round-trip of (grad + residual),
                # carry what the quantizer dropped into the next step
                from repro.collectives import transforms as tf_lib

                ef_bufs = jnp.split(opt["ef"][0], full_bounds)

                def wire(i, buf):
                    return tf_lib.ef_roundtrip(buf, ef_bufs[i])

            if tcfg.overlap:
                # segmented backward feeding ready buckets straight into
                # the plan's stage pipeline (bit-identical to the
                # post-backward path below — DESIGN.md S16)
                with shd.sharding_ctx(cfg, common.manual_rules(rules)):
                    emitter = overlap_lib.segmented_grads(
                        params, local_batch, cfg, remat_policy,
                        tcfg.microbatches,
                    )
                    loss, metrics, red, efs = overlap_lib.drive(
                        emitter, layout, koffs, bgroups,
                        plan=grad_plan, wire=wire if use_ef else None,
                    )
                if use_ef:
                    new_ef = jnp.concatenate(efs)
            else:
                with shd.sharding_ctx(cfg, common.manual_rules(rules)):
                    grads, loss, metrics = common.microbatched_grads(
                        params, local_batch, cfg, remat_policy, tcfg.microbatches
                    )
                # dtype-homogeneous, quantum-padded gradient buckets
                bufs = buckets.pack(
                    jax.tree.map(lambda g: g.astype(jnp.float32), grads), layout
                )
                if use_ef:
                    pairs = [wire(i, b) for i, b in enumerate(bufs)]
                    bufs = [s for s, _ in pairs]
                    new_ef = jnp.concatenate([e for _, e in pairs])
                # paper_mode: the paper's Allreduce, a full-buffer XOR
                # butterfly per DP axis; else the beyond-paper chained RS —
                # either way one pipelined stage-major pass over all buckets
                red = grad_plan.run_buffers(bufs)
            if paper_mode:
                gshard = jnp.concatenate(red) / dp
                gnorm = jnp.sqrt(jnp.sum(gshard * gshard))
            else:
                # concatenated mean segments of the reduce-scattered buckets
                gshard = jnp.concatenate(red) / dp
                # global grad norm via the paper's MRD allreduce on a scalar
                own = _is_owner()
                sq = jnp.where(own, jnp.sum(gshard * gshard), 0.0)
                gnorm = jnp.sqrt(scalar_ar.run(sq))
            if tcfg.optimizer.grad_clip > 0:
                scale = jnp.minimum(
                    1.0, tcfg.optimizer.grad_clip / jnp.maximum(gnorm, 1e-12)
                )
                gshard = gshard * scale
            master, new_opt = opt_lib.apply_update_vector(
                gshard,
                {"master": opt["master"][0], "mu": opt["mu"][0], "nu": opt["nu"][0]},
                tcfg.optimizer,
                step,
            )
            if paper_mode:
                out_bufs = jnp.split(master.astype(pdt), full_bounds)
            else:
                # recursive-doubling all-gather of the updated bf16 params,
                # again pipelined per bucket
                out_bufs = ag_plan.run_buffers(
                    jnp.split(master.astype(pdt), shard_bounds)
                )
            # unpack casts each bucket back to its layout dtype (fp32)
            new_params = buckets.unpack(out_bufs, layout)
            new_params = jax.tree.map(
                lambda a, b: a.astype(b.dtype), new_params, params
            )

            mon_out, done, val = common.local_monitor_tick(
                monitor, mon_state, metrics["per_example"].mean(), step
            )
            opt_out = jax.tree.map(lambda x: x[None], new_opt)
            if use_ef:
                opt_out["ef"] = new_ef[None]
            return (
                new_params,
                opt_out,
                mon_out,
                loss[None],
                gnorm[None],
                done,
                val,
            )

        dpP = P(dp_axes)
        opt_spec = jax.tree.map(lambda _: dpP, state["opt"])
        bspecs = common.batch_specs(cfg, rules, batch)
        if monitor is not None:
            mon_state_in = state["monitor"]
            mon_spec = jax.tree.map(lambda _: dpP, state["monitor"])
        else:
            mon_state_in = jnp.zeros((dp, 1), jnp.float32)
            mon_spec = dpP
        out = compat.shard_map(
            local_step,
            mesh=mesh,
            in_specs=(
                jax.tree.map(lambda _: P(), state["params"]),
                opt_spec,
                P(),
                mon_spec,
                bspecs,
            ),
            out_specs=(
                jax.tree.map(lambda _: P(), state["params"]),
                opt_spec,
                mon_spec,
                dpP,
                dpP,
                dpP,
                dpP,
            ),
            axis_names=set(dp_axes),
            check_vma=False,
        )(state["params"], state["opt"], state["step"], mon_state_in, batch)
        params, opt, mon, loss, gnorm, done, val = out
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        if monitor is not None:
            new_state["monitor"] = mon
        metrics = {
            "loss": loss.mean(),
            "grad_norm": gnorm[0],
            "converged": done[0],
            "monitor_value": val[0],
        }
        return new_state, metrics

    return train_step, init_state, state_specs, rules


@register("mrd_zero1")
def make(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig):
    return make_zero1(cfg, mesh, tcfg)


# ---------------------------------------------------------------------------
# Elastic resize (DESIGN.md S12): in-place ZeRO-1 shard re-layout
# ---------------------------------------------------------------------------


def resize_zero1(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    old_mesh: Mesh,
    new_mesh: Mesh,
    state,
    keep,
    *,
    paper_mode: bool = False,
):
    """Migrate a live zero1/paper/compressed train state across a resize
    without a checkpoint round-trip.

    Params are DP-replicated (any survivor holds them — the controller
    broadcasts to joiners over an MRD plan at the new extent).  The fp32
    master/moment rows are owner-segment sharded over the *old* pivot
    product; we reassemble the full flat vectors from the surviving
    owners, re-bucket for the new extent's layout (:func:`zero1_regrid` —
    bit-exact, pad regions are structurally zero), and re-scatter onto
    the new owner segments.  The EF-SGD residual is per-worker state and
    follows its worker via ``keep`` (joiners start with a zero residual —
    they have sent nothing to compensate for).  Monitor rows migrate via
    the detection-protocol layer.
    """
    rules_o = shd.make_rules(cfg, old_mesh, fsdp=False)
    rules_n = shd.make_rules(cfg, new_mesh, fsdp=False)
    pshape = jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0))
    )
    layout_o, prod_o = zero1_layout(
        pshape, old_mesh, rules_o.dp_axes, bucket_bytes=tcfg.bucket_bytes
    )
    layout_n, prod_n = zero1_layout(
        pshape, new_mesh, rules_n.dp_axes, bucket_bytes=tcfg.bucket_bytes
    )
    owners_o = zero1_owner_segments(old_mesh, rules_o.dp_axes)
    owners_n = zero1_owner_segments(new_mesh, rules_n.dp_axes)
    bounds_o = list(np.cumsum(layout_o.bucket_lengths)[:-1])
    dp_n = rules_n.dp

    opt = state["opt"]
    new_opt = {}
    for name in ("master", "mu", "nu"):
        rows = jnp.asarray(opt[name])
        if paper_mode:
            # fully replicated rows: re-bucket one survivor's copy
            full = zero1_regrid(
                jnp.split(rows[0], bounds_o), layout_o, layout_n
            )
            flat = jnp.concatenate(full)
            new_opt[name] = jnp.broadcast_to(flat, (dp_n, flat.shape[0]))
        else:
            bufs = zero1_gather_buckets(rows, layout_o, owners_o, prod_o)
            bufs = zero1_regrid(bufs, layout_o, layout_n)
            new_opt[name] = zero1_scatter_buckets(bufs, layout_n, owners_n, prod_n)
    if "ef" in opt:
        ef_rows = jnp.asarray(opt["ef"])
        zero_row = None
        rows_out = []
        for k in keep:
            if k is None:
                if zero_row is None:
                    zero_row = jnp.zeros((layout_n.total_padded,), jnp.float32)
                rows_out.append(zero_row)
            else:
                regridded = zero1_regrid(
                    jnp.split(ef_rows[int(k)], bounds_o), layout_o, layout_n
                )
                rows_out.append(jnp.concatenate(regridded))
        new_opt["ef"] = jnp.stack(rows_out)

    new_state = dict(state)
    new_state["opt"] = new_opt
    if "monitor" in state:
        new_state["monitor"] = common.monitor_rows_migrate(
            tcfg, rules_n, state["monitor"], keep
        )
    return new_state


@register_resize("mrd_zero1")
def _resize(cfg, tcfg, old_mesh, new_mesh, state, keep):
    return resize_zero1(cfg, tcfg, old_mesh, new_mesh, state, keep)


@register_resize("mrd_paper")
def _resize_paper(cfg, tcfg, old_mesh, new_mesh, state, keep):
    return resize_zero1(
        cfg, tcfg, old_mesh, new_mesh, state, keep, paper_mode=True
    )


@register_resize("compressed")
def _resize_compressed(cfg, tcfg, old_mesh, new_mesh, state, keep):
    return resize_zero1(cfg, tcfg, old_mesh, new_mesh, state, keep)
