"""Unified collectives subsystem (the paper's reduction machinery, layered).

Four explicit layers, each a registry so adding a schedule, backend, or
wire format is a one-file change (DESIGN.md S1):

1. **schedules**  — pure-data stage lists + the ``SCHEDULES`` registry
   (``mrd`` | ``rabenseifner`` | ``hierarchical``);
2. **executors**  — the ``Backend`` protocol + ``EXECUTORS`` registry
   (``device`` | ``device_fused`` | ``sim``);
3. **transforms** — wire formats + the ``TRANSFORMS`` registry
   (``identity`` | ``int8``);
4. **plans**      — :class:`CollectivePlan` binds one of each to axes/p
   and exposes blocking ``run()``, the bucketed pipelined
   ``run_bucketed()``/``run_buffers()`` engine (DESIGN.md S10, packing
   via ``repro.collectives.buckets``), and the paper's non-blocking
   ``init()``/``step()`` state machine.
"""

from repro.collectives.buckets import (  # noqa: F401
    Bucket,
    BucketLayout,
    LeafSlot,
    build_layout,
    pack,
    unpack,
)
from repro.collectives.executors import (  # noqa: F401
    EXECUTORS,
    Backend,
    DeviceBackend,
    FusedDeviceBackend,
    OPS,
    SimBackend,
    make_backend,
    register_executor,
    resolve_op,
)
from repro.collectives.plans import (  # noqa: F401
    CollectivePlan,
    allgather_plan,
    allreduce_plan,
    exec_stage,
    reduce_scatter_plan,
    tree_allreduce,
)
from repro.collectives.schedules import (  # noqa: F401
    SCHEDULES,
    Phase,
    ScheduleFamily,
    Stage,
    get_schedule,
    pivot,
    register_schedule,
)
from repro.collectives.transforms import (  # noqa: F401
    TRANSFORMS,
    IdentityTransform,
    Int8BlockwiseTransform,
    register_transform,
    resolve_transform,
)
