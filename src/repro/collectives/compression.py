"""Deprecated shim: gradient compression moved to
``repro.collectives.transforms`` (the payload-transform layer).

``compressed_reduce_scatter(vec, axis)`` is now
``reduce_scatter_plan(axes=(axis,), transform="int8").run(vec)``.
This module keeps the original quantization API importable.
"""

from repro.collectives.transforms import (  # noqa: F401
    BLOCK,
    dequantize,
    quantization_error,
    quantize,
    wire_bytes_factor,
)
