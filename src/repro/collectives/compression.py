"""Gradient compression: blockwise int8 quantization + error feedback.

Used by the ``compressed`` grad-sync mode: the reduce-scatter halves are
quantized before each ``ppermute`` (wire bytes / 2 vs bf16, / 4 vs fp32, plus
~1.6% scale overhead) and dequant-accumulated on receive — that accumulate is
the ``mrd_combine`` Pallas kernel's job on TPU.

Error feedback (EF-SGD style) is applied at the grad-sync level: the residual
of the *first* quantization of the local contribution is carried to the next
step.  (Per-stage requantization error inside the butterfly is secondary and
documented in EXPERIMENTS.md.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize(x, block: int = BLOCK):
    """x: [n] float -> (q int8 [n], scales f32 [n/block]). n % block == 0."""
    n = x.shape[0]
    assert n % block == 0, (n, block)
    xb = x.astype(jnp.float32).reshape(n // block, block)
    amax = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(n), scale[:, 0]


def dequantize(q, scales, block: int = BLOCK):
    n = q.shape[0]
    xb = q.astype(jnp.float32).reshape(n // block, block) * scales[:, None]
    return xb.reshape(n)


def quantization_error(x, block: int = BLOCK):
    q, s = quantize(x, block)
    return x.astype(jnp.float32) - dequantize(q, s, block)


def wire_bytes_factor(dtype_bytes: int = 4, block: int = BLOCK) -> float:
    """Bytes-on-wire ratio of compressed vs uncompressed payloads."""
    return (1.0 + 4.0 / block) / dtype_bytes
