"""Layer 4 of the collectives subsystem: *plans*.

A :class:`CollectivePlan` binds one choice from each lower layer —
schedule (``repro.collectives.schedules``), executor
(``repro.collectives.executors``), payload transform
(``repro.collectives.transforms``) — plus a reduction op, to a concrete
communication domain: named mesh axes (device executors, called inside
``shard_map``) or a stacked rank count ``p`` (sim executor).

Every collective in the repo — blocking or non-blocking, compressed or
plain, single- or multi-axis — executes through this one stage
interpreter, so there is exactly one code path to validate:

- :meth:`CollectivePlan.run` executes all stages of all phases (blocking).
- :meth:`CollectivePlan.init` / :meth:`CollectivePlan.step` expose the
  paper's non-blocking state machine (Fig. 4): each ``step`` call
  advances **one** communication stage via ``lax.switch`` over a stage
  counter carried in a pytree; a cycle completes after
  :meth:`cycle_length` calls, sets ``flag``, publishes the reduced
  value, and re-latches the caller's current local contribution
  ("each cycle begins with the backward shift").

Chained (multi-axis) plans concatenate per-axis stage lists, which is
how non-power-of-two DP domains and ``("pod","data")`` meshes run the
same code path as a single axis.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.collectives import transforms as T
from repro.collectives.executors import make_backend, resolve_op
from repro.collectives.schedules import Phase, Stage, get_schedule, pivot

# ---------------------------------------------------------------------------
# The one stage interpreter (all backends, all transforms, all stage kinds)
# ---------------------------------------------------------------------------


def exec_stage(x, st: Stage, be, p: int, op: Callable, tf=None):
    """Apply one schedule stage under backend ``be`` with transform ``tf``.

    Reducing stages (``bshift``/``butterfly``/``rs``) send
    ``tf.encode``-ed payloads and fold them back with ``tf.combine``;
    copy stages (``fshift``/``ag``) always move raw buffers.
    """
    tf = tf or T.IdentityTransform()
    p0, _, extra = pivot(p)
    r = be.rank()
    if st.kind in ("bshift", "butterfly"):
        payload = tf.encode(x, be)
        recv = tuple(be.permute(leaf, st.pairs) for leaf in payload)
        # butterfly partners both hold the stage result, so each must combine
        # the *canonical* (wire-roundtripped) views — otherwise a lossy
        # transform leaves the two ranks with slightly different values and
        # the allreduce contract (all ranks equal) silently breaks.
        keep = tf.canonicalize(x, be) if st.kind == "butterfly" else x
        combined = tf.combine(keep, recv, op, be)
        pred = (r < extra) if st.kind == "bshift" else (r < p0)
        return be.where(pred, combined, x)
    if st.kind == "fshift":
        recv = be.permute(x, st.pairs)
        return be.where(r >= p0, recv, x)
    if st.kind == "rs":
        d = st.distance
        lower, upper = be.split_half(x)
        my_bit = (r & d) != 0
        to_send = be.where(my_bit, lower, upper)
        keep = be.where(my_bit, upper, lower)
        payload = tf.encode(to_send, be)
        recv = tuple(be.permute(leaf, st.pairs) for leaf in payload)
        combined = tf.combine(keep, recv, op, be)
        return be.where(r < p0, combined, keep)
    if st.kind == "ag":
        recv = be.permute(x, st.pairs)
        my_bit = (r & st.distance) != 0
        return be.where(my_bit, be.concat(recv, x), be.concat(x, recv))
    raise ValueError(f"bad stage kind {st.kind}")


def _run_phase(x, collective: str, be, p: int, op: Callable, tf):
    if p == 1:
        return x
    for st in Phase(collective, 0).stages(p):
        x = exec_stage(x, st, be, p, op, tf if collective != "allgather" else None)
    return x


# ---------------------------------------------------------------------------
# CollectivePlan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CollectivePlan:
    """schedule x executor x transform x op, bound to axes (device) or p (sim).

    ``phases`` defaults to the registered decomposition of ``schedule``;
    pass it explicitly for primitive plans (a bare reduce-scatter or
    all-gather, see :func:`reduce_scatter_plan` / :func:`allgather_plan`).
    """

    schedule: str = "mrd"
    op: Any = "sum"  # 'sum' | 'max' | 'min' | callable
    transform: Any = "identity"  # name | transform instance
    executor: str = "device"  # 'device' | 'device_fused' | 'sim'
    axes: Optional[tuple[str, ...]] = None  # device: mesh axis names (chained)
    p: Optional[int] = None  # sim: stacked rank count
    phases: Optional[tuple[Phase, ...]] = None
    transform_kwargs: tuple = ()  # e.g. (('block', 128),)

    def __post_init__(self):
        if (self.axes is None) == (self.p is None):
            raise ValueError("bind exactly one of axes= (device) or p= (sim)")
        if self.p is not None and self.executor == "device":
            object.__setattr__(self, "executor", "sim")
        if self.axes is not None and isinstance(self.axes, str):
            object.__setattr__(self, "axes", (self.axes,))
        self._transform().validate_op(self.op)

    # -- layer resolution ---------------------------------------------------

    def _n_axes(self) -> int:
        return len(self.axes) if self.axes is not None else 1

    def _phases(self) -> tuple[Phase, ...]:
        if self.phases is not None:
            return self.phases
        return tuple(get_schedule(self.schedule).phases(self._n_axes()))

    def _transform(self):
        return T.resolve_transform(self.transform, **dict(self.transform_kwargs))

    def _backend(self, axis_index: int):
        if self.axes is not None:
            return make_backend(self.executor, axis=self.axes[axis_index])
        return make_backend(self.executor, p=self.p)

    def _size(self, axis_index: int) -> int:
        """Static axis size; device sizes resolve inside the traced region."""
        if self.p is not None:
            return self.p
        from repro import compat

        return compat.axis_size(self.axes[axis_index])

    # -- introspection ------------------------------------------------------

    def bound_stages(self) -> list[tuple[Stage, int, int]]:
        """Flat [(stage, axis_index, p)] across phases (allreduce plans)."""
        out = []
        for ph in self._phases():
            if ph.collective != "allreduce":
                raise ValueError(
                    "stage-at-a-time stepping needs an allreduce-only plan "
                    f"(schedule {self.schedule!r} has a {ph.collective} phase)"
                )
            p = self._size(ph.axis_index)
            for st in ph.stages(p):
                out.append((st, ph.axis_index, p))
        return out

    def cycle_length(self) -> int:
        """Non-blocking calls per completed reduction (>= 1)."""
        return max(len(self.bound_stages()), 1)

    def pad_quantum(self) -> int:
        """Required divisor of the (1-D) buffer length for this plan."""
        q = self._transform().quantum
        for ph in self._phases():
            if ph.collective == "reduce_scatter":
                q *= pivot(self._size(ph.axis_index))[0]
        return q

    # -- blocking execution -------------------------------------------------

    def run(self, x):
        """Execute all phases.  Allreduce-only plans accept a pytree; plans
        with reduce-scatter/all-gather phases take a single array (device:
        1-D local vector, sim: ``[p, n]`` stacked)."""
        op = resolve_op(self.op)
        tf = self._transform()
        phases = self._phases()
        ar_only = all(ph.collective == "allreduce" for ph in phases)
        if ar_only:
            for ph in phases:
                be = self._backend(ph.axis_index)
                p = self._size(ph.axis_index)
                if p == 1:
                    continue
                x = jax.tree.map(
                    lambda leaf: _run_phase(leaf, "allreduce", be, p, op, tf), x
                )
            return x
        for ph in phases:
            be = self._backend(ph.axis_index)
            p = self._size(ph.axis_index)
            if ph.collective == "reduce_scatter" and p > 1:
                ndim = 2 if self.p is not None else 1
                if x.ndim != ndim:
                    raise ValueError(
                        f"reduce-scatter phase needs a {ndim}-D buffer "
                        f"({'[p, n] stacked' if ndim == 2 else 'rank-local 1-D'}), "
                        f"got shape {x.shape}"
                    )
                n = x.shape[-1]
                quantum = pivot(p)[0] * tf.quantum
                if n % quantum:
                    raise ValueError(
                        f"reduce-scatter phase over p={p} needs len % {quantum} "
                        f"== 0 (p0 x transform quantum), got {n}"
                    )
            x = _run_phase(x, ph.collective, be, p, op, tf)
        return x

    # -- non-blocking state machine (paper Fig. 4) --------------------------

    def init(self, value) -> dict[str, Any]:
        """Create the state machine's state, latching ``value`` as the first
        cycle's contribution.  ``value``: per-rank pytree (device) or
        ``[p, ...]`` stacked (sim)."""
        return {
            "stage": jnp.zeros((), jnp.int32),
            "buf": value,
            "result": jax.tree.map(jnp.zeros_like, value),
            "flag": jnp.zeros((), jnp.bool_),  # True for exactly the completing call
            "cycles": jnp.zeros((), jnp.int32),
        }

    def step(self, state: dict[str, Any], local_value) -> dict[str, Any]:
        """Advance the non-blocking collective by one stage.

        Returns the new state.  ``state['flag']`` is True iff this call
        completed a cycle; then ``state['result']`` holds the reduction of
        the values latched at that cycle's start.  ``local_value`` is
        latched only when a new cycle begins (stage == 0), matching the
        paper's statechart.
        """
        op = resolve_op(self.op)
        tf = self._transform()
        bound = self.bound_stages()
        nstages = len(bound)

        if nstages == 0:  # all axes size 1: every call is a complete cycle
            return {
                "stage": state["stage"],
                "buf": local_value,
                "result": local_value,
                "flag": jnp.ones((), jnp.bool_),
                "cycles": state["cycles"] + 1,
            }

        starting = state["stage"] == 0
        buf = jax.tree.map(
            lambda lv, b: jnp.where(starting, lv, b), local_value, state["buf"]
        )

        def _stage_fn(st, axis_index, p):
            be = self._backend(axis_index)

            def apply(b):
                return jax.tree.map(
                    lambda leaf: exec_stage(leaf, st, be, p, op, tf), b
                )

            return apply

        buf = jax.lax.switch(
            state["stage"], [_stage_fn(*b) for b in bound], buf
        )

        nxt = state["stage"] + 1
        done = nxt == nstages
        return {
            "stage": jnp.where(done, 0, nxt),
            "buf": buf,
            "result": jax.tree.map(
                lambda b, r: jnp.where(done, b, r), buf, state["result"]
            ),
            "flag": done,
            "cycles": state["cycles"] + done.astype(jnp.int32),
        }

    def run_blocking(self, value):
        """Drive the state machine through one full cycle (tests/reference)."""
        st = self.init(value)
        for _ in range(self.cycle_length()):
            st = self.step(st, value)
        return st["result"]


# ---------------------------------------------------------------------------
# Plan factories
# ---------------------------------------------------------------------------


def allreduce_plan(
    *,
    schedule: str = "mrd",
    op: Any = "sum",
    transform: Any = "identity",
    executor: str = "device",
    axes: Optional[Sequence[str]] = None,
    p: Optional[int] = None,
    **transform_kwargs,
) -> CollectivePlan:
    return CollectivePlan(
        schedule=schedule,
        op=op,
        transform=transform,
        executor=executor,
        axes=tuple(axes) if axes is not None else None,
        p=p,
        transform_kwargs=tuple(sorted(transform_kwargs.items())),
    )


def reduce_scatter_plan(
    *,
    op: Any = "sum",
    transform: Any = "identity",
    executor: str = "device",
    axes: Optional[Sequence[str]] = None,
    p: Optional[int] = None,
    **transform_kwargs,
) -> CollectivePlan:
    """Chained recursive-halving reduce-scatter over ``axes`` (in order)."""
    n = len(axes) if axes is not None else 1
    return CollectivePlan(
        schedule="reduce_scatter",
        op=op,
        transform=transform,
        executor=executor,
        axes=tuple(axes) if axes is not None else None,
        p=p,
        phases=tuple(Phase("reduce_scatter", i) for i in range(n)),
        transform_kwargs=tuple(sorted(transform_kwargs.items())),
    )


def allgather_plan(
    *,
    executor: str = "device",
    axes: Optional[Sequence[str]] = None,
    p: Optional[int] = None,
) -> CollectivePlan:
    """Chained recursive-doubling all-gather (reverse axis order, the inverse
    of :func:`reduce_scatter_plan`)."""
    n = len(axes) if axes is not None else 1
    return CollectivePlan(
        schedule="allgather",
        executor=executor,
        axes=tuple(axes) if axes is not None else None,
        p=p,
        phases=tuple(Phase("allgather", i) for i in reversed(range(n))),
    )


def tree_allreduce(
    tree,
    *,
    schedule: str = "mrd",
    op: Any = "sum",
    transform: Any = "identity",
    executor: str = "device",
    axes: Sequence[str] = (),
    **transform_kwargs,
):
    """Allreduce a pytree as one flat padded vector (flat-bucket), chained
    over ``axes``.  ``rabenseifner`` is the default-worthy choice for
    bandwidth-bound payloads like gradients; ``mrd`` for latency-bound."""
    plan = allreduce_plan(
        schedule=schedule,
        op=op,
        transform=transform,
        executor=executor,
        axes=axes,
        **transform_kwargs,
    )
    vec, unravel = ravel_pytree(tree)
    pad = (-vec.shape[0]) % plan.pad_quantum()
    out = plan.run(jnp.pad(vec, (0, pad)))
    return unravel(out[: vec.shape[0]])
