"""Layer 4 of the collectives subsystem: *plans*.

A :class:`CollectivePlan` binds one choice from each lower layer —
schedule (``repro.collectives.schedules``), executor
(``repro.collectives.executors``), payload transform
(``repro.collectives.transforms``) — plus a reduction op, to a concrete
communication domain: named mesh axes (device executors, called inside
``shard_map``) or a stacked rank count ``p`` (sim executor).

Every collective in the repo — blocking or non-blocking, compressed or
plain, single- or multi-axis — executes through this one stage
interpreter, so there is exactly one code path to validate:

- :meth:`CollectivePlan.run` executes all stages of all phases (blocking).
- :meth:`CollectivePlan.init` / :meth:`CollectivePlan.step` expose the
  paper's non-blocking state machine (Fig. 4): each ``step`` call
  advances **one** communication stage via ``lax.switch`` over a stage
  counter carried in a pytree; a cycle completes after
  :meth:`cycle_length` calls, sets ``flag``, publishes the reduced
  value, and re-latches the caller's current local contribution
  ("each cycle begins with the backward shift").

Chained (multi-axis) plans concatenate per-axis stage lists, which is
how non-power-of-two DP domains and ``("pod","data")`` meshes run the
same code path as a single axis.
"""

from __future__ import annotations

import dataclasses
import weakref
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro import obs
from repro.collectives import transforms as T
from repro.collectives.executors import make_backend, resolve_op
from repro.collectives.schedules import Phase, Stage, get_schedule, pivot

# ---------------------------------------------------------------------------
# The one stage interpreter (all backends, all transforms, all stage kinds)
#
# Each stage is split into a *start* half (encode the outgoing payload and
# issue the permute) and a *finish* half (fold the received payload in).
# Blocking execution composes the two back-to-back; the bucketed engine
# (:meth:`CollectivePlan.run_buffers`) interleaves them stage-major across
# buckets so a bucket's permute is in flight while its neighbours run
# their encode/combine compute (DESIGN.md S10).
# ---------------------------------------------------------------------------


def _stage_start(x, st: Stage, be, tf):
    """Issue the stage's communication; returns the in-flight context.

    Reducing stages (``bshift``/``butterfly``/``rs``) send
    ``tf.encode``-ed payloads; copy stages (``fshift``/``ag``) always
    move raw buffers.
    """
    if st.kind in ("bshift", "butterfly"):
        payload = tf.encode(x, be)
        return x, tuple(be.permute(leaf, st.pairs) for leaf in payload)
    if st.kind == "fshift":
        return x, be.permute(x, st.pairs)
    if st.kind == "rs":
        d = st.distance
        lower, upper = be.split_half(x)
        my_bit = (be.rank() & d) != 0
        to_send = be.where(my_bit, lower, upper)
        keep = be.where(my_bit, upper, lower)
        payload = tf.encode(to_send, be)
        return keep, tuple(be.permute(leaf, st.pairs) for leaf in payload)
    if st.kind == "ag":
        return x, be.permute(x, st.pairs)
    raise ValueError(f"bad stage kind {st.kind}")


def _stage_finish(ctx, st: Stage, be, p: int, op: Callable, tf):
    """Fold the in-flight payload from :func:`_stage_start` back in."""
    p0, _, extra = pivot(p)
    r = be.rank()
    if st.kind in ("bshift", "butterfly"):
        x, recv = ctx
        # butterfly partners both hold the stage result, so each must combine
        # the *canonical* (wire-roundtripped) views — otherwise a lossy
        # transform leaves the two ranks with slightly different values and
        # the allreduce contract (all ranks equal) silently breaks.
        keep = tf.canonicalize(x, be) if st.kind == "butterfly" else x
        combined = tf.combine(keep, recv, op, be)
        pred = (r < extra) if st.kind == "bshift" else (r < p0)
        return be.where(pred, combined, x)
    if st.kind == "fshift":
        x, recv = ctx
        return be.where(r >= p0, recv, x)
    if st.kind == "rs":
        keep, recv = ctx
        combined = tf.combine(keep, recv, op, be)
        return be.where(r < p0, combined, keep)
    if st.kind == "ag":
        x, recv = ctx
        my_bit = (r & st.distance) != 0
        return be.where(my_bit, be.concat(recv, x), be.concat(x, recv))
    raise ValueError(f"bad stage kind {st.kind}")


def exec_stage(x, st: Stage, be, p: int, op: Callable, tf=None):
    """Apply one schedule stage under backend ``be`` with transform ``tf``
    (start and finish back-to-back — the blocking composition)."""
    tf = tf or T.IdentityTransform()
    return _stage_finish(_stage_start(x, st, be, tf), st, be, p, op, tf)


def _run_phase(x, collective: str, be, p: int, op: Callable, tf):
    if p == 1:
        return x
    for st in Phase(collective, 0).stages(p):
        x = exec_stage(x, st, be, p, op, tf if collective != "allgather" else None)
    return x


# ---------------------------------------------------------------------------
# Live-plan tracking (elastic resize invalidation hook, DESIGN.md S12)
#
# Plans memoize derived state (backends, bound stage tables) per instance.
# A mesh resize changes axis sizes out from under long-lived plan objects;
# the elastic runtime calls invalidate_all_plans() at each ResizeEvent so
# every live plan rebuilds its derivations on next use.  A plain weakref
# list (not a WeakSet — frozen-dataclass equality would collapse distinct
# instances with equal fields) tracks liveness without pinning plans.
# ---------------------------------------------------------------------------

_LIVE_PLANS: list = []
_PRUNE_THRESHOLD = 256


def _track_plan(plan) -> None:
    global _PRUNE_THRESHOLD
    _LIVE_PLANS.append(weakref.ref(plan))
    # amortized prune: long-running non-elastic workloads construct plans
    # indefinitely and never call invalidate_all_plans(), so dead refs
    # must not accumulate unboundedly
    if len(_LIVE_PLANS) >= _PRUNE_THRESHOLD:
        _LIVE_PLANS[:] = [r for r in _LIVE_PLANS if r() is not None]
        _PRUNE_THRESHOLD = max(256, 2 * len(_LIVE_PLANS))


def live_plans() -> list:
    """Currently alive CollectivePlan instances (prunes dead refs)."""
    alive = []
    kept = []
    for ref in _LIVE_PLANS:
        p = ref()
        if p is not None:
            alive.append(p)
            kept.append(ref)
    _LIVE_PLANS[:] = kept
    return alive


def invalidate_all_plans() -> int:
    """Invalidate every live plan's memoized derivations (mesh resize
    hook).  Returns the number of plans invalidated."""
    plans_alive = live_plans()
    for p in plans_alive:
        p.invalidate()
    return len(plans_alive)


# ---------------------------------------------------------------------------
# CollectivePlan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CollectivePlan:
    """schedule x executor x transform x op, bound to axes (device) or p (sim).

    ``phases`` defaults to the registered decomposition of ``schedule``;
    pass it explicitly for primitive plans (a bare reduce-scatter or
    all-gather, see :func:`reduce_scatter_plan` / :func:`allgather_plan`).
    """

    schedule: str = "mrd"
    op: Any = "sum"  # 'sum' | 'max' | 'min' | callable
    transform: Any = "identity"  # name | transform instance
    executor: str = "device"  # 'device' | 'device_fused' | 'sim'
    axes: Optional[tuple[str, ...]] = None  # device: mesh axis names (chained)
    p: Optional[int] = None  # sim: stacked rank count
    phases: Optional[tuple[Phase, ...]] = None
    transform_kwargs: tuple = ()  # e.g. (('block', 128),)

    def __post_init__(self):
        if (self.axes is None) == (self.p is None):
            raise ValueError("bind exactly one of axes= (device) or p= (sim)")
        if self.p is not None and self.executor == "device":
            object.__setattr__(self, "executor", "sim")
        if self.axes is not None and isinstance(self.axes, str):
            object.__setattr__(self, "axes", (self.axes,))
        self._transform().validate_op(self.op)
        _track_plan(self)

    # -- layer resolution ---------------------------------------------------
    #
    # A frozen dataclass is memoizable: schedule construction, transform
    # resolution, and backend instantiation are cached per instance (in
    # ``__dict__``, invisible to dataclass eq/hash) so ``step()``/``run()``
    # don't rebuild them on every trace.  Anything depending on *device*
    # axis sizes is keyed by the resolved sizes, since the same plan object
    # may be traced under meshes of different shapes.

    def _memo(self, key, build):
        memo = self.__dict__.get("_memo_cache")
        if memo is None:
            memo = {}
            object.__setattr__(self, "_memo_cache", memo)
        if key not in memo:
            memo[key] = build()
        return memo[key]

    def invalidate(self):
        """Drop every memoized derivation (resolved backends, bound stage
        tables, cached permute specs) so the next use rebuilds against the
        current mesh/axis sizes.  The elastic runtime calls
        :func:`invalidate_all_plans` after a resize — device-axis plans
        re-resolve sizes per trace anyway (memo keys include the resolved
        sizes), so this is a hard guarantee plus a memory release for
        stage tables of extents that no longer exist."""
        self.__dict__.pop("_memo_cache", None)

    def _n_axes(self) -> int:
        return len(self.axes) if self.axes is not None else 1

    def _phases(self) -> tuple[Phase, ...]:
        if self.phases is not None:
            return self.phases
        return self._memo(
            "phases",
            lambda: tuple(get_schedule(self.schedule).phases(self._n_axes())),
        )

    def _transform(self):
        return self._memo(
            "transform",
            lambda: T.resolve_transform(
                self.transform, **dict(self.transform_kwargs)
            ),
        )

    def _backend(self, axis_index: int):
        if self.axes is not None:
            return self._memo(
                ("backend", axis_index),
                lambda: make_backend(self.executor, axis=self.axes[axis_index]),
            )
        return self._memo(
            ("backend", axis_index),
            lambda: make_backend(self.executor, p=self.p),
        )

    def _size(self, axis_index: int) -> int:
        """Static axis size; device sizes resolve inside the traced region."""
        if self.p is not None:
            return self.p
        from repro import compat

        return compat.axis_size(self.axes[axis_index])

    def _sizes(self) -> tuple[int, ...]:
        return tuple(self._size(ph.axis_index) for ph in self._phases())

    # -- introspection ------------------------------------------------------

    def bound_stages(self) -> tuple[tuple[Stage, int, int], ...]:
        """Flat [(stage, axis_index, p)] across phases (allreduce plans)."""

        def build():
            out = []
            for ph in self._phases():
                if ph.collective != "allreduce":
                    raise ValueError(
                        "stage-at-a-time stepping needs an allreduce-only plan "
                        f"(schedule {self.schedule!r} has a {ph.collective} phase)"
                    )
                p = self._size(ph.axis_index)
                for st in ph.stages(p):
                    out.append((st, ph.axis_index, p))
            return tuple(out)

        return self._memo(("bound_stages", self._sizes()), build)

    def bound_stage_table(
        self,
    ) -> tuple[tuple[Stage, str, int, int], ...]:
        """Flat [(stage, collective, axis_index, p)] across *all* phases —
        the bucketed engine's iteration order (any phase kinds)."""

        def build():
            out = []
            for ph in self._phases():
                p = self._size(ph.axis_index)
                for st in ph.stages(p):
                    out.append((st, ph.collective, ph.axis_index, p))
            return tuple(out)

        return self._memo(("stage_table", self._sizes()), build)

    def cycle_length(self) -> int:
        """Non-blocking calls per completed reduction (>= 1)."""
        return max(len(self.bound_stages()), 1)

    def pad_quantum(self) -> int:
        """Required divisor of the (1-D) buffer length for this plan."""
        q = self._transform().quantum
        for ph in self._phases():
            if ph.collective == "reduce_scatter":
                q *= pivot(self._size(ph.axis_index))[0]
        return q

    # -- telemetry ----------------------------------------------------------

    def _emit_stage_telemetry(self, n_bufs: int, nbytes: int) -> None:
        """Per-stage trace events + message/byte counters (caller gates on
        ``obs.enabled()``).  Stage structure is static per (schedule, sizes)
        so this emits at *bind* time — inside jit that is trace time, the
        only honest place: the traced region cannot host-record per call.
        Message counts come straight from the schedule (``len(st.pairs)``),
        so summing ``coll.messages`` over one MRD cycle reproduces the
        paper's p0*mu0 + 2*(p - 2^floor(log2 p)) closed form — the
        extra-message prediction lands in ``coll.extra_msgs`` (the shift
        stages)."""
        total = extra = vol = 0.0
        for s_idx, (st, coll, _ai, p) in enumerate(self.bound_stage_table()):
            msgs = len(st.pairs) * n_bufs
            per_rank = nbytes / max(p, 1) if self.p is not None else nbytes
            stage_bytes = len(st.pairs) * st.payload_fraction * per_rank
            total += msgs
            vol += stage_bytes
            if st.kind in ("bshift", "fshift"):
                extra += msgs
            obs.instant(
                "coll.stage",
                schedule=self.schedule,
                stage=s_idx,
                kind=st.kind,
                collective=coll,
                p=p,
                distance=st.distance,
                msgs=msgs,
                payload_fraction=st.payload_fraction,
            )
        obs.counter("coll.messages", schedule=self.schedule).add(total)
        obs.counter("coll.extra_msgs", schedule=self.schedule).add(extra)
        obs.counter("coll.bytes", schedule=self.schedule).add(vol)
        obs.counter("coll.runs", schedule=self.schedule).add(1)

    # -- blocking execution -------------------------------------------------

    def run(self, x):
        """Execute all phases.  Allreduce-only plans accept a pytree; plans
        with reduce-scatter/all-gather phases take a single array (device:
        1-D local vector, sim: ``[p, n]`` stacked)."""
        if not obs.enabled():
            return self._run_impl(x)
        nbytes = sum(int(l.size) * l.dtype.itemsize for l in jax.tree.leaves(x))
        with obs.span(
            "coll.run",
            schedule=self.schedule,
            executor=self.executor,
            sizes=list(self._sizes()),
            nbytes=nbytes,
        ):
            out = self._run_impl(x)
        self._emit_stage_telemetry(1, nbytes)
        return out

    def _run_impl(self, x):
        op = resolve_op(self.op)
        tf = self._transform()
        phases = self._phases()
        ar_only = all(ph.collective == "allreduce" for ph in phases)
        if ar_only:
            for ph in phases:
                be = self._backend(ph.axis_index)
                p = self._size(ph.axis_index)
                if p == 1:
                    continue
                x = jax.tree.map(
                    lambda leaf: _run_phase(leaf, "allreduce", be, p, op, tf), x
                )
            return x
        for ph in phases:
            be = self._backend(ph.axis_index)
            p = self._size(ph.axis_index)
            if ph.collective == "reduce_scatter" and p > 1:
                ndim = 2 if self.p is not None else 1
                if x.ndim != ndim:
                    raise ValueError(
                        f"reduce-scatter phase needs a {ndim}-D buffer "
                        f"({'[p, n] stacked' if ndim == 2 else 'rank-local 1-D'}), "
                        f"got shape {x.shape}"
                    )
                n = x.shape[-1]
                quantum = pivot(p)[0] * tf.quantum
                if n % quantum:
                    raise ValueError(
                        f"reduce-scatter phase over p={p} needs len % {quantum} "
                        f"== 0 (p0 x transform quantum), got {n}"
                    )
            x = _run_phase(x, ph.collective, be, p, op, tf)
        return x

    # -- bucketed, pipelined execution (DESIGN.md S10) ----------------------

    def run_buffers(self, bufs: Sequence) -> list:
        """Execute this plan's stages **stage-major across buffers**.

        ``bufs`` are independent 1-D buffers (sim: ``[p, n]`` stacked) —
        typically the buckets of :func:`repro.collectives.buckets.pack`.
        For every stage, buffer *k*'s permute is issued before buffer
        *k+1*'s previous-stage combine runs, so XLA can overlap
        collective-permute with the neighbouring buffers' encode/combine
        compute and no more than one stage of payload per buffer is in
        flight.  Identical math to :meth:`run` per buffer — bit-identical
        for the identity transform.
        """
        bufs = list(bufs)
        if not obs.enabled():
            return self._run_buffers_impl(bufs)
        nbytes = sum(int(b.size) * b.dtype.itemsize for b in bufs)
        with obs.span(
            "coll.run_buffers",
            schedule=self.schedule,
            n_buffers=len(bufs),
            nbytes=nbytes,
        ):
            out = self._run_buffers_impl(bufs)
        if bufs:
            self._emit_stage_telemetry(len(bufs), nbytes)
        return out

    def _run_buffers_impl(self, bufs: list) -> list:
        table = self.bound_stage_table()
        if not table or not bufs:
            return bufs
        op = resolve_op(self.op)
        tf = self._transform()
        if any(coll == "reduce_scatter" for _, coll, _, _ in table):
            q = self.pad_quantum()
            for i, b in enumerate(bufs):
                if b.shape[-1] % q:
                    raise ValueError(
                        f"reduce-scatter phases need buffer len % {q} == 0 "
                        f"(pad_quantum), got {b.shape[-1]} for buffer {i}"
                    )
        ctxs: list = [None] * len(bufs)
        prev = None  # (stage, backend, p) whose permutes are in flight
        for st, _coll, ai, p in table:
            be = self._backend(ai)
            for k in range(len(bufs)):
                if prev is not None:
                    bufs[k] = _stage_finish(ctxs[k], *prev, op, tf)
                ctxs[k] = _stage_start(bufs[k], st, be, tf)
            prev = (st, be, p)
        return [_stage_finish(c, *prev, op, tf) for c in ctxs]

    def run_bucketed(self, tree, *, bucket_bytes=None, layout=None):
        """Allreduce a pytree in dtype-homogeneous, size-capped buckets.

        Leaves are packed by :mod:`repro.collectives.buckets` (dtypes are
        preserved end-to-end — a bf16 leaf travels and reduces as bf16),
        each bucket padded to :meth:`pad_quantum`, then all stages execute
        pipelined via :meth:`run_buffers`.  Pass ``layout`` to reuse a
        prebuilt :class:`~repro.collectives.buckets.BucketLayout`;
        otherwise one is derived from the tree (``bucket_bytes=None`` =
        one bucket per dtype).  Only allreduce-composition schedules
        (every registered ``SCHEDULES`` entry) preserve buffer lengths
        end-to-end, so primitive RS/AG plans are rejected.
        """
        from repro.collectives import buckets as B

        if self.phases is not None and not all(
            ph.collective == "allreduce" for ph in self.phases
        ):
            raise ValueError(
                "run_bucketed needs an allreduce-schedule plan (primitive "
                "reduce-scatter/all-gather plans change buffer lengths)"
            )
        if layout is None:
            layout = B.build_layout(
                tree,
                bucket_bytes=bucket_bytes,
                quantum=self.pad_quantum(),
                stacked=self.p,
            )
        bufs = B.pack(tree, layout)
        return B.unpack(self.run_buffers(bufs), layout)

    # -- late-admission pipelined execution (DESIGN.md S16) -----------------

    def pipeline(self) -> "BucketPipeline":
        """A :class:`BucketPipeline` over this plan — :meth:`run_buffers`
        generalized so buckets may be *admitted while earlier buckets are
        already in flight* (the ready-bucket grad-sync overlap path)."""
        return BucketPipeline(self)

    # -- non-blocking state machine (paper Fig. 4) --------------------------

    def init(self, value) -> dict[str, Any]:
        """Create the state machine's state, latching ``value`` as the first
        cycle's contribution.  ``value``: per-rank pytree (device) or
        ``[p, ...]`` stacked (sim)."""
        return {
            "stage": jnp.zeros((), jnp.int32),
            "buf": value,
            "result": jax.tree.map(jnp.zeros_like, value),
            "flag": jnp.zeros((), jnp.bool_),  # True for exactly the completing call
            "cycles": jnp.zeros((), jnp.int32),
        }

    def step(self, state: dict[str, Any], local_value) -> dict[str, Any]:
        """Advance the non-blocking collective by one stage.

        Returns the new state.  ``state['flag']`` is True iff this call
        completed a cycle; then ``state['result']`` holds the reduction of
        the values latched at that cycle's start.  ``local_value`` is
        latched only when a new cycle begins (stage == 0), matching the
        paper's statechart.
        """
        op = resolve_op(self.op)
        tf = self._transform()
        bound = self.bound_stages()
        nstages = len(bound)

        if nstages == 0:  # all axes size 1: every call is a complete cycle
            return {
                "stage": state["stage"],
                "buf": local_value,
                "result": local_value,
                "flag": jnp.ones((), jnp.bool_),
                "cycles": state["cycles"] + 1,
            }

        starting = state["stage"] == 0
        buf = jax.tree.map(
            lambda lv, b: jnp.where(starting, lv, b), local_value, state["buf"]
        )

        def _stage_fn(st, axis_index, p):
            be = self._backend(axis_index)

            def apply(b):
                return jax.tree.map(
                    lambda leaf: exec_stage(leaf, st, be, p, op, tf), b
                )

            return apply

        buf = jax.lax.switch(
            state["stage"], [_stage_fn(*b) for b in bound], buf
        )

        nxt = state["stage"] + 1
        done = nxt == nstages
        return {
            "stage": jnp.where(done, 0, nxt),
            "buf": buf,
            "result": jax.tree.map(
                lambda b, r: jnp.where(done, b, r), buf, state["result"]
            ),
            "flag": done,
            "cycles": state["cycles"] + done.astype(jnp.int32),
        }

    def run_blocking(self, value):
        """Drive the state machine through one full cycle (tests/reference)."""
        st = self.init(value)
        for _ in range(self.cycle_length()):
            st = self.step(st, value)
        return st["result"]


# ---------------------------------------------------------------------------
# BucketPipeline: run_buffers with late admission (DESIGN.md S16)
# ---------------------------------------------------------------------------


class BucketPipeline:
    """Stage-major pipelined execution with **late bucket admission**.

    :meth:`CollectivePlan.run_buffers` needs every bucket up front; the
    ready-bucket overlap path (gradsync ``overlap=True``) produces
    buckets *while earlier buckets are already mid-schedule* — bucket k's
    permutes must be in flight while the backward segments that feed
    buckets k+1..N are still tracing.  This class is the same stage
    interpreter (:func:`_stage_start` / :func:`_stage_finish`) with an
    explicit in-flight set:

    - :meth:`admit` packs a new bucket into the pipeline and issues its
      first stage's permute;
    - :meth:`advance` moves every in-flight bucket forward one stage
      (finish the received payload, issue the next permute) — call it
      between backward segments so the permutes overlap autodiff compute;
    - :meth:`drain` runs all remaining stages stage-major and returns
      the finished buffers.

    Per bucket the stage sequence is exactly ``run_buffers``'s, and every
    stage's math touches only that bucket's arrays, so results are
    **bit-identical** to ``run_buffers`` for any admission/advance
    interleaving — including for lossy transforms (int8 block grids are
    keyed to offsets within a bucket, which this never changes).
    """

    def __init__(self, plan: CollectivePlan):
        self.plan = plan
        self.table = plan.bound_stage_table()
        self._op = resolve_op(plan.op)
        self._tf = plan._transform()
        self._check_quantum = any(
            coll == "reduce_scatter" for _, coll, _, _ in self.table
        )
        self._q = plan.pad_quantum() if self._check_quantum else 1
        self._inflight: dict = {}  # key -> (stage index started, ctx)
        self._done: dict = {}

    def _start(self, buf, i: int):
        st, _coll, ai, _p = self.table[i]
        return _stage_start(buf, st, self.plan._backend(ai), self._tf)

    def _finish(self, ctx, i: int):
        st, _coll, ai, p = self.table[i]
        return _stage_finish(ctx, st, self.plan._backend(ai), p, self._op, self._tf)

    def admit(self, key, buf) -> None:
        """Enter ``buf`` into the pipeline under ``key`` and issue its
        first stage.  Plans with no stages (all axes size 1) complete
        immediately."""
        if key in self._inflight or key in self._done:
            raise ValueError(f"bucket {key!r} admitted twice")
        if self._check_quantum and buf.shape[-1] % self._q:
            raise ValueError(
                f"reduce-scatter phases need buffer len % {self._q} == 0 "
                f"(pad_quantum), got {buf.shape[-1]} for bucket {key!r}"
            )
        obs.instant(
            "coll.pipeline.admit",
            key=str(key),
            inflight=len(self._inflight) + 1,
            nbytes=int(buf.size) * buf.dtype.itemsize,
        )
        if not self.table:
            self._done[key] = buf
            return
        self._inflight[key] = (0, self._start(buf, 0))

    def advance(self) -> None:
        """Advance every in-flight bucket by one stage (admission order)."""
        for key in list(self._inflight):
            i, ctx = self._inflight[key]
            buf = self._finish(ctx, i)
            if i + 1 < len(self.table):
                self._inflight[key] = (i + 1, self._start(buf, i + 1))
            else:
                del self._inflight[key]
                self._done[key] = buf

    def drain(self) -> dict:
        """Run all remaining stages stage-major; returns {key: buffer}
        and resets the pipeline."""
        n = len(self._inflight) + len(self._done)
        with obs.span("coll.pipeline.drain", n_buckets=n):
            while self._inflight:
                self.advance()
            out, self._done = self._done, {}
        if obs.enabled() and out:
            nbytes = sum(int(b.size) * b.dtype.itemsize for b in out.values())
            self.plan._emit_stage_telemetry(len(out), nbytes)
        return out


# ---------------------------------------------------------------------------
# Plan factories
# ---------------------------------------------------------------------------


def allreduce_plan(
    *,
    schedule: str = "mrd",
    op: Any = "sum",
    transform: Any = "identity",
    executor: str = "device",
    axes: Optional[Sequence[str]] = None,
    p: Optional[int] = None,
    **transform_kwargs,
) -> CollectivePlan:
    return CollectivePlan(
        schedule=schedule,
        op=op,
        transform=transform,
        executor=executor,
        axes=tuple(axes) if axes is not None else None,
        p=p,
        transform_kwargs=tuple(sorted(transform_kwargs.items())),
    )


def reduce_scatter_plan(
    *,
    op: Any = "sum",
    transform: Any = "identity",
    executor: str = "device",
    axes: Optional[Sequence[str]] = None,
    p: Optional[int] = None,
    **transform_kwargs,
) -> CollectivePlan:
    """Chained recursive-halving reduce-scatter over ``axes`` (in order)."""
    n = len(axes) if axes is not None else 1
    return CollectivePlan(
        schedule="reduce_scatter",
        op=op,
        transform=transform,
        executor=executor,
        axes=tuple(axes) if axes is not None else None,
        p=p,
        phases=tuple(Phase("reduce_scatter", i) for i in range(n)),
        transform_kwargs=tuple(sorted(transform_kwargs.items())),
    )


def allgather_plan(
    *,
    executor: str = "device",
    axes: Optional[Sequence[str]] = None,
    p: Optional[int] = None,
) -> CollectivePlan:
    """Chained recursive-doubling all-gather (reverse axis order, the inverse
    of :func:`reduce_scatter_plan`)."""
    n = len(axes) if axes is not None else 1
    return CollectivePlan(
        schedule="allgather",
        executor=executor,
        axes=tuple(axes) if axes is not None else None,
        p=p,
        phases=tuple(Phase("allgather", i) for i in reversed(range(n))),
    )


def tree_allreduce(
    tree,
    *,
    schedule: str = "mrd",
    op: Any = "sum",
    transform: Any = "identity",
    executor: str = "device",
    axes: Sequence[str] = (),
    p: Optional[int] = None,
    bucket_bytes: Optional[int] = None,
    **transform_kwargs,
):
    """Allreduce a pytree in dtype-homogeneous buckets, chained over
    ``axes`` (device) or a stacked rank count ``p`` (sim).

    Runs through :meth:`CollectivePlan.run_bucketed`: leaf dtypes are
    preserved end-to-end (a bf16+fp32 tree no longer promotes to one fp32
    wire vector), and ``bucket_bytes`` caps each wire buffer so stages
    pipeline across buckets instead of materializing one flat gradient.
    ``rabenseifner`` is the default-worthy schedule for bandwidth-bound
    payloads like gradients; ``mrd`` for latency-bound.
    """
    if p is not None and axes:
        raise ValueError(
            "bind exactly one of axes= (device) or p= (sim), not both"
        )
    plan = allreduce_plan(
        schedule=schedule,
        op=op,
        transform=transform,
        executor=executor,
        axes=axes if p is None else None,
        p=p,
        **transform_kwargs,
    )
    return plan.run_bucketed(tree, bucket_bytes=bucket_bytes)
