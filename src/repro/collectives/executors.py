"""Layer 2 of the collectives subsystem: *executors* (backends).

A backend knows how to move data between ranks and how to express
rank-dependent selection — nothing about schedules or wire formats:

- :class:`DeviceBackend`: runs inside ``shard_map`` using
  ``jax.lax.ppermute`` (collective-permute, the native TPU ICI
  primitive).  SPMD: every rank runs the same program; shift stages are
  masked by rank predicates.
- :class:`FusedDeviceBackend`: same, but the per-stage quantized combine
  (``keep += dequant(recv)``) runs through the ``mrd_combine`` Pallas
  kernel — one VMEM pass instead of three HBM round-trips.
- :class:`SimBackend`: pure ``jnp`` over a stacked leading rank axis
  ``[p, ...]``.  Runs on a single CPU device, so correctness of the
  schedule math is exhaustively testable for any ``p`` (including
  non-powers-of-two, the paper's case) without multi-device hardware.

All backends share the same stage-interpretation code
(``repro.collectives.plans``), so the compiled collective is, by
construction, the validated math.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat

OPS: dict[str, Callable[[Any, Any], Any]] = {
    "sum": jnp.add,
    "max": jnp.maximum,
    "min": jnp.minimum,
}


def resolve_op(op: str | Callable) -> Callable:
    if callable(op):
        return op
    try:
        return OPS[op]
    except KeyError:
        raise ValueError(f"unknown reduction op {op!r}; known: {sorted(OPS)}")


@runtime_checkable
class Backend(Protocol):
    """What the plan layer needs from an executor."""

    def rank(self): ...

    def size(self) -> int: ...

    def permute(self, x, pairs): ...

    def where(self, mask, a, b): ...

    def split_half(self, x): ...

    def concat(self, a, b): ...

    def vmap_ranks(self, fn: Callable) -> Callable:
        """Lift a per-rank (local-view) function to this backend's layout."""
        ...


class DeviceBackend:
    """Executes stages with ppermute over a named mesh axis (inside shard_map)."""

    def __init__(self, axis_name: str):
        self.axis = axis_name

    def rank(self):
        return jax.lax.axis_index(self.axis)

    def size(self) -> int:
        return compat.axis_size(self.axis)

    def permute(self, x, pairs):
        if not pairs:
            return jnp.zeros_like(x)
        return jax.lax.ppermute(x, self.axis, pairs)

    def where(self, mask, a, b):
        return jnp.where(mask, a, b)

    # value-dimension helpers (device arrays carry no rank axis)
    def split_half(self, x):
        n = x.shape[0]
        return x[: n // 2], x[n // 2 :]

    def concat(self, a, b):
        return jnp.concatenate([a, b], axis=0)

    def vmap_ranks(self, fn):
        return fn  # device arrays are already the local view

    def combine_quantized(self, x, q, scales, block: int):
        """keep + dequant(q, scales) — overridden by the fused executor."""
        deq = q.astype(jnp.float32).reshape(-1, block) * scales[:, None]
        return x + deq.reshape(x.shape)


class FusedDeviceBackend(DeviceBackend):
    """DeviceBackend whose quantized combine is the Pallas ``mrd_combine`` op
    (compiled on TPU, interpret elsewhere).  Falls back to the unfused path
    when the payload doesn't tile the kernel's 256-element quantization
    block."""

    def combine_quantized(self, x, q, scales, block: int):
        from repro.kernels.mrd_combine.kernel import QBLOCK
        from repro.kernels.mrd_combine.ops import mrd_combine

        if block != QBLOCK or x.ndim != 1 or x.shape[0] % QBLOCK:
            return super().combine_quantized(x, q, scales, block)
        return mrd_combine(x, q, scales)


@functools.lru_cache(maxsize=4096)
def _sim_gather_spec(p: int, pairs) -> tuple[np.ndarray, np.ndarray]:
    """Per-(p, pairs) gather index / receive mask, built once.

    Schedules reuse the same static pairs tuples across stages, buckets,
    and traces, so the Python loop runs once per distinct stage shape
    instead of on every trace.  Arrays are frozen — cache entries are
    shared.
    """
    idx = np.zeros(p, dtype=np.int32)
    has = np.zeros(p, dtype=bool)
    for s, d in pairs:
        idx[d] = s
        has[d] = True
    idx.setflags(write=False)
    has.setflags(write=False)
    return idx, has


class SimBackend:
    """Executes stages on stacked arrays [p, ...] on a single device."""

    def __init__(self, p: int):
        self.p = p

    def rank(self):
        return jnp.arange(self.p)

    def size(self) -> int:
        return self.p

    def permute(self, x, pairs):
        idx, has = _sim_gather_spec(self.p, tuple(pairs))
        recv = jnp.take(x, jnp.asarray(idx), axis=0)
        mask = jnp.asarray(has).reshape((self.p,) + (1,) * (x.ndim - 1))
        return jnp.where(mask, recv, jnp.zeros_like(recv))

    def where(self, mask, a, b):
        mask = jnp.asarray(mask)
        nd = max(getattr(a, "ndim", 0), getattr(b, "ndim", 0))
        mask = mask.reshape(mask.shape + (1,) * (nd - mask.ndim))
        return jnp.where(mask, a, b)

    def split_half(self, x):
        n = x.shape[1]
        return x[:, : n // 2], x[:, n // 2 :]

    def concat(self, a, b):
        return jnp.concatenate([a, b], axis=1)

    def vmap_ranks(self, fn):
        return jax.vmap(fn)

    def combine_quantized(self, x, q, scales, block: int):
        def one(xr, qr, sr):
            deq = qr.astype(jnp.float32).reshape(-1, block) * sr[:, None]
            return xr + deq.reshape(xr.shape)

        return jax.vmap(one)(x, q, scales)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

EXECUTORS: dict[str, Callable[..., Any]] = {}


def register_executor(name: str):
    def deco(factory):
        EXECUTORS[name] = factory
        return factory

    return deco


def make_backend(
    executor: str, *, axis: Optional[str] = None, p: Optional[int] = None
):
    """Instantiate a registered executor, bound to a device axis or a sim p."""
    try:
        factory = EXECUTORS[executor]
    except KeyError:
        raise ValueError(
            f"unknown executor {executor!r}; registered: {sorted(EXECUTORS)}"
        ) from None
    return factory(axis=axis, p=p)


@register_executor("device")
def _device(axis=None, p=None):
    if axis is None:
        raise ValueError("executor 'device' needs an axis name")
    return DeviceBackend(axis)


@register_executor("device_fused")
def _device_fused(axis=None, p=None):
    if axis is None:
        raise ValueError("executor 'device_fused' needs an axis name")
    return FusedDeviceBackend(axis)


@register_executor("sim")
def _sim(axis=None, p=None):
    if p is None:
        raise ValueError("executor 'sim' needs p")
    return SimBackend(p)
