"""Layer 1 of the collectives subsystem: communication *schedules*.

This module is the mathematical heart of the paper: it builds, for an
arbitrary number of ranks ``p``, the static stage list of the modified
recursive doubling Allreduce (backward shift -> XOR butterfly -> forward
shift), plus the recursive-halving reduce-scatter and recursive-doubling
all-gather used by the beyond-paper Rabenseifner/ZeRO-1 paths.

Schedules are pure data (rank pairs + stage kinds).  Executors
(``repro.collectives.executors``) consume them through plans
(``repro.collectives.plans``).  Message/step accounting for the paper's
cost claims lives here so benchmarks and tests read from the same source
of truth as the executors.

The :data:`SCHEDULES` registry maps allreduce-schedule names to *phase*
decompositions over one or more mesh axes:

- ``mrd``:           one MRD allreduce per axis (latency-optimal,
                     ``log2(p0)+2`` stages, full payload each stage);
- ``rabenseifner``:  chained reduce-scatter then all-gather (bandwidth-
                     optimal, ~2n per rank; paper ref. [20]);
- ``hierarchical``:  RS on the inner (fast) axis, MRD allreduce across
                     the outer axis on the 1/p0-size shard, AG back on
                     the inner axis — inter-pod bytes drop by 1/p0(inner).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Literal, Sequence

StageKind = Literal["bshift", "butterfly", "rs", "ag", "fshift"]


@dataclasses.dataclass(frozen=True)
class Stage:
    """One communication stage: a static list of (src, dst) rank pairs.

    ``kind`` controls the combine rule applied by executors:
      - ``bshift``:    dst (< extra) does ``x = op(x, recv)``
      - ``butterfly``: ranks < p0 do ``x = op(x, recv)`` (full-buffer exchange)
      - ``rs``:        recursive-halving exchange (half-buffer, keep+reduce)
      - ``ag``:        recursive-doubling gather (buffer doubles)
      - ``fshift``:    dst (>= p0) does ``x = recv``
    """

    kind: StageKind
    pairs: tuple[tuple[int, int], ...]
    distance: int = 0  # butterfly/rs/ag partner distance, 0 for shifts
    # Fraction of the full buffer each message carries at this stage
    # (1.0 for allreduce stages; 2^-(s+1) for rs; mirrored for ag).
    payload_fraction: float = 1.0


def pivot(p: int) -> tuple[int, int, int]:
    """Return (p0, mu0, extra) with p0 = 2^mu0 <= p < 2^(mu0+1)."""
    if p < 1:
        raise ValueError(f"need p >= 1, got {p}")
    mu0 = p.bit_length() - 1
    p0 = 1 << mu0
    return p0, mu0, p - p0


def is_power_of_two(p: int) -> bool:
    return p >= 1 and (p & (p - 1)) == 0


def backward_shift_stage(p: int) -> Stage:
    p0, _, _ = pivot(p)
    return Stage("bshift", tuple((r, r - p0) for r in range(p0, p)))


def forward_shift_stage(p: int) -> Stage:
    p0, _, extra = pivot(p)
    return Stage("fshift", tuple((r, r + p0) for r in range(extra)))


def allreduce_schedule(p: int) -> list[Stage]:
    """The paper's modified recursive doubling Allreduce.

    backward shift (if p != p0) -> mu0 XOR-butterfly stages -> forward shift.
    Exactly ``log2(p0) + 2`` stages in the general case and ``log2(p0)`` when
    ``p`` is a power of two (the shifts are skipped, paper S4).
    """
    p0, mu0, extra = pivot(p)
    stages: list[Stage] = []
    if extra:
        stages.append(backward_shift_stage(p))
    for s in range(mu0):
        d = 1 << s
        stages.append(
            Stage("butterfly", tuple((i, i ^ d) for i in range(p0)), distance=d)
        )
    if extra:
        stages.append(forward_shift_stage(p))
    return stages


def reduce_scatter_schedule(p: int) -> list[Stage]:
    """Recursive-halving reduce-scatter over the p0 pivot ranks.

    After the backward shift, stage s exchanges buffer halves with the partner
    at distance ``p0 >> (s+1)`` (large -> small).  Rank r (< p0) ends holding
    segment r (natural order) of the reduced vector.  Extra ranks carry dummy
    buffers (masked by executors).
    """
    p0, mu0, extra = pivot(p)
    stages: list[Stage] = []
    if extra:
        stages.append(backward_shift_stage(p))
    for s in range(mu0):
        d = p0 >> (s + 1)
        stages.append(
            Stage(
                "rs",
                tuple((i, i ^ d) for i in range(p0)),
                distance=d,
                payload_fraction=0.5 ** (s + 1),
            )
        )
    return stages


def allgather_schedule(p: int) -> list[Stage]:
    """Recursive-doubling all-gather (inverse of reduce_scatter_schedule).

    Stage s exchanges the current buffer with the partner at distance
    ``p0 >> (mu0 - s)`` (small -> large); buffers double each stage.  A
    forward shift delivers the full vector to the extra ranks.
    """
    p0, mu0, extra = pivot(p)
    stages: list[Stage] = []
    for s in range(mu0):
        d = 1 << s
        stages.append(
            Stage(
                "ag",
                tuple((i, i ^ d) for i in range(p0)),
                distance=d,
                payload_fraction=0.5 ** (mu0 - s),
            )
        )
    if extra:
        stages.append(forward_shift_stage(p))
    return stages


def rabenseifner_schedule(p: int) -> list[Stage]:
    """Bandwidth-optimal allreduce = reduce-scatter + all-gather.

    Beyond-paper (the paper's own ref. [20]): per-rank traffic is
    ~2n(1 - 1/p0) instead of n*log2(p0); the same backward/forward shifts
    handle the non-power-of-two case.
    """
    rs = reduce_scatter_schedule(p)
    ag = allgather_schedule(p)
    return rs + ag


# ---------------------------------------------------------------------------
# Schedule registry: name -> phase decomposition over the plan's axes.
# ---------------------------------------------------------------------------

Collective = Literal["allreduce", "reduce_scatter", "allgather"]


@dataclasses.dataclass(frozen=True)
class Phase:
    """One primitive collective applied over one of a plan's axes."""

    collective: Collective
    axis_index: int

    def stages(self, p: int) -> list[Stage]:
        return PRIMITIVES[self.collective](p)


PRIMITIVES: dict[str, Callable[[int], list[Stage]]] = {
    "allreduce": allreduce_schedule,
    "reduce_scatter": reduce_scatter_schedule,
    "allgather": allgather_schedule,
}


@dataclasses.dataclass(frozen=True)
class ScheduleFamily:
    """A named allreduce realization: how to phase it over ``n_axes`` axes."""

    name: str
    phases_fn: Callable[[int], list[Phase]]
    min_axes: int = 1

    def phases(self, n_axes: int) -> list[Phase]:
        if n_axes < self.min_axes:
            raise ValueError(
                f"schedule {self.name!r} needs >= {self.min_axes} axes, got {n_axes}"
            )
        return self.phases_fn(n_axes)


SCHEDULES: dict[str, ScheduleFamily] = {}


def register_schedule(name: str, phases_fn: Callable[[int], list[Phase]], **kw):
    fam = ScheduleFamily(name, phases_fn, **kw)
    SCHEDULES[name] = fam
    return fam


def get_schedule(name: str) -> ScheduleFamily:
    try:
        return SCHEDULES[name]
    except KeyError:
        raise ValueError(
            f"unknown schedule {name!r}; registered: {sorted(SCHEDULES)}"
        ) from None


register_schedule(
    "mrd",
    lambda n: [Phase("allreduce", i) for i in range(n)],
)
register_schedule(
    "rabenseifner",
    lambda n: [Phase("reduce_scatter", i) for i in range(n)]
    + [Phase("allgather", i) for i in reversed(range(n))],
)
register_schedule(
    "hierarchical",
    # chained RS over the inner (fast-link) axes -> allreduce across the
    # outermost (slow-link) axis on the 1/prod(p0_inner) shard -> chained AG
    # back out.  Axis order: innermost first, outermost last.
    lambda n: [Phase("reduce_scatter", i) for i in range(n - 1)]
    + [Phase("allreduce", n - 1)]
    + [Phase("allgather", i) for i in reversed(range(n - 1))],
    min_axes=2,
)


# ---------------------------------------------------------------------------
# Cost accounting (the paper's S2 claims; benchmarks/tests read these).
# ---------------------------------------------------------------------------


def schedule_steps(stages: Sequence[Stage]) -> int:
    return len(stages)


def schedule_messages(stages: Sequence[Stage]) -> int:
    """Total point-to-point messages in one cycle (paper: p0*log2(p0) + 2(p-p0)
    for the MRD allreduce)."""
    return sum(len(st.pairs) for st in stages)


def schedule_volume(stages: Sequence[Stage], n_elements: int) -> float:
    """Total elements moved across the network in one cycle."""
    return sum(len(st.pairs) * st.payload_fraction * n_elements for st in stages)


def per_rank_volume(stages: Sequence[Stage], n_elements: int, rank: int) -> float:
    """Elements *sent* by ``rank`` over the cycle."""
    total = 0.0
    for st in stages:
        for src, _ in st.pairs:
            if src == rank:
                total += st.payload_fraction * n_elements
    return total


def paper_message_count(p: int) -> int:
    """Closed form from the paper, S2: p0*log2(p0) + 2*(p - p0)."""
    p0, mu0, extra = pivot(p)
    return p0 * mu0 + 2 * extra


def paper_step_count(p: int) -> int:
    """Closed form from the paper, S2: log2(p0) + 2 (shifts skipped if p=2^k)."""
    _, mu0, extra = pivot(p)
    return mu0 + (2 if extra else 0)


# ---------------------------------------------------------------------------
# Latency/bandwidth cost model (alpha-beta), used to compare schedules for a
# given interconnect without running them (benchmarks/bench_mrd.py).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinkModel:
    alpha_s: float  # per-message latency (seconds)
    beta_s_per_byte: float  # inverse bandwidth (seconds/byte)

    @classmethod
    def tpu_v5e_ici(cls) -> "LinkModel":
        # ~50 GB/s per ICI link; ~1us collective-permute launch latency.
        return cls(alpha_s=1e-6, beta_s_per_byte=1.0 / 50e9)

    @classmethod
    def dcn(cls) -> "LinkModel":
        # Inter-pod data-center network: ~25 GB/s effective, ~10us latency.
        return cls(alpha_s=10e-6, beta_s_per_byte=1.0 / 25e9)


def schedule_time(
    stages: Sequence[Stage], n_bytes: int, link: LinkModel
) -> float:
    """Alpha-beta time of one cycle: stages are sequential; within a stage all
    pairs proceed in parallel, so a stage costs alpha + fraction*n*beta."""
    t = 0.0
    for st in stages:
        if not st.pairs:
            continue
        t += link.alpha_s + st.payload_fraction * n_bytes * link.beta_s_per_byte
    return t


def ring_allreduce_time(p: int, n_bytes: int, link: LinkModel) -> float:
    """Reference: ring allreduce = 2(p-1) steps of n/p bytes."""
    if p == 1:
        return 0.0
    return 2 * (p - 1) * (link.alpha_s + (n_bytes / p) * link.beta_s_per_byte)


def tree_allreduce_time(p: int, n_bytes: int, link: LinkModel) -> float:
    """Reference: binomial tree reduce+bcast = 2*ceil(log2 p) full-buffer steps."""
    if p == 1:
        return 0.0
    return 2 * math.ceil(math.log2(p)) * (
        link.alpha_s + n_bytes * link.beta_s_per_byte
    )
