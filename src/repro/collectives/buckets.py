"""Bucketizer for the pipelined collective execution engine (DESIGN.md S10).

Gradient-scale collectives should neither ravel the whole pytree into one
flat vector (mixed dtypes promote — bf16 leaves travel as fp32, ~2x wire
bytes — and the single flat buffer doubles peak memory) nor run a full
schedule cycle per leaf (per-message alpha cost paid once per tensor).
This module packs pytree leaves into **dtype-homogeneous, size-capped
buckets** with stable pack/unpack layout metadata; the plan layer
(:meth:`repro.collectives.plans.CollectivePlan.run_bucketed`) then
executes schedules stage-major across the buckets so collective-permute
overlaps with the neighbouring buckets' encode/combine compute.

Layout rules (deterministic for a given tree structure + cap):

- leaves are visited in ``jax.tree.leaves`` order;
- each bucket holds leaves of exactly one dtype (no promotion, ever);
- a bucket closes when adding the next same-dtype leaf would push it past
  ``bucket_bytes`` (a leaf larger than the cap gets a bucket of its own —
  leaves are never split);
- each bucket's element length is padded up to a multiple of ``quantum``
  (the owning plan's :meth:`pad_quantum`), so reduce-scatter phases
  divide evenly;
- buckets are ordered by their first leaf's tree position.

Peak extra memory is therefore bounded by ``max(bucket_bytes,
largest_leaf_bytes) + quantum padding`` per in-flight bucket instead of
the full flat gradient.

Sim-executor trees carry a stacked leading rank axis ``[p, ...]``; pass
``stacked=p`` to :func:`build_layout` and the per-rank views are packed
along the trailing axis (buffers become ``[p, length]``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

DEFAULT_BUCKET_BYTES = 32 * 2**20  # production-ish cap (cf. DDP's 25 MB)


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Where one pytree leaf lives inside its bucket.

    ``shape`` is the per-rank (local) shape — the stacked sim rank axis,
    if any, is *not* included.  ``offset``/``size`` are element counts
    into the bucket's unpadded prefix.
    """

    index: int  # position in jax.tree.leaves order
    shape: tuple[int, ...]
    dtype: str  # canonical dtype name ('float32', 'bfloat16', ...)
    offset: int
    size: int


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One dtype-homogeneous wire buffer: which slots it carries and how
    long it is after padding to the plan's quantum."""

    dtype: str
    slots: tuple[LeafSlot, ...]
    length: int  # padded element length (multiple of the layout quantum)

    @property
    def used(self) -> int:
        return sum(s.size for s in self.slots)

    @property
    def nbytes(self) -> int:
        return self.length * jnp.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class BucketLayout:
    """Stable pack/unpack metadata for one tree structure.

    Built once per (tree structure, bucket_bytes, quantum) — reusable
    across steps since it depends only on static shapes/dtypes.
    """

    buckets: tuple[Bucket, ...]
    treedef: Any
    n_leaves: int
    quantum: int
    stacked: Optional[int]  # sim rank count, or None for device/local trees

    @property
    def bucket_lengths(self) -> tuple[int, ...]:
        return tuple(b.length for b in self.buckets)

    @property
    def total_padded(self) -> int:
        return sum(b.length for b in self.buckets)


def _dtype_name(dtype) -> str:
    return jnp.dtype(dtype).name


def build_layout(
    tree,
    *,
    bucket_bytes: Optional[int] = None,
    quantum: int = 1,
    stacked: Optional[int] = None,
) -> BucketLayout:
    """Plan dtype-homogeneous, size-capped buckets for ``tree``.

    ``tree`` may hold arrays or ``jax.ShapeDtypeStruct``s (only shapes and
    dtypes are read).  ``bucket_bytes=None`` means one unbounded bucket
    per dtype.  ``quantum`` is the element-count divisor each bucket is
    padded to (the owning plan's ``pad_quantum()``).
    """
    if quantum < 1:
        raise ValueError(f"quantum must be >= 1, got {quantum}")
    leaves, treedef = jax.tree.flatten(tree)
    open_slots: dict[str, list[LeafSlot]] = {}
    open_elems: dict[str, int] = {}
    closed: list[tuple[str, tuple[LeafSlot, ...]]] = []

    def close(dt: str):
        closed.append((dt, tuple(open_slots.pop(dt))))
        open_elems.pop(dt)

    for i, leaf in enumerate(leaves):
        shape = tuple(leaf.shape)
        if stacked is not None:
            if not shape or shape[0] != stacked:
                raise ValueError(
                    f"stacked={stacked} needs every leaf to carry a leading "
                    f"rank axis of that size; leaf {i} has shape {shape}"
                )
            shape = shape[1:]
        dt = _dtype_name(leaf.dtype)
        size = math.prod(shape)
        itemsize = jnp.dtype(dt).itemsize
        if dt in open_slots:
            if (
                bucket_bytes is not None
                and (open_elems[dt] + size) * itemsize > bucket_bytes
                and open_slots[dt]
            ):
                close(dt)
        if dt not in open_slots:
            open_slots[dt] = []
            open_elems[dt] = 0
        open_slots[dt].append(
            LeafSlot(index=i, shape=shape, dtype=dt, offset=open_elems[dt], size=size)
        )
        open_elems[dt] += size
    for dt in list(open_slots):
        close(dt)

    closed.sort(key=lambda b: b[1][0].index)  # stable: first-leaf tree order
    buckets = tuple(
        Bucket(
            dtype=dt,
            slots=slots,
            length=max(quantum, -(-sum(s.size for s in slots) // quantum) * quantum),
        )
        for dt, slots in closed
    )
    return BucketLayout(
        buckets=buckets,
        treedef=treedef,
        n_leaves=len(leaves),
        quantum=quantum,
        stacked=stacked,
    )


def pack_bucket(leaves: Sequence, layout: BucketLayout, i: int):
    """Pack bucket ``i`` of ``layout`` from a full ``jax.tree.leaves``-order
    leaf list (entries outside the bucket's slots may be ``None``).

    This is the per-bucket half of :func:`pack` — the ready-bucket
    overlap path (DESIGN.md S16) packs each bucket the moment its
    backward segment delivers the slots' gradients, so it must produce
    byte-identical buffers to a post-backward :func:`pack`.
    """
    b = layout.buckets[i]
    p = layout.stacked
    parts = []
    for s in b.slots:
        leaf = leaves[s.index]
        if leaf is None:
            raise ValueError(
                f"bucket {i} slot leaf {s.index} is not available yet"
            )
        if _dtype_name(leaf.dtype) != s.dtype:
            raise ValueError(
                f"leaf {s.index} has dtype {_dtype_name(leaf.dtype)}, "
                f"layout expects {s.dtype} (buckets never promote)"
            )
        parts.append(leaf.reshape(-1) if p is None else leaf.reshape(p, -1))
    pad = b.length - b.used
    if p is None:
        buf = jnp.concatenate(parts) if parts else jnp.zeros((0,), b.dtype)
        if pad:
            buf = jnp.pad(buf, (0, pad))
    else:
        buf = (
            jnp.concatenate(parts, axis=1)
            if parts
            else jnp.zeros((p, 0), b.dtype)
        )
        if pad:
            buf = jnp.pad(buf, ((0, 0), (0, pad)))
    return buf


def pack(tree, layout: BucketLayout) -> list:
    """Flatten ``tree`` into the layout's bucket buffers.

    Returns one 1-D buffer per bucket (``[p, length]`` when the layout is
    stacked).  Leaf dtypes must match the layout exactly — buckets never
    promote.
    """
    leaves, treedef = jax.tree.flatten(tree)
    if treedef != layout.treedef or len(leaves) != layout.n_leaves:
        raise ValueError(
            f"tree structure {treedef} does not match the layout's "
            f"{layout.treedef}"
        )
    return [pack_bucket(leaves, layout, i) for i in range(len(layout.buckets))]


def unpack(bufs: Sequence, layout: BucketLayout):
    """Inverse of :func:`pack`: slice each bucket back into leaves with
    their original shapes and dtypes and rebuild the tree.

    Buffers are cast to each slot's layout dtype, so a path that widened
    a bucket (e.g. bf16 params gathered after an fp32 optimizer step)
    still round-trips to the layout's dtypes.
    """
    if len(bufs) != len(layout.buckets):
        raise ValueError(
            f"got {len(bufs)} buffers for a {len(layout.buckets)}-bucket layout"
        )
    p = layout.stacked
    leaves: list = [None] * layout.n_leaves
    for b, buf in zip(layout.buckets, bufs):
        for s in b.slots:
            if p is None:
                piece = buf[s.offset : s.offset + s.size].reshape(s.shape)
            else:
                piece = buf[:, s.offset : s.offset + s.size].reshape((p,) + s.shape)
            leaves[s.index] = piece.astype(s.dtype)
    return jax.tree.unflatten(layout.treedef, leaves)
