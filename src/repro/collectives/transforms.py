"""Layer 3 of the collectives subsystem: payload *transforms* (wire formats).

A transform decides what bytes each combining stage puts on the wire and
how the receiver folds them back in.  Transforms apply only to stages
that *reduce* (``bshift``/``butterfly``/``rs``); pure copies
(``fshift``/``ag``) always travel raw so transport loss never lands in a
final value verbatim.

- ``identity``: payload is the buffer itself; combine is the plan's op.
- ``int8``: blockwise int8 quantization (wire bytes / 2 vs bf16, / 4 vs
  fp32, plus ~1.6% scale overhead) with dequant-accumulate on receive —
  on TPU that accumulate is the ``mrd_combine`` Pallas kernel's job
  (executor ``device_fused``).  Only valid for ``op='sum'``.
  Quantization noise is bounded per stage (|err| <= amax/254 per block);
  the grad-sync layer compensates the first hop with EF-SGD residual
  carry (:func:`ef_roundtrip` — see ``gradsync/mrd_zero1.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp

BLOCK = 256


def quantize(x, block: int = BLOCK):
    """x: [n] float -> (q int8 [n], scales f32 [n/block]). n % block == 0."""
    n = x.shape[0]
    assert n % block == 0, (n, block)
    xb = x.astype(jnp.float32).reshape(n // block, block)
    amax = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(n), scale[:, 0]


def dequantize(q, scales, block: int = BLOCK):
    n = q.shape[0]
    xb = q.astype(jnp.float32).reshape(n // block, block) * scales[:, None]
    return xb.reshape(n)


def quantization_error(x, block: int = BLOCK):
    q, s = quantize(x, block)
    return x.astype(jnp.float32) - dequantize(q, s, block)


def wire_bytes_factor(dtype_bytes: int = 4, block: int = BLOCK) -> float:
    """Bytes-on-wire ratio of compressed vs uncompressed payloads."""
    return (1.0 + 4.0 / block) / dtype_bytes


def ef_roundtrip(x, ef, block: int = BLOCK):
    """EF-SGD error feedback for a quantized send (Stich et al. / Karimireddy
    et al.): compress what you *meant* to send (``x + ef``), remember what
    the grid dropped.

    Returns ``(sendable, new_ef)``: ``sendable`` is the quantization-grid
    round-trip of ``x + ef`` (feeding it to an int8-transform collective
    makes the first-hop encode near-lossless), and ``new_ef = (x + ef) -
    sendable`` is the residual to carry into the next step.  Coordinates
    persistently below their block's quantization step accumulate in ``ef``
    until they cross it — without this they are silently dropped forever.
    """
    want = x.astype(jnp.float32) + ef
    q, s = quantize(want, block)
    sendable = dequantize(q, s, block)
    return sendable, want - sendable


# ---------------------------------------------------------------------------
# Transform protocol + registry
# ---------------------------------------------------------------------------


class IdentityTransform:
    """Raw payloads; combine = the plan's reduction op."""

    name = "identity"
    quantum = 1  # buffer-length divisibility the transform needs

    def validate_op(self, op: str | Callable):
        pass

    def encode(self, x, be):
        return (x,)

    def canonicalize(self, x, be):
        """The value a *partner* would reconstruct from this rank's payload.

        Symmetric full-buffer exchanges (butterfly) combine the canonical
        view instead of the raw local buffer, so both partners compute the
        same result and the allreduce contract (all ranks equal) holds for
        lossy wire formats too.
        """
        return x

    def combine(self, keep, payload, op: Callable, be):
        (recv,) = payload
        return op(keep, recv)


@dataclasses.dataclass(frozen=True)
class Int8BlockwiseTransform:
    """Blockwise int8 wire format; combine = dequant-accumulate (sum only).

    The combine is delegated to the backend (``combine_quantized``) so the
    ``device_fused`` executor can route it through the ``mrd_combine``
    Pallas kernel.
    """

    block: int = BLOCK
    name: str = "int8"

    @property
    def quantum(self) -> int:
        return self.block

    def validate_op(self, op: str | Callable):
        if op != "sum" and op is not jnp.add:
            raise ValueError(
                f"transform 'int8' only supports op='sum' (dequant-accumulate), got {op!r}"
            )

    def encode(self, x, be):
        return be.vmap_ranks(lambda v: quantize(v, self.block))(x)

    def canonicalize(self, x, be):
        def roundtrip(v):
            q, s = quantize(v, self.block)
            return dequantize(q, s, self.block)

        return be.vmap_ranks(roundtrip)(x)

    def combine(self, keep, payload, op: Callable, be):
        q, scales = payload
        return be.combine_quantized(keep, q, scales, self.block)


TRANSFORMS: dict[str, Callable[..., Any]] = {}


def register_transform(name: str):
    def deco(factory):
        TRANSFORMS[name] = factory
        return factory

    return deco


register_transform("identity")(lambda **kw: IdentityTransform())
register_transform("int8")(lambda block=BLOCK, **kw: Int8BlockwiseTransform(block))


def resolve_transform(transform, **kw):
    """Accept a name, a transform instance, or None (identity)."""
    if transform is None:
        return IdentityTransform()
    if isinstance(transform, str):
        try:
            return TRANSFORMS[transform](**kw)
        except KeyError:
            raise ValueError(
                f"unknown transform {transform!r}; registered: {sorted(TRANSFORMS)}"
            ) from None
    return transform
