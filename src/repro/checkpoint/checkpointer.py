"""Sharded checkpointing with async save, atomic publish, and elastic
reshard-on-restore.

Layout:  <dir>/step_<N>/   arrays.npz  (flat {path: np.array})
                           manifest.json (step, config fingerprint, mesh shape,
                                          data-pipeline state, wall time)
         <dir>/LATEST      (atomic pointer file)

- *async save*: device->host transfer happens synchronously (cheap), the npz
  write runs in a background thread; `wait()` joins before the next save.
- *atomic publish*: write to step_N.tmp, fsync, rename, then update LATEST —
  a crash mid-save never corrupts the restore point.
- *elastic reshard*: restore takes the *target* shardings (possibly for a
  different mesh than the save-time mesh) and uses ``jax.device_put`` per
  leaf; combined with the MRD collectives' non-power-of-two support this is
  the shrink-on-failure path (see runtime/fault_tolerance.py).
- *layout versioning*: the manifest records ``layout_version`` and restore
  runs the registered migration passes from the checkpoint's version up to
  :data:`LAYOUT_VERSION`, so checkpoints written before a state-layout
  change keep restoring (see :func:`migrate_layout`).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

# Current on-disk state layout.  History:
#   1: pre-PR-3 — flat optimizer {master, mu, nu}; ConvergenceMonitor
#      policy state at the top level of the monitor dict (e.g.
#      'monitor/latched' for the exact mode).
#   2: PR-3 — EF-SGD residual carry adds an 'opt/ef' leaf to compressed
#      runs; the monitor's per-protocol policy state moved under 'm/'
#      ('monitor/latched' -> 'monitor/m/latched', new 'monitor/m/win').
LAYOUT_VERSION = 2


def _flatten_with_paths(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(p.idx)
            for p in path
        )
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_like(template, flat: dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(p.idx)
            for p in path
        )
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _template_specs(template) -> dict[str, Any]:
    """{flat key: leaf} for the restore template (shapes/dtypes only)."""
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(template)[0]:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(p.idx)
            for p in path
        )
        out[key] = leaf
    return out


# ---------------------------------------------------------------------------
# Layout migrations: version N -> N+1 passes over the flat array dict
# ---------------------------------------------------------------------------


def _migrate_v1_to_v2(flat: dict, template_specs: dict) -> dict:
    """Pre-PR-3 checkpoints: monitor policy state moves under ``m/`` and
    compressed runs gain a zero ``opt/ef`` residual (a fresh EF carry is
    exactly what a run that never compensated anything should hold)."""
    out = dict(flat)
    for key, spec in template_specs.items():
        if key in out:
            continue
        parts = key.split("/")
        if "m" in parts:
            i = parts.index("m")
            old_key = "/".join(parts[:i] + parts[i + 1 :])
            if old_key in out:
                out[key] = out.pop(old_key)
                continue
        if parts[-1] == "ef" and "opt" in parts:
            out[key] = np.zeros(tuple(spec.shape), spec.dtype)
    return out


_MIGRATIONS: Dict[int, Callable] = {1: _migrate_v1_to_v2}


def migrate_layout(
    flat: dict, template, from_version: int, to_version: int = LAYOUT_VERSION
) -> dict:
    """Run the registered migration passes ``from_version -> to_version``
    over a checkpoint's flat array dict, then verify every template leaf is
    present (clear error instead of a KeyError deep in unflatten)."""
    if from_version > to_version:
        raise ValueError(
            f"checkpoint layout v{from_version} is newer than this code's "
            f"v{to_version}; upgrade the code, not the checkpoint"
        )
    specs = _template_specs(template)
    for v in range(from_version, to_version):
        if v not in _MIGRATIONS:
            raise ValueError(f"no layout migration registered for v{v} -> v{v + 1}")
        flat = _MIGRATIONS[v](flat, specs)
    missing = sorted(k for k in specs if k not in flat)
    if missing:
        raise ValueError(
            f"checkpoint (layout v{from_version}) is missing {len(missing)} "
            f"leaves the restore template expects even after migration to "
            f"v{to_version}: {missing[:8]}{'...' if len(missing) > 8 else ''}"
        )
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, extra: Optional[dict] = None, *, block=False):
        """Snapshot state (device->host now), write in background."""
        self.wait()
        flat = _flatten_with_paths(state)
        manifest = {
            "step": int(step),
            "time": time.time(),
            "extra": extra or {},
            "n_arrays": len(flat),
            "layout_version": LAYOUT_VERSION,
        }

        def _write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            latest_tmp = os.path.join(self.dir, "LATEST.tmp")
            with open(latest_tmp, "w") as f:
                f.write(str(step))
                f.flush()
                os.fsync(f.fileno())
            os.replace(latest_tmp, os.path.join(self.dir, "LATEST"))
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            step = int(f.read().strip())
        if os.path.exists(os.path.join(self.dir, f"step_{step}")):
            return step
        # LATEST points at a half-gc'd dir: fall back to newest on disk
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: Any, shardings: Any = None):
        """Load into the structure of ``template``; optionally re-place onto
        ``shardings`` (a pytree of NamedSharding for a possibly-new mesh).
        Checkpoints written under an older state layout are migrated
        through the versioned passes first (:func:`migrate_layout`)."""
        d = os.path.join(self.dir, f"step_{step}")
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        version = 1
        mpath = os.path.join(d, "manifest.json")
        if os.path.exists(mpath):
            with open(mpath) as f:
                version = json.load(f).get("layout_version", 1)
        if version != LAYOUT_VERSION:
            flat = migrate_layout(flat, template, version)
        state = _unflatten_like(template, flat)
        if shardings is not None:
            state = jax.tree.map(jax.device_put, state, shardings)
        return state

    def manifest(self, step: int) -> dict:
        with open(os.path.join(self.dir, f"step_{step}", "manifest.json")) as f:
            return json.load(f)
