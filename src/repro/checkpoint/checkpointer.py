"""Sharded checkpointing with async save, atomic publish, and elastic
reshard-on-restore.

Layout:  <dir>/step_<N>/   arrays.npz  (flat {path: np.array})
                           manifest.json (step, config fingerprint, mesh shape,
                                          data-pipeline state, wall time)
         <dir>/LATEST      (atomic pointer file)

- *async save*: device->host transfer happens synchronously (cheap), the npz
  write runs in a background thread; `wait()` joins before the next save.
- *atomic publish*: write to step_N.tmp, fsync, rename, then update LATEST —
  a crash mid-save never corrupts the restore point.
- *elastic reshard*: restore takes the *target* shardings (possibly for a
  different mesh than the save-time mesh) and uses ``jax.device_put`` per
  leaf; combined with the MRD collectives' non-power-of-two support this is
  the shrink-on-failure path (see runtime/fault_tolerance.py).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(p.idx)
            for p in path
        )
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_like(template, flat: dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(p.idx)
            for p in path
        )
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, extra: Optional[dict] = None, *, block=False):
        """Snapshot state (device->host now), write in background."""
        self.wait()
        flat = _flatten_with_paths(state)
        manifest = {
            "step": int(step),
            "time": time.time(),
            "extra": extra or {},
            "n_arrays": len(flat),
        }

        def _write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            latest_tmp = os.path.join(self.dir, "LATEST.tmp")
            with open(latest_tmp, "w") as f:
                f.write(str(step))
                f.flush()
                os.fsync(f.fileno())
            os.replace(latest_tmp, os.path.join(self.dir, "LATEST"))
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            step = int(f.read().strip())
        if os.path.exists(os.path.join(self.dir, f"step_{step}")):
            return step
        # LATEST points at a half-gc'd dir: fall back to newest on disk
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: Any, shardings: Any = None):
        """Load into the structure of ``template``; optionally re-place onto
        ``shardings`` (a pytree of NamedSharding for a possibly-new mesh)."""
        d = os.path.join(self.dir, f"step_{step}")
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        state = _unflatten_like(template, flat)
        if shardings is not None:
            state = jax.tree.map(jax.device_put, state, shardings)
        return state

    def manifest(self, step: int) -> dict:
        with open(os.path.join(self.dir, f"step_{step}", "manifest.json")) as f:
            return json.load(f)
