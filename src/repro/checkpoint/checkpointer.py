"""Sharded checkpointing with async save, atomic publish, and elastic
reshard-on-restore.

Layout:  <dir>/step_<N>/   arrays.npz  (flat {path: np.array})
                           manifest.json (step, config fingerprint, mesh shape,
                                          data-pipeline state, wall time)
         <dir>/LATEST      (atomic pointer file)

- *async save* (DESIGN.md S16): ``save(block=False)`` issues a per-leaf
  ``copy_to_host_async`` and returns — the device->host transfer drains
  while the next train step launches; a background thread materializes
  the host arrays and writes the npz.  ``block='transfer'`` returns once
  every leaf is materialized on the host (use when the train step
  *donates* the state — the snapshot must not race the donor's buffer
  deletion); ``block=True`` additionally joins the disk write.
  ``wait()`` joins before the next save and re-raises any writer error.
- *save policies*: ``save_every_steps`` / ``save_every_seconds`` drive
  :meth:`maybe_save` (levanter-style time-based checkpointing for long
  runs where a step cadence is the wrong unit).
- *atomic publish*: write to step_N.tmp, fsync, rename, then update LATEST —
  a crash mid-save never corrupts the restore point.  Stale ``step_N.tmp``
  dirs a crash left behind are swept on construction and are invisible to
  ``list_steps``/``latest_step``.
- *elastic reshard*: restore takes the *target* shardings (possibly for a
  different mesh than the save-time mesh) and uses ``jax.device_put`` per
  leaf; combined with the MRD collectives' non-power-of-two support this is
  the shrink-on-failure path (see runtime/fault_tolerance.py).
- *layout versioning*: the manifest records ``layout_version`` and restore
  runs the registered migration passes from the checkpoint's version up to
  :data:`LAYOUT_VERSION`, so checkpoints written before a state-layout
  change keep restoring (see :func:`migrate_layout`).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro import obs

# Current on-disk state layout.  History:
#   1: pre-PR-3 — flat optimizer {master, mu, nu}; ConvergenceMonitor
#      policy state at the top level of the monitor dict (e.g.
#      'monitor/latched' for the exact mode).
#   2: PR-3 — EF-SGD residual carry adds an 'opt/ef' leaf to compressed
#      runs; the monitor's per-protocol policy state moved under 'm/'
#      ('monitor/latched' -> 'monitor/m/latched', new 'monitor/m/win').
LAYOUT_VERSION = 2


def _flatten_with_paths(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(p.idx)
            for p in path
        )
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _stage_with_paths(tree) -> dict[str, Any]:
    """{flat key: leaf} with the device->host copy *started* but not
    awaited — the cheap, non-blocking half of :func:`_flatten_with_paths`.
    Materialize later with ``np.asarray`` (which waits on the transfer)."""
    staged = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(p.idx)
            for p in path
        )
        if hasattr(leaf, "copy_to_host_async"):
            leaf.copy_to_host_async()
        staged[key] = leaf
    return staged


def _unflatten_like(template, flat: dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(p.idx)
            for p in path
        )
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _template_specs(template) -> dict[str, Any]:
    """{flat key: leaf} for the restore template (shapes/dtypes only)."""
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(template)[0]:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(p.idx)
            for p in path
        )
        out[key] = leaf
    return out


# ---------------------------------------------------------------------------
# Layout migrations: version N -> N+1 passes over the flat array dict
# ---------------------------------------------------------------------------


def _migrate_v1_to_v2(flat: dict, template_specs: dict) -> dict:
    """Pre-PR-3 checkpoints: monitor policy state moves under ``m/`` and
    compressed runs gain a zero ``opt/ef`` residual (a fresh EF carry is
    exactly what a run that never compensated anything should hold)."""
    out = dict(flat)
    for key, spec in template_specs.items():
        if key in out:
            continue
        parts = key.split("/")
        if "m" in parts:
            i = parts.index("m")
            old_key = "/".join(parts[:i] + parts[i + 1 :])
            if old_key in out:
                out[key] = out.pop(old_key)
                continue
        if parts[-1] == "ef" and "opt" in parts:
            out[key] = np.zeros(tuple(spec.shape), spec.dtype)
    return out


_MIGRATIONS: Dict[int, Callable] = {1: _migrate_v1_to_v2}


def migrate_layout(
    flat: dict, template, from_version: int, to_version: int = LAYOUT_VERSION
) -> dict:
    """Run the registered migration passes ``from_version -> to_version``
    over a checkpoint's flat array dict, then verify every template leaf is
    present (clear error instead of a KeyError deep in unflatten)."""
    if from_version > to_version:
        raise ValueError(
            f"checkpoint layout v{from_version} is newer than this code's "
            f"v{to_version}; upgrade the code, not the checkpoint"
        )
    specs = _template_specs(template)
    for v in range(from_version, to_version):
        if v not in _MIGRATIONS:
            raise ValueError(f"no layout migration registered for v{v} -> v{v + 1}")
        flat = _MIGRATIONS[v](flat, specs)
    missing = sorted(k for k in specs if k not in flat)
    if missing:
        raise ValueError(
            f"checkpoint (layout v{from_version}) is missing {len(missing)} "
            f"leaves the restore template expects even after migration to "
            f"v{to_version}: {missing[:8]}{'...' if len(missing) > 8 else ''}"
        )
    return flat


class Checkpointer:
    def __init__(
        self,
        directory: str,
        keep: int = 3,
        *,
        save_every_steps: Optional[int] = None,
        save_every_seconds: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.dir = directory
        self.keep = keep
        self.save_every_steps = save_every_steps
        self.save_every_seconds = save_every_seconds
        self._clock = clock
        os.makedirs(directory, exist_ok=True)
        self._clean_stale_tmp()
        self._thread: Optional[threading.Thread] = None
        self._staged: Optional[threading.Event] = None
        self._error: Optional[BaseException] = None
        # time-based policy counts from construction, so `save_every_seconds`
        # means "at most this long between snapshots", not "save at step 1"
        self._last_save_at = self._clock()

    def _clean_stale_tmp(self):
        """Sweep ``step_N.tmp`` dirs (and a dangling ``LATEST.tmp``) that a
        crash mid-write left behind — they hold a torn snapshot and would
        otherwise accumulate forever."""
        for name in os.listdir(self.dir):
            path = os.path.join(self.dir, name)
            if name.startswith("step_") and name.endswith(".tmp"):
                shutil.rmtree(path, ignore_errors=True)
            elif name == "LATEST.tmp":
                os.unlink(path)

    # ------------------------------------------------------------------ save
    def should_save(self, step: int) -> bool:
        """Step- or time-based save policy (whichever fires first)."""
        if self.save_every_steps and step % self.save_every_steps == 0:
            return True
        if self.save_every_seconds is not None:
            return self._clock() - self._last_save_at >= self.save_every_seconds
        return False

    def maybe_save(
        self, step: int, state: Any, extra: Optional[dict] = None, *, block=False
    ) -> bool:
        """:meth:`save` iff the configured policy says so; returns whether
        a save was issued."""
        if not self.should_save(step):
            return False
        self.save(step, state, extra, block=block)
        return True

    def save(self, step: int, state: Any, extra: Optional[dict] = None, *, block=False):
        """Snapshot ``state`` without blocking the caller on the
        device->host transfer: issue per-leaf ``copy_to_host_async`` and
        hand off to a background writer thread that materializes the host
        arrays and publishes atomically.

        ``block``: ``False`` returns immediately (safe whenever the
        caller's buffers stay alive, e.g. donation off); ``'transfer'``
        returns once every leaf is materialized on the host (required
        before a donating train step may reuse the state's buffers);
        ``True`` additionally joins the disk write.
        """
        self.wait()
        with obs.span("ckpt.save.stage", step=int(step)):
            staged = _stage_with_paths(state)
        manifest = {
            "step": int(step),
            "time": time.time(),
            "extra": extra or {},
            "n_arrays": len(staged),
            "layout_version": LAYOUT_VERSION,
        }
        transferred = threading.Event()

        def _write():
            # runs on the writer thread — its spans land in their own
            # trace lane, showing the d2h drain/npz write overlapping
            # the train thread's next steps
            try:
                with obs.span("ckpt.d2h_wait", step=int(step)):
                    # waits on the in-flight d2h copies, off the train thread
                    flat = {k: np.asarray(v) for k, v in staged.items()}
                transferred.set()
                with obs.span("ckpt.write", step=int(step), n_arrays=len(flat)):
                    tmp = os.path.join(self.dir, f"step_{step}.tmp")
                    final = os.path.join(self.dir, f"step_{step}")
                    os.makedirs(tmp, exist_ok=True)
                    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
                    with open(os.path.join(tmp, "manifest.json"), "w") as f:
                        json.dump(manifest, f)
                    if os.path.exists(final):
                        shutil.rmtree(final)
                    os.rename(tmp, final)
                    latest_tmp = os.path.join(self.dir, "LATEST.tmp")
                    with open(latest_tmp, "w") as f:
                        f.write(str(step))
                        f.flush()
                        os.fsync(f.fileno())
                    os.replace(latest_tmp, os.path.join(self.dir, "LATEST"))
                    self._gc()
            except BaseException as e:  # surfaced by the next wait()
                self._error = e
                transferred.set()

        self._staged = transferred
        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        self._last_save_at = self._clock()
        if block == "transfer":
            transferred.wait()
            self._raise_pending()
        elif block:
            self.wait()

    def wait(self):
        """Join the in-flight save (if any); re-raises a writer failure so a
        torn snapshot can't silently become the restore point."""
        if self._thread is not None:
            with obs.span("ckpt.wait"):
                self._thread.join()
            self._thread = None
            self._staged = None
        self._raise_pending()

    def _raise_pending(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            step = int(f.read().strip())
        if os.path.exists(os.path.join(self.dir, f"step_{step}")):
            return step
        # LATEST points at a half-gc'd dir: fall back to newest on disk
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: Any, shardings: Any = None):
        """Load into the structure of ``template``; optionally re-place onto
        ``shardings`` (a pytree of NamedSharding for a possibly-new mesh).
        Checkpoints written under an older state layout are migrated
        through the versioned passes first (:func:`migrate_layout`)."""
        d = os.path.join(self.dir, f"step_{step}")
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        version = 1
        mpath = os.path.join(d, "manifest.json")
        if os.path.exists(mpath):
            with open(mpath) as f:
                version = json.load(f).get("layout_version", 1)
        if version != LAYOUT_VERSION:
            flat = migrate_layout(flat, template, version)
        state = _unflatten_like(template, flat)
        if shardings is not None:
            state = jax.tree.map(jax.device_put, state, shardings)
        return state

    def manifest(self, step: int) -> dict:
        with open(os.path.join(self.dir, f"step_{step}", "manifest.json")) as f:
            return json.load(f)
