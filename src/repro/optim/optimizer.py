"""Optimizers (AdamW, Lion) + LR schedules (cosine, WSD, const).

Tree form (gspmd mode): fp32 master + moments sharded like the params
(FSDP+TP), bf16 working params re-derived each step.
Vector form (MRD-ZeRO-1 mode): the same math on flat fp32 shards owned by
each DP rank (reduce-scattered grads in, all-gathered params out).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"  # 'adamw' | 'lion' | 'sgd'
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"  # 'cosine' | 'wsd' | 'const'
    warmup_steps: int = 100
    total_steps: int = 10000
    wsd_decay_frac: float = 0.1  # minicpm's WSD: final decay fraction


def schedule_lr(ocfg: OptimizerConfig, step):
    step = step.astype(jnp.float32)
    if ocfg.warmup_steps <= 0:
        warm = jnp.ones((), jnp.float32)
    else:
        warm = jnp.minimum(step / ocfg.warmup_steps, 1.0)
    if ocfg.schedule == "const":
        return ocfg.lr * warm
    total = float(max(ocfg.total_steps, 1))
    if ocfg.schedule == "cosine":
        t = jnp.clip((step - ocfg.warmup_steps) / max(total - ocfg.warmup_steps, 1), 0, 1)
        return ocfg.lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * t))
    if ocfg.schedule == "wsd":  # warmup -> stable -> linear decay tail
        decay_start = total * (1 - ocfg.wsd_decay_frac)
        tail = jnp.clip((step - decay_start) / max(total - decay_start, 1), 0, 1)
        return ocfg.lr * warm * (1 - tail)
    raise ValueError(ocfg.schedule)


def clip_by_global_norm(tree, max_norm: float):
    if max_norm <= 0:
        return tree, jnp.zeros((), jnp.float32)
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), gnorm


# --- tree form -------------------------------------------------------------


def init_opt_state(params):
    f32 = lambda p: p.astype(jnp.float32)
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "master": jax.tree.map(f32, params),
        "mu": jax.tree.map(z, params),
        "nu": jax.tree.map(z, params),
    }


def apply_update(grads, opt, ocfg: OptimizerConfig, step, param_dtype):
    """grads: fp32 tree. Returns (new_params(param_dtype), new_opt)."""
    lr = schedule_lr(ocfg, step)
    t = step.astype(jnp.float32) + 1.0

    def upd(g, m, mu, nu):
        g = g.astype(jnp.float32)
        if ocfg.name == "sgd":
            new_m = m - lr * (g + ocfg.weight_decay * m)
            return new_m, mu, nu
        if ocfg.name == "lion":
            u = jnp.sign(ocfg.beta1 * mu + (1 - ocfg.beta1) * g)
            new_mu = ocfg.beta2 * mu + (1 - ocfg.beta2) * g
            new_m = m - lr * (u + ocfg.weight_decay * m)
            return new_m, new_mu, nu
        # adamw
        new_mu = ocfg.beta1 * mu + (1 - ocfg.beta1) * g
        new_nu = ocfg.beta2 * nu + (1 - ocfg.beta2) * g * g
        mu_hat = new_mu / (1 - ocfg.beta1**t)
        nu_hat = new_nu / (1 - ocfg.beta2**t)
        new_m = m - lr * (mu_hat / (jnp.sqrt(nu_hat) + ocfg.eps) + ocfg.weight_decay * m)
        return new_m, new_mu, new_nu

    g_l, tdef = jax.tree.flatten(grads)
    outs = [
        upd(g, m, mu, nu)
        for g, m, mu, nu in zip(
            g_l,
            jax.tree.leaves(opt["master"]),
            jax.tree.leaves(opt["mu"]),
            jax.tree.leaves(opt["nu"]),
        )
    ]
    master = jax.tree.unflatten(tdef, [o[0] for o in outs])
    mu = jax.tree.unflatten(tdef, [o[1] for o in outs])
    nu = jax.tree.unflatten(tdef, [o[2] for o in outs])
    params = jax.tree.map(lambda m: m.astype(param_dtype), master)
    return params, {"master": master, "mu": mu, "nu": nu}


# --- vector form (ZeRO-1 shards) -------------------------------------------


def init_opt_vector(n: int):
    return {
        "master": jnp.zeros((n,), jnp.float32),
        "mu": jnp.zeros((n,), jnp.float32),
        "nu": jnp.zeros((n,), jnp.float32),
    }


def apply_update_vector(g, opt, ocfg: OptimizerConfig, step):
    """g: fp32 [n] gradient shard. Returns (new_master [n], new_opt)."""
    lr = schedule_lr(ocfg, step)
    t = step.astype(jnp.float32) + 1.0
    m, mu, nu = opt["master"], opt["mu"], opt["nu"]
    if ocfg.name == "sgd":
        new_m = m - lr * (g + ocfg.weight_decay * m)
        return new_m, {"master": new_m, "mu": mu, "nu": nu}
    if ocfg.name == "lion":
        u = jnp.sign(ocfg.beta1 * mu + (1 - ocfg.beta1) * g)
        new_mu = ocfg.beta2 * mu + (1 - ocfg.beta2) * g
        new_m = m - lr * (u + ocfg.weight_decay * m)
        return new_m, {"master": new_m, "mu": new_mu, "nu": nu}
    new_mu = ocfg.beta1 * mu + (1 - ocfg.beta1) * g
    new_nu = ocfg.beta2 * nu + (1 - ocfg.beta2) * g * g
    mu_hat = new_mu / (1 - ocfg.beta1**t)
    nu_hat = new_nu / (1 - ocfg.beta2**t)
    new_m = m - lr * (mu_hat / (jnp.sqrt(nu_hat) + ocfg.eps) + ocfg.weight_decay * m)
    return new_m, {"master": new_m, "mu": new_mu, "nu": new_nu}
