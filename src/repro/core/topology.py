"""Deprecated shim: schedules moved to ``repro.collectives.schedules``
(layer 1 of the collectives subsystem).  All public names re-export; new
code should import from ``repro.collectives``."""

from repro.collectives.schedules import (  # noqa: F401
    LinkModel,
    Phase,
    PRIMITIVES,
    SCHEDULES,
    ScheduleFamily,
    Stage,
    StageKind,
    allgather_schedule,
    allreduce_schedule,
    backward_shift_stage,
    forward_shift_stage,
    get_schedule,
    is_power_of_two,
    paper_message_count,
    paper_step_count,
    per_rank_volume,
    pivot,
    rabenseifner_schedule,
    reduce_scatter_schedule,
    register_schedule,
    ring_allreduce_time,
    schedule_messages,
    schedule_steps,
    schedule_time,
    schedule_volume,
    tree_allreduce_time,
)
