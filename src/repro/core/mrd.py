"""Modified recursive doubling collectives: device and simulation executors.

One schedule (``repro.core.topology``), two executors:

- **device**: runs inside ``jax.shard_map`` using ``jax.lax.ppermute``
  (collective-permute, the native TPU ICI primitive).  SPMD: every rank runs
  the same program; shift stages are masked by rank predicates.
- **sim**: pure ``jnp`` over a stacked leading rank axis ``[p, ...]``.  Runs on
  a single CPU device, so correctness of the schedule math is exhaustively
  testable for any ``p`` (including non-powers-of-two, the paper's case)
  without multi-device hardware.

Both executors share the same stage-interpretation code via a tiny backend
shim, so the compiled collective is, by construction, the validated math.

Ops follow the paper (S2): summation, maximization, minimization.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.core import topology
from repro.core.topology import (
    Stage,
    allgather_schedule,
    allreduce_schedule,
    pivot,
    rabenseifner_schedule,
    reduce_scatter_schedule,
)

OPS: dict[str, Callable[[Any, Any], Any]] = {
    "sum": jnp.add,
    "max": jnp.maximum,
    "min": jnp.minimum,
}


def _resolve_op(op: str | Callable) -> Callable:
    if callable(op):
        return op
    try:
        return OPS[op]
    except KeyError:
        raise ValueError(f"unknown reduction op {op!r}; known: {sorted(OPS)}")


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------


class DeviceBackend:
    """Executes stages with ppermute over a named mesh axis (inside shard_map)."""

    def __init__(self, axis_name: str):
        self.axis = axis_name

    def rank(self):
        return jax.lax.axis_index(self.axis)

    def permute(self, x, pairs):
        if not pairs:
            return jnp.zeros_like(x)
        return jax.lax.ppermute(x, self.axis, pairs)

    def where(self, mask, a, b):
        return jnp.where(mask, a, b)

    # value-dimension helpers (device arrays carry no rank axis)
    def split_half(self, x):
        n = x.shape[0]
        return x[: n // 2], x[n // 2 :]

    def concat(self, a, b):
        return jnp.concatenate([a, b], axis=0)


class SimBackend:
    """Executes stages on stacked arrays [p, ...] on a single device."""

    def __init__(self, p: int):
        self.p = p

    def rank(self):
        return jnp.arange(self.p)

    def permute(self, x, pairs):
        idx = np.zeros(self.p, dtype=np.int32)
        has = np.zeros(self.p, dtype=bool)
        for s, d in pairs:
            idx[d] = s
            has[d] = True
        recv = jnp.take(x, jnp.asarray(idx), axis=0)
        mask = jnp.asarray(has).reshape((self.p,) + (1,) * (x.ndim - 1))
        return jnp.where(mask, recv, jnp.zeros_like(recv))

    def where(self, mask, a, b):
        mask = jnp.asarray(mask)
        nd = max(getattr(a, "ndim", 0), getattr(b, "ndim", 0))
        mask = mask.reshape(mask.shape + (1,) * (nd - mask.ndim))
        return jnp.where(mask, a, b)

    def split_half(self, x):
        n = x.shape[1]
        return x[:, : n // 2], x[:, n // 2 :]

    def concat(self, a, b):
        return jnp.concatenate([a, b], axis=1)


# ---------------------------------------------------------------------------
# Stage interpreters (shared by both backends)
# ---------------------------------------------------------------------------


def _exec_allreduce_stage(x, st: Stage, be, p: int, op: Callable):
    p0, _, extra = pivot(p)
    r = be.rank()
    recv = be.permute(x, st.pairs)
    if st.kind == "bshift":
        return be.where(r < extra, op(x, recv), x)
    if st.kind == "butterfly":
        return be.where(r < p0, op(x, recv), x)
    if st.kind == "fshift":
        return be.where(r >= p0, recv, x)
    raise ValueError(f"bad allreduce stage kind {st.kind}")


def _exec_allreduce(x, be, p: int, op: Callable):
    for st in allreduce_schedule(p):
        x = _exec_allreduce_stage(x, st, be, p, op)
    return x


def _exec_reduce_scatter(x, be, p: int, op: Callable):
    """x: full vector (len divisible by p0). Returns rank's segment (len/p0),
    natural order; junk on extra ranks (>= p0)."""
    p0, _, extra = pivot(p)
    r = be.rank()
    for st in reduce_scatter_schedule(p):
        if st.kind == "bshift":
            recv = be.permute(x, st.pairs)
            x = be.where(r < extra, op(x, recv), x)
        else:  # 'rs'
            d = st.distance
            lower, upper = be.split_half(x)
            my_bit = (r & d) != 0
            to_send = be.where(my_bit, lower, upper)
            recv = be.permute(to_send, st.pairs)
            keep = be.where(my_bit, upper, lower)
            x = be.where(r < p0, op(keep, recv), keep)
    return x


def _exec_allgather(x, be, p: int):
    """x: rank's segment (ranks >= p0 carry junk). Returns the full vector on
    every rank."""
    p0, _, _ = pivot(p)
    r = be.rank()
    for st in allgather_schedule(p):
        recv = be.permute(x, st.pairs)
        if st.kind == "ag":
            my_bit = (r & st.distance) != 0
            x = be.where(my_bit, be.concat(recv, x), be.concat(x, recv))
        else:  # fshift
            x = be.where(r >= p0, recv, x)
    return x


# ---------------------------------------------------------------------------
# Device API (call inside shard_map over `axis_name`)
# ---------------------------------------------------------------------------


def axis_size(axis_name: str) -> int:
    return jax.lax.axis_size(axis_name)


def allreduce(tree, axis_name: str, *, op: str | Callable = "sum"):
    """Paper-faithful MRD allreduce of a pytree over ``axis_name``.

    Latency-optimal: log2(p0)+2 stages, full payload each stage.
    """
    p = axis_size(axis_name)
    if p == 1:
        return tree
    be = DeviceBackend(axis_name)
    fn = functools.partial(_exec_allreduce, be=be, p=p, op=_resolve_op(op))
    return jax.tree.map(fn, tree)


def reduce_scatter(vec, axis_name: str, *, op: str | Callable = "sum"):
    """Recursive-halving reduce-scatter of a 1-D vector. ``len(vec)`` must be
    divisible by p0; ranks >= p0 return junk (mask or ignore)."""
    p = axis_size(axis_name)
    if p == 1:
        return vec
    p0, _, _ = pivot(p)
    if vec.ndim != 1 or vec.shape[0] % p0:
        raise ValueError(f"need 1-D vec with len % {p0} == 0, got {vec.shape}")
    return _exec_reduce_scatter(vec, DeviceBackend(axis_name), p, _resolve_op(op))


def compressed_reduce_scatter(vec, axis_name: str, *, block: int = 256):
    """Reduce-scatter with int8-quantized wire payloads (beyond-paper).

    Each recursive-halving stage quantizes the outgoing half blockwise and
    dequant-accumulates on receive (the ``mrd_combine`` kernel's op).  Wire
    bytes drop ~4x vs fp32.  Quantization noise is bounded per stage
    (|err| <= amax/254 per block); the grad-sync layer adds error feedback.
    """
    from repro.collectives import compression as C

    p = axis_size(axis_name)
    if p == 1:
        return vec
    p0, _, extra = pivot(p)
    if vec.ndim != 1 or vec.shape[0] % (p0 * block):
        raise ValueError(f"need len % {p0 * block} == 0, got {vec.shape}")
    be = DeviceBackend(axis_name)
    r = be.rank()
    x = vec
    for st in reduce_scatter_schedule(p):
        if st.kind == "bshift":
            q, s = C.quantize(x, block)
            qr = be.permute(q, st.pairs)
            sr = be.permute(s, st.pairs)
            x = be.where(r < extra, x + C.dequantize(qr, sr, block), x)
        else:
            d = st.distance
            lower, upper = be.split_half(x)
            my_bit = (r & d) != 0
            to_send = be.where(my_bit, lower, upper)
            q, s = C.quantize(to_send, block)
            qr = be.permute(q, st.pairs)
            sr = be.permute(s, st.pairs)
            keep = be.where(my_bit, upper, lower)
            x = be.where(r < p0, keep + C.dequantize(qr, sr, block), keep)
    return x


def allgather(seg, axis_name: str):
    """Recursive-doubling all-gather of each pivot rank's 1-D segment."""
    p = axis_size(axis_name)
    if p == 1:
        return seg
    return _exec_allgather(seg, DeviceBackend(axis_name), p)


def rabenseifner_allreduce(vec, axis_name: str, *, op: str | Callable = "sum"):
    """Bandwidth-optimal allreduce (beyond-paper; paper ref. [20]):
    reduce-scatter + all-gather, ~2n per rank instead of n*log2(p0)."""
    return allgather(reduce_scatter(vec, axis_name, op=op), axis_name)


def hierarchical_allreduce(
    vec, inner_axis: str, outer_axis: str, *, op: str | Callable = "sum"
):
    """Pod-aware allreduce (beyond-paper): reduce-scatter within ``inner_axis``
    (intra-pod ICI), MRD allreduce across ``outer_axis`` (inter-pod DCN) on the
    1/p0_inner-size shard, then all-gather within ``inner_axis``.

    Inter-pod traffic drops from n*log2(pods) to (n/p0_inner)*log2(pods)."""
    seg = reduce_scatter(vec, inner_axis, op=op)
    seg = allreduce(seg, outer_axis, op=op)
    return allgather(seg, inner_axis)


def tree_allreduce_flat(
    tree,
    axis_name: str,
    *,
    op: str | Callable = "sum",
    schedule: str = "rabenseifner",
):
    """Allreduce a pytree as one flat padded vector (flat-bucket).

    ``schedule``: 'mrd' (paper), 'rabenseifner' (beyond-paper, default for
    bandwidth-bound payloads like gradients).
    """
    p = axis_size(axis_name)
    if p == 1:
        return tree
    vec, unravel = ravel_pytree(tree)
    p0, _, _ = pivot(p)
    pad = (-vec.shape[0]) % p0
    padded = jnp.pad(vec, (0, pad))
    if schedule == "mrd":
        out = _exec_allreduce(padded, DeviceBackend(axis_name), p, _resolve_op(op))
    elif schedule == "rabenseifner":
        out = rabenseifner_allreduce(padded, axis_name, op=op)
    else:
        raise ValueError(f"unknown schedule {schedule!r}")
    return unravel(out[: vec.shape[0]])


# ---------------------------------------------------------------------------
# Simulation API (single device, stacked rank axis)
# ---------------------------------------------------------------------------


def sim_allreduce(x, *, op: str | Callable = "sum"):
    """x: [p, ...] stacked per-rank values -> [p, ...] (all rows = reduction)."""
    p = x.shape[0]
    if p == 1:
        return x
    return _exec_allreduce(x, SimBackend(p), p, _resolve_op(op))


def sim_reduce_scatter(x, *, op: str | Callable = "sum"):
    """x: [p, n] with n % p0 == 0 -> [p, n/p0] (rows >= p0 are junk)."""
    p = x.shape[0]
    if p == 1:
        return x
    p0, _, _ = pivot(p)
    if x.shape[1] % p0:
        raise ValueError(f"n={x.shape[1]} not divisible by p0={p0}")
    return _exec_reduce_scatter(x, SimBackend(p), p, _resolve_op(op))


def sim_allgather(x):
    """x: [p, m] segments (rows >= p0 junk) -> [p, m*p0]."""
    p = x.shape[0]
    if p == 1:
        return x
    return _exec_allgather(x, SimBackend(p), p)


def sim_rabenseifner_allreduce(x, *, op: str | Callable = "sum"):
    return sim_allgather(sim_reduce_scatter(x, op=op))


# ---------------------------------------------------------------------------
# Whole-array convenience wrappers (build the shard_map for the caller)
# ---------------------------------------------------------------------------


def make_allreduce(mesh, axis_name: str, *, op: str = "sum", schedule: str = "mrd"):
    """Returns a jitted fn: [p, ...] global array sharded over ``axis_name`` ->
    allreduced array of the same shape (each shard = full reduction)."""
    from jax.sharding import PartitionSpec as P

    spec = P(axis_name)

    def fn(x):
        def local(v):
            y = v[0]
            if schedule == "mrd":
                out = allreduce(y, axis_name, op=op)
            elif schedule == "rabenseifner":
                flat = y.reshape(-1)
                p0, _, _ = pivot(mesh.shape[axis_name])
                pad = (-flat.shape[0]) % p0
                out = rabenseifner_allreduce(jnp.pad(flat, (0, pad)), axis_name, op=op)
                out = out[: flat.shape[0]].reshape(y.shape)
            elif schedule == "psum":
                out = jax.lax.psum(y, axis_name)
            else:
                raise ValueError(schedule)
            return out[None]

        return jax.shard_map(local, mesh=mesh, in_specs=spec, out_specs=spec)(x)

    return jax.jit(fn)
