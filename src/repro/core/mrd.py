"""Deprecated shim: MRD executors moved into the layered collectives
subsystem (``repro.collectives``).  Every public name keeps working, but
each function is now a thin wrapper over a :class:`CollectivePlan`, so
blocking/non-blocking, compressed/plain, device/sim all execute through
the single validated stage interpreter (``repro.collectives.plans``).

New code should build plans directly::

    from repro.collectives import allreduce_plan, reduce_scatter_plan
    plan = allreduce_plan(schedule="mrd", axes=("data",), op="sum")
    out = plan.run(tree)                       # inside shard_map
    rs = reduce_scatter_plan(axes=("data",), transform="int8").run(vec)
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro import compat
from repro.collectives import plans
from repro.collectives.executors import (  # noqa: F401
    OPS,
    DeviceBackend,
    SimBackend,
    resolve_op as _resolve_op,
)
from repro.collectives.plans import exec_stage
from repro.collectives.schedules import (  # noqa: F401
    Stage,
    allgather_schedule,
    allreduce_schedule,
    pivot,
    rabenseifner_schedule,
    reduce_scatter_schedule,
)


def _exec_allreduce_stage(x, st: Stage, be, p: int, op: Callable):
    """Back-compat alias for the plan layer's stage interpreter."""
    return exec_stage(x, st, be, p, op)


# ---------------------------------------------------------------------------
# Device API (call inside shard_map over `axis_name`)
# ---------------------------------------------------------------------------


def axis_size(axis_name: str) -> int:
    return compat.axis_size(axis_name)


def allreduce(tree, axis_name: str, *, op: str | Callable = "sum"):
    """Paper-faithful MRD allreduce of a pytree over ``axis_name``.

    Latency-optimal: log2(p0)+2 stages, full payload each stage.
    """
    return plans.allreduce_plan(schedule="mrd", axes=(axis_name,), op=op).run(tree)


def reduce_scatter(vec, axis_name: str, *, op: str | Callable = "sum"):
    """Recursive-halving reduce-scatter of a 1-D vector. ``len(vec)`` must be
    divisible by p0; ranks >= p0 return junk (mask or ignore)."""
    p = axis_size(axis_name)
    if p == 1:
        return vec
    p0, _, _ = pivot(p)
    if vec.ndim != 1 or vec.shape[0] % p0:
        raise ValueError(f"need 1-D vec with len % {p0} == 0, got {vec.shape}")
    return plans.reduce_scatter_plan(axes=(axis_name,), op=op).run(vec)


def compressed_reduce_scatter(vec, axis_name: str, *, block: int = 256):
    """Reduce-scatter with int8-quantized wire payloads (beyond-paper).

    Each recursive-halving stage quantizes the outgoing half blockwise and
    dequant-accumulates on receive (the ``mrd_combine`` kernel's op).  Wire
    bytes drop ~4x vs fp32.  Quantization noise is bounded per stage
    (|err| <= amax/254 per block) but uncompensated (no error feedback yet).
    """
    p = axis_size(axis_name)
    if p == 1:
        return vec
    p0, _, _ = pivot(p)
    if vec.ndim != 1 or vec.shape[0] % (p0 * block):
        raise ValueError(f"need len % {p0 * block} == 0, got {vec.shape}")
    return plans.reduce_scatter_plan(
        axes=(axis_name,), transform="int8", block=block
    ).run(vec)


def allgather(seg, axis_name: str):
    """Recursive-doubling all-gather of each pivot rank's 1-D segment."""
    if axis_size(axis_name) == 1:
        return seg
    return plans.allgather_plan(axes=(axis_name,)).run(seg)


def rabenseifner_allreduce(vec, axis_name: str, *, op: str | Callable = "sum"):
    """Bandwidth-optimal allreduce (beyond-paper; paper ref. [20]):
    reduce-scatter + all-gather, ~2n per rank instead of n*log2(p0)."""
    return plans.allreduce_plan(
        schedule="rabenseifner", axes=(axis_name,), op=op
    ).run(vec)


def hierarchical_allreduce(
    vec, inner_axis: str, outer_axis: str, *, op: str | Callable = "sum"
):
    """Pod-aware allreduce (beyond-paper): reduce-scatter within ``inner_axis``
    (intra-pod ICI), MRD allreduce across ``outer_axis`` (inter-pod DCN) on the
    1/p0_inner-size shard, then all-gather within ``inner_axis``.

    Inter-pod traffic drops from n*log2(pods) to (n/p0_inner)*log2(pods)."""
    return plans.allreduce_plan(
        schedule="hierarchical", axes=(inner_axis, outer_axis), op=op
    ).run(vec)


def tree_allreduce_flat(
    tree,
    axis_name: str,
    *,
    op: str | Callable = "sum",
    schedule: str = "rabenseifner",
    bucket_bytes=None,
):
    """Allreduce a pytree through the bucketed engine (DESIGN.md S10).

    ``schedule``: any registered schedule name; 'mrd' (paper),
    'rabenseifner' (beyond-paper, default for bandwidth-bound payloads
    like gradients).  ``bucket_bytes`` caps each dtype-homogeneous wire
    bucket (None = one bucket per dtype — the closest analog of the
    historical flat-ravel path, but dtype-preserving).
    """
    if axis_size(axis_name) == 1:
        return tree
    return plans.tree_allreduce(
        tree, schedule=schedule, op=op, axes=(axis_name,),
        bucket_bytes=bucket_bytes,
    )


# ---------------------------------------------------------------------------
# Simulation API (single device, stacked rank axis)
# ---------------------------------------------------------------------------


def sim_allreduce(x, *, op: str | Callable = "sum"):
    """x: [p, ...] stacked per-rank values -> [p, ...] (all rows = reduction)."""
    p = x.shape[0]
    if p == 1:
        return x
    return plans.allreduce_plan(schedule="mrd", p=p, op=op).run(x)


def sim_reduce_scatter(x, *, op: str | Callable = "sum"):
    """x: [p, n] with n % p0 == 0 -> [p, n/p0] (rows >= p0 are junk)."""
    p = x.shape[0]
    if p == 1:
        return x
    p0, _, _ = pivot(p)
    if x.shape[1] % p0:
        raise ValueError(f"n={x.shape[1]} not divisible by p0={p0}")
    return plans.reduce_scatter_plan(p=p, op=op).run(x)


def sim_allgather(x):
    """x: [p, m] segments (rows >= p0 junk) -> [p, m*p0]."""
    p = x.shape[0]
    if p == 1:
        return x
    return plans.allgather_plan(p=p).run(x)


def sim_rabenseifner_allreduce(x, *, op: str | Callable = "sum"):
    p = x.shape[0]
    if p == 1:
        return x
    return plans.allreduce_plan(schedule="rabenseifner", p=p, op=op).run(x)


# ---------------------------------------------------------------------------
# Whole-array convenience wrappers (build the shard_map for the caller)
# ---------------------------------------------------------------------------


def make_allreduce(mesh, axis_name: str, *, op: str = "sum", schedule: str = "mrd"):
    """Returns a jitted fn: [p, ...] global array sharded over ``axis_name`` ->
    allreduced array of the same shape (each shard = full reduction)."""
    from jax.sharding import PartitionSpec as P

    spec = P(axis_name)

    def fn(x):
        def local(v):
            y = v[0]
            if schedule == "psum":
                out = jax.lax.psum(y, axis_name)
            elif schedule == "mrd":
                out = allreduce(y, axis_name, op=op)
            else:
                plan = plans.allreduce_plan(
                    schedule=schedule, axes=(axis_name,), op=op
                )
                flat = y.reshape(-1)
                pad = (-flat.shape[0]) % plan.pad_quantum()
                out = plan.run(jnp.pad(flat, (0, pad)))
                out = out[: flat.shape[0]].reshape(y.shape)
            return out[None]

        return compat.shard_map(
            local, mesh=mesh, in_specs=spec, out_specs=spec,
            axis_names={axis_name}, check_vma=False,
        )(x)

    return jax.jit(fn)
