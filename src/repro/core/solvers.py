"""Fixed-point mappings for (asynchronous) iterative solvers.

The paper's setting: ``Ax = b``, splitting ``A = M - N``, iteration
``x <- Tx + c`` with ``T = M^{-1}N``.  The engine (``async_engine``) only
needs the fixed-point map ``f`` and block partitioning; solvers here provide
the paper's S4 experiment (1-D two-point boundary-value problem, finite
differences) plus dense variants for tests.

Asynchronous convergence requires rho(|T|) < 1 (contraction in a weighted max
norm [4,2]); ``spectral_radius_abs_T`` estimates it for test matrices.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FixedPoint:
    """A fixed-point problem f(x) = x partitioned into p equal blocks."""

    n: int
    full_map: Callable  # [n] -> [n], the map f
    name: str = "fixed-point"

    def residual_norm(self, x):
        """||f(x) - x||_inf — the paper's termination functional."""
        return jnp.max(jnp.abs(self.full_map(x) - x))

    def block_views_update(self, views):
        """views: [p, n] (worker i's possibly-stale global view).
        Returns [p, m]: worker i's new block = f(view_i) restricted to block i."""
        p = views.shape[0]
        m = self.n // p
        full = jax.vmap(self.full_map)(views)  # [p, n]
        return full.reshape(p, p, m)[jnp.arange(p), jnp.arange(p)]


def poisson_1d(
    n: int,
    *,
    omega: float = 1.0,
    shift: float = 0.0,
    rhs: jnp.ndarray | None = None,
    seed: int = 0,
    rhs_scale: float = 10.0,
) -> FixedPoint:
    """The paper's S4 problem: 1-D two-point BVP, finite differences.

    A = tridiag(-1, 2+shift, -1) (n x n), b ~ U[-rhs_scale, rhs_scale] (paper:
    n = 10000, b in [-10, 10], shift = 0).  Weighted-Jacobi fixed point:
    ``f(x) = x + (omega/diag) * (b - Ax)``.  ``shift > 0`` makes A strictly
    diagonally dominant (rho(|T|) <= 2/(2+shift) < 1), giving fast asynchronous
    contraction for protocol benchmarks; shift = 0 is the paper's exact (slow,
    rho ~ 1 - O(1/n^2)) problem.
    """
    if rhs is None:
        rhs = jax.random.uniform(
            jax.random.PRNGKey(seed), (n,), minval=-rhs_scale, maxval=rhs_scale
        )
    diag = 2.0 + shift

    def apply_A(x):
        up = jnp.concatenate([x[1:], jnp.zeros((1,), x.dtype)])
        down = jnp.concatenate([jnp.zeros((1,), x.dtype), x[:-1]])
        return diag * x - up - down

    def f(x):
        return x + (omega / diag) * (rhs - apply_A(x))

    return FixedPoint(
        n=n, full_map=f, name=f"poisson1d(n={n},omega={omega},shift={shift})"
    )


def jacobi_dense(A: jnp.ndarray, b: jnp.ndarray, *, omega: float = 1.0) -> FixedPoint:
    """Weighted Jacobi on a dense system (tests): f(x) = x + omega*D^-1(b-Ax)."""
    n = A.shape[0]
    dinv = 1.0 / jnp.diag(A)

    def f(x):
        return x + omega * dinv * (b - A @ x)

    return FixedPoint(n=n, full_map=f, name=f"jacobi_dense(n={n})")


def richardson_dense(A, b, *, alpha: float) -> FixedPoint:
    """Richardson iteration (a 'gradient method' in the paper's sense):
    f(x) = x + alpha*(b - Ax)."""
    n = A.shape[0]

    def f(x):
        return x + alpha * (b - A @ x)

    return FixedPoint(n=n, full_map=f, name=f"richardson(n={n})")


def random_dd_system(n: int, *, seed: int = 0, dominance: float = 2.0):
    """Random strictly diagonally dominant system (async-convergent Jacobi:
    rho(|T|) <= 1/dominance < 1).  Returns (A, b) as numpy arrays."""
    rng = np.random.default_rng(seed)
    A = rng.uniform(-1.0, 1.0, size=(n, n))
    np.fill_diagonal(A, 0.0)
    rowsum = np.abs(A).sum(axis=1)
    np.fill_diagonal(A, dominance * rowsum + 1e-3)
    b = rng.uniform(-10.0, 10.0, size=(n,))
    return A, b


def spectral_radius_abs_T(A: np.ndarray, iters: int = 200) -> float:
    """Power-iteration estimate of rho(|T|) for Jacobi T = I - D^-1 A
    (asynchronous convergence criterion [4])."""
    D = np.diag(A)
    T = np.abs(np.eye(A.shape[0]) - A / D[:, None])
    v = np.ones(A.shape[0]) / np.sqrt(A.shape[0])
    lam = 0.0
    for _ in range(iters):
        w = T @ v
        lam = float(np.linalg.norm(w))
        if lam == 0.0:
            return 0.0
        v = w / lam
    return lam
