"""Import-compatible shim over :mod:`repro.asynchrony.solvers`.

Fixed-point solvers are now a registry (``repro.asynchrony.SOLVERS``:
``poisson1d`` / ``poisson2d`` / ``jacobi_dense`` / ``richardson`` /
``d_iteration``); this module keeps the historical names alive.  New code
should import from ``repro.asynchrony``.
"""

from __future__ import annotations

from repro.asynchrony.solvers import (  # noqa: F401
    SOLVERS,
    FixedPoint,
    d_iteration,
    jacobi_dense,
    poisson_1d,
    poisson_2d,
    random_dd_system,
    richardson_dense,
    spectral_radius_abs_T,
)
