"""Import-compatible shim over :mod:`repro.asynchrony.protocols`.

The paper's detection algorithms are now registry entries
(``repro.asynchrony.DETECTION_PROTOCOLS``: ``inexact`` / ``exact`` /
``interval`` / ``oracle`` / ``sync``), each an ``init``/``tick``/``finalize``
object over a :class:`repro.collectives.plans.CollectivePlan`; the
training-loop :class:`ConvergenceMonitor` is built from the same registry.
This module keeps the historical tick-function surface alive for old
callers.  New code should import from ``repro.asynchrony``.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.asynchrony.protocols import (  # noqa: F401
    DETECTION_PROTOCOLS,
    RES_INIT,
    ConvergenceMonitor,
    Obs,
    get_protocol,
)

# Deprecated alias (was the module-private residual latch); prefer RES_INIT.
_BIG = RES_INIT


def _obs(**kw) -> Obs:
    defaults = dict(
        x=None, update_mag=None, tick=jnp.zeros((), jnp.int32), key=None,
        fp=None, eps=0.0, max_delay=0,
        msg_table=jnp.zeros((1,), jnp.int32),
        coll_cycle_msgs=jnp.zeros((), jnp.int32),
    )
    defaults.update(kw)
    return Obs(**defaults)


def inexact_init(p: int):
    return get_protocol("inexact").init(p, 0, None)


def inexact_tick(det, update_mag, *, p: int, eps: float):
    st, _ = get_protocol("inexact").tick(
        det, _obs(update_mag=update_mag, eps=eps)
    )
    return st


def exact_init(p: int, m: int):
    return get_protocol("exact").init(p, m, None)


def exact_tick(det, x_blocks, *, fp, now, key, max_delay: int, eps: float):
    p = x_blocks.shape[0]
    st, _ = get_protocol("exact").tick(
        det,
        _obs(
            x=x_blocks, update_mag=jnp.zeros((p,), jnp.float32), tick=now,
            key=key, fp=fp, eps=eps, max_delay=max_delay,
        ),
    )
    return st
