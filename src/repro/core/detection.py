"""Convergence detection (paper S3): Algorithm 1 (inexact) and Algorithm 2
(exact, snapshot-based), plus the training-loop ConvergenceMonitor.

The solver-level detectors are tick-wise state machines driven by
``repro.core.async_engine`` over the **sim** executor.  The training-level
``ConvergenceMonitor`` runs the same non-blocking MRD reduction over one or
more mesh axes (the **device** executor) and is advanced one stage per train
step — the paper's statechart embedded in a production training loop.

Everything here drives :class:`repro.collectives.plans.CollectivePlan`
(``init``/``step``), so detection uses the exact same stage interpreter as
the gradient collectives.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import compat
from repro.collectives import plans
from repro.core import snapshot
from repro.core.solvers import FixedPoint

_BIG = 1e30  # finite 'infinity' for residual latches


def _sim_plan(p: int) -> plans.CollectivePlan:
    return plans.allreduce_plan(schedule="mrd", p=p, op="max")


# ---------------------------------------------------------------------------
# Algorithm 1: inexact detection (non-blocking Allreduce of local residuals)
# ---------------------------------------------------------------------------


def inexact_init(p: int) -> dict[str, Any]:
    return {
        "nb": _sim_plan(p).init(jnp.full((p,), _BIG, jnp.float32)),
        "res_loc": jnp.full((p,), _BIG, jnp.float32),
        "res_norm": jnp.full((), _BIG, jnp.float32),
        "detected": jnp.zeros((), jnp.bool_),
    }


def inexact_tick(det, update_mag, *, p: int, eps: float):
    """One tick of Algorithm 1.

    ``update_mag``: [p], each worker's last local update magnitude
    ``||x_i - z_i||_inf`` (its res_loc candidate).  Following the paper, the
    Allreduce is advanced every iteration; when a cycle completes (flag), the
    worker reads res_glb into res_norm and re-latches res_loc from its current
    local residual.  Inexact: contributions mix different local iterations.
    """
    nb = _sim_plan(p).step(det["nb"], det["res_loc"])
    flag = nb["flag"]
    res_norm = jnp.where(flag, jnp.max(nb["result"]), det["res_norm"])
    res_loc = jnp.where(flag, update_mag, det["res_loc"])
    detected = det["detected"] | (flag & (res_norm < eps))
    return {"nb": nb, "res_loc": res_loc, "res_norm": res_norm, "detected": detected}


# ---------------------------------------------------------------------------
# Algorithm 2: exact detection (snapshot -> residual on x̄ -> Allreduce)
# ---------------------------------------------------------------------------


def exact_init(p: int, m: int) -> dict[str, Any]:
    return {
        "snap": snapshot.init(p, m),
        "nb": _sim_plan(p).init(jnp.full((p,), _BIG, jnp.float32)),
        "res_loc": jnp.full((p,), _BIG, jnp.float32),
        "res_norm": jnp.full((), _BIG, jnp.float32),
        "mode": jnp.zeros((), jnp.int32),  # 0 = snapshot (sflag), 1 = reduce
        "xbar": jnp.zeros((p * m,), jnp.float32),
        "detected": jnp.zeros((), jnp.bool_),
    }


def exact_tick(det, x_blocks, *, fp: FixedPoint, now, key, max_delay: int, eps: float):
    """One tick of Algorithm 2.

    Snapshot phase (sflag): the Chandy–Lamport cut assembles a consistent x̄;
    on completion each worker computes ``res_loc_i = ||f_i(x̄) - x̄_i||_inf``
    on the *frozen* x̄ (eflag in the paper).  Reduce phase: the non-blocking
    MRD Allreduce certifies ``||f(x̄) - x̄||_inf < eps`` exactly for that x̄;
    on failure a new snapshot begins.
    """
    p, m = x_blocks.shape

    def snapshot_phase(d):
        snap = d["snap"]
        fresh = ~snap["in_progress"]
        started = snapshot.start(snap, now, key, max_delay)
        snap = jax.tree.map(
            lambda a, b: jnp.where(fresh, a, b), started, snap
        )
        snap = snapshot.tick(snap, x_blocks, now)
        fin = snapshot.done(snap, now)
        xbar = snapshot.assembled(snap)
        fx = fp.full_map(xbar)
        res_blocks = jnp.max(jnp.abs(fx - xbar).reshape(p, m), axis=1)
        return {
            **d,
            "snap": {**snap, "in_progress": snap["in_progress"] & ~fin},
            "res_loc": jnp.where(fin, res_blocks, d["res_loc"]),
            "xbar": jnp.where(fin, xbar, d["xbar"]),
            "mode": jnp.where(fin, 1, d["mode"]),
        }

    def reduce_phase(d):
        nb = _sim_plan(p).step(d["nb"], d["res_loc"])
        flag = nb["flag"]
        res_norm = jnp.where(flag, jnp.max(nb["result"]), d["res_norm"])
        det_now = flag & (res_norm < eps)
        return {
            **d,
            "nb": nb,
            "res_norm": res_norm,
            "detected": d["detected"] | det_now,
            # on a failed certification, go back to the snapshot phase
            "mode": jnp.where(flag & ~det_now, 0, d["mode"]),
        }

    return jax.lax.cond(det["mode"] == 0, snapshot_phase, reduce_phase, det)


# ---------------------------------------------------------------------------
# Training-loop monitor (device executor)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ConvergenceMonitor:
    """Paper's detection embedded in a training step, over the DP mesh axes.

    ``mode='inexact'``: each cycle latches the worker's *current* metric (e.g.
    local grad-norm or loss delta); the certified global value lags by
    ``cycle_length`` steps and may mix step indices across workers — exactly
    the paper's Algorithm 1 trade-off, but it never blocks the step.

    ``mode='exact'``: contributions are latched only from steps where
    ``step_idx % cycle_length == 0``; all workers therefore reduce metrics
    from the *same* global step (a consistent cut — the BSP analogue of the
    snapshot), so the certified value is exact for that step.

    ``axis_name`` may be a single mesh axis or a tuple (e.g. a multi-pod
    ``("pod", "data")`` DP domain): the underlying plan chains the per-axis
    MRD schedules into one stage list, so detection over a product of axes
    costs one scalar ppermute per step exactly like the single-axis case.

    Use inside shard_map/jit: ``state, done, value = monitor.step(state, metric,
    step_idx)``.
    """

    axis_name: Any  # str or tuple of axis names (e.g. ("pod","data"))
    threshold: float
    mode: str = "inexact"  # 'inexact' | 'exact'
    op: str = "max"

    def _axes(self) -> tuple[str, ...]:
        if isinstance(self.axis_name, str):
            return (self.axis_name,)
        return tuple(self.axis_name)

    def _plan(self) -> plans.CollectivePlan:
        return plans.allreduce_plan(schedule="mrd", axes=self._axes(), op=self.op)

    def init(self, varying: bool = True) -> dict[str, Any]:
        """``varying=True`` when called *inside* a shard_map region with VMA
        checking on (marks state as varying over the manual axes so it can be
        carried through scan/while).  Use ``varying=False`` when building the
        global state outside shard_map (e.g. replicated-then-sharded train
        state)."""
        metric0 = jnp.full((), _BIG, jnp.float32)
        state = {
            "nb": plans.allreduce_plan(schedule="mrd", p=1).init(metric0),
            "latched": metric0,
            "value": metric0,
            "done": jnp.zeros((), jnp.bool_),
        }
        if not varying:
            return state
        return jax.tree.map(lambda x: compat.pvary(x, self._axes()), state)

    def step(self, state, local_metric, step_idx):
        local_metric = local_metric.astype(jnp.float32)
        plan = self._plan()
        if self.mode == "exact":
            clen = plan.cycle_length()
            latch_now = (step_idx % clen) == 0
            latched = jnp.where(latch_now, local_metric, state["latched"])
        else:
            latched = local_metric
        nb = plan.step(state["nb"], latched)
        value = jnp.where(nb["flag"], nb["result"], state["value"])
        done = state["done"] | (nb["flag"] & (value < self.threshold))
        return (
            {"nb": nb, "latched": latched, "value": value, "done": done},
            done,
            value,
        )
