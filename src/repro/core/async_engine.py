"""Bounded-delay simulator of asynchronous iterations (paper S1) with the
paper's convergence-detection protocols layered on top.

``p`` virtual workers each own one block of the iterate.  Per global tick:

1. an activity subset ``P^k`` is drawn (Bernoulli + forced activity every
   ``force_every`` ticks — the paper's first fairness condition);
2. each active worker applies its block map to a *stale view* of the global
   vector assembled from a ring-buffer history with per-(i,j) delays bounded
   by ``max_delay`` (the second fairness condition: tau -> infinity);
3. the selected detection protocol advances one step (the non-blocking MRD
   Allreduce advances exactly one stage per tick — communication progresses
   while workers compute, which is the point of the paper's statechart).

Modes: ``inexact`` (Alg. 1), ``exact`` (Alg. 2, snapshot-certified),
``oracle`` (physically unrealizable ground truth: the true residual of the
*current* global iterate), ``sync`` (classic synchronous Jacobi + blocking
Allreduce every iteration, for the paper's Fig. 5 comparison).

Everything is a single ``lax.while_loop`` — jittable and deterministic.
Message accounting follows the paper: point-to-point ``Send(x_i)`` to all
dependent neighbors (all-to-all assumption) plus per-stage collective
messages from the schedule.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import detection, topology
from repro.core.solvers import FixedPoint


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    p: int
    max_delay: int = 3
    activity: float = 0.7
    force_every: int = 5
    detection: str = "exact"  # 'inexact' | 'exact' | 'oracle' | 'sync'
    eps: float = 1e-6
    max_ticks: int = 20000
    seed: int = 0


@dataclasses.dataclass
class AsyncResult:
    detected: bool
    det_tick: int
    ticks: int
    res_glb: float  # detector's certified value at detection
    true_res: float  # ground-truth ||f(.)-.||_inf of the returned solution
    kiter: np.ndarray  # per-worker local iteration counts
    messages_p2p: int
    messages_coll: int
    x: np.ndarray  # returned solution (x̄ for 'exact', current x otherwise)


def _stage_message_table(p: int) -> jnp.ndarray:
    """messages sent at stage s of the MRD allreduce cycle."""
    sched = topology.allreduce_schedule(p)
    if not sched:
        return jnp.zeros((1,), jnp.int32)
    return jnp.asarray([len(st.pairs) for st in sched], jnp.int32)


def run(fp: FixedPoint, cfg: AsyncConfig) -> AsyncResult:
    p = cfg.p
    if fp.n % p:
        raise ValueError(f"n={fp.n} must be divisible by p={p}")
    m = fp.n // p
    H = cfg.max_delay + 2  # ring-buffer depth (delays in [0, max_delay])
    sync = cfg.detection == "sync"
    base_key = jax.random.PRNGKey(cfg.seed)
    msg_table = _stage_message_table(p)
    coll_cycle_msgs = topology.paper_message_count(p)

    x0 = jnp.zeros((p, m), jnp.float32)

    def det_init():
        if cfg.detection == "inexact":
            return detection.inexact_init(p)
        if cfg.detection == "exact":
            return detection.exact_init(p, m)
        # oracle / sync carry a trivial det state
        return {
            "res_norm": jnp.full((), detection._BIG, jnp.float32),
            "detected": jnp.zeros((), jnp.bool_),
        }

    def cond(c):
        return (~c["det"]["detected"]) & (c["tick"] < cfg.max_ticks)

    def body(c):
        tick = c["tick"]
        key = jax.random.fold_in(base_key, tick)
        k_act, k_delay, k_snap = jax.random.split(key, 3)

        if sync:
            active = jnp.ones((p,), jnp.bool_)
            delays = jnp.zeros((p, p), jnp.int32)
        else:
            active = jax.random.bernoulli(k_act, cfg.activity, (p,)) | (
                tick - c["last_active"] >= cfg.force_every
            )
            delays = jax.random.randint(k_delay, (p, p), 0, cfg.max_delay + 1)

        # Assemble stale views: worker i sees block j from `delays[i,j]` ticks
        # ago (its own block is always current).
        idx = jnp.mod(tick - 1 - delays, H)  # [p, p]
        views = c["hist"][idx, jnp.arange(p)[None, :]]  # [p, p, m]
        views = views.at[jnp.arange(p), jnp.arange(p)].set(c["x"])
        xnew = fp.block_views_update(views.reshape(p, p * m))  # [p, m]

        x = jnp.where(active[:, None], xnew, c["x"])
        upd = jnp.max(jnp.abs(x - c["x"]), axis=1)
        update_mag = jnp.where(active, upd, c["update_mag"])
        hist = c["hist"].at[jnp.mod(tick, H)].set(x)

        # --- detection ---
        det = c["det"]
        coll_msgs = c["messages_coll"]
        if cfg.detection == "inexact":
            stage_before = det["nb"]["stage"]
            det = detection.inexact_tick(det, update_mag, p=p, eps=cfg.eps)
            coll_msgs = coll_msgs + msg_table[jnp.minimum(stage_before, msg_table.shape[0] - 1)]
        elif cfg.detection == "exact":
            stage_before = det["nb"]["stage"]
            in_reduce = det["mode"] == 1
            det = detection.exact_tick(
                det, x, fp=fp, now=tick, key=k_snap,
                max_delay=cfg.max_delay, eps=cfg.eps,
            )
            coll_msgs = coll_msgs + jnp.where(
                in_reduce, msg_table[jnp.minimum(stage_before, msg_table.shape[0] - 1)], 0
            )
            # snapshot markers + data replies (all-to-all) on snapshot start
            started = (~in_reduce) & (c["det"]["snap"]["in_progress"] == False)  # noqa: E712
            coll_msgs = coll_msgs + jnp.where(started, 2 * p * (p - 1), 0)
        elif cfg.detection == "oracle":
            res = fp.residual_norm(x.reshape(-1))
            det = {"res_norm": res, "detected": res < cfg.eps}
        else:  # sync: blocking allreduce of update magnitudes every iteration
            res = jnp.max(update_mag)
            det = {"res_norm": res, "detected": res < cfg.eps}
            coll_msgs = coll_msgs + coll_cycle_msgs

        n_active = jnp.sum(active.astype(jnp.int32))
        return {
            "tick": tick + 1,
            "x": x,
            "hist": hist,
            "update_mag": update_mag,
            "kiter": c["kiter"] + active.astype(jnp.int32),
            "last_active": jnp.where(active, tick, c["last_active"]),
            "det": det,
            "messages_p2p": c["messages_p2p"] + n_active * (p - 1),
            "messages_coll": coll_msgs,
        }

    carry = {
        "tick": jnp.ones((), jnp.int32),
        "x": x0,
        "hist": jnp.broadcast_to(x0, (H, p, m)).astype(jnp.float32),
        "update_mag": jnp.full((p,), detection._BIG, jnp.float32),
        "kiter": jnp.zeros((p,), jnp.int32),
        "last_active": jnp.zeros((p,), jnp.int32),
        "det": det_init(),
        "messages_p2p": jnp.zeros((), jnp.int32),
        "messages_coll": jnp.zeros((), jnp.int32),
    }

    final = jax.jit(lambda c: jax.lax.while_loop(cond, body, c))(carry)

    detected = bool(final["det"]["detected"])
    if cfg.detection == "exact":
        x_out = np.asarray(final["det"]["xbar"])
    else:
        x_out = np.asarray(final["x"]).reshape(-1)
    true_res = float(fp.residual_norm(jnp.asarray(x_out)))
    return AsyncResult(
        detected=detected,
        det_tick=int(final["tick"]) - 1,
        ticks=int(final["tick"]) - 1,
        res_glb=float(final["det"]["res_norm"]),
        true_res=true_res,
        kiter=np.asarray(final["kiter"]),
        messages_p2p=int(final["messages_p2p"]),
        messages_coll=int(final["messages_coll"]),
        x=x_out,
    )
