"""Import-compatible shim over :mod:`repro.asynchrony.engine`.

The bounded-delay simulator, its delay models, detection protocols, and the
solver registry live in ``repro.asynchrony`` (DESIGN.md S11); this module
keeps the historical ``repro.core.async_engine`` surface alive.  New code
should import from ``repro.asynchrony``.
"""

from __future__ import annotations

from repro.asynchrony.engine import (  # noqa: F401
    AsyncConfig,
    AsyncResult,
    SweepResult,
    resolve_delay_params,
    run,
    sweep,
)
from repro.asynchrony.engine import _stage_message_table  # noqa: F401
