"""Distributed snapshot (Chandy–Lamport [3]) for the exact detector.

The paper simplifies to an "all-to-all" pattern: dependent neighbors ==
essential neighbors == all other workers, so after the snapshot every worker
holds the full consistent vector ``x̄ = (x_1^{k_1}, ..., x_p^{k_p})``.

In the bounded-delay simulator, a snapshot started at tick ``t0`` latches
worker ``j``'s block when its marker arrives (tick ``t0 + d_j``, ``d_j`` ~
U{0..D}); the assembled x̄ is available to everyone once every latch plus the
data replies have propagated (``complete_tick``).  The paper only requires x̄
to be *some* combination of locally-consistent components — exactness of
Algorithm 2 comes from evaluating ``f`` on the frozen x̄, not from temporal
alignment of the k_j.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def init(p: int, m: int, dtype=jnp.float32) -> dict[str, Any]:
    return {
        "xbar": jnp.zeros((p, m), dtype),  # latched blocks
        "latched": jnp.zeros((p,), jnp.bool_),
        "latch_tick": jnp.zeros((p,), jnp.int32),
        "complete_tick": jnp.zeros((), jnp.int32),
        "in_progress": jnp.zeros((), jnp.bool_),
    }


def start(state, tick, key, max_delay: int, *, reply_delay: bool = True):
    """Begin a snapshot at ``tick``: sample marker delays per worker."""
    p = state["latched"].shape[0]
    d = jax.random.randint(key, (p,), 0, max_delay + 1)
    latch = tick + d
    reply = jax.random.randint(
        jax.random.fold_in(key, 1), (), 0, (max_delay + 1) if reply_delay else 1
    )
    return {
        **state,
        "latched": jnp.zeros((p,), jnp.bool_),
        "latch_tick": latch,
        "complete_tick": jnp.max(latch) + reply,
        "in_progress": jnp.ones((), jnp.bool_),
    }


def tick(state, x_blocks, now):
    """Advance one tick: latch any block whose marker arrives now (or earlier,
    for the tick the snapshot starts on)."""
    due = state["in_progress"] & ~state["latched"] & (state["latch_tick"] <= now)
    xbar = jnp.where(due[:, None], x_blocks, state["xbar"])
    return {**state, "xbar": xbar, "latched": state["latched"] | due}


def done(state, now):
    return (
        state["in_progress"]
        & jnp.all(state["latched"])
        & (now >= state["complete_tick"])
    )


def assembled(state):
    """Full consistent vector x̄ (valid once done() is True)."""
    return state["xbar"].reshape(-1)
