"""Non-blocking MRD Allreduce as a state machine (paper Fig. 4).

Deprecated shim: the state machine now lives on
:class:`repro.collectives.plans.CollectivePlan` (``init``/``step``), so
the staged collective and the blocking one are literally the same stage
interpreter.  This module keeps the original functional API:

- device: call :func:`step` inside ``shard_map`` with ``axis_name=...``
  (state leaves are per-rank, stage counter is replicated-in-lockstep);
  ``axis_name`` may be a *tuple* of mesh axes — the plan chains the
  per-axis schedules into one stage list;
- sim: call with ``p=...`` on stacked ``[p, ...]`` arrays (used by the
  asynchronous-iteration engine and exhaustive CPU tests).
"""

from __future__ import annotations

from typing import Any, Callable

from repro.collectives import plans
from repro.collectives.schedules import allreduce_schedule


def _make_plan(axis_name, p, op) -> plans.CollectivePlan:
    if (axis_name is None) == (p is None):
        raise ValueError("pass exactly one of axis_name= (device) or p= (sim)")
    if axis_name is not None:
        axes = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
        return plans.allreduce_plan(schedule="mrd", axes=axes, op=op)
    return plans.allreduce_plan(schedule="mrd", p=p, op=op)


def init(value) -> dict[str, Any]:
    """Create the state machine's state, latching ``value`` as the first
    cycle's contribution.  ``value``: per-rank array (device) or [p, ...]
    stacked (sim)."""
    # state layout is plan-independent; use a sim plan to build it
    return plans.allreduce_plan(schedule="mrd", p=1).init(value)


def step(
    state: dict[str, Any],
    local_value,
    *,
    axis_name: Any | None = None,
    p: int | None = None,
    op: str | Callable = "max",
) -> dict[str, Any]:
    """Advance the non-blocking Allreduce by one stage.

    Returns the new state.  ``state['flag']`` is True iff this call completed a
    cycle; then ``state['result']`` holds the reduction of the values latched
    at that cycle's start.  ``local_value`` is latched only when a new cycle
    begins (stage == 0), matching the paper's statechart.
    """
    return _make_plan(axis_name, p, op).step(state, local_value)


def cycle_length(p: int) -> int:
    """Calls per completed reduction (= paper step count; >= 1)."""
    return max(len(allreduce_schedule(p)), 1)


def run_blocking(value, *, axis_name=None, p=None, op="max"):
    """Drive the state machine to one full cycle (for tests/reference)."""
    return _make_plan(axis_name, p, op).run_blocking(value)
