"""Non-blocking MRD Allreduce as a state machine (paper Fig. 4).

The paper rejects thread-based non-blocking collectives in favor of a
*state-based interface invoked repeatedly from the application loop*.  This is
its exact JAX analogue: the collective's stage list becomes a ``lax.switch``
over a stage counter carried in a pytree.  Each call to :func:`step` advances
**one** communication stage; a cycle completes after ``log2(p0)+2`` calls
(``log2(p0)`` for power-of-two ``p``), sets ``flag`` (paper's ``flag``/
``eflag``), publishes the reduced value, and re-latches the caller's current
local contribution to begin the next cycle — "each cycle begins with the
backward shift".

Works under both executors:
- device: call :func:`step` inside ``shard_map`` with ``axis_name=...``
  (state leaves are per-rank, stage counter is replicated-in-lockstep);
- sim: call with ``p=...`` on stacked ``[p, ...]`` arrays (used by the
  asynchronous-iteration engine and exhaustive CPU tests).
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import mrd
from repro.core.mrd import DeviceBackend, SimBackend, _exec_allreduce_stage, _resolve_op
from repro.core.topology import allreduce_schedule


def init(value) -> dict[str, Any]:
    """Create the state machine's state, latching ``value`` as the first
    cycle's contribution.  ``value``: per-rank array (device) or [p, ...]
    stacked (sim)."""
    return {
        "stage": jnp.zeros((), jnp.int32),
        "buf": value,
        "result": jax.tree.map(jnp.zeros_like, value),
        "flag": jnp.zeros((), jnp.bool_),  # True for exactly the completing call
        "cycles": jnp.zeros((), jnp.int32),
    }


def _make_backend(axis_name: str | None, p: int | None):
    if (axis_name is None) == (p is None):
        raise ValueError("pass exactly one of axis_name= (device) or p= (sim)")
    if axis_name is not None:
        return DeviceBackend(axis_name), jax.lax.axis_size(axis_name)
    return SimBackend(p), p


def step(
    state: dict[str, Any],
    local_value,
    *,
    axis_name: str | None = None,
    p: int | None = None,
    op: str | Callable = "max",
) -> dict[str, Any]:
    """Advance the non-blocking Allreduce by one stage.

    Returns the new state.  ``state['flag']`` is True iff this call completed a
    cycle; then ``state['result']`` holds the reduction of the values latched
    at that cycle's start.  ``local_value`` is latched only when a new cycle
    begins (stage == 0), matching the paper's statechart.
    """
    be, psize = _make_backend(axis_name, p)
    opf = _resolve_op(op)
    sched = allreduce_schedule(psize)
    nstages = len(sched)

    if nstages == 0:  # p == 1: every call is a complete cycle
        return {
            "stage": state["stage"],
            "buf": local_value,
            "result": local_value,
            "flag": jnp.ones((), jnp.bool_),
            "cycles": state["cycles"] + 1,
        }

    starting = state["stage"] == 0
    buf = jax.tree.map(
        lambda lv, b: jnp.where(starting, lv, b), local_value, state["buf"]
    )

    def _stage_fn(st):
        def apply(b):
            return jax.tree.map(
                lambda leaf: _exec_allreduce_stage(leaf, st=st, be=be, p=psize, op=opf),
                b,
            )

        return apply

    buf = jax.lax.switch(state["stage"], [_stage_fn(st) for st in sched], buf)

    nxt = state["stage"] + 1
    done = nxt == nstages
    return {
        "stage": jnp.where(done, 0, nxt),
        "buf": buf,
        "result": jax.tree.map(
            lambda b, r: jnp.where(done, b, r), buf, state["result"]
        ),
        "flag": done,
        "cycles": state["cycles"] + done.astype(jnp.int32),
    }


def cycle_length(p: int) -> int:
    """Calls per completed reduction (= paper step count; >= 1)."""
    return max(len(allreduce_schedule(p)), 1)


def run_blocking(value, *, axis_name=None, p=None, op="max"):
    """Drive the state machine to one full cycle (for tests/reference)."""
    st = init(value)
    for _ in range(cycle_length(p if p is not None else jax.lax.axis_size(axis_name))):
        st = step(st, value, axis_name=axis_name, p=p, op=op)
    return st["result"]
