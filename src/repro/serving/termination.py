"""Layer 3 of the serving subsystem: request *termination* (``TERMINATION``).

Deciding *when each in-flight request is done* without a global barrier is
exactly the paper's distributed convergence-detection problem, so this layer
is built from the same parts as ``repro.asynchrony.protocols``: a
non-blocking MRD :class:`~repro.collectives.plans.CollectivePlan` advanced
one stage per engine tick, plus the per-worker contribution policies of
``DETECTION_PROTOCOLS`` (re-used directly — ``residual_interval`` vmaps the
``interval`` protocol's windowed latch over replicas x slots).

With ``dp > 1`` replicas, the per-slot done decision is **agreed**: every
replica contributes its local view (its block residual, or its local
EOS/max-len bit) into a staged MRD max-reduction over the ``[dp]`` axis —
non-power-of-two ``dp`` works natively, the paper's point — and a slot
retires only when a reduction cycle completes and certifies it.  Because
retirement is a pure function of the *agreed* result's completion tick, all
replicas retire the same slots on the same tick by construction; with
``dp = 1`` the plan has zero stages and every tick certifies immediately.

Slot recycling is handled without tagging the wire payload: each cycle
latches contributions at its start tick (``t_latch``), and a completed
cycle may only retire requests admitted **at or before** that latch — a
request prefilled into a recycled slot mid-cycle can never be killed by its
predecessor's agreed done-bit.

Registered protocols:

- ``eos_maxlen`` — LLM decode: done when the last token equals the
  request's EOS id or the generation budget is exhausted.
- ``residual_inexact`` — fixed-point requests, paper Alg. 1: replicas
  contribute their instantaneous block-update magnitude; certify when the
  agreed max drops below the request's eps.
- ``residual_interval`` — windowed Alg. 1 (the hardened protocol): each
  replica contributes the max over its last ``window`` magnitudes, so one
  momentarily-small update cannot retire a request; the default window
  covers a full agreement cycle.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.asynchrony.protocols import RES_INIT, get_protocol
from repro.collectives import plans

TERMINATION: Dict[str, Any] = {}


def register_termination(name: str):
    def deco(cls):
        TERMINATION[name] = cls()
        return cls

    return deco


def get_termination(name: str):
    try:
        return TERMINATION[name]
    except KeyError:
        raise ValueError(
            f"unknown termination protocol {name!r}; "
            f"registered: {sorted(TERMINATION)}"
        ) from None


@dataclasses.dataclass(frozen=True)
class TerminationConfig:
    """Static config for a termination protocol (hashable: jit-friendly)."""

    dp: int = 1  # replica count the done decision is agreed across
    eps: float = 1e-6  # default residual threshold (requests may override)
    window: int = 0  # residual_interval: 0 -> one full agreement cycle + 1
    schedule: str = "mrd"  # any repro.collectives SCHEDULES entry


def make_signals(
    *, tokens, new_tokens, eos, max_new, eps, active, admit_tick, tick, residual
):
    """The per-tick observation dict every protocol's ``tick`` consumes.

    ``tokens``/``new_tokens``/``eos``/``max_new``/``admit_tick``: ``[S]``
    int32; ``eps``: ``[S]`` float32 per-request thresholds; ``active``:
    ``[S]`` bool; ``tick``: scalar; ``residual``: ``[dp, S]`` float32 —
    each replica's block-local update magnitude (zeros for LLM decode).
    """
    return {
        "tokens": tokens, "new_tokens": new_tokens, "eos": eos,
        "max_new": max_new, "eps": eps, "active": active,
        "admit_tick": admit_tick, "tick": tick, "residual": residual,
    }


class _TerminationBase:
    """Shared agreement machinery: one staged MRD max-reduction over dp."""

    def _plan(self, cfg: TerminationConfig) -> plans.CollectivePlan:
        return plans.allreduce_plan(schedule=cfg.schedule, p=cfg.dp, op="max")

    def cycle_length(self, cfg: TerminationConfig) -> int:
        return self._plan(cfg).cycle_length()

    def _agree(self, st, cfg, sig, contribution):
        """Advance the non-blocking reduction one stage.

        Returns ``(new_nb, t_latch, flag, agreed [S])`` where ``agreed`` is
        the replica-agreed reduction of the contributions latched at
        ``t_latch`` (valid only when ``flag``).
        """
        plan = self._plan(cfg)
        starting = st["nb"]["stage"] == 0
        t_latch = jnp.where(starting, sig["tick"], st["t_latch"])
        nb = plan.step(st["nb"], contribution)
        return nb, t_latch, nb["flag"], nb["result"][0]

    def _guard(self, sig, t_latch):
        """Only requests admitted at or before the cycle's latch may retire."""
        return sig["active"] & (sig["admit_tick"] <= t_latch)

    # -- elastic resize (DESIGN.md S15) --------------------------------------

    def migrate(self, st, keep, cfg: TerminationConfig, slots: int):
        """Re-agree in-flight slot state after the replica extent changes.

        ``keep[i]`` is the old replica now at new rank ``i`` (None = a
        joiner); ``cfg`` is the config at the *new* extent.  The staged
        reduction is abandoned — its stage counter and partial combines are
        meaningless at the new extent, whose MRD cycle length differs — and
        restarts from stage 0, so the next tick re-latches ``t_latch`` to
        the current tick and every pre-resize admission stays retirable
        (``admit_tick <= t_latch`` still holds: no re-prefill needed).
        Everything certified so far survives; retirement requires a full
        fresh cycle of agreement among the *new* replica set.
        """
        new = self.init(cfg, slots)
        new["certified"] = st["certified"]
        return new


def _migrate_replica_rows(old_leaf, fresh_leaf, keep):
    """Select per-replica monitor rows (axis 0) along the resize keep map.

    Joiners take the fresh (RES_INIT-saturated) row, so they cannot help
    certify a slot before observing a whole window themselves.  When the
    per-row shape differs across extents (``window=0`` derives the window
    from the cycle length, which changes with dp), a survivor's new window
    is refilled with its running max — conservative by construction: the
    row's contribution can only be >= what it was, never optimistic.
    """
    parts = []
    for k in keep:
        if k is None:
            parts.append(fresh_leaf[0])
        elif old_leaf.shape[1:] == fresh_leaf.shape[1:]:
            parts.append(old_leaf[k])
        else:
            row_max = jnp.max(old_leaf[k], axis=-1, keepdims=True)
            parts.append(
                jnp.broadcast_to(row_max, fresh_leaf.shape[1:]).astype(
                    fresh_leaf.dtype
                )
            )
    return jnp.stack(parts)


@register_termination("eos_maxlen")
class EosMaxlenTermination(_TerminationBase):
    """LLM decode termination: EOS token or generation budget, agreed."""

    name = "eos_maxlen"

    def init(self, cfg: TerminationConfig, slots: int):
        return {
            "nb": self._plan(cfg).init(jnp.zeros((cfg.dp, slots), jnp.float32)),
            "t_latch": jnp.zeros((), jnp.int32),
            "certified": jnp.zeros((slots,), jnp.float32),
        }

    def tick(self, st, sig, cfg: TerminationConfig):
        local = sig["active"] & (
            (sig["tokens"] == sig["eos"]) | (sig["new_tokens"] >= sig["max_new"])
        )
        contribution = jnp.broadcast_to(
            local.astype(jnp.float32)[None, :], (cfg.dp, local.shape[0])
        )
        nb, t_latch, flag, agreed = self._agree(st, cfg, sig, contribution)
        retire = flag & (agreed >= 0.5) & self._guard(sig, t_latch)
        certified = jnp.where(retire, agreed, st["certified"])
        return {"nb": nb, "t_latch": t_latch, "certified": certified}, retire


class _ResidualTermination(_TerminationBase):
    """Residual-certified termination for fixed-point requests.

    Delegates the per-(replica, slot) contribution policy to the matching
    ``DETECTION_PROTOCOLS`` entry (``policy``) — the same latching code the
    sim engine and the training-loop ConvergenceMonitor run.
    """

    policy = "inexact"

    def _window(self, cfg: TerminationConfig) -> int:
        return cfg.window if cfg.window else self.cycle_length(cfg) + 1

    def _policy_init(self, cfg: TerminationConfig, dp: int, slots: int):
        proto = get_protocol(self.policy)
        metric0 = jnp.full((), RES_INIT, jnp.float32)
        if self.policy == "interval":
            one = proto.monitor_init(metric0, window=self._window(cfg))
        else:
            one = proto.monitor_init(metric0)
        return jax.tree.map(
            lambda leaf: jnp.broadcast_to(leaf, (dp, slots) + leaf.shape), one
        )

    def init(self, cfg: TerminationConfig, slots: int):
        return {
            "nb": self._plan(cfg).init(
                jnp.full((cfg.dp, slots), RES_INIT, jnp.float32)
            ),
            "m": self._policy_init(cfg, cfg.dp, slots),
            "t_latch": jnp.zeros((), jnp.int32),
            "certified": jnp.full((slots,), RES_INIT, jnp.float32),
        }

    def tick(self, st, sig, cfg: TerminationConfig):
        proto = get_protocol(self.policy)
        slots = sig["active"].shape[0]

        # a slot admitted this tick restarts its policy state (the window
        # refills before the new request can certify)
        fresh = self._policy_init(cfg, cfg.dp, slots)
        admitted_now = sig["admit_tick"] == sig["tick"]
        m = jax.tree.map(
            lambda cur, f: jnp.where(
                admitted_now.reshape((1, slots) + (1,) * (cur.ndim - 2)), f, cur
            ),
            st["m"], fresh,
        )

        def contribute(mstate, metric):
            return proto.monitor_contribution(
                mstate, metric, sig["tick"], self.cycle_length(cfg)
            )

        m, contribution = jax.vmap(jax.vmap(contribute))(m, sig["residual"])
        nb, t_latch, flag, agreed = self._agree(st, cfg, sig, contribution)
        retire = flag & (agreed < sig["eps"]) & self._guard(sig, t_latch)
        certified = jnp.where(retire, agreed, st["certified"])
        return {
            "nb": nb, "m": m, "t_latch": t_latch, "certified": certified,
        }, retire

    def migrate(self, st, keep, cfg: TerminationConfig, slots: int):
        new = super().migrate(st, keep, cfg, slots)
        new["m"] = jax.tree.map(
            lambda o, f: _migrate_replica_rows(o, f, keep), st["m"], new["m"]
        )
        return new


@register_termination("residual_inexact")
class ResidualInexactTermination(_ResidualTermination):
    name = "residual_inexact"
    policy = "inexact"


@register_termination("residual_interval")
class ResidualIntervalTermination(_ResidualTermination):
    name = "residual_interval"
    policy = "interval"
