"""Layer 1b of the serving subsystem: the *block-paged* decode pool.

The contiguous :class:`repro.serving.pool.DecodePool` reserves a full
``max_len`` cache slice per slot, so memory and occupancy are capped by the
worst-case request.  Here the cache is a shared physical pool of fixed-size
blocks (``distributed.serve.init_paged_pool``):

- :class:`BlockAllocator` — host-side free-list with refcounts and a
  content-addressed prefix registry.  Identical system prompts map their
  full prefix blocks to the *same* physical blocks (stored once, refcounted
  per sharer); :meth:`BlockAllocator.fork_private` is the copy-on-write
  primitive guarding any block a request may write.
- :class:`PagedDecodePool` — the device half.  Admission plans blocks for
  the request's *whole* budget up front (``ceil((plen+max_new+1)/bs)``), so
  the engine's multi-tick fused dispatch never faults on a missing block;
  a per-slot ``slot_cap`` freezes lengths at the reservation edge exactly
  like the contiguous pool's ``max_len`` clamp.  Decode gathers each slot's
  blocks into a view of exactly the contiguous layout and runs the
  *unchanged* per-slot decode vmap — which is what makes paged decode
  bit-identical to contiguous decode token-for-token (tested in
  ``tests/test_serving_paged.py``).  ``attn="pallas"`` switches the fused
  tick to :func:`repro.models.transformer.forward_decode_paged`, reading
  K/V through the block table inside the Pallas paged-attention kernel.

Block 0 is reserved as the trash block: device writes for inactive slots
are redirected there, so the fused tick stays one dispatch with no host
branching on allocator state.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import serve as dserve
from repro.models import transformer
from repro.models.config import ModelConfig


class BlockAllocator:
    """Free-list block allocator with refcounts and a prefix registry.

    Block ids are ``1..num_blocks-1`` (0 is the reserved trash block).
    Invariants (checked by :meth:`check`, property-tested in
    ``tests/test_paged_allocator.py``):

    - a block is on the free list iff its refcount is 0;
    - a block is never handed out twice while allocated;
    - a registered prefix key always points at a live (refcount > 0)
      block, and is dropped exactly when the last sharer releases it.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need at least 1 usable block + the trash block")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # pop() from the end -> lowest ids first (deterministic layouts)
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self.ref = np.zeros((num_blocks,), np.int64)
        self.ref[0] = 1  # trash block: permanently pinned
        self._block_of: Dict[bytes, int] = {}  # prefix key -> block id
        self._key_of: Dict[int, bytes] = {}    # block id -> prefix key

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        """Live blocks excluding the trash block."""
        return self.num_blocks - 1 - len(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise MemoryError(
                f"out of cache blocks ({self.num_blocks - 1} usable)"
            )
        b = self._free.pop()
        self.ref[b] = 1
        return b

    def retain(self, bid: int) -> None:
        if bid == 0 or self.ref[bid] <= 0:
            raise ValueError(f"retain of unallocated block {bid}")
        self.ref[bid] += 1

    def release(self, bid: int) -> bool:
        """Drop one reference; returns True when the block was freed."""
        if bid == 0 or self.ref[bid] <= 0:
            raise ValueError(f"release of unallocated block {bid}")
        self.ref[bid] -= 1
        if self.ref[bid] == 0:
            key = self._key_of.pop(bid, None)
            if key is not None:
                del self._block_of[key]
            self._free.append(bid)
            return True
        return False

    # -- prefix sharing ------------------------------------------------------

    def lookup(self, key: bytes) -> Optional[int]:
        """Adopt the block registered under ``key`` (bumps its refcount)."""
        bid = self._block_of.get(key)
        if bid is not None:
            self.ref[bid] += 1
        return bid

    def peek(self, key: bytes) -> Optional[int]:
        """Registry lookup without taking a reference (capacity planning)."""
        return self._block_of.get(key)

    def register(self, key: bytes, bid: int) -> None:
        """Publish ``bid`` (which the caller holds) as the block for ``key``."""
        if bid == 0 or self.ref[bid] <= 0:
            raise ValueError(f"register of unallocated block {bid}")
        if key in self._block_of:
            return  # first registration wins (content is identical anyway)
        self._block_of[key] = bid
        self._key_of[bid] = key

    def fork_private(self, bid: int) -> Tuple[int, bool]:
        """Copy-on-write: return a block id the caller may safely write.

        If the caller is the only owner, that's ``(bid, False)``.  If the
        block is shared, the caller's reference moves to a fresh private
        block — ``(new_bid, True)`` — and the shared block (and every other
        sharer's view of it) is left untouched.  The caller is responsible
        for filling the new block (admission refills it from the prompt
        recompute, so no device-side copy is needed).
        """
        if self.ref[bid] == 1:
            return bid, False
        nb = self.alloc()  # before release: MemoryError must not leak the ref
        self.release(bid)
        return nb, True

    # -- elastic resize (DESIGN.md S15) --------------------------------------

    def export_arrays(self) -> Dict[str, np.ndarray]:
        """Allocator state as flat int arrays — the broadcastable form a
        joining replica adopts.  The free list is exported *in order* (pop
        order determines future block layouts, so a joiner must replay it
        exactly); the prefix registry is packed as concatenated key bytes +
        per-key lengths + block ids, sorted by key for determinism.  All
        ids are int32 (x64 is off; int64 leaves would be silently coerced).
        """
        keys = sorted(self._block_of.items())
        return {
            "ref": self.ref.astype(np.int32),
            "free": np.asarray(self._free, np.int32),
            "key_bytes": np.frombuffer(
                b"".join(k for k, _ in keys), np.uint8
            ).copy(),
            "key_lens": np.asarray([len(k) for k, _ in keys], np.int32),
            "key_blocks": np.asarray([b for _, b in keys], np.int32),
        }

    @classmethod
    def from_arrays(
        cls, arrays: Dict[str, np.ndarray], num_blocks: int, block_size: int
    ) -> "BlockAllocator":
        """Rebuild an allocator from :meth:`export_arrays` output."""
        a = cls(num_blocks, block_size)
        a.ref = np.asarray(arrays["ref"]).astype(np.int64).copy()
        a._free = [int(b) for b in np.asarray(arrays["free"])]
        packed = np.asarray(arrays["key_bytes"], np.uint8).tobytes()
        off = 0
        for ln, bid in zip(
            np.asarray(arrays["key_lens"]), np.asarray(arrays["key_blocks"])
        ):
            key = packed[off : off + int(ln)]
            off += int(ln)
            a._block_of[key] = int(bid)
            a._key_of[int(bid)] = key
        a.check()
        return a

    def check(self) -> None:
        """Assert the allocator invariants (test hook)."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate block on free list"
        assert 0 not in free, "trash block on free list"
        assert self.ref[0] >= 1, "trash block unpinned"
        for b in range(1, self.num_blocks):
            assert (self.ref[b] == 0) == (b in free), (
                f"block {b}: ref={self.ref[b]} free={b in free}"
            )
        for key, b in self._block_of.items():
            assert self._key_of.get(b) == key, f"registry asymmetry at {b}"
            assert self.ref[b] > 0, f"registered block {b} is free"


class PagedDecodePool:
    """Block-paged continuous-batching pool (drop-in for ``DecodePool``)."""

    def __init__(
        self,
        cfg: ModelConfig,
        mesh,
        *,
        slots: int,
        max_len: int,
        max_prompt_len: int,
        block_size: int = 8,
        num_blocks: Optional[int] = None,
        share_prefixes: bool = True,
        attn: str = "gather",
    ):
        if max_prompt_len >= max_len:
            raise ValueError("max_prompt_len must leave room to decode")
        if max_len % block_size:
            raise ValueError(
                f"max_len={max_len} must be a multiple of block_size={block_size}"
            )
        dserve.validate_pageable(cfg, max_len)
        self.cfg, self.mesh = cfg, mesh
        self.slots, self.max_len, self.max_prompt_len = slots, max_len, max_prompt_len
        self.block_size = block_size
        self.blocks_per_slot = max_len // block_size
        if num_blocks is None:
            # capacity parity with the contiguous pool (+ the trash block)
            num_blocks = slots * self.blocks_per_slot + 1
        self.num_blocks = num_blocks
        self.share_prefixes = share_prefixes
        pool_step, self.rules = dserve.make_paged_pool_decode_step(
            cfg, mesh, block_size, attn=attn
        )
        slot_prefill, _ = dserve.make_paged_slot_prefill_step(
            cfg, mesh, max_prompt_len, max_len, block_size
        )

        def _step(params, state, active):
            logits, pages2, slot2 = pool_step(
                params, state["tokens"], state["pages"], state["tables"],
                state["slot"], state["lengths"], active,
            )
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            # freeze at the slot's *reserved* capacity — the per-slot
            # analogue of the contiguous pool's max_len clamp (the
            # reservation covers plen+max_new+1, so useful tokens are
            # produced strictly before the freeze; the engine surfaces any
            # capacity-forced retirement separately)
            adv = active & (state["lengths"] < state["slot_cap"] - 1)
            return {
                **state,
                "pages": pages2,
                "slot": dserve.select_slots(active, slot2, state["slot"]),
                "tokens": jnp.where(active, nxt, state["tokens"]),
                "lengths": jnp.where(adv, state["lengths"] + 1, state["lengths"]),
            }

        self.device_step = _step

        def _admit(params, state, prompt, plen, slot, table_row, write_mask,
                   cap):
            last_logits, pages, tables, slot_leaves = slot_prefill(
                params, prompt, plen, state["pages"], state["tables"],
                state["slot"], slot, table_row, write_mask,
            )
            tok0 = jnp.argmax(last_logits, -1).astype(jnp.int32)
            return {
                "pages": pages,
                "tables": tables,
                "slot": slot_leaves,
                "tokens": state["tokens"].at[slot].set(tok0),
                "lengths": state["lengths"].at[slot].set(plen),
                "slot_cap": state["slot_cap"].at[slot].set(cap),
            }

        self._jadmit = jax.jit(_admit)
        self.reset()

    def reset(self):
        from jax.sharding import NamedSharding, PartitionSpec

        self.allocator = BlockAllocator(self.num_blocks, self.block_size)
        self.slot_blocks: List[List[int]] = [[] for _ in range(self.slots)]
        self.prefix_saved_blocks = 0  # running count of share hits
        with self.mesh:
            pages = dserve.init_paged_pool(
                self.cfg, self.max_len, self.num_blocks, self.block_size
            )
            _, slot_leaves = dserve.split_paged_cache(
                transformer.init_cache(self.cfg, self.slots, self.max_len)
            )
        # commit everything to its sharding up front (same jit-cache
        # discipline as DecodePool.reset)
        pspecs = dserve.paged_pool_specs(self.cfg, self.rules, pages)
        pages = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(self.mesh, s)),
            pages, pspecs,
        )
        sspecs = dserve.cache_specs(self.cfg, self.rules, slot_leaves)
        slot_leaves = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(self.mesh, s)),
            slot_leaves, sspecs,
        )
        rep = NamedSharding(self.mesh, PartitionSpec())
        zi32 = lambda *sh: jax.device_put(jnp.zeros(sh, jnp.int32), rep)  # noqa: E731
        self.state = {
            "pages": pages,
            "slot": slot_leaves,
            "tables": zi32(self.slots, self.blocks_per_slot),
            "tokens": zi32(self.slots),
            "lengths": zi32(self.slots),
            "slot_cap": zi32(self.slots),
        }

    # -- host-side block planning -------------------------------------------

    def _blocks_needed(self, plen: int, max_new: int) -> int:
        need = min(self.max_len, plen + max_new + 1)
        return -(-need // self.block_size)

    def _plan_blocks(self, prompt: np.ndarray, plen: int, max_new: int):
        """Map a request onto physical blocks.

        Full prompt blocks are content-addressed by their *cumulative*
        prefix (``prompt[:(j+1)*bs]``), so two requests with the same
        system prompt adopt the same physical blocks.  Any block the
        request may write (``j >= plen // bs``) passes through the
        copy-on-write guard — with full-prefix sharing those are private by
        construction, but the fork is the invariant that keeps a broadened
        sharing policy safe.  Rolls back cleanly on exhaustion.
        """
        bs = self.block_size
        n_need = self._blocks_needed(plen, max_new)
        first_write = plen // bs
        blocks: List[int] = []
        write_mask: List[bool] = []
        shared = 0
        try:
            for j in range(n_need):
                if self.share_prefixes and j < first_write:
                    key = prompt[: (j + 1) * bs].tobytes()
                    bid = self.allocator.lookup(key)
                    if bid is not None:
                        blocks.append(bid)
                        write_mask.append(False)
                        shared += 1
                        continue
                    bid = self.allocator.alloc()
                    self.allocator.register(key, bid)
                else:
                    bid = self.allocator.alloc()
                if j >= first_write:
                    bid, _ = self.allocator.fork_private(bid)
                blocks.append(bid)
                write_mask.append(True)
        except MemoryError:
            for b in blocks:
                self.allocator.release(b)
            raise
        return blocks, write_mask, shared

    def can_admit(self, prompt, max_new: int) -> bool:
        """Would :meth:`admit` succeed right now without evicting anyone?"""
        prompt = np.asarray(prompt, np.int32)
        plen = int(prompt.shape[0])
        n_need = self._blocks_needed(plen, max_new)
        if n_need > self.num_blocks - 1:
            raise ValueError(
                f"request needs {n_need} blocks but the pool only has "
                f"{self.num_blocks - 1} — it can never be admitted"
            )
        hits = 0
        if self.share_prefixes:
            bs = self.block_size
            for j in range(plen // bs):
                if self.allocator.peek(prompt[: (j + 1) * bs].tobytes()) is not None:
                    hits += 1
        return self.allocator.free_blocks >= n_need - hits

    # -- admission / retirement ---------------------------------------------

    def admit(self, params, prompt, slot: int, *, max_new: int) -> int:
        """Plan blocks for the request's whole budget, offset-prefill the
        prompt through the slot's new block table, return the first token."""
        prompt = np.asarray(prompt, np.int32)
        plen = int(prompt.shape[0])
        if not 0 < plen <= self.max_prompt_len:
            raise ValueError(
                f"prompt length {plen} not in (0, {self.max_prompt_len}]"
            )
        if self.slot_blocks[slot]:
            self.release_slot(slot)  # defensive: engine releases at retire
        blocks, write_mask, shared = self._plan_blocks(prompt, plen, int(max_new))
        self.slot_blocks[slot] = blocks
        self.prefix_saved_blocks += shared
        table_row = np.zeros((self.blocks_per_slot,), np.int32)
        table_row[: len(blocks)] = blocks
        mask = np.zeros((self.blocks_per_slot,), bool)
        mask[: len(blocks)] = write_mask
        padded = np.zeros((self.max_prompt_len,), np.int32)
        padded[:plen] = prompt
        with self.mesh:
            self.state = self._jadmit(
                params, self.state, jnp.asarray(padded), jnp.int32(plen),
                jnp.int32(slot), jnp.asarray(table_row), jnp.asarray(mask),
                jnp.int32(len(blocks) * self.block_size),
            )
        return int(self.state["tokens"][slot])

    def release_slot(self, slot: int) -> None:
        """Return the slot's blocks to the allocator (slot recycling)."""
        for b in self.slot_blocks[slot]:
            self.allocator.release(b)
        self.slot_blocks[slot] = []

    # -- elastic resize (DESIGN.md S15) --------------------------------------

    def export_state(self) -> Dict[str, np.ndarray]:
        """Host-side pool control state as flat arrays (broadcastable to a
        joining replica next to the device state): allocator refcounts +
        free-list order + prefix registry, and the per-slot block lists
        packed as (flat ids, per-slot lengths)."""
        flat = [b for bl in self.slot_blocks for b in bl]
        return {
            "allocator": self.allocator.export_arrays(),
            "slot_blocks": np.asarray(flat, np.int32),
            "slot_lens": np.asarray(
                [len(bl) for bl in self.slot_blocks], np.int32
            ),
            "prefix_saved": np.asarray(self.prefix_saved_blocks, np.int32),
        }

    def import_state(self, st: Dict[str, np.ndarray]) -> None:
        """Adopt a broadcast :meth:`export_state` tree (the joiner's half
        of a grow — the device ``state`` arrives separately)."""
        self.allocator = BlockAllocator.from_arrays(
            st["allocator"], self.num_blocks, self.block_size
        )
        flat = [int(b) for b in np.asarray(st["slot_blocks"])]
        out, off = [], 0
        for ln in np.asarray(st["slot_lens"]):
            out.append(flat[off : off + int(ln)])
            off += int(ln)
        self.slot_blocks = out
        self.prefix_saved_blocks = int(st["prefix_saved"])

    # -- introspection -------------------------------------------------------

    def capacity_mask(self, state):
        """Traced: slots frozen at their reserved capacity."""
        return state["lengths"] >= state["slot_cap"] - 1

    @property
    def cache_bytes(self) -> int:
        return int(
            sum(l.nbytes for l in jax.tree.leaves(self.state["pages"]))
            + sum(l.nbytes for l in jax.tree.leaves(self.state["slot"]))
            + self.state["tables"].nbytes
        )
