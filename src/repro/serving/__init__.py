"""Registry-backed continuous-batching serving subsystem (DESIGN.md S13).

Mirrors the collectives/asynchrony/runtime architecture: layered
registries, one engine composing them —

| layer | module | registry |
|---|---|---|
| decode pools | ``serving/pool.py`` | (pool classes; jitted slot steps) |
| paged cache | ``serving/paged.py`` | (block allocator + paged pool, S14) |
| schedulers | ``serving/schedulers.py`` | ``SCHEDULERS`` |
| termination | ``serving/termination.py`` | ``TERMINATION`` |
| workloads | ``serving/workloads.py`` | ``WORKLOADS`` |
| engine | ``serving/engine.py`` | composes the four |
| tenants | ``serving/tenants.py`` | ``ARRIVALS`` (traffic model, S17) |

The load-bearing idea: deciding *when each in-flight request is done*
without a global barrier is the paper's distributed convergence-detection
problem, so per-request termination runs the same non-blocking MRD
reduction machinery (``repro.collectives.plans`` +
``repro.asynchrony.DETECTION_PROTOCOLS``) as the solver engine and the
training-loop monitor — at ``dp > 1`` all replicas retire the same slots
on the same tick because retirement is a pure function of the *agreed*
reduction, at any (non-power-of-two) replica count.
"""

from repro.serving.engine import (  # noqa: F401
    Request,
    RequestResult,
    ServeConfig,
    ServeEngine,
)
from repro.serving.paged import BlockAllocator, PagedDecodePool  # noqa: F401
from repro.serving.pool import DecodePool, FixedPointPool  # noqa: F401
from repro.serving.schedulers import (  # noqa: F401
    SCHEDULERS,
    get_scheduler,
    register_scheduler,
)
from repro.serving.tenants import (  # noqa: F401
    ARRIVALS,
    TenantScenario,
    TenantSpec,
    build_requests,
    make_arrival_ticks,
    parse_tenant_specs,
    quotas_of,
    register_arrival,
)
from repro.serving.termination import (  # noqa: F401
    TERMINATION,
    TerminationConfig,
    get_termination,
    register_termination,
)
from repro.serving.workloads import (  # noqa: F401
    WORKLOADS,
    get_workload,
    make_workload,
    register_workload,
)
