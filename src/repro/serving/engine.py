"""Layer 5 of the serving subsystem: the *engine* — a thin composition of
workload x scheduler x termination (mirroring ``repro.asynchrony.engine``).

Per engine tick:

1. **admit** — the scheduler maps (pending queue, free slots) to
   admissions; each admission is one jitted offset-prefill into a recycled
   slot (shapes fixed, never recompiles) and produces the request's first
   token (TTFT stops here);
2. **step** — one jitted pool step advances every active slot at its own
   cache offset;
3. **terminate/retire** — the termination protocol advances its staged MRD
   reduction one stage (the paper's non-blocking detection loop as serving
   control plane); slots certified done by the *agreed* result retire, are
   freed, and their outputs collected.

Metrics: TTFT / TPOT (wall seconds, p50/p95 in :meth:`ServeEngine.summary`),
token throughput, slot occupancy, plus deterministic tick-domain latencies
(queue wait, admission tick, retirement tick) for the bit-level tests.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.asynchrony.protocols import RES_INIT
from repro.runtime.elastic import ResizeEvent
from repro.runtime.policies import LoadSnapshot
from repro.serving.schedulers import get_scheduler
from repro.serving.termination import (
    TerminationConfig,
    get_termination,
    make_signals,
)


@dataclasses.dataclass
class Request:
    """One serving request (either a token prompt or a solver payload)."""

    id: int
    arrival: int = 0  # tick at which the request enters the queue
    prompt: Any = None  # llm_decode: 1-D int token array
    payload: Any = None  # fixedpoint_solve: affine payload [n] (None = default)
    max_new: int = 32  # generation budget / iteration budget
    eos: int = -1  # llm_decode: EOS token id (-1 = never)
    priority: int = 0  # 'priority' scheduler: higher first
    sla: Optional[int] = None  # TTFT SLA in ticks: deadline = arrival + sla
    eps: Optional[float] = None  # residual protocols: per-request threshold
    tenant: str = ""  # multi-tenant traffic model (serving/tenants.py)


@dataclasses.dataclass
class RequestResult:
    id: int
    output: np.ndarray  # token ids (trimmed) or solution vector
    arrival: int
    admit_tick: int
    retire_tick: int
    n_tokens: int
    certified: float  # agreed value at retirement (residual / done bit)
    converged: bool  # False only for budget-forced fixed-point retirement
    ttft_s: float
    tpot_s: float  # NaN for n_tokens <= 1 (no inter-token interval exists)
    retries: int = 0  # capacity-forced requeues this request went through
    tenant: str = ""
    sla: Optional[int] = None
    sla_met: Optional[bool] = None  # TTFT tick deadline met (None = no SLA)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    scheduler: str = "fcfs"
    termination: str = "eos_maxlen"
    dp: int = 1  # termination-agreement replicas (MRD over [dp])
    eps: float = 1e-6
    window: int = 0  # residual_interval: 0 -> one agreement cycle + 1
    max_admit_per_tick: int = 0  # 0 = fill every free slot
    max_ticks: int = 100_000
    # capacity-forced requests (forced_at_capacity) get this many requeues
    # before retiring converged=False — 0 keeps the old fail-fast behavior
    max_retries: int = 0
    # ticks per fused dispatch: the device loop early-exits on the first
    # retiring tick (so retirement -> admission latency is one dispatch)
    # and the host caps it at the next pending arrival, so larger values
    # only amortize host overhead — they never delay scheduling decisions
    steps_per_dispatch: int = 16
    # multi-tenant admission quotas: {tenant name: max in-flight slots}
    # (0 / absent = unlimited); enforced at admission, so a tenant at its
    # quota is passed over and the slot goes to the next eligible request
    quotas: Any = None
    # capacity model for SLA autoscaling: each agreement replica funds
    # this many pool slots, so only min(slots, dp * slots_per_replica)
    # slots accept admissions — ServeEngine.resize() therefore changes
    # serving capacity, which is what the autoscaler trades against SLA
    # pressure.  None = every slot usable at any extent (the old model).
    slots_per_replica: Optional[int] = None


class ServeEngine:
    """Continuous-batching serving loop over a workload's pool."""

    def __init__(self, workload, cfg: ServeConfig = ServeConfig()):
        if cfg.termination.startswith("residual") and not workload.residual_capable:
            raise ValueError(
                f"termination {cfg.termination!r} needs a residual-reporting "
                f"workload (got {type(workload).__name__}); use 'eos_maxlen'"
            )
        self.workload = workload
        self.cfg = cfg
        self.slots = workload.slots
        self.dp = cfg.dp  # live replica extent (resize() changes it)
        # a dp-sharded workload (fixed-point pools) must agree with the
        # engine's extent — align it, as resize() does, so a workload that
        # served at another extent can be re-engined at any dp
        mig = getattr(workload, "migrate_dp", None)
        if mig is not None and getattr(workload, "dp", cfg.dp) != cfg.dp:
            mig(cfg.dp)
        # canonicalize the workload's device state: a fresh __init__ hands
        # the first dispatch mesh-committed leaves while a reset() hands it
        # uncommitted ones, and jit propagates that difference through every
        # downstream signature — forking the executable cache per history
        workload.params = self._commit(workload.params)
        workload.wstate = self._commit(workload.wstate)
        self.scheduler = get_scheduler(cfg.scheduler)
        self.term = get_termination(cfg.termination)
        self._build_fused()
        self.tstate = self._commit(self.term.init(self.tcfg, self.slots))
        self._ctrl = None  # device control block (pushed when host-dirty)
        self._ctrl_dirty = True

        self.tick = 0
        self.queue: List[Request] = []
        self.pending: List[Request] = []  # submitted, not yet arrived
        self.slot_req: List[Optional[Request]] = [None] * self.slots
        self.results: Dict[int, RequestResult] = {}
        self.resizes: List[ResizeEvent] = []
        # per-slot host mirrors of the device control block
        self._active = np.zeros((self.slots,), bool)
        self._admit_tick = np.zeros((self.slots,), np.int32)
        self._new_tokens = np.zeros((self.slots,), np.int32)
        self._max_new = np.ones((self.slots,), np.int32)
        self._eos = np.full((self.slots,), -1, np.int32)
        self._eps = np.full((self.slots,), cfg.eps, np.float32)
        self._t_queue = np.zeros((self.slots,), np.float64)
        self._t_first = np.zeros((self.slots,), np.float64)
        self._quotas = dict(cfg.quotas or {})
        # metrics accumulators
        self._occupancy_ticks = 0
        self._occupancy_sum = 0.0
        self._forced_at_capacity = 0
        self._retried = 0
        self._replica_ticks = 0  # sum of dp over every clock tick passed
        self._t_start: Optional[float] = None
        self._t_last = 0.0

    def _build_fused(self):
        """(Re)build the fused per-tick dispatch at the current replica
        extent ``self.dp`` — called at construction and by :meth:`resize`.

        One jitted dispatch per tick: pool step + signal assembly +
        termination tick + budget force-retire + slot deactivation, all
        fused — the engine's host loop only syncs the tiny retire/token
        vectors, which is what keeps continuous batching ahead of the
        static baseline at small per-step costs.
        """
        cfg, workload = self.cfg, self.workload
        self.tcfg = TerminationConfig(
            dp=self.dp, eps=cfg.eps, window=cfg.window
        )
        certifying = cfg.termination.startswith("residual")
        dp, slots = self.dp, self.slots
        term, tcfg = self.term, self.tcfg
        cap_fn = getattr(workload, "capacity_mask", None)

        def _fused(params, wstate, tstate, ctrl, tick):
            wstate, tokens, residual = workload.device_step(
                params, wstate, ctrl["active"], tick
            )
            new_tokens = jnp.where(
                ctrl["active"], ctrl["new_tokens"] + 1, ctrl["new_tokens"]
            )
            if residual is None:
                residual = jnp.zeros((dp, slots), jnp.float32)
            sig = make_signals(
                tokens=tokens, new_tokens=new_tokens, eos=ctrl["eos"],
                max_new=ctrl["max_new"], eps=ctrl["eps"],
                active=ctrl["active"], admit_tick=ctrl["admit_tick"],
                tick=tick, residual=residual,
            )
            tstate, retire = term.tick(tstate, sig, tcfg)
            if certifying:
                # iteration budget exhausted before the protocol certified
                forced = ctrl["active"] & (new_tokens >= ctrl["max_new"]) & ~retire
            else:
                forced = jnp.zeros_like(retire)
            if cap_fn is not None:
                # slot frozen at cache capacity but not naturally done: it
                # can produce no further useful tokens, so force-retire NOW
                # (previously such slots spun silently until their budget)
                # — surfaced separately as `forced_at_capacity`
                nat = (tokens == ctrl["eos"]) | (new_tokens >= ctrl["max_new"])
                at_cap = (
                    ctrl["active"] & cap_fn(wstate) & ~nat & ~retire & ~forced
                )
            else:
                at_cap = jnp.zeros_like(retire)
            forced = forced | at_cap
            ctrl = {
                **ctrl,
                "active": ctrl["active"] & ~(retire | forced),
                "new_tokens": new_tokens,
            }
            return wstate, tstate, ctrl, retire, forced, at_cap, tokens

        K = cfg.steps_per_dispatch

        def _fused_loop(params, wstate, tstate, ctrl, tick0, klim):
            """Up to ``klim <= K`` fused ticks in one dispatch, early-exiting
            after the first tick that retires a slot (the host then collects
            outputs and admits from the queue)."""

            def cond(c):
                return (c["i"] < klim) & ~c["stop"] & jnp.any(c["ctrl"]["active"])

            def body(c):
                i = c["i"]
                wstate, tstate, ctrl, retire, forced, at_cap, tokens = _fused(
                    params, c["wstate"], c["tstate"], c["ctrl"], tick0 + i
                )
                return {
                    "wstate": wstate, "tstate": tstate, "ctrl": ctrl,
                    "i": i + 1,
                    "stop": jnp.any(retire | forced),
                    "active_buf": c["active_buf"].at[i].set(c["ctrl"]["active"]),
                    "tokens_buf": c["tokens_buf"].at[i].set(tokens),
                    "retire_buf": c["retire_buf"].at[i].set(retire),
                    "forced_buf": c["forced_buf"].at[i].set(forced),
                    "cap_buf": c["cap_buf"].at[i].set(at_cap),
                }

            init = {
                "wstate": wstate, "tstate": tstate, "ctrl": ctrl,
                "i": jnp.zeros((), jnp.int32),
                "stop": jnp.zeros((), jnp.bool_),
                "active_buf": jnp.zeros((K, slots), jnp.bool_),
                "tokens_buf": jnp.zeros((K, slots), jnp.int32),
                "retire_buf": jnp.zeros((K, slots), jnp.bool_),
                "forced_buf": jnp.zeros((K, slots), jnp.bool_),
                "cap_buf": jnp.zeros((K, slots), jnp.bool_),
            }
            return jax.lax.while_loop(cond, body, init)

        # compile once per (workload, termination config): engines over the
        # same workload (bench re-runs, resets, revisited elastic extents)
        # reuse the compiled tick — the key includes dp via tcfg, so each
        # extent compiles exactly once per workload
        cache = getattr(workload, "_fused_cache", None)
        if cache is None:
            cache = workload._fused_cache = {}
        key = (cfg.termination, self.tcfg, K)
        if key not in cache:
            cache[key] = jax.jit(_fused_loop)
        self._jfused = cache[key]

    # -- request intake -----------------------------------------------------

    def submit(self, req: Request) -> None:
        """Queue ``req`` (it becomes schedulable at ``req.arrival``)."""
        if req.arrival <= self.tick:
            req.arrival = self.tick
            req._t_submit = time.perf_counter()
            self.queue.append(req)
        else:
            self.pending.append(req)

    @property
    def active(self) -> np.ndarray:
        return self._active

    @property
    def usable_slots(self) -> int:
        """Slots currently funded by the replica extent (capacity model).

        With ``cfg.slots_per_replica`` set, a shrink stops *admissions*
        into the defunded tail slots — in-flight requests there drain
        naturally (nothing is preempted), then the slots idle until a
        grow refunds them.
        """
        spr = self.cfg.slots_per_replica
        return self.slots if not spr else min(self.slots, self.dp * spr)

    def _free_slots(self) -> List[int]:
        return [
            s for s in range(self.usable_slots) if self.slot_req[s] is None
        ]

    def load_snapshot(self) -> LoadSnapshot:
        """Deterministic tick-domain load picture: queue depth, TTFT-SLA
        pressure (near = past half the deadline while still queued), and
        free capacity under the ``slots_per_replica`` model.

        This is the *single* load surface: the autoscaler
        (``ElasticServeController._load``) reads it for resize decisions,
        and — when telemetry is on — the same numbers land as gauges, so
        the trace shows exactly the pressure the policy acted on.
        """
        tick = self.tick
        near = overdue = 0
        for r in self.queue:
            if r.sla is None:
                continue
            waited = tick - r.arrival
            if waited > r.sla:
                overdue += 1
            elif 2 * waited >= r.sla:
                near += 1
        snap = LoadSnapshot(
            tick=tick,
            queue_depth=len(self.queue),
            sla_near=near,
            sla_overdue=overdue,
            free_slots=len(self._free_slots()),
            usable_slots=self.usable_slots,
            dp=self.dp,
        )
        if obs.enabled():
            obs.gauge("serve.queue_depth").set(snap.queue_depth)
            obs.gauge("serve.sla_near").set(snap.sla_near)
            obs.gauge("serve.sla_overdue").set(snap.sla_overdue)
            obs.gauge("serve.free_slots").set(snap.free_slots)
            obs.gauge("serve.usable_slots").set(snap.usable_slots)
            obs.gauge("serve.dp").set(snap.dp)
        return snap

    def _commit(self, tree):
        """Pin replicated control/termination state to the workload's mesh.

        Host-pushed (uncommitted) arrays and jit outputs (committed) hash to
        different jit cache entries; committing both sides keeps the fused
        tick at exactly one compilation."""
        mesh = getattr(self.workload, "mesh", None)
        if mesh is None:
            return tree
        from jax.sharding import NamedSharding, PartitionSpec

        sh = NamedSharding(mesh, PartitionSpec())
        return jax.tree.map(lambda x: jax.device_put(x, sh), tree)

    # -- elastic resize (DESIGN.md S15) --------------------------------------

    def resize(self, new_dp: int, keep, *, reason: str = ""):
        """Change the termination-agreement replica extent under live
        traffic — no request is lost, no slot re-prefills.

        ``keep[i]`` is the old replica rank now at new rank ``i`` (None =
        a joiner).  On **shrink**, survivors re-agree in-flight slot state
        through the protocol ``migrate`` hooks: certified latches and
        per-replica interval windows survive, the staged MRD reduction
        restarts at the new (typically non-power-of-two) extent, and the
        re-latched cycle guard keeps every pre-resize admission retirable.
        On **grow**, the joiner receives params, KV/state cache, pool
        control state, and (paged pools) block tables + allocator
        refcounts/prefix registry through the bit-exact
        :func:`repro.distributed.serve.mrd_broadcast_stacked` path at the
        new extent.  Returns the recorded :class:`ResizeEvent` (or None
        for a no-op resize).
        """
        keep = tuple(keep)
        if new_dp < 1 or len(keep) != new_dp:
            raise ValueError(f"keep map {keep} does not cover dp={new_dp}")
        old_dp = self.dp
        for k in keep:
            if k is not None and not 0 <= k < old_dp:
                raise ValueError(f"keep entry {k} outside old extent {old_dp}")
        if new_dp == old_dp and keep == tuple(range(old_dp)):
            return None
        kind = "grow" if any(k is None for k in keep) else "shrink"

        with obs.span(
            "serve.resize",
            kind=kind,
            old_dp=old_dp,
            new_dp=new_dp,
            tick=self.tick,
            reason=reason,
        ):
            mig = getattr(self.workload, "migrate_dp", None)
            if mig is not None:
                mig(new_dp)
            old_tstate = self.tstate
            self.dp = new_dp
            self._build_fused()  # new tcfg -> new jit cache entry per extent
            with obs.span("serve.resize.migrate", kind=kind):
                self.tstate = self._commit(
                    self.term.migrate(old_tstate, keep, self.tcfg, self.slots)
                )
            if kind == "grow":
                with obs.span("serve.resize.broadcast", new_dp=new_dp):
                    self._broadcast_to_joiners()
        ev = ResizeEvent(
            kind=kind, step=self.tick, old_dp=old_dp, new_dp=new_dp,
            keep=keep, device_ids=(), reason=reason,
        )
        self.resizes.append(ev)
        return ev

    def _broadcast_to_joiners(self):
        """Route the full serving state through the MRD sum-broadcast at
        the new extent and install the *joiner's* copy — the protocol-level
        transfer a joining replica performs instead of a cold start.  The
        broadcast is bit-exact (non-source ranks contribute true zeros), so
        survivors' state is unchanged and the joiner decodes bit-identical
        tokens from its first tick; every leaf's committed sharding is
        restored so the fused tick stays at one compilation per extent.
        """
        from repro.distributed import serve as dserve

        tree = {
            "params": self.workload.params,
            "wstate": self.workload.wstate,
            "tstate": self.tstate,
        }
        if self._ctrl is not None and not self._ctrl_dirty:
            tree["ctrl"] = self._ctrl
        exp = getattr(self.workload, "export_state", None)
        if exp is not None:
            tree["host"] = exp()
        leaves, treedef = jax.tree.flatten(tree)
        shardings = [
            leaf.sharding if isinstance(leaf, jax.Array) else None
            for leaf in leaves
        ]
        out = dserve.mrd_broadcast_stacked(leaves, self.dp, src=0)
        out = [
            jax.device_put(o, s) if s is not None else np.asarray(o)
            for o, s in zip(out, shardings)
        ]
        tree = jax.tree.unflatten(treedef, out)
        self.workload.params = tree["params"]
        self.workload.wstate = tree["wstate"]
        self.tstate = tree["tstate"]
        if "ctrl" in tree:
            self._ctrl = tree["ctrl"]
        if "host" in tree:
            self.workload.import_state(tree["host"])

    def _abort_inflight(self):
        """A crashed fused dispatch must not leak cache blocks or strand
        requests: every in-flight slot's blocks are rolled back to the
        allocator and its request returns to the queue for a clean
        re-admission (the tick never happened as far as the request is
        concerned)."""
        rel = getattr(self.workload, "release", None)
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            if rel is not None:
                rel(slot)
            self.slot_req[slot] = None
            self._active[slot] = False
            self.queue.append(req)
        self._ctrl_dirty = True

    # -- one tick -----------------------------------------------------------

    def _after_admit(self, req, slot: int, now: int, t0: float) -> None:
        """Slot bookkeeping for a just-admitted request."""
        self.slot_req[slot] = req
        self._active[slot] = True
        self._admit_tick[slot] = now
        # llm: the prefill's argmax token; fixedpoint: no iteration yet
        self._new_tokens[slot] = self.workload.prefill_tokens
        self._max_new[slot] = self.workload.clamp_max_new(req)
        self._eos[slot] = req.eos
        self._eps[slot] = self.cfg.eps if req.eps is None else req.eps
        self._t_queue[slot] = getattr(req, "_t_submit", t0)
        self._t_first[slot] = time.perf_counter()
        self._ctrl_dirty = True

    def step(self) -> np.ndarray:
        """Advance one tick; returns the retired-slot mask ``[S]``."""
        if self._t_start is None:
            self._t_start = time.perf_counter()
        now = self.tick
        # release arrivals into the schedulable queue (TTFT clock starts
        # when a request becomes visible, not when the caller built it)
        still = []
        for r in self.pending:
            if r.arrival <= now:
                r._t_submit = time.perf_counter()
                self.queue.append(r)
            else:
                still.append(r)
        self.pending = still

        # 1. admit: walk the scheduler's order, filling free slots with the
        # first *eligible* requests — a request blocked by its tenant quota
        # or the cache-block budget is passed over (it stays queued) and
        # the slot goes to the next request instead of idling a tick
        free = self._free_slots()
        if self.cfg.max_admit_per_tick:
            free = free[: self.cfg.max_admit_per_tick]
        gate = getattr(self.workload, "can_admit", None)
        inflight: Dict[str, int] = {}
        if self._quotas:
            for r in self.slot_req:
                if r is not None:
                    inflight[r.tenant] = inflight.get(r.tenant, 0) + 1
        ordered = (
            self.scheduler.order(list(self.queue), now)
            if self.queue and free else []
        )
        n_admitted = 0
        with obs.span("serve.admit", tick=now, queue_depth=len(self.queue)) as sp:
            for req in ordered:
                if not free:
                    break
                quota = self._quotas.get(req.tenant, 0)
                if quota and inflight.get(req.tenant, 0) >= quota:
                    continue  # tenant at its admission quota: req stays queued
                if gate is not None and not gate(req):
                    continue  # out of cache blocks: req waits in the queue
                slot = free.pop(0)
                self.queue.remove(req)
                if self._quotas:
                    inflight[req.tenant] = inflight.get(req.tenant, 0) + 1
                t0 = time.perf_counter()
                self.workload.admit(req, slot, now)
                self._after_admit(req, slot, now, t0)
                n_admitted += 1
            if sp is not None:
                sp["n_admitted"] = n_admitted

        if not self._active.any():
            # nothing in flight: fast-forward the virtual clock to the next
            # arrival instead of burning empty device ticks
            self.tick = (
                min(r.arrival for r in self.pending)
                if self.pending else now + 1
            )
            # provisioned-but-idle replicas still cost replica-ticks —
            # that is exactly the waste the autoscaler exists to shed
            self._replica_ticks += (self.tick - now) * self.dp
            self._t_last = time.perf_counter()
            return np.zeros((self.slots,), bool)

        if self._ctrl_dirty:
            ctrl = {
                "active": jnp.asarray(self._active),
                "new_tokens": jnp.asarray(self._new_tokens),
                "admit_tick": jnp.asarray(self._admit_tick),
                "eos": jnp.asarray(self._eos),
                "max_new": jnp.asarray(self._max_new),
                "eps": jnp.asarray(self._eps),
            }
            self._ctrl = self._commit(ctrl)
            self._ctrl_dirty = False

        # 2-3. pool steps + termination ticks, one fused dispatch running up
        # to `klim` ticks (early exit on the first retiring tick); capped at
        # the next pending arrival so scheduling never waits on the device
        klim = self.cfg.steps_per_dispatch
        if self.pending:
            nxt = min(r.arrival for r in self.pending)
            klim = max(1, min(klim, nxt - now))
        if self.cfg.max_admit_per_tick and self.queue and self._free_slots():
            klim = 1  # rate-limited admissions resume next tick
        with obs.span("serve.tick", tick=now, klim=klim, dp=self.dp) as sp:
            try:
                final = self._jfused(
                    self.workload.params, self.workload.wstate, self.tstate,
                    self._ctrl, jnp.int32(now), jnp.int32(klim),
                )
            except Exception:
                self._abort_inflight()
                raise
            self.workload.wstate = final["wstate"]
            self.tstate = final["tstate"]
            self._ctrl = final["ctrl"]
            n_ticks = int(final["i"])
            # convert whole buffers, slice on host: device-side slicing at a
            # data-dependent length would compile one kernel per distinct
            # length
            active_buf = np.asarray(final["active_buf"])[:n_ticks]
            tokens_buf = np.asarray(final["tokens_buf"])[:n_ticks]

            for k in range(n_ticks):
                act = active_buf[k]
                self._new_tokens[act] += 1
                self.workload.collect_tick(tokens_buf[k], act)
                self._occupancy_sum += float(act.sum()) / self.slots
                self._occupancy_ticks += 1
            if sp is not None:
                sp["n_ticks"] = n_ticks

        # 4. retire: by construction only the last executed tick can retire
        # (the device loop exits right after it)
        last = n_ticks - 1
        retire = np.asarray(final["retire_buf"])[last]
        forced = np.asarray(final["forced_buf"])[last]
        at_cap = np.asarray(final["cap_buf"])[last]
        out_mask = retire | forced
        if out_mask.any():
            self._active[out_mask] = False
            certified = np.asarray(self.tstate["certified"])
            t_done = time.perf_counter()
            for slot in np.nonzero(out_mask)[0]:
                req = self.slot_req[slot]
                obs.instant(
                    "serve.retire",
                    slot=int(slot),
                    tick=now + last,
                    forced=bool(forced[slot]),
                    request=req.id if req is not None else None,
                )
                self._collect(int(slot), now + last, certified,
                              bool(forced[slot]), t_done,
                              at_capacity=bool(at_cap[slot]))
        self.tick = now + n_ticks
        self._replica_ticks += n_ticks * self.dp
        self._t_last = time.perf_counter()
        if obs.enabled():
            self.load_snapshot()  # records the load gauges for this step
        return out_mask

    def _collect(self, slot, now, certified, was_forced, t_done,
                 at_capacity=False):
        req = self.slot_req[slot]
        if at_capacity:
            self._forced_at_capacity += 1
            if getattr(req, "_retries", 0) < self.cfg.max_retries:
                # bounded requeue: the request frozen at capacity gets a
                # fresh admission (and a fresh block reservation) instead
                # of silently retiring converged=False
                req._retries = getattr(req, "_retries", 0) + 1
                self._retried += 1
                self.slot_req[slot] = None
                rel = getattr(self.workload, "release", None)
                if rel is not None:
                    rel(slot)
                req.arrival = self.tick
                self.queue.append(req)
                return
        out = self.workload.output(slot)
        n_tok = int(self._new_tokens[slot])
        if req.prompt is not None:  # llm: trim to EOS / budget
            toks = out[: min(n_tok, int(self._max_new[slot]))]
            hits = np.nonzero(toks == req.eos)[0]
            if req.eos >= 0 and hits.size:
                toks = toks[: hits[0] + 1]
            out = toks
            n_tok = int(out.shape[0])
        ttft = self._t_first[slot] - self._t_queue[slot]
        # a single-token completion has no inter-token interval: reporting
        # 0.0 s here dragged TPOT percentiles down in mixed-length traffic,
        # so it is NaN and summary() excludes it from the percentiles
        tpot = (
            (t_done - self._t_first[slot]) / (n_tok - 1)
            if n_tok > 1 else float("nan")
        )
        admit_tick = int(self._admit_tick[slot])
        # TTFT SLA is tick-domain (deterministic): first token no later
        # than `sla` ticks after the request became schedulable
        sla_met = (
            None if req.sla is None
            else bool(admit_tick - req.arrival <= req.sla)
        )
        # the protocol's per-slot certified latch is only written on
        # protocol retirement; a budget-forced request must not inherit the
        # value its slot's *previous* occupant certified at
        cert = RES_INIT if was_forced else float(certified[slot])
        self.results[req.id] = RequestResult(
            id=req.id, output=out, arrival=req.arrival,
            admit_tick=admit_tick, retire_tick=now,
            n_tokens=n_tok, certified=cert,
            converged=not was_forced, ttft_s=ttft, tpot_s=tpot,
            retries=getattr(req, "_retries", 0),
            tenant=req.tenant, sla=req.sla, sla_met=sla_met,
        )
        self.slot_req[slot] = None
        rel = getattr(self.workload, "release", None)
        if rel is not None:
            rel(slot)  # paged pools return the slot's blocks to the allocator

    # -- drive to completion ------------------------------------------------

    def run(self, requests=None, *, max_ticks: Optional[int] = None):
        """Submit ``requests`` (scheduled by their ``arrival`` ticks) and
        step until everything submitted has retired.  Returns ``results``."""
        for r in requests or []:
            self.submit(r)
        budget = max_ticks or self.cfg.max_ticks
        steps = 0
        while self.queue or self.pending or any(self.slot_req):
            if steps >= budget:
                raise RuntimeError(
                    f"serve loop did not drain within {budget} engine steps "
                    f"({len(self.queue) + len(self.pending)} queued, "
                    f"{sum(r is not None for r in self.slot_req)} in flight)"
                )
            self.step()
            steps += 1
        return self.results

    # -- metrics ------------------------------------------------------------

    def summary(self) -> Dict[str, Any]:
        res = list(self.results.values())
        wall = (self._t_last - self._t_start) if self._t_start else 0.0
        return {
            "completed": len(res),
            "ticks": self.tick,
            "wall_s": wall,
            "tokens_out": int(sum(r.n_tokens for r in res)),
            "throughput_tok_s": (
                sum(r.n_tokens for r in res) / wall if wall > 0 else 0.0
            ),
            # percentiles are NaN — never a fake 0 ms — when no request
            # retired (or, for TPOT, when every completion was single-token
            # and carries no inter-token interval); bench `--check` gates
            # treat a NaN percentile as a hard failure, not a pass
            **_latency_percentiles(res),
            "occupancy": (
                self._occupancy_sum / self._occupancy_ticks
                if self._occupancy_ticks else 0.0
            ),
            **_sla_fields(res, self.tick, wall),
            "replica_ticks": self._replica_ticks,
            "tenants": _tenant_summaries(res),
            "converged": int(sum(r.converged for r in res)),
            "forced_at_capacity": self._forced_at_capacity,
            "retried": self._retried,
            "resizes": len(self.resizes),
            # pipeline health of the telemetry plane itself — span counts
            # and ring-buffer overflow are surfaced here so a saturated
            # tracer is observable, never silent
            "telemetry": obs.summary(),
        }


def _pct_ms(seconds: np.ndarray, q: float) -> float:
    """NaN-safe percentile in milliseconds (NaN when nothing to rank)."""
    finite = seconds[np.isfinite(seconds)]
    return float(np.percentile(finite, q) * 1e3) if finite.size else float("nan")


def _latency_percentiles(res) -> Dict[str, float]:
    ttft = np.asarray([r.ttft_s for r in res], np.float64)
    tpot = np.asarray([r.tpot_s for r in res], np.float64)
    out = {}
    for q in (50, 95, 99):
        out[f"ttft_p{q}_ms"] = _pct_ms(ttft, q)
        out[f"tpot_p{q}_ms"] = _pct_ms(tpot, q)
    return out


def _sla_fields(res, ticks: int, wall: float) -> Dict[str, Any]:
    """Goodput under SLA.  ``sla_met`` counts requests whose tick-domain
    TTFT met their deadline (over the ``sla_total`` that carry one);
    ``goodput_ok`` adds completed no-SLA (batch) requests, and the rates
    divide by elapsed ticks (deterministic — what the CI gates compare)
    and wall seconds."""
    sla_total = sum(1 for r in res if r.sla is not None)
    sla_met = sum(1 for r in res if r.sla_met)
    goodput_ok = sla_met + (len(res) - sla_total)
    return {
        "sla_total": sla_total,
        "sla_met": sla_met,
        "goodput_ok": goodput_ok,
        "goodput_per_ktick": (
            goodput_ok / ticks * 1000.0 if ticks > 0 else 0.0
        ),
        "goodput_req_s": goodput_ok / wall if wall > 0 else 0.0,
    }


def _tenant_summaries(res) -> Dict[str, Dict[str, Any]]:
    """Per-tenant breakdown (empty when the traffic is untenanted)."""
    by: Dict[str, list] = {}
    for r in res:
        by.setdefault(r.tenant, []).append(r)
    if set(by) <= {""}:
        return {}
    out = {}
    for name in sorted(by):
        rs = by[name]
        ttft_ticks = np.asarray(
            [r.admit_tick - r.arrival for r in rs], np.float64
        )
        sla_total = sum(1 for r in rs if r.sla is not None)
        sla_met = sum(1 for r in rs if r.sla_met)
        out[name] = {
            "completed": len(rs),
            "tokens_out": int(sum(r.n_tokens for r in rs)),
            "sla_total": sla_total,
            "sla_met": sla_met,
            "goodput_ok": sla_met + (len(rs) - sla_total),
            "ttft_p99_ticks": (
                float(np.percentile(ttft_ticks, 99)) if rs else float("nan")
            ),
            **_latency_percentiles(rs),
        }
    return out
