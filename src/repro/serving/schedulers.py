"""Layer 2 of the serving subsystem: admission *schedulers* (``SCHEDULERS``).

A scheduler is pure host-side control plane: given the pending-request
queue and the pool's free slots at a tick, it returns the admissions to
perform this tick.  It never touches device state — admission itself is
the workload's (jitted) offset-prefill — so schedulers are plain Python
and trivially pluggable, mirroring the registry layering of
``repro.collectives`` / ``repro.asynchrony``.

Registered schedulers:

- ``fcfs`` — first come, first served (arrival order; ties by id).
- ``priority`` — highest ``Request.priority`` first (ties FCFS), the
  classic two-class serving split (interactive vs batch).
- ``sla_edf`` — earliest deadline first over ``Request.arrival +
  Request.sla`` (requests without an SLA sort last, FCFS among
  themselves); the canonical latency-target policy.

All three admit at most ``len(free_slots)`` requests and assign the
lowest-numbered free slots first, so scheduling decisions are
deterministic given the queue — what the bit-equivalence tests rely on.
"""

from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

SCHEDULERS: Dict[str, Any] = {}


def register_scheduler(name: str):
    def deco(cls):
        SCHEDULERS[name] = cls()
        return cls

    return deco


def get_scheduler(name: str):
    try:
        return SCHEDULERS[name]
    except KeyError:
        raise ValueError(
            f"unknown scheduler {name!r}; registered: {sorted(SCHEDULERS)}"
        ) from None


class _SchedulerBase:
    """Order the queue, then zip with the free slots."""

    def order(self, queue: Sequence, now: int) -> List:
        raise NotImplementedError

    def select(
        self, queue: Sequence, free_slots: Sequence[int], now: int
    ) -> List[Tuple[Any, int]]:
        """-> [(request, slot)] admissions for this tick (subset of queue)."""
        if not queue or not free_slots:
            return []
        ordered = self.order(list(queue), now)
        slots = sorted(free_slots)
        return list(zip(ordered[: len(slots)], slots))


@register_scheduler("fcfs")
class FCFSScheduler(_SchedulerBase):
    name = "fcfs"

    def order(self, queue, now):
        return sorted(queue, key=lambda r: (r.arrival, r.id))


@register_scheduler("priority")
class PriorityScheduler(_SchedulerBase):
    name = "priority"

    def order(self, queue, now):
        return sorted(queue, key=lambda r: (-r.priority, r.arrival, r.id))


@register_scheduler("sla_edf")
class SlaEdfScheduler(_SchedulerBase):
    name = "sla_edf"

    def order(self, queue, now):
        def deadline(r):
            return r.arrival + r.sla if r.sla is not None else float("inf")

        return sorted(queue, key=lambda r: (deadline(r), r.arrival, r.id))
