"""Layer 2 of the serving subsystem: admission *schedulers* (``SCHEDULERS``).

A scheduler is pure host-side control plane: given the pending-request
queue and the pool's free slots at a tick, it returns the admissions to
perform this tick.  It never touches device state — admission itself is
the workload's (jitted) offset-prefill — so schedulers are plain Python
and trivially pluggable, mirroring the registry layering of
``repro.collectives`` / ``repro.asynchrony``.

Registered schedulers:

- ``fcfs`` — first come, first served (arrival order; ties by id).
- ``priority`` — highest ``Request.priority`` first (ties FCFS), the
  classic two-class serving split (interactive vs batch).
- ``sla_edf`` — earliest deadline first over ``Request.arrival +
  Request.sla`` (requests without an SLA sort last, FCFS among
  themselves), with an age-based anti-starvation tiebreak: any request —
  SLA'd or not — that has waited ``max_wait`` ticks is promoted ahead of
  the deadline order (oldest promoted first), so a sustained stream of
  tight-deadline traffic cannot starve batch requests indefinitely.
  ``sla_edf:N`` selects a non-default promotion bound.

A scheduler name may carry a ``:arg`` suffix (``sla_edf:32``); the bare
name resolves to the registered default instance.

All schedulers admit at most ``len(free_slots)`` requests and assign the
lowest-numbered free slots first, so scheduling decisions are
deterministic given the queue — what the bit-equivalence tests rely on.
The engine walks :meth:`~_SchedulerBase.order` itself so that live
admission gates (tenant quotas, paged-cache block budgets) can pass a
blocked request over without wasting the slot; :meth:`~_SchedulerBase
.select` remains the one-shot functional form of the same decision.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

SCHEDULERS: Dict[str, Any] = {}


def register_scheduler(name: str):
    def deco(cls):
        SCHEDULERS[name] = cls()
        return cls

    return deco


def get_scheduler(name: str):
    """Resolve ``name`` (optionally ``name:arg``) to a scheduler instance."""
    base, _, arg = name.partition(":")
    try:
        sched = SCHEDULERS[base]
    except KeyError:
        raise ValueError(
            f"unknown scheduler {base!r}; registered: {sorted(SCHEDULERS)}"
        ) from None
    return sched.configure(arg) if arg else sched


class _SchedulerBase:
    """Order the queue; the engine (or ``select``) fills the free slots."""

    def order(self, queue: Sequence, now: int) -> List:
        raise NotImplementedError

    def configure(self, arg: str):
        """Build a re-parameterized instance from a ``name:arg`` spec."""
        raise ValueError(
            f"scheduler {type(self).__name__} takes no ':{arg}' parameter"
        )

    def select(
        self,
        queue: Sequence,
        free_slots: Sequence[int],
        now: int,
        eligible: Optional[Callable[[Any], bool]] = None,
    ) -> List[Tuple[Any, int]]:
        """-> [(request, slot)] admissions for this tick (subset of queue).

        ``eligible`` is the live admission gate (tenant quota / cache
        budget): an ineligible request is passed over and the next request
        in scheduling order takes the slot instead.
        """
        if not queue or not free_slots:
            return []
        slots = sorted(free_slots)
        picked = []
        for r in self.order(list(queue), now):
            if len(picked) == len(slots):
                break
            if eligible is None or eligible(r):
                picked.append(r)
        return list(zip(picked, slots))


@register_scheduler("fcfs")
class FCFSScheduler(_SchedulerBase):
    name = "fcfs"

    def order(self, queue, now):
        return sorted(queue, key=lambda r: (r.arrival, r.id))


@register_scheduler("priority")
class PriorityScheduler(_SchedulerBase):
    name = "priority"

    def order(self, queue, now):
        return sorted(queue, key=lambda r: (-r.priority, r.arrival, r.id))


@register_scheduler("sla_edf")
class SlaEdfScheduler(_SchedulerBase):
    name = "sla_edf"

    def __init__(self, max_wait: int = 64):
        if max_wait < 1:
            raise ValueError(f"max_wait must be >= 1, got {max_wait}")
        self.max_wait = max_wait

    def configure(self, arg: str):
        return SlaEdfScheduler(max_wait=int(arg))

    def order(self, queue, now):
        def key(r):
            if now - r.arrival >= self.max_wait:
                # anti-starvation promotion: a request that has waited the
                # bound goes ahead of every unpromoted deadline, oldest
                # first — EDF pressure can no longer starve it
                return (0, r.arrival, 0, r.id)
            deadline = (
                r.arrival + r.sla if r.sla is not None else float("inf")
            )
            return (1, deadline, r.arrival, r.id)

        return sorted(queue, key=key)
