"""Layer 4 of the serving subsystem: *workloads* (``WORKLOADS``).

A workload binds a pool to a request type and gives the engine one uniform
surface: ``admit(request, slot, now)``, ``step(now) -> per-tick
observations``, ``output(slot)``, ``retire(mask)``.  Registered factories
(select-by-name, like every other subsystem registry):

- ``llm_decode`` — greedy LLM decode over :class:`repro.serving.pool.DecodePool`
  (the ``make_serve_step`` / ``make_cached_prefill_step`` model path, with
  per-slot lengths).  Requests carry a token prompt, a generation budget
  and an EOS id; terminates with ``eos_maxlen``.
- ``fixedpoint_solve`` — per-query fixed-point solves from the
  ``repro.asynchrony.SOLVERS`` registry (the D-iteration serving workload:
  personalized PageRank-style damped diffusion, weighted-Jacobi systems).
  Requests carry an affine payload (personalization vector / right-hand
  side); terminates with ``residual_interval`` / ``residual_inexact`` —
  the paper's detection protocols certifying each request's convergence.
"""

from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.asynchrony.solvers import make_solver, random_dd_system
from repro.serving.paged import PagedDecodePool
from repro.serving.pool import DecodePool, FixedPointPool

WORKLOADS: Dict[str, Callable[..., Any]] = {}


def register_workload(name: str):
    def deco(fn):
        WORKLOADS[name] = fn
        return fn

    return deco


def get_workload(name: str) -> Callable[..., Any]:
    try:
        return WORKLOADS[name]
    except KeyError:
        raise ValueError(
            f"unknown workload {name!r}; registered: {sorted(WORKLOADS)}"
        ) from None


def make_workload(name: str, **kwargs):
    return get_workload(name)(**kwargs)


class LLMDecodeWorkload:
    """Continuous greedy decode over a :class:`DecodePool`."""

    residual_capable = False
    default_termination = "eos_maxlen"
    prefill_tokens = 1  # admission's prefill emits the first token

    def __init__(
        self,
        *,
        cfg,
        mesh,
        slots: int = 8,
        max_len: int = 64,
        max_prompt_len: int = 16,
        params=None,
        seed: int = 0,
        **pool_kwargs,
    ):
        if cfg.is_encoder_only:
            raise ValueError(f"{cfg.name} is encoder-only: no decode serving")
        from repro.models import transformer

        self.cfg, self.mesh = cfg, mesh
        self.pool = self._make_pool(
            cfg, mesh, slots=slots, max_len=max_len,
            max_prompt_len=max_prompt_len, **pool_kwargs,
        )
        if params is None:
            with mesh:
                params = transformer.init_params(cfg, jax.random.PRNGKey(seed))
        self.params = params
        self.slots = slots
        self._out = [[] for _ in range(slots)]
        self.prefills = 0  # total prefill dispatches (chaos suite asserts
        # resizes never force a re-prefill: prefills == requests served)

    def _make_pool(self, cfg, mesh, **kw):
        return DecodePool(cfg, mesh, **kw)

    @property
    def wstate(self):
        return self.pool.state

    @wstate.setter
    def wstate(self, value):
        self.pool.state = value

    @property
    def cache_bytes(self) -> int:
        return self.pool.cache_bytes

    def capacity_mask(self, wstate):
        """Traced: active slots frozen at cache capacity (the engine
        force-retires them and counts ``forced_at_capacity``)."""
        return self.pool.capacity_mask(wstate)

    def clamp_max_new(self, req) -> int:
        """Generation budget that fits the slot's cache capacity."""
        plen = int(np.asarray(req.prompt).shape[0])
        return max(1, min(int(req.max_new), self.pool.max_len - plen - 1))

    def sample_request(self, tenant, rng, *, id: int, arrival: int):
        """One seeded request for ``tenant`` (serving/tenants.py): prompt
        length in [1, tenant.prompt_len] clamped to the pool's shape,
        budget in [max_new/2, max_new] clamped to cache capacity."""
        from repro.serving.engine import Request

        plen = int(rng.integers(
            1, max(1, min(tenant.prompt_len, self.pool.max_prompt_len)) + 1
        ))
        hi = max(1, min(tenant.max_new, self.pool.max_len - plen - 1))
        lo = max(1, hi // 2)
        return Request(
            id=id,
            arrival=arrival,
            prompt=rng.integers(0, self.cfg.vocab, size=plen).astype(np.int32),
            max_new=int(rng.integers(lo, hi + 1)),
            eos=-1,
            priority=tenant.priority,
            sla=tenant.sla,
            tenant=tenant.name,
        )

    def admit(self, req, slot: int, now: int) -> None:
        tok0 = self.pool.admit(self.params, req.prompt, slot)
        self._out[slot] = [tok0]
        self.prefills += 1

    def device_step(self, params, wstate, active, tick):
        """Pure traced tick: ``-> (wstate, tokens [S], residual|None)``.

        The engine fuses this with the termination tick into one jitted
        dispatch per engine tick.
        """
        wstate = self.pool.device_step(params, wstate, active)
        return wstate, wstate["tokens"], None

    def collect_tick(self, tokens: np.ndarray, active: np.ndarray) -> None:
        for s in np.nonzero(active)[0]:
            self._out[s].append(int(tokens[s]))

    def output(self, slot: int) -> np.ndarray:
        return np.asarray(self._out[slot], np.int32)

    def reset(self) -> None:
        """Fresh pool state, compiled steps kept (cheap engine re-runs)."""
        self.pool.reset()
        self._out = [[] for _ in range(self.slots)]
        self.prefills = 0


class PagedLLMWorkload(LLMDecodeWorkload):
    """Continuous greedy decode over a :class:`PagedDecodePool`.

    Same engine surface as :class:`LLMDecodeWorkload` plus the paged
    hooks: ``can_admit`` (block-budget backpressure — requests wait in the
    queue when the pool is out of blocks), ``release`` (blocks return to
    the allocator at retirement), and per-slot ``capacity_mask``.
    Admission reserves blocks for the request's whole clamped budget, so
    the fused multi-tick dispatch never faults on a missing block.
    """

    def _make_pool(self, cfg, mesh, **kw):
        return PagedDecodePool(cfg, mesh, **kw)

    def admit(self, req, slot: int, now: int) -> None:
        tok0 = self.pool.admit(
            self.params, req.prompt, slot, max_new=self.clamp_max_new(req)
        )
        self._out[slot] = [tok0]
        self.prefills += 1

    def can_admit(self, req) -> bool:
        return self.pool.can_admit(
            np.asarray(req.prompt, np.int32), self.clamp_max_new(req)
        )

    def release(self, slot: int) -> None:
        self.pool.release_slot(slot)

    @property
    def prefix_saved_blocks(self) -> int:
        return self.pool.prefix_saved_blocks

    # block tables + allocator refcounts/prefix registry ride the grow
    # broadcast next to params and the paged device state
    def export_state(self):
        return self.pool.export_state()

    def import_state(self, st) -> None:
        self.pool.import_state(st)


class FixedPointWorkload:
    """Per-request fixed-point solves over a :class:`FixedPointPool`."""

    residual_capable = True
    default_termination = "residual_interval"
    prefill_tokens = 0  # admission performs no iteration

    def __init__(self, base, gain, payload0, *, slots: int, dp: int):
        self.base = base
        self.pool = FixedPointPool(
            base, slots=slots, dp=dp, gain=gain, payload0=payload0
        )
        self.payload0 = np.asarray(payload0, np.float32)
        self.slots, self.dp = slots, dp
        self.params = {}  # no model params: uniform engine surface

    @property
    def wstate(self):
        return self.pool.state

    @wstate.setter
    def wstate(self, value):
        self.pool.state = value

    def clamp_max_new(self, req) -> int:
        return int(req.max_new)

    def sample_request(self, tenant, rng, *, id: int, arrival: int):
        """One seeded request for ``tenant``: a normalized random
        personalization vector / right-hand side of the pool's size
        (payload scale matched to ``payload0`` so thresholds transfer)."""
        from repro.serving.engine import Request

        n = self.payload0.shape[0]
        v = rng.random(n).astype(np.float32)
        scale = float(np.abs(self.payload0).sum()) or 1.0
        return Request(
            id=id,
            arrival=arrival,
            payload=v * (scale / max(float(v.sum()), 1e-9)),
            max_new=tenant.max_new,
            priority=tenant.priority,
            sla=tenant.sla,
            eps=tenant.eps,
            tenant=tenant.name,
        )

    def migrate_dp(self, new_dp: int) -> None:
        """Elastic resize: per-slot iterates survive untouched; only the
        pool's residual block report re-layouts at the new extent."""
        self.pool.migrate_dp(new_dp)
        self.dp = new_dp

    def admit(self, req, slot: int, now: int) -> None:
        payload = self.payload0 if req.payload is None else req.payload
        self.pool.admit(payload, slot)

    def device_step(self, params, wstate, active, tick):
        wstate, residual = self.pool.device_step(wstate, active)
        return wstate, jnp.zeros((self.slots,), jnp.int32), residual

    def collect_tick(self, tokens: np.ndarray, active: np.ndarray) -> None:
        pass  # outputs are read from the pool at retirement

    def output(self, slot: int) -> np.ndarray:
        return self.pool.solution(slot)

    def reset(self) -> None:
        self.pool.reset()

    def true_residual(self, slot: int, payload) -> float:
        """Ground-truth ||f(x)-x||_inf of the slot's iterate under its own
        payload — what the certification soundness tests check."""
        x = jnp.asarray(self.pool.solution(slot))
        v = jnp.asarray(
            self.payload0 if payload is None else np.asarray(payload, np.float32)
        )
        return float(jnp.max(jnp.abs(self.pool.param_map(x, v) - x)))


@register_workload("llm_decode")
def llm_decode(**kwargs) -> LLMDecodeWorkload:
    return LLMDecodeWorkload(**kwargs)


@register_workload("llm_decode_paged")
def llm_decode_paged(**kwargs) -> PagedLLMWorkload:
    """Block-paged LLM decode (``serving/paged.py``, DESIGN.md S14).

    Extra kwargs forwarded to :class:`PagedDecodePool`: ``block_size``,
    ``num_blocks`` (the cache *byte* budget, default = contiguous-capacity
    parity), ``share_prefixes``, ``attn`` ('gather' bit-exact | 'pallas'
    paged-kernel).
    """
    return PagedLLMWorkload(**kwargs)


@register_workload("fixedpoint_solve")
def fixedpoint_solve(
    *,
    solver: str = "d_iteration",
    slots: int = 8,
    dp: int = 1,
    n: int = 64,
    **solver_kwargs,
) -> FixedPointWorkload:
    """Build the fixed-point serving workload from a ``SOLVERS`` entry.

    The pool shares one operator across slots and treats each request as an
    affine payload, so only solvers whose parameter enters affinely (with a
    known gain) are supported — which covers the serving-relevant families.
    """
    if solver == "d_iteration":
        damping = float(solver_kwargs.pop("damping", 0.85))
        v0 = solver_kwargs.pop("v", None)
        if v0 is None:
            v0 = np.full((n,), 1.0 / n, np.float32)
        base = make_solver(
            "d_iteration", n=n, damping=damping, v=jnp.asarray(v0),
            **solver_kwargs,
        )
        gain = 1.0 - damping
        payload0 = v0
    elif solver == "poisson1d":
        omega = float(solver_kwargs.pop("omega", 1.0))
        shift = float(solver_kwargs.pop("shift", 0.0))
        seed = int(solver_kwargs.pop("seed", 0))
        scale = float(solver_kwargs.pop("rhs_scale", 10.0))
        rhs = solver_kwargs.pop("rhs", None)
        if rhs is None:
            rhs = jax.random.uniform(
                jax.random.PRNGKey(seed), (n,), minval=-scale, maxval=scale
            )
        base = make_solver(
            "poisson1d", n=n, omega=omega, shift=shift, rhs=jnp.asarray(rhs),
            **solver_kwargs,
        )
        gain = omega / (2.0 + shift)
        payload0 = np.asarray(rhs, np.float32)
    elif solver == "jacobi_dense":
        omega = float(solver_kwargs.pop("omega", 1.0))
        seed = int(solver_kwargs.pop("seed", 0))
        dominance = float(solver_kwargs.pop("dominance", 2.0))
        A, b = random_dd_system(n, seed=seed, dominance=dominance)
        base = make_solver(
            "jacobi_dense", A=jnp.asarray(A, jnp.float32),
            b=jnp.asarray(b, jnp.float32), omega=omega,
        )
        gain = omega / np.diag(A).astype(np.float32)
        payload0 = np.asarray(b, np.float32)
    else:
        raise ValueError(
            f"fixedpoint_solve serves affine-payload solvers "
            f"(d_iteration | poisson1d | jacobi_dense), got {solver!r}"
        )
    return FixedPointWorkload(base, gain, payload0, slots=slots, dp=dp)
