"""Layer 6 of the serving subsystem: the multi-tenant *traffic model*
(``serving/tenants.py``, DESIGN.md S17).

ROADMAP item 5's production-shaped scenario layer: named tenants share a
serving deployment, each with its own workload kind (``llm_decode`` /
``llm_decode_paged`` decode traffic next to ``fixedpoint_solve``
per-query D-iteration/PageRank solves), TTFT SLA, scheduler priority,
and admission quota.  Three pieces:

- :class:`TenantSpec` + :func:`parse_tenant_specs` — the declarative
  tenant table (also the ``--tenants`` CLI surface);
- ``ARRIVALS`` — arrival-tick generators (``none`` / ``poisson`` /
  ``bursty`` / ``diurnal`` / ``trace``).  ``bursty`` mirrors the
  correlated outage-window process of
  :class:`repro.asynchrony.delay_models.BurstyModel` (same
  ``outage_rate`` / ``outage_len`` shape, with an outage window mapped to
  a traffic *burst*), and ``trace`` replays a recorded arrival file the
  way the delay-model ``trace`` entry replays a recorded delay matrix —
  so a measured production trace drives the exact same admission
  decisions on every run;
- :func:`build_requests` + :class:`TenantScenario` — materialize one
  seeded request stream across the tenant mix (each workload object
  samples its own request payloads via ``sample_request``) and drive one
  engine per workload kind over it, merging per-tenant SLA metrics.

Everything is tick-domain and seeded, so goodput-under-SLA numbers are a
deterministic function of (tenants, arrival spec, seed) — what lets
``bench_scale.py`` gate scheduler and autoscaler quality in CI.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One named tenant of the serving deployment."""

    name: str
    weight: float = 1.0  # share of total arrivals (normalized over tenants)
    workload: str = "llm_decode"  # WORKLOADS entry this tenant targets
    sla: Optional[int] = None  # TTFT deadline in ticks (None = batch tier)
    priority: int = 0  # 'priority' scheduler class
    quota: int = 0  # max in-flight slots (0 = unlimited)
    prompt_len: int = 8  # llm: prompts sampled in [1, prompt_len]
    max_new: int = 16  # budget sampled in [max(1, max_new//2), max_new]
    eps: Optional[float] = None  # fixedpoint: per-request threshold


_TENANT_KEYS = {
    "workload": str,
    "sla": int,
    "prio": int,
    "quota": int,
    "prompt": int,
    "gen": int,
    "eps": float,
}
_TENANT_FIELDS = {
    "prio": "priority", "prompt": "prompt_len", "gen": "max_new",
}


def parse_tenant_specs(spec: str) -> tuple:
    """Parse the CLI/bench tenant table.

    ``spec`` is comma-separated ``name:weight[:key=value...]`` entries,
    e.g. ``chat:3:sla=8:prio=2:gen=12,batch:1:quota=4:gen=24``.  Keys:
    ``workload`` ``sla`` ``prio`` ``quota`` ``prompt`` ``gen`` ``eps``.
    """
    tenants = []
    for entry in spec.split(","):
        parts = [p for p in entry.strip().split(":") if p]
        if not parts:
            continue
        kw: Dict[str, Any] = {"name": parts[0]}
        rest = parts[1:]
        if rest and "=" not in rest[0]:
            kw["weight"] = float(rest.pop(0))
        for item in rest:
            key, _, val = item.partition("=")
            if key not in _TENANT_KEYS:
                raise ValueError(
                    f"unknown tenant key {key!r} in {entry!r}; "
                    f"known: {sorted(_TENANT_KEYS)}"
                )
            kw[_TENANT_FIELDS.get(key, key)] = _TENANT_KEYS[key](val)
        tenants.append(TenantSpec(**kw))
    if not tenants:
        raise ValueError(f"no tenants in spec {spec!r}")
    names = [t.name for t in tenants]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate tenant names in {spec!r}")
    return tuple(tenants)


def quotas_of(tenants: Sequence[TenantSpec]) -> Dict[str, int]:
    """The ``ServeConfig.quotas`` mapping (only tenants with a quota)."""
    return {t.name: t.quota for t in tenants if t.quota}


# -- arrival generators ------------------------------------------------------

ARRIVALS: Dict[str, Callable[..., List[int]]] = {}


def register_arrival(name: str):
    def deco(fn):
        ARRIVALS[name] = fn
        return fn

    return deco


def make_arrival_ticks(spec: str, n: int, seed: int) -> List[int]:
    """``kind[:args]`` -> ``n`` sorted arrival ticks (seeded, tick-domain).

    Kinds: ``none`` (all at t=0), ``poisson:RATE`` (requests/tick),
    ``bursty:BASE,PEAK[,RATE,LEN]``, ``diurnal:PEAK,PERIOD[,FLOOR]``,
    ``trace:FILE`` (JSON arrival-tick list).
    """
    kind, _, arg = spec.partition(":")
    try:
        gen = ARRIVALS[kind]
    except KeyError:
        raise ValueError(
            f"unknown arrival spec {spec!r}; kinds: {sorted(ARRIVALS)}"
        ) from None
    ticks = gen(arg, n, seed)
    if len(ticks) < n:
        raise ValueError(
            f"arrival spec {spec!r} produced {len(ticks)} arrivals, need {n}"
        )
    return sorted(int(t) for t in ticks[:n])


@register_arrival("none")
def _arrive_none(arg: str, n: int, seed: int) -> List[int]:
    """Everything queued at t=0 — peak (burst) load."""
    return [0] * n


@register_arrival("poisson")
def _arrive_poisson(arg: str, n: int, seed: int) -> List[int]:
    """Homogeneous Poisson arrivals at ``RATE`` requests/tick."""
    rate = float(arg)
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate, 1e-9), size=n)
    return np.floor(np.cumsum(gaps)).astype(int).tolist()


def _thin(rate_of, n: int, rng, horizon: int = 1_000_000) -> List[int]:
    """Inhomogeneous Poisson sampling: per-tick counts at ``rate_of(t)``."""
    ticks: List[int] = []
    t = 0
    while len(ticks) < n:
        if t >= horizon:
            raise ValueError(
                f"arrival envelope produced only {len(ticks)}/{n} requests "
                f"within {horizon} ticks — rate too low"
            )
        k = int(rng.poisson(max(0.0, float(rate_of(t)))))
        ticks.extend([t] * k)
        t += 1
    return ticks[:n]


@register_arrival("bursty")
def _arrive_bursty(arg: str, n: int, seed: int) -> List[int]:
    """Correlated traffic bursts: ``BASE,PEAK[,RATE,LEN]``.

    Mirrors the outage-window process of
    :class:`repro.asynchrony.delay_models.BurstyModel`: with probability
    ``RATE`` per tick a window of ``LEN`` ticks opens, during which the
    arrival rate jumps from ``BASE`` to ``PEAK`` — an outage there is a
    burst here (a failing upstream shedding its queue onto this service).
    """
    parts = [p for p in arg.split(",") if p]
    base, peak = float(parts[0]), float(parts[1])
    burst_rate = float(parts[2]) if len(parts) > 2 else 0.05
    burst_len = int(float(parts[3])) if len(parts) > 3 else 20
    rng = np.random.default_rng(seed)
    state = {"until": -1}

    def rate_of(t):
        if rng.random() < burst_rate:
            state["until"] = t + burst_len
        return peak if t < state["until"] else base

    return _thin(rate_of, n, rng)


@register_arrival("diurnal")
def _arrive_diurnal(arg: str, n: int, seed: int) -> List[int]:
    """Sinusoidal day/night load: ``PEAK,PERIOD[,FLOOR]`` — the rate swings
    between ``FLOOR`` (default ``PEAK/10``) and ``PEAK`` over ``PERIOD``
    ticks, starting at the valley (the autoscaler's canonical input)."""
    parts = [p for p in arg.split(",") if p]
    peak, period = float(parts[0]), int(float(parts[1]))
    floor = float(parts[2]) if len(parts) > 2 else peak / 10.0
    rng = np.random.default_rng(seed)

    def rate_of(t):
        phase = 0.5 - 0.5 * np.cos(2.0 * np.pi * t / max(1, period))
        return floor + (peak - floor) * phase

    return _thin(rate_of, n, rng)


@register_arrival("trace")
def _arrive_trace(arg: str, n: int, seed: int) -> List[int]:
    """Replay a recorded arrival trace: a JSON file holding a list of
    arrival ticks (or ``{"arrivals": [...]}``) — the measured-production
    analogue of the delay-model ``trace`` entry."""
    with open(arg) as f:
        data = json.load(f)
    if isinstance(data, dict):
        data = data["arrivals"]
    return [int(t) for t in data]


# -- request materialization -------------------------------------------------


def assign_tenants(
    tenants: Sequence[TenantSpec], n: int, seed: int
) -> List[TenantSpec]:
    """Weighted seeded tenant draw for each of ``n`` arrivals."""
    w = np.asarray([t.weight for t in tenants], np.float64)
    if (w <= 0).any():
        raise ValueError("tenant weights must be positive")
    rng = np.random.default_rng(seed)
    idx = rng.choice(len(tenants), size=n, p=w / w.sum())
    return [tenants[i] for i in idx]


def build_requests(
    tenants: Sequence[TenantSpec],
    workloads: Mapping[str, Any],
    n: int,
    arrival_spec: str,
    seed: int,
) -> Dict[str, List[Any]]:
    """Materialize the scenario's request streams.

    One seeded pass: arrival ticks from ``arrival_spec``, a weighted
    tenant draw per arrival, and each request's payload sampled by the
    *workload object* the tenant targets (``sample_request`` — prompts
    clamped to the pool's shape for LLM tenants, normalized personalization
    vectors for fixed-point tenants).  Returns ``{workload name: [Request]}``
    with globally unique request ids in arrival order.
    """
    missing = {t.workload for t in tenants} - set(workloads)
    if missing:
        raise ValueError(
            f"tenants target workloads {sorted(missing)} but only "
            f"{sorted(workloads)} are deployed"
        )
    arrivals = make_arrival_ticks(arrival_spec, n, seed)
    drawn = assign_tenants(tenants, n, seed + 1)
    rng = np.random.default_rng(seed + 2)
    out: Dict[str, List[Any]] = {name: [] for name in workloads}
    for rid, (tick, tenant) in enumerate(zip(arrivals, drawn)):
        req = workloads[tenant.workload].sample_request(
            tenant, rng, id=rid, arrival=tick
        )
        out[tenant.workload].append(req)
    return out


class TenantScenario:
    """One engine per deployed workload kind, sharing a tenant trace.

    The engines are independent services (separate pools, separate
    termination extents), so they run sequentially and the merged summary
    is exact: counts/ticks/replica-ticks add, percentiles re-rank the
    pooled per-request results, and the per-tenant table concatenates
    (a tenant targets exactly one workload).
    """

    def __init__(self, engines: Mapping[str, Any]):
        if not engines:
            raise ValueError("TenantScenario needs at least one engine")
        self.engines = dict(engines)

    def run(self, requests: Mapping[str, Sequence[Any]], **kw):
        """Drive every engine over its stream; returns {workload: results}."""
        out = {}
        for name in sorted(self.engines):
            out[name] = self.engines[name].run(requests.get(name, ()), **kw)
        return out

    def summary(self) -> Dict[str, Any]:
        from repro.serving.engine import (
            _latency_percentiles,
            _sla_fields,
            _tenant_summaries,
        )

        res = [
            r for name in sorted(self.engines)
            for r in self.engines[name].results.values()
        ]
        subs = {n: e.summary() for n, e in self.engines.items()}
        ticks = sum(s["ticks"] for s in subs.values())
        wall = sum(s["wall_s"] for s in subs.values())
        return {
            "completed": len(res),
            "ticks": ticks,
            "wall_s": wall,
            "tokens_out": int(sum(r.n_tokens for r in res)),
            **_latency_percentiles(res),
            **_sla_fields(res, ticks, wall),
            "replica_ticks": sum(s["replica_ticks"] for s in subs.values()),
            "tenants": _tenant_summaries(res),
            "converged": int(sum(r.converged for r in res)),
            "engines": subs,
        }
