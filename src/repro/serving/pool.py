"""Layer 1 of the serving subsystem: fixed-slot decode *pools*.

A pool is the device-resident half of continuous batching: a fixed number
of slots (the jitted batch dimension — shapes never change, so admission
never recompiles) over the existing sharded KV/state cache, with per-slot
``lengths`` / ``active`` / ``age`` state and slot recycling — a retired
slot is re-used by offset-prefilling the next request into that slot's
cache slice while every other slot keeps decoding.

Two pools, one per workload family:

- :class:`DecodePool` — LLM decode over ``transformer.init_cache`` and the
  per-slot-length ``make_pool_decode_step`` /
  ``make_slot_prefill_step`` builders in ``repro.distributed.serve``.
  Slot math is an independent vmap lane per request, so a request's
  greedy tokens are bit-identical to decoding it alone in a static batch
  (tested in ``tests/test_serving.py``).
- :class:`FixedPointPool` — per-request fixed-point solves (the
  D-iteration serving workload): every slot carries its own iterate and
  affine payload (personalization vector / right-hand side) over one
  shared operator, one fused vmapped update per tick, block residuals
  reported per termination replica.

Pools own the device state and the jitted admission step; the engine owns
the host-side control plane (``active`` / token counters / ages) and
drives ``device_step`` inside its fused per-tick dispatch.  Schedulers
and termination protocols never touch the cache directly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.asynchrony.protocols import RES_INIT
from repro.distributed import serve as dserve
from repro.models import transformer
from repro.models.config import ModelConfig


class DecodePool:
    """Fixed-slot continuous-batching pool over the sharded decode cache."""

    def __init__(
        self,
        cfg: ModelConfig,
        mesh,
        *,
        slots: int,
        max_len: int,
        max_prompt_len: int,
    ):
        if max_prompt_len >= max_len:
            raise ValueError("max_prompt_len must leave room to decode")
        self.cfg, self.mesh = cfg, mesh
        self.slots, self.max_len, self.max_prompt_len = slots, max_len, max_prompt_len
        pool_step, self.rules = dserve.make_pool_decode_step(cfg, mesh)
        slot_prefill, _ = dserve.make_slot_prefill_step(cfg, mesh, max_prompt_len)

        def _step(params, state, active):
            logits, cache2 = pool_step(
                params, state["tokens"], state["cache"], state["lengths"]
            )
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            # freeze slots at cache capacity (the engine retires them; the
            # clamp only keeps the rolling write from wrapping meanwhile)
            adv = active & (state["lengths"] < self.max_len - 1)
            return {
                "cache": dserve.select_slots(active, cache2, state["cache"]),
                "tokens": jnp.where(active, nxt, state["tokens"]),
                "lengths": jnp.where(adv, state["lengths"] + 1, state["lengths"]),
            }

        # pure traced step — the engine fuses this with the termination
        # protocol's tick into one dispatch per engine tick
        self.device_step = _step

        def _admit(params, state, prompt, plen, slot):
            last_logits, cache = slot_prefill(
                params, prompt, plen, state["cache"], slot
            )
            tok0 = jnp.argmax(last_logits, -1).astype(jnp.int32)
            return {
                "cache": cache,
                "tokens": state["tokens"].at[slot].set(tok0),
                "lengths": state["lengths"].at[slot].set(plen),
            }

        self._jadmit = jax.jit(_admit)
        self.reset()

    def reset(self):
        from jax.sharding import NamedSharding, PartitionSpec

        with self.mesh:
            cache = transformer.init_cache(self.cfg, self.slots, self.max_len)
        # commit every array to its sharding up front: jit caches key on
        # argument shardings, so uncommitted fresh state next to committed
        # stepped state would silently compile the pool step twice
        specs = dserve.cache_specs(self.cfg, self.rules, cache)
        cache = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(self.mesh, s)),
            cache, specs,
        )
        rep = NamedSharding(self.mesh, PartitionSpec())
        self.state = {
            "cache": cache,
            "tokens": jax.device_put(jnp.zeros((self.slots,), jnp.int32), rep),
            "lengths": jax.device_put(jnp.zeros((self.slots,), jnp.int32), rep),
        }

    def capacity_mask(self, state):
        """Traced: slots frozen at the cache capacity clamp."""
        return state["lengths"] >= self.max_len - 1

    @property
    def cache_bytes(self) -> int:
        return int(
            sum(l.nbytes for l in jax.tree.leaves(self.state["cache"]))
        )

    def admit(self, params, prompt, slot: int) -> int:
        """Offset-prefill ``prompt`` (1-D int array) into ``slot``.

        Returns the request's first generated token (greedy argmax of the
        prefill's last-position logits).
        """
        prompt = np.asarray(prompt, np.int32)
        plen = int(prompt.shape[0])
        if not 0 < plen <= self.max_prompt_len:
            raise ValueError(
                f"prompt length {plen} not in (0, {self.max_prompt_len}]"
            )
        padded = np.zeros((self.max_prompt_len,), np.int32)
        padded[:plen] = prompt
        with self.mesh:
            self.state = self._jadmit(
                params, self.state, jnp.asarray(padded), jnp.int32(plen),
                jnp.int32(slot),
            )
        return int(self.state["tokens"][slot])


class FixedPointPool:
    """Per-request fixed-point solves in pool slots (D-iteration serving).

    All requests share one operator (``base.full_map``); a request is its
    affine payload ``v`` (personalization vector / right-hand side):
    ``f(x, v) = base(x) + gain * (v - v0)``, which is exact for the linear
    solvers this serves (``d_iteration``: ``gain = 1 - damping``;
    weighted-Jacobi families: ``gain = omega / diag``).  One vmapped update
    advances every active slot per tick; residuals are reported per
    ``dp``-replica block for the agreement reduction.
    """

    def __init__(self, base, *, slots: int, dp: int, gain, payload0=None):
        if base.n % dp:
            raise ValueError(f"n={base.n} must divide into dp={dp} blocks")
        self.base, self.slots, self.dp = base, slots, dp
        self.n = base.n
        gain = jnp.asarray(gain, jnp.float32)
        v0 = (
            jnp.zeros((self.n,), jnp.float32)
            if payload0 is None
            else jnp.asarray(payload0, jnp.float32)
        )

        def param_map(x, v):
            return base.full_map(x) + gain * (v - v0)

        self.param_map = param_map
        self._build_step()

        def _admit(state, v, slot):
            return {
                "x": state["x"].at[slot].set(jnp.zeros((self.n,), jnp.float32)),
                "payload": state["payload"].at[slot].set(v),
            }

        self._jadmit = jax.jit(_admit)
        self.reset()

    def _build_step(self):
        """(Re)build the vmapped tick at the current replica extent: the
        residual block reshape is the only dp-dependent piece of the pool."""
        dp, m = self.dp, self.n // self.dp

        def _step(state, active):
            xnew = jax.vmap(self.param_map)(state["x"], state["payload"])
            upd = jnp.max(
                jnp.abs(xnew - state["x"]).reshape(self.slots, dp, m), axis=2
            )  # [S, dp]
            x = jnp.where(active[:, None], xnew, state["x"])
            residual = jnp.where(active[:, None], upd, RES_INIT).T  # [dp, S]
            return {**state, "x": x}, residual

        self.device_step = _step

    def migrate_dp(self, new_dp: int) -> None:
        """Elastic resize: re-block the residual report at the new extent.

        The per-slot iterates and payloads are replica-independent (every
        replica holds the same ``x``), so only the reporting reshape
        changes — requests keep iterating exactly where they were.
        """
        if self.n % new_dp:
            raise ValueError(f"n={self.n} must divide into dp={new_dp} blocks")
        self.dp = new_dp
        self._build_step()

    def reset(self):
        self.state = {
            "x": jnp.zeros((self.slots, self.n), jnp.float32),
            "payload": jnp.zeros((self.slots, self.n), jnp.float32),
        }

    def admit(self, payload, slot: int) -> None:
        v = jnp.asarray(np.asarray(payload, np.float32))
        if v.shape != (self.n,):
            raise ValueError(f"payload shape {v.shape} != ({self.n},)")
        self.state = self._jadmit(self.state, v, jnp.int32(slot))

    def solution(self, slot: int) -> np.ndarray:
        return np.asarray(self.state["x"][slot])
