"""Unified telemetry subsystem (DESIGN.md S18).

Three layers, mirroring the collectives/asynchrony architecture:

- :mod:`repro.obs.metrics` — typed Counter/Gauge/Histogram instruments
  over a ring-buffered :class:`MetricsRegistry` drained by a background
  writer thread (flush-only ``jax.block_until_ready`` fencing);
- :mod:`repro.obs.tracing` — span/instant :class:`Tracer` with monotonic
  timestamps and a ``chrome_trace()`` Perfetto exporter;
- :mod:`repro.obs.sinks` — SINKS registry (null / jsonl / csv /
  chrome_trace) selected by ``--telemetry name[:path]`` on both
  launchers.

The process-global instance is **disabled by default**: every hook in
collectives / asynchrony / serving / runtime / checkpoint guards on
:func:`enabled`, so an uninstrumented run pays one attribute load + one
branch per hook (this is the ``--telemetry null`` baseline the CI
overhead gate compares against).  :func:`configure` turns it on:

    from repro import obs
    obs.configure("chrome_trace:out.json")
    ...
    obs.shutdown()     # drain metrics, export trace via the sink

Instrumentation sites use the module-level conveniences::

    with obs.span("serve.tick", n_ticks=k): ...
    obs.instant("protocol.certify", tick=t)
    obs.counter("coll.messages", op="allreduce").add(m)
    obs.gauge("serve.queue_depth").set(depth)

All of them are cheap no-ops while disabled.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .sinks import SINKS, Sink, get_sink, parse_spec, register_sink
from .tracing import _NULL_SPAN, Tracer

__all__ = [
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "Tracer",
    "Sink",
    "SINKS",
    "register_sink",
    "get_sink",
    "parse_spec",
    "Telemetry",
    "configure",
    "shutdown",
    "enabled",
    "telemetry",
    "span",
    "instant",
    "counter",
    "gauge",
    "histogram",
    "snapshot",
    "summary",
    "reset",
]


class Telemetry:
    """A registry + tracer + sink bundle. One process-global instance lives
    in this module; tests construct their own to stay isolated."""

    def __init__(
        self,
        registry: Optional[MetricsRegistry] = None,
        tracer: Optional[Tracer] = None,
        sink: Optional[Sink] = None,
    ):
        self.registry = registry or MetricsRegistry()
        self.tracer = tracer or Tracer()
        self.sink = sink
        self.enabled = False

    def configure(self, spec: str = "null", background: bool = True) -> "Telemetry":
        """Select a sink by spec and enable recording.  ``background=True``
        starts the metrics writer thread; tests pass False and drive
        :meth:`MetricsRegistry.flush` themselves."""
        self.sink = get_sink(spec)
        self.enabled = True
        self.tracer.enabled = True
        if background:
            self.registry.start(self.sink)
        else:
            self.registry._sink = self.sink
        return self

    def shutdown(self) -> Dict[str, Any]:
        """Stop the writer, drain, hand the tracer to the sink for export,
        and disable. Returns the final pipeline summary."""
        self.registry.stop()
        if self.sink is not None:
            self.sink.close(self.tracer)
        out = self.summary()
        self.enabled = False
        self.tracer.enabled = False
        return out

    def summary(self) -> Dict[str, Any]:
        """Pipeline health for embedding in other summaries (e.g.
        ``ServeEngine.summary()['telemetry']``)."""
        tr = self.tracer.summary()
        mx = self.registry.summary()
        return {
            "enabled": self.enabled,
            "spans": tr["spans"],
            "instants": tr["instants"],
            "events_dropped": tr["dropped"],
            "metrics_recorded": mx["recorded"],
            "metrics_dropped": mx["dropped"],
            "sink": self.sink.name if self.sink is not None else None,
        }


_GLOBAL = Telemetry()


def telemetry() -> Telemetry:
    """The process-global telemetry instance."""
    return _GLOBAL


def configure(spec: str = "null", background: bool = True) -> Telemetry:
    return _GLOBAL.configure(spec, background=background)


def shutdown() -> Dict[str, Any]:
    return _GLOBAL.shutdown()


def enabled() -> bool:
    return _GLOBAL.enabled


# -- module-level conveniences: the instrumentation-site API.  Each is a
# guarded forward onto the global instance and a no-op while disabled. ------


def span(name: str, **args):
    if not _GLOBAL.enabled:
        return _NULL_SPAN
    return _GLOBAL.tracer.span(name, **args)


def instant(name: str, **args) -> None:
    if _GLOBAL.enabled:
        _GLOBAL.tracer.instant(name, **args)


def counter(name: str, **labels) -> Counter:
    return _GLOBAL.registry.counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    return _GLOBAL.registry.gauge(name, **labels)


def histogram(name: str, **labels) -> Histogram:
    return _GLOBAL.registry.histogram(name, **labels)


def snapshot() -> Dict[str, Any]:
    return _GLOBAL.registry.snapshot()


def summary() -> Dict[str, Any]:
    return _GLOBAL.summary()


def reset() -> None:
    """Swap in a fresh disabled global — used between benches in
    ``benchmarks/run.py`` (one trace artifact per bench) and by tests."""
    global _GLOBAL
    try:
        _GLOBAL.registry.stop()
    except Exception:
        pass
    _GLOBAL = Telemetry()


_reset_for_tests = reset
