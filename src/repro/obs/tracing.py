"""Layer 2 of the observability subsystem: structured *event tracing* with a
Chrome-trace exporter (DESIGN.md S18).

A :class:`Tracer` records two event shapes into the same kind of bounded
ring the metrics registry uses:

- **spans** — ``with tracer.span("serve.tick", n=4):`` records a complete
  duration event (begin timestamp + duration, both from
  ``time.perf_counter_ns`` so they are monotonic and immune to wall-clock
  steps);
- **instants** — ``tracer.instant("protocol.certify", tick=12)`` records a
  zero-duration marker.

Both carry free-form ``args`` key/values that land verbatim in the
exported trace, so per-stage message counts, byte volumes, resize extents
etc. are attached to the event that produced them rather than logged out
of band.

Export is :meth:`Tracer.chrome_trace`: the Trace Event Format JSON object
(``{"traceEvents": [...]}``) that ``chrome://tracing`` and Perfetto load
directly.  Complete events use phase ``"X"`` with microsecond ``ts``/
``dur``; instants use phase ``"i"``.  Thread ids are mapped to small
stable ints so e.g. the checkpoint writer thread gets its own lane.

Overflow policy matches metrics: when the ring is full new events are
dropped and counted (:attr:`Tracer.dropped`), never blocking the caller.
"""

from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

_PH_SPAN = "X"
_PH_INSTANT = "i"


class Tracer:
    """Ring-buffered span/instant recorder with Chrome-trace export."""

    def __init__(self, capacity: int = 65536, enabled: bool = True):
        self.capacity = capacity
        self.enabled = enabled
        self._events: List[tuple] = []  # (ph, name, ts_ns, dur_ns, tid, args)
        self.dropped = 0
        self._tids: Dict[int, int] = {}
        self._spans = 0
        self._instants = 0
        self._lock = threading.Lock()  # export-time snapshot only

    # -- hot path ------------------------------------------------------------

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            tid = self._tids[ident] = len(self._tids)
        return tid

    def _push(self, ev: tuple) -> None:
        if len(self._events) >= self.capacity:
            self.dropped += 1
            return
        self._events.append(ev)

    @contextmanager
    def span(self, name: str, **args):
        """Record a complete duration event around the enclosed block.

        Yields the args dict so the body can attach values only known at
        exit (``with tr.span("tick") as sp: ...; sp["n"] = n``) — the dict
        is read when the event is pushed, at exit."""
        if not self.enabled:
            yield None
            return
        t0 = time.perf_counter_ns()
        try:
            yield args
        finally:
            dur = time.perf_counter_ns() - t0
            self._spans += 1
            self._push((_PH_SPAN, name, t0, dur, self._tid(), args or None))

    def instant(self, name: str, **args) -> None:
        """Record a zero-duration marker event."""
        if not self.enabled:
            return
        self._instants += 1
        self._push(
            (_PH_INSTANT, name, time.perf_counter_ns(), 0, self._tid(), args or None)
        )

    # -- export ----------------------------------------------------------------

    def events(self) -> List[tuple]:
        with self._lock:
            return list(self._events)

    def chrome_trace(self, process_name: str = "repro") -> Dict[str, Any]:
        """Trace Event Format object loadable by chrome://tracing / Perfetto.

        Timestamps are microseconds relative to the earliest recorded event
        (Perfetto renders absolute perf_counter epochs poorly)."""
        evs = self.events()
        t0 = min((e[2] for e in evs), default=0)
        out = []
        for ph, name, ts, dur, tid, args in evs:
            rec: Dict[str, Any] = {
                "name": name,
                "ph": ph,
                "ts": (ts - t0) / 1e3,
                "pid": 0,
                "tid": tid,
            }
            if ph == _PH_SPAN:
                rec["dur"] = dur / 1e3
            else:
                rec["s"] = "t"  # thread-scoped instant
            if args:
                rec["args"] = {k: _jsonable(v) for k, v in args.items()}
            out.append(rec)
        meta = [
            {
                "name": "process_name",
                "ph": "M",
                "pid": 0,
                "args": {"name": process_name},
            }
        ]
        for ident, tid in sorted(self._tids.items(), key=lambda kv: kv[1]):
            meta.append(
                {
                    "name": "thread_name",
                    "ph": "M",
                    "pid": 0,
                    "tid": tid,
                    "args": {"name": "main" if tid == 0 else f"thread-{tid}"},
                }
            )
        return {"traceEvents": meta + out, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str, process_name: str = "repro") -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(process_name), f)

    # -- read-back --------------------------------------------------------------

    def summary(self) -> Dict[str, int]:
        return {
            "spans": self._spans,
            "instants": self._instants,
            "recorded": len(self._events),
            "dropped": self.dropped,
        }

    def counts(self, prefix: str = "") -> Dict[str, int]:
        """Event counts by name (optionally filtered by prefix)."""
        out: Dict[str, int] = {}
        for ev in self.events():
            name = ev[1]
            if name.startswith(prefix):
                out[name] = out.get(name, 0) + 1
        return out

    def reset(self) -> None:
        with self._lock:
            self._events.clear()
        self.dropped = 0
        self._spans = 0
        self._instants = 0


class _NullSpan:
    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return str(v)
