"""Layer 1 of the observability subsystem: typed *metric instruments* over a
process-global registry (DESIGN.md S18).

The design constraint is the one PR 8 deferred this subsystem over: the
recording hot path must never stall device dispatch.  Instruments therefore
write fixed-size records into a preallocated ring buffer — an append plus
two integer bumps under the GIL, no locks, no I/O, no host<->device sync —
and a background *writer thread* drains the ring on a period, aggregates,
and forwards raw records to the configured sink.  Two consequences:

- a :class:`Gauge` may be handed a live ``jax.Array`` (e.g. a loss still in
  flight); the hot path stores the reference and the **drain** converts it
  (``jax.block_until_ready`` fencing happens only at flush, so recording a
  device value never forces a dispatch fence);
- when producers outrun the drain the ring *drops* — overflow is counted in
  :attr:`MetricsRegistry.dropped` and surfaced (``ServeEngine.summary()``
  reports it), never silent.

Instrument kinds:

- :class:`Counter` — monotonically accumulating totals (``add``/``inc``);
- :class:`Gauge` — last-value-wins samples (``set``);
- :class:`Histogram` — streaming count/sum/min/max plus a bounded tail
  reservoir for percentiles (``observe``).

All three are cheap handles onto their registry; get-or-create them via
:meth:`MetricsRegistry.counter` / ``gauge`` / ``histogram`` (or the
module-level conveniences in :mod:`repro.obs`).  Aggregated state is read
back with :meth:`MetricsRegistry.snapshot`.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Dict, Optional

_KIND_COUNTER = 0
_KIND_GAUGE = 1
_KIND_HIST = 2

_KIND_NAMES = {_KIND_COUNTER: "counter", _KIND_GAUGE: "gauge", _KIND_HIST: "histogram"}


def _now_ns() -> int:
    return time.perf_counter_ns()


class _Instrument:
    """Shared handle shape: records go through the owning registry's ring."""

    __slots__ = ("name", "labels", "_reg")
    kind = -1

    def __init__(self, reg: "MetricsRegistry", name: str, labels: tuple):
        self._reg = reg
        self.name = name
        self.labels = labels


class Counter(_Instrument):
    kind = _KIND_COUNTER
    __slots__ = ()

    def add(self, value: float = 1.0) -> None:
        self._reg._record(_KIND_COUNTER, self.name, value, self.labels)

    inc = add


class Gauge(_Instrument):
    kind = _KIND_GAUGE
    __slots__ = ()

    def set(self, value: Any) -> None:
        # `value` may be a device array still in flight: stored by reference,
        # materialized at drain time (flush-only fencing)
        self._reg._record(_KIND_GAUGE, self.name, value, self.labels)


class Histogram(_Instrument):
    kind = _KIND_HIST
    __slots__ = ()

    def observe(self, value: float) -> None:
        self._reg._record(_KIND_HIST, self.name, value, self.labels)


class _HistState:
    __slots__ = ("count", "total", "vmin", "vmax", "tail")
    TAIL = 512  # bounded reservoir: last N observations, for percentiles

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self.tail: list = []

    def push(self, v: float):
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        self.tail.append(v)
        if len(self.tail) > self.TAIL:
            del self.tail[: len(self.tail) - self.TAIL]


def _materialize(value: Any) -> float:
    """Convert a drained value to a float — the only place a device value is
    waited on (``jax.block_until_ready`` fencing at flush, never at record)."""
    if isinstance(value, (int, float)):
        return float(value)
    try:
        import jax

        if isinstance(value, jax.Array):
            return float(jax.block_until_ready(value))
    except Exception:
        pass
    return float(value)


class MetricsRegistry:
    """Ring-buffered instrument registry with a background drain thread.

    ``capacity`` bounds the ring (records between drains); ``interval``
    is the writer thread's drain period in seconds.  The writer starts
    lazily on the first :meth:`start` (the registry works fully
    synchronously without it — :meth:`flush` drains inline)."""

    def __init__(self, capacity: int = 65536, interval: float = 0.5):
        self.capacity = capacity
        self.interval = interval
        # ring: preallocated slots, single head counter.  Writers fill
        # slot (head % capacity) then bump head; the drain thread owns
        # tail.  Under the GIL each record is one slot store + one int
        # add — no locks on the hot path.
        self._ring: list = [None] * capacity
        self._head = 0
        self._tail = 0
        self.dropped = 0
        self._instruments: Dict[tuple, _Instrument] = {}
        # aggregated (drained) state
        self._counters: Dict[tuple, float] = {}
        self._gauges: Dict[tuple, float] = {}
        self._hists: Dict[tuple, _HistState] = {}
        self._drained = 0
        self._sink = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._drain_lock = threading.Lock()  # drain is not reentrant

    # -- instrument construction (get-or-create, label-keyed) ---------------

    def _get(self, cls, name: str, labels: dict):
        key = (cls.kind, name, tuple(sorted(labels.items())))
        inst = self._instruments.get(key)
        if inst is None:
            inst = self._instruments[key] = cls(self, name, key[2])
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    # -- hot path ------------------------------------------------------------

    def _record(self, kind: int, name: str, value: Any, labels: tuple) -> None:
        head = self._head
        if head - self._tail >= self.capacity:
            self.dropped += 1  # ring full: drop, count, never block
            return
        self._ring[head % self.capacity] = (_now_ns(), kind, name, value, labels)
        self._head = head + 1

    # -- drain / background writer -------------------------------------------

    def drain(self) -> int:
        """Move every pending record from the ring into the aggregated
        state (and the sink, when one is attached).  Returns the number of
        records drained.  This is where device values are materialized —
        the flush-side fence."""
        with self._drain_lock:
            head = self._head  # records past this arrive in the next drain
            n = 0
            batch = []
            while self._tail < head:
                rec = self._ring[self._tail % self.capacity]
                self._tail += 1
                if rec is None:  # torn write (racing producer): skip
                    continue
                ts, kind, name, value, labels = rec
                v = _materialize(value)
                key = (name, labels)
                if kind == _KIND_COUNTER:
                    self._counters[key] = self._counters.get(key, 0.0) + v
                elif kind == _KIND_GAUGE:
                    self._gauges[key] = v
                else:
                    h = self._hists.get(key)
                    if h is None:
                        h = self._hists[key] = _HistState()
                    h.push(v)
                batch.append((ts, _KIND_NAMES[kind], name, v, labels))
                n += 1
            self._drained += n
            if batch and self._sink is not None:
                self._sink.write_metrics(batch)
            return n

    def start(self, sink=None) -> None:
        """Attach ``sink`` and start the background writer thread (idempotent)."""
        if sink is not None:
            self._sink = sink
        if self._thread is not None and self._thread.is_alive():
            return
        self._stop.clear()

        def _loop():
            while not self._stop.wait(self.interval):
                self.drain()
            self.drain()

        self._thread = threading.Thread(
            target=_loop, name="obs-metrics-writer", daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        """Stop the writer thread (drains once more on the way out)."""
        if self._thread is not None:
            self._stop.set()
            self._thread.join()
            self._thread = None
        else:
            self.drain()

    def flush(self) -> int:
        """Synchronous drain (works with or without the writer thread)."""
        return self.drain()

    # -- read-back ------------------------------------------------------------

    @staticmethod
    def _label_str(labels: tuple) -> str:
        return ",".join(f"{k}={v}" for k, v in labels)

    def snapshot(self) -> Dict[str, Any]:
        """Aggregated view of everything drained so far:
        ``{"counters": {...}, "gauges": {...}, "histograms": {...}}`` keyed
        by ``name[label=value,...]``.  Flushes first."""
        self.flush()

        def keyname(key):
            name, labels = key
            return f"{name}[{self._label_str(labels)}]" if labels else name

        hists = {}
        for key, h in self._hists.items():
            tail = sorted(h.tail)
            entry = {
                "count": h.count,
                "sum": h.total,
                "min": h.vmin,
                "max": h.vmax,
                "mean": h.total / h.count if h.count else 0.0,
            }
            if tail:
                entry["p50"] = tail[len(tail) // 2]
                entry["p95"] = tail[min(len(tail) - 1, int(len(tail) * 0.95))]
            hists[keyname(key)] = entry
        return {
            "counters": {keyname(k): v for k, v in self._counters.items()},
            "gauges": {keyname(k): v for k, v in self._gauges.items()},
            "histograms": hists,
        }

    def summary(self) -> Dict[str, int]:
        """Health of the pipeline itself (satellite: overflow must be
        observable, never silent)."""
        return {
            "recorded": self._drained + (self._head - self._tail),
            "drained": self._drained,
            "pending": self._head - self._tail,
            "dropped": self.dropped,
        }
