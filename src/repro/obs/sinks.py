"""Layer 3 of the observability subsystem: output *sinks* behind a SINKS
registry (DESIGN.md S18), mirroring SCHEDULES / DETECTION_PROTOCOLS /
TERMINATION.

A sink receives drained metric batches (``write_metrics``) and, at
shutdown, the tracer for final export (``close``).  Selection is by spec
string — ``"jsonl:telemetry.jsonl"``, ``"chrome_trace:out.json"``,
``"csv"``, ``"null"`` — parsed by :func:`parse_spec` and resolved by
:func:`get_sink`; launchers expose the spec verbatim as ``--telemetry``.

Built-ins:

- ``null`` — drop everything (the overhead-gate baseline);
- ``jsonl`` — one JSON object per drained metric record, streamed;
- ``csv`` — same records as ``ts_ns,kind,name,value,labels`` rows;
- ``chrome_trace`` — buffers nothing per-record; on close writes the
  tracer's Perfetto-loadable JSON to the spec path.
"""

from __future__ import annotations

import csv
import json
from typing import Callable, Dict, List, Optional, Tuple

SINKS: Dict[str, Callable[..., "Sink"]] = {}


def register_sink(name: str):
    def deco(fn):
        SINKS[name] = fn
        return fn

    return deco


class Sink:
    """Base sink: ignores everything. Subclasses override what they need."""

    name = "null"

    def write_metrics(self, batch: List[tuple]) -> None:
        pass

    def close(self, tracer=None) -> None:
        pass


@register_sink("null")
class NullSink(Sink):
    name = "null"


@register_sink("jsonl")
class JsonlSink(Sink):
    name = "jsonl"

    def __init__(self, path: Optional[str] = None):
        self.path = path or "telemetry.jsonl"
        self._f = open(self.path, "w")

    def write_metrics(self, batch: List[tuple]) -> None:
        for ts, kind, name, value, labels in batch:
            self._f.write(
                json.dumps(
                    {
                        "ts_ns": ts,
                        "kind": kind,
                        "name": name,
                        "value": value,
                        "labels": dict(labels) if labels else {},
                    }
                )
                + "\n"
            )

    def close(self, tracer=None) -> None:
        if tracer is not None:
            self._f.write(json.dumps({"trace_summary": tracer.summary()}) + "\n")
        self._f.close()


@register_sink("csv")
class CsvSink(Sink):
    name = "csv"

    def __init__(self, path: Optional[str] = None):
        self.path = path or "telemetry.csv"
        self._f = open(self.path, "w", newline="")
        self._w = csv.writer(self._f)
        self._w.writerow(["ts_ns", "kind", "name", "value", "labels"])

    def write_metrics(self, batch: List[tuple]) -> None:
        for ts, kind, name, value, labels in batch:
            self._w.writerow(
                [ts, kind, name, value, ";".join(f"{k}={v}" for k, v in labels)]
            )

    def close(self, tracer=None) -> None:
        self._f.close()


@register_sink("chrome_trace")
class ChromeTraceSink(Sink):
    """Per-record metrics are dropped; the trace is written once at close.
    Pair with ``MetricsRegistry.snapshot()`` for the aggregate view."""

    name = "chrome_trace"

    def __init__(self, path: Optional[str] = None):
        self.path = path or "trace.json"

    def close(self, tracer=None) -> None:
        if tracer is not None:
            tracer.write_chrome_trace(self.path)


def parse_spec(spec: str) -> Tuple[str, Optional[str]]:
    """``"name[:path]"`` → ``(name, path_or_None)``.  Unknown names raise
    with the registry contents, matching the other registries' errors."""
    name, _, path = spec.partition(":")
    if name not in SINKS:
        raise ValueError(f"unknown telemetry sink {name!r}; have {sorted(SINKS)}")
    return name, (path or None)


def get_sink(spec: str) -> Sink:
    name, path = parse_spec(spec)
    cls = SINKS[name]
    return cls() if name == "null" else cls(path)
