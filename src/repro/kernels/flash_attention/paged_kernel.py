"""Pallas paged-attention decode kernel: K/V gathered through a block table.

Serving decode with a block-paged cache (DESIGN.md S14): each sequence's
K/V lives in fixed-size blocks scattered across a shared physical pool
``[num_blocks, block_size, KV, hd]``, addressed by a per-sequence block
table.  One decode query attends over its blocks by walking the table
*inside* the kernel with ``pl.ds`` dynamic slices — no gathered/contiguous
copy of the cache is ever materialized.

Grid: (S * KV,) — one program per (sequence, kv-head).  The GQA query
group (rep = H // KV) rides in the sublane dimension, so the score matrix
per block is [rep, block_size] and the online-softmax running state
(m, l, acc) matches ``kernel.py``'s flash forward exactly.  The loop bound
is the *dynamic* ``ceil(length / block_size)``, so a short sequence in a
long table does proportional work.

On production TPU the block table and length belong in SMEM via
``pltpu.PrefetchScalarGridSpec`` so the address arithmetic runs ahead of
the VMEM data fetches; interpret mode (CPU CI) has no SMEM, so they ride
as ordinary VMEM operands here — the access *pattern* (gather by table,
online softmax over blocks, trash-block masking) is identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _paged_kernel(
    q_ref,    # [rep, hd]       queries of this sequence's kv-head group
    bt_ref,   # [nb] int32      the sequence's block table
    len_ref,  # [1] int32       valid cache positions
    k_ref,    # [N*bs, hd]      flattened physical pool, this kv head
    v_ref,    # [N*bs, hd]
    o_ref,    # [rep, hd]
    *,
    block_size: int,
):
    rep, hd = q_ref.shape
    q = q_ref[...].astype(jnp.float32) * (hd**-0.5)
    length = len_ref[0]
    nblk = pl.cdiv(length, block_size)

    def body(j, carry):
        m_run, l_run, acc = carry
        pb = bt_ref[j]
        k_blk = k_ref[pl.ds(pb * block_size, block_size), :].astype(jnp.float32)
        v_blk = v_ref[pl.ds(pb * block_size, block_size), :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k_blk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [rep, bs]
        k_pos = j * block_size + jax.lax.iota(jnp.int32, block_size)
        s = jnp.where(k_pos[None, :] < length, s, NEG_INF)
        m_new = jnp.maximum(m_run, s.max(axis=1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_run * alpha + p.sum(axis=1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc

    m0 = jnp.full((rep,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((rep,), jnp.float32)
    a0 = jnp.zeros((rep, hd), jnp.float32)
    m_f, l_f, acc = jax.lax.fori_loop(0, nblk, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l_f, 1e-30)[:, None]).astype(o_ref.dtype)


def paged_attention_fwd(q, k_pages, v_pages, block_tables, lengths, *,
                        interpret: bool = False):
    """q: [S, H, hd]; k_pages/v_pages: [N, bs, KV, hd];
    block_tables: [S, nb] int; lengths: [S] int (valid positions per
    sequence) -> [S, H, hd]."""
    S, H, hd = q.shape
    N, bs, KV, _ = k_pages.shape
    rep = H // KV
    nb = block_tables.shape[1]

    # layout: [S*KV, rep, hd] for q; [KV, N*bs, hd] pool stripes for kv
    qx = q.reshape(S, KV, rep, hd).reshape(S * KV, rep, hd)
    kx = k_pages.transpose(2, 0, 1, 3).reshape(KV, N * bs, hd)
    vx = v_pages.transpose(2, 0, 1, 3).reshape(KV, N * bs, hd)
    bt = block_tables.astype(jnp.int32)
    ln = lengths.astype(jnp.int32).reshape(S, 1)

    out = pl.pallas_call(
        functools.partial(_paged_kernel, block_size=bs),
        grid=(S * KV,),
        in_specs=[
            pl.BlockSpec((None, rep, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, nb), lambda i: (i // KV, 0)),
            pl.BlockSpec((None, 1), lambda i: (i // KV, 0)),
            pl.BlockSpec((None, N * bs, hd), lambda i: (i % KV, 0, 0)),
            pl.BlockSpec((None, N * bs, hd), lambda i: (i % KV, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, rep, hd), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((S * KV, rep, hd), q.dtype),
        interpret=interpret,
    )(qx, bt, ln, kx, vx)

    return out.reshape(S, KV, rep, hd).reshape(S, H, hd)
