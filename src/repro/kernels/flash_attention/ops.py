"""jit'd public wrapper for the flash attention kernel.

On TPU: the Pallas kernel.  On CPU (this container): interpret mode executes
the kernel body in Python — used by the allclose test sweeps; production CPU
paths use ``models.attention`` flash_scan instead.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.paged_kernel import paged_attention_fwd


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "bq", "bk", "interpret"),
)
def flash_attention(
    q, k, v, *, causal=True, window=None, q_offset=0, bq=128, bk=128, interpret=None
):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return flash_attention_fwd(
        q, k, v,
        causal=causal, window=window, q_offset=q_offset,
        bq=bq, bk=bk, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    interpret=None):
    """Decode attention over a block-paged KV cache (one query/sequence).

    q: [S, H, hd]; k_pages/v_pages: [N, block_size, KV, hd] physical pool;
    block_tables: [S, nb]; lengths: [S] valid positions -> [S, H, hd].
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return paged_attention_fwd(
        q, k_pages, v_pages, block_tables, lengths, interpret=interpret
    )
