"""Pallas TPU flash-attention (forward): online softmax over KV blocks.

Grid: (B*KV, rep, Sq/bq) — one program per (batch x kv-head, q-head-in-group,
q block).  The KV axis is walked *inside* the kernel body with
``jax.lax.fori_loop`` over VMEM-resident blocks delivered by the BlockSpec
index_map, so the running (m, l, acc) state stays in registers/VMEM.

Block shapes are MXU-aligned: bq x bk scores with hd in {64, 80, 128, 256};
bq = bk = 128 default (8x128 lanes x 16 MXU passes).  Causal + sliding-window
masks are positional, matching ``ref.py`` / ``models.attention`` semantics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(
    q_ref,  # [bq, hd]
    k_ref,  # [Skv, hd]  (full kv stripe for this (b, kv-head))
    v_ref,  # [Skv, hd]
    o_ref,  # [bq, hd]
    *,
    bk: int,
    causal: bool,
    window,
    q_offset: int,
    skv: int,
):
    bq, hd = q_ref.shape
    qi = pl.program_id(2)
    q_pos = q_offset + qi * bq + jax.lax.iota(jnp.int32, bq)

    q = q_ref[...].astype(jnp.float32) * (hd**-0.5)

    nblocks = pl.cdiv(skv, bk)

    def body(ki, carry):
        m_run, l_run, acc = carry
        k_blk = k_ref[pl.ds(ki * bk, bk), :].astype(jnp.float32)
        v_blk = v_ref[pl.ds(ki * bk, bk), :].astype(jnp.float32)
        k_pos = ki * bk + jax.lax.iota(jnp.int32, bk)
        s = jax.lax.dot_general(
            q, k_blk, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [bq, bk]
        mask = k_pos[None, :] < skv
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_run, s.max(axis=1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_run * alpha + p.sum(axis=1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, hd), jnp.float32)
    # causal: blocks strictly above the diagonal contribute nothing — skip.
    if causal:
        last = ((q_offset + (qi + 1) * bq - 1) // bk) + 1
        nblk = jnp.minimum(nblocks, last)
    else:
        nblk = nblocks
    m_f, l_f, acc = jax.lax.fori_loop(0, nblk, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l_f, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
):
    """q: [B,Sq,H,hd]; k,v: [B,Skv,KV,hd] -> [B,Sq,H,hd]."""
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    rep = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Skv)

    # pad Sq to bq multiple; kv stripe padded to bk multiple
    sq_pad = (-Sq) % bq
    skv_pad = (-Skv) % bk
    if sq_pad:
        q = jnp.pad(q, ((0, 0), (0, sq_pad), (0, 0), (0, 0)))
    if skv_pad:
        k = jnp.pad(k, ((0, 0), (0, skv_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv_pad), (0, 0), (0, 0)))
    Sq_p, Skv_p = Sq + sq_pad, Skv + skv_pad

    # layout: [B*KV, rep, Sq_p, hd] for q; [B*KV, Skv_p, hd] for kv
    qx = q.reshape(B, Sq_p, KV, rep, hd).transpose(0, 2, 3, 1, 4).reshape(
        B * KV, rep, Sq_p, hd
    )
    kx = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv_p, hd)
    vx = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv_p, hd)

    grid = (B * KV, rep, Sq_p // bq)
    kernel = functools.partial(
        _fa_kernel, bk=bk, causal=causal, window=window, q_offset=q_offset, skv=Skv
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, bq, hd), lambda b, r, i: (b, r, i, 0)),
            pl.BlockSpec((None, Skv_p, hd), lambda b, r, i: (b, 0, 0)),
            pl.BlockSpec((None, Skv_p, hd), lambda b, r, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, bq, hd), lambda b, r, i: (b, r, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, rep, Sq_p, hd), q.dtype),
        interpret=interpret,
    )(qx, kx, vx)

    out = out.reshape(B, KV, rep, Sq_p, hd).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, Sq_p, H, hd)[:, :Sq]
