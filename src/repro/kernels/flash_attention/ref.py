"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=None, q_offset=0):
    """q: [B,Sq,H,hd]; k,v: [B,Skv,KV,hd] -> [B,Sq,H,hd].  fp32 softmax."""
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    rep = H // KV
    qg = (q.astype(jnp.float32) * hd**-0.5).reshape(B, Sq, KV, rep, hd)
    s = jnp.einsum("bqgrh,bkgh->bgrqk", qg, k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    m = jnp.ones((Sq, Skv), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrqk,bkgh->bqgrh", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths):
    """Oracle for the paged decode kernel: gather blocks into contiguous
    views, then masked fp32 softmax attention.

    q: [S, H, hd]; k_pages/v_pages: [N, bs, KV, hd]; block_tables: [S, nb];
    lengths: [S] -> [S, H, hd].
    """
    S, H, hd = q.shape
    _, bs, KV, _ = k_pages.shape
    rep = H // KV
    k = jnp.take(k_pages, block_tables, axis=0)  # [S, nb, bs, KV, hd]
    v = jnp.take(v_pages, block_tables, axis=0)
    W = k.shape[1] * bs
    k = k.reshape(S, W, KV, hd)
    v = v.reshape(S, W, KV, hd)
    qg = (q.astype(jnp.float32) * hd**-0.5).reshape(S, KV, rep, hd)
    s = jnp.einsum("sgrh,skgh->sgrk", qg, k.astype(jnp.float32))
    mask = jnp.arange(W)[None, :] < lengths[:, None]  # [S, W]
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("sgrk,skgh->sgrh", p, v.astype(jnp.float32))
    return out.reshape(S, H, hd).astype(q.dtype)
