"""Pallas TPU selective scan (mamba1): chunked recurrence with the carry
state held in VMEM scratch across sequential grid steps.

Grid: (B, D/bd, S/chunk) with dimension_semantics ("parallel", "parallel",
"arbitrary") — the S axis is the minor-most grid dim, iterated sequentially
per (batch, channel-block), so ``h_scratch`` carries h across chunks: the
HBM->VMEM stream is one chunk of (decay, Bx, C) at a time (the TPU analogue
of the CUDA kernel's register-resident scan; see DESIGN.md S7).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across JAX versions
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _scan_kernel(decay_ref, bx_ref, c_ref, y_ref, h_scratch, *, chunk: int):
    # decay_ref/bx_ref: [chunk, bd, N]; c_ref: [chunk, N]; y_ref: [chunk, bd]
    i_s = pl.program_id(2)

    @pl.when(i_s == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    def body(t, h):
        h = decay_ref[t].astype(jnp.float32) * h + bx_ref[t].astype(jnp.float32)
        y_ref[t, :] = jnp.sum(h * c_ref[t].astype(jnp.float32)[None, :], axis=-1).astype(
            y_ref.dtype
        )
        return h

    h = jax.lax.fori_loop(0, chunk, body, h_scratch[...])
    h_scratch[...] = h


def selective_scan_fwd(
    decay, bx, cs, *, bd: int = 512, chunk: int = 64, interpret: bool = False
):
    """decay, bx: [B,S,D,N]; cs: [B,S,N] -> y [B,S,D] fp32."""
    B, S, D, N = decay.shape
    bd = min(bd, D)
    chunk = min(chunk, S)
    assert D % bd == 0, (D, bd)
    s_pad = (-S) % chunk
    if s_pad:
        decay = jnp.pad(decay, ((0, 0), (0, s_pad), (0, 0), (0, 0)), constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
        cs = jnp.pad(cs, ((0, 0), (0, s_pad), (0, 0)))
    S_p = S + s_pad

    grid = (B, D // bd, S_p // chunk)
    out = pl.pallas_call(
        functools.partial(_scan_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, bd, N), lambda b, d, s: (b, s, d, 0)),
            pl.BlockSpec((None, chunk, bd, N), lambda b, d, s: (b, s, d, 0)),
            pl.BlockSpec((None, chunk, N), lambda b, d, s: (b, s, 0)),
        ],
        out_specs=pl.BlockSpec((None, chunk, bd), lambda b, d, s: (b, s, d)),
        out_shape=jax.ShapeDtypeStruct((B, S_p, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(decay, bx, cs)
    return out[:, :S]
