"""jit'd wrapper for the selective-scan kernel (TPU: compiled; CPU: interpret)."""

from __future__ import annotations

import functools

import jax

from repro.kernels.selective_scan.kernel import selective_scan_fwd


@functools.partial(jax.jit, static_argnames=("bd", "chunk", "interpret"))
def selective_scan(decay, bx, cs, *, bd=512, chunk=64, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return selective_scan_fwd(decay, bx, cs, bd=bd, chunk=chunk, interpret=interpret)
