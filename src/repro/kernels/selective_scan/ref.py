"""Pure-jnp oracle for the selective-scan kernel (mamba1 recurrence)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(decay, bx, cs, h0=None):
    """h_t = decay_t * h_{t-1} + bx_t ;  y_t = sum_s h_t[., s] * cs_t[s].

    decay, bx: [B, S, D, N]; cs: [B, S, N]; h0: [B, D, N] (zeros default).
    Returns (y [B, S, D] fp32, h_final [B, D, N])."""
    B, S, D, N = decay.shape
    if h0 is None:
        h0 = jnp.zeros((B, D, N), jnp.float32)

    def step(h, inp):
        d_t, b_t, c_t = inp
        h = d_t * h + b_t
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (
        decay.astype(jnp.float32).transpose(1, 0, 2, 3),
        bx.astype(jnp.float32).transpose(1, 0, 2, 3),
        cs.astype(jnp.float32).transpose(1, 0, 2),
    )
    h_f, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2), h_f
