"""jit'd wrapper for the fused rmsnorm kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm.kernel import rmsnorm_fwd


@functools.partial(jax.jit, static_argnames=("eps", "bt", "interpret"))
def rmsnorm(x, w, *, eps=1e-5, bt=256, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return rmsnorm_fwd(x, w, eps=eps, bt=bt, interpret=interpret)
