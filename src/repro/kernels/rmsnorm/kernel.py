"""Pallas TPU fused RMSNorm (forward): one HBM read, one write per row block.

Grid: (T / bt,); block [bt, d] resident in VMEM with the row statistics
computed in fp32 on the VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * (1.0 + w_ref[...].astype(jnp.float32))).astype(
        o_ref.dtype
    )


def rmsnorm_fwd(x, w, *, eps: float = 1e-5, bt: int = 256, interpret: bool = False):
    """x: [T, d]; w: [d]."""
    T, d = x.shape
    bt = min(bt, T)
    pad = (-T) % bt
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rms_kernel, eps=eps),
        grid=((T + pad) // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T + pad, d), x.dtype),
        interpret=interpret,
    )(x, w)
    return out[:T]
