"""Pure-jnp oracle for the mrd_combine kernel (fused dequant-accumulate)."""

from __future__ import annotations

import jax.numpy as jnp


def mrd_combine_ref(x, q, scales, block: int = 256):
    """x: [n] float; q: [n] int8; scales: [n/block] f32.
    Returns x + dequant(q, scales) in x.dtype (f32 accumulate)."""
    n = x.shape[0]
    deq = (q.astype(jnp.float32).reshape(n // block, block) * scales[:, None]).reshape(n)
    return (x.astype(jnp.float32) + deq).astype(x.dtype)
