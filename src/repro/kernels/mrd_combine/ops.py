"""jit'd wrapper for mrd_combine (TPU: compiled; CPU: interpret)."""

from __future__ import annotations

import functools

import jax

from repro.kernels.mrd_combine.kernel import mrd_combine_fwd


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def mrd_combine(x, q, scales, *, bn=32768, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return mrd_combine_fwd(x, q, scales, bn=bn, interpret=interpret)
