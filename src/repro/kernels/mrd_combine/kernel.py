"""Pallas TPU fused dequant-accumulate for compressed MRD reduce-scatter.

The receive path of the compressed butterfly does, per stage:
``keep += dequantize(recv_q, recv_scales)``.  Unfused this is int8->f32 cast,
reshape-scale, add — three HBM round-trips over the gradient shard.  The
kernel streams (x, q, scales) blocks through VMEM once.

Grid: (n / bn,), bn a multiple of the 256-element quantization block so the
scale vector tiles align (bn/256 scales per program).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QBLOCK = 256


def _combine_kernel(x_ref, q_ref, s_ref, o_ref):
    # x_ref/q_ref: [bn]; s_ref: [bn/256]; o_ref: [bn]
    bn = x_ref.shape[0]
    q = q_ref[...].astype(jnp.float32).reshape(bn // QBLOCK, QBLOCK)
    deq = q * s_ref[...][:, None]
    o_ref[...] = (x_ref[...].astype(jnp.float32) + deq.reshape(bn)).astype(o_ref.dtype)


def mrd_combine_fwd(x, q, scales, *, bn: int = 32768, interpret: bool = False):
    """x: [n]; q: [n] int8; scales: [n/256] f32 -> x + dequant(q)."""
    n = x.shape[0]
    assert n % QBLOCK == 0, n
    bn = min(bn, n)
    assert bn % QBLOCK == 0 and n % bn == 0, (n, bn)
    return pl.pallas_call(
        _combine_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn // QBLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(x, q, scales)
