"""--arch hubert-xlarge: full config (dry-run) + reduced smoke config."""

from repro.configs.registry import get_config, get_smoke_config

ARCH = "hubert-xlarge"
CONFIG = get_config(ARCH)
SMOKE = get_smoke_config(ARCH)
