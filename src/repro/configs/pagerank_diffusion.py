"""PageRank-style example config for the ``d_iteration`` solver
(``repro.asynchrony.SOLVERS['d_iteration']``) — the D-iteration family's
damped-diffusion fixed point (arXiv:1301.3007, arXiv:1202.3108) run as an
asynchronous workload next to the paper's weighted-Jacobi experiment.

``f(x) = damping * P x + (1 - damping) * v`` with P column-stochastic;
rho(|T|) = damping, so any damping < 1 is asynchronously convergent and the
exact detector certifies the diffusion vector itself.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class PageRankDiffusion:
    n: int = 256  # nodes (divisible by every p in p_sweep)
    damping: float = 0.85  # the classic PageRank damping
    out_degree: int = 4  # random successors per node (+ a ring edge)
    seed: int = 0
    eps: float = 1e-8  # mass scale is 1/n; certify well below it
    p_sweep: tuple = (2, 4, 8, 16)
    max_delay: int = 3
    activity: float = 0.7

    def solver_kwargs(self) -> dict:
        return dict(
            n=self.n, damping=self.damping,
            out_degree=self.out_degree, seed=self.seed,
        )


CONFIG = PageRankDiffusion()
