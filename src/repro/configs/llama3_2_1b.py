"""--arch llama3.2-1b: full config (dry-run) + reduced smoke config."""

from repro.configs.registry import get_config, get_smoke_config

ARCH = "llama3.2-1b"
CONFIG = get_config(ARCH)
SMOKE = get_smoke_config(ARCH)
