"""--arch llama4-scout-17b-a16e: full config (dry-run) + reduced smoke config."""

from repro.configs.registry import get_config, get_smoke_config

ARCH = "llama4-scout-17b-a16e"
CONFIG = get_config(ARCH)
SMOKE = get_smoke_config(ARCH)
