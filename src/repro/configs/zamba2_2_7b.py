"""--arch zamba2-2.7b: full config (dry-run) + reduced smoke config."""

from repro.configs.registry import get_config, get_smoke_config

ARCH = "zamba2-2.7b"
CONFIG = get_config(ARCH)
SMOKE = get_smoke_config(ARCH)
