"""Architecture registry: full configs (dry-run only) + reduced smoke configs.

Every assigned arch is selectable via ``--arch <id>`` in the launchers.
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

# --- full configs (public-literature numbers; see assignment brackets) ---

_FULL: dict[str, ModelConfig] = {}
_SMOKE: dict[str, ModelConfig] = {}


def _register(full: ModelConfig, smoke: ModelConfig):
    _FULL[full.name] = full
    _SMOKE[full.name] = smoke


_register(
    # [arXiv:2401.04088] 8 experts top-2, SWA 4096
    ModelConfig(
        name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab=32000,
        n_experts=8, top_k=2, sliding_window=4096, rope_theta=1e6,
    ),
    ModelConfig(
        name="mixtral-8x7b", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
        n_experts=4, top_k=2, sliding_window=32, param_dtype="float32",
        compute_dtype="float32",
    ),
)

_register(
    # [hf:meta-llama/Llama-4-Scout-17B-16E] MoE top-1, early fusion
    ModelConfig(
        name="llama4-scout-17b-a16e", family="moe", n_layers=48, d_model=5120,
        n_heads=40, n_kv_heads=8, d_ff=8192, vocab=202048,
        n_experts=16, top_k=1, rope_theta=5e5,
    ),
    ModelConfig(
        name="llama4-scout-17b-a16e", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=96, vocab=256,
        n_experts=4, top_k=1, param_dtype="float32", compute_dtype="float32",
    ),
)

_register(
    # [hf:meta-llama/Llama-3.2-1B]
    ModelConfig(
        name="llama3.2-1b", family="dense", n_layers=16, d_model=2048,
        n_heads=32, n_kv_heads=8, d_ff=8192, vocab=128256,
        head_dim=64, rope_theta=5e5, tie_embeddings=True,
    ),
    ModelConfig(
        name="llama3.2-1b", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32",
    ),
)

_register(
    # [arXiv:2404.06395] llama-like; WSD schedule handled by the optimizer
    ModelConfig(
        name="minicpm-2b", family="dense", n_layers=40, d_model=2304,
        n_heads=36, n_kv_heads=36, d_ff=5760, vocab=122880,  # 122753 padded to /256 for TP
        head_dim=64, tie_embeddings=True,
    ),
    ModelConfig(
        name="minicpm-2b", family="dense", n_layers=2, d_model=72,
        n_heads=6, n_kv_heads=6, d_ff=144, vocab=256, tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32",
    ),
)

_register(
    # [hf:google/gemma-3-12b] 5:1 local:global, local window 1024
    ModelConfig(
        name="gemma3-12b", family="dense", n_layers=48, d_model=3840,
        n_heads=16, n_kv_heads=8, d_ff=15360, vocab=262144,
        head_dim=256, pattern_local=5, pattern_global=1, local_window=1024,
        rope_theta=1e4, rope_theta_global=1e6, tie_embeddings=True,
    ),
    ModelConfig(
        name="gemma3-12b", family="dense", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
        pattern_local=2, pattern_global=1, local_window=16,
        rope_theta=1e4, rope_theta_global=1e6, tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32",
    ),
)

_register(
    # [hf:Qwen/Qwen2.5-32B] GQA + QKV bias
    ModelConfig(
        name="qwen2.5-32b", family="dense", n_layers=64, d_model=5120,
        n_heads=40, n_kv_heads=8, d_ff=27648, vocab=152064,
        qkv_bias=True, rope_theta=1e6,
    ),
    ModelConfig(
        name="qwen2.5-32b", family="dense", n_layers=2, d_model=80,
        n_heads=5, n_kv_heads=1, d_ff=192, vocab=256, qkv_bias=True,
        param_dtype="float32", compute_dtype="float32",
    ),
)

_register(
    # [arXiv:2410.05355] mamba1, attention-free
    ModelConfig(
        name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab=65024,
        head_dim=1, ssm_state=16, ssm_version=1, tie_embeddings=True,
    ),
    ModelConfig(
        name="falcon-mamba-7b", family="ssm", n_layers=2, d_model=64,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab=256,
        head_dim=1, ssm_state=8, ssm_version=1, tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32",
    ),
)

_register(
    # [arXiv:2411.15242] mamba2 + shared attention blocks
    ModelConfig(
        name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
        n_heads=32, n_kv_heads=32, d_ff=10240, vocab=32000,
        head_dim=80, ssm_state=64, ssm_version=2, ssm_headdim=64, attn_every=6,
    ),
    ModelConfig(
        name="zamba2-2.7b", family="hybrid", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
        head_dim=16, ssm_state=16, ssm_version=2, ssm_headdim=16, attn_every=2,
        param_dtype="float32", compute_dtype="float32",
    ),
)

_register(
    # [arXiv:2404.16821] InternViT frontend (stub) + InternLM2-ish backbone
    ModelConfig(
        name="internvl2-1b", family="vlm", n_layers=24, d_model=896,
        n_heads=14, n_kv_heads=2, d_ff=4864, vocab=151680,  # 151655 padded to /16 for TP
        head_dim=64, frontend="vision", n_frontend_tokens=256, rope_theta=1e6,
    ),
    ModelConfig(
        name="internvl2-1b", family="vlm", n_layers=2, d_model=56,
        n_heads=7, n_kv_heads=1, d_ff=112, vocab=256,
        head_dim=8, frontend="vision", n_frontend_tokens=8,
        param_dtype="float32", compute_dtype="float32",
    ),
)

_register(
    # [arXiv:2106.07447] encoder-only; frame embeddings from a stub frontend
    ModelConfig(
        name="hubert-xlarge", family="audio", n_layers=48, d_model=1280,
        n_heads=16, n_kv_heads=16, d_ff=5120, vocab=512,  # 504 padded to /16 for TP
        head_dim=80, causal=False, frontend="audio", act="gelu",
    ),
    ModelConfig(
        name="hubert-xlarge", family="audio", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=64,
        causal=False, frontend="audio", act="gelu",
        param_dtype="float32", compute_dtype="float32",
    ),
)


def get_config(name: str) -> ModelConfig:
    return _FULL[name]


def get_smoke_config(name: str) -> ModelConfig:
    return _SMOKE[name]


def list_archs() -> list[str]:
    return sorted(_FULL)


def override(cfg: ModelConfig, **kw) -> ModelConfig:
    return dataclasses.replace(cfg, **kw)
