"""--arch gemma3-12b: full config (dry-run) + reduced smoke config."""

from repro.configs.registry import get_config, get_smoke_config

ARCH = "gemma3-12b"
CONFIG = get_config(ARCH)
SMOKE = get_smoke_config(ARCH)
