"""Assigned input-shape cells (same four for every LM-family arch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of ``seq_len``), not ``train_step``.  Skip rules (DESIGN.md S6):
``long_500k`` only for sub-quadratic archs; encoder-only archs have no decode.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

# archs whose attention is sub-quadratic in cache size (SSM / hybrid / SWA /
# mostly-local): eligible for long_500k
LONG_CONTEXT_OK = {"falcon-mamba-7b", "zamba2-2.7b", "mixtral-8x7b", "gemma3-12b"}

ENCODER_ONLY = {"hubert-xlarge"}


def cells_for(arch: str) -> list[str]:
    out = ["train_4k", "prefill_32k"]
    if arch not in ENCODER_ONLY:
        out.append("decode_32k")
        if arch in LONG_CONTEXT_OK:
            out.append("long_500k")
    return out


def skip_reason(arch: str, shape: str) -> str | None:
    if shape in ("decode_32k", "long_500k") and arch in ENCODER_ONLY:
        return "encoder-only: no decode step"
    if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
        return "pure full attention: 500k decode cache infeasible (DESIGN.md S6)"
    return None
