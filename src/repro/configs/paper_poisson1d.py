"""The paper's own experiment config (S4): 1-D two-point BVP, n = 10000,
b ~ U[-10, 10], asynchronous relaxation, FDR-Infiniband-like 'concentrated'
environment."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperExperiment:
    n: int = 10000
    rhs_low: float = -10.0
    rhs_high: float = 10.0
    eps: float = 1e-5
    # 'concentrated' environment: tiny delays, near-full activity
    max_delay: int = 1
    activity: float = 0.95
    p_sweep: tuple = (2, 3, 4, 5, 6, 7, 8, 12, 16)
    # diagonally-dominant shift for protocol benchmarks (0.0 = paper's exact
    # operator; convergence then takes O(n^2) iterations — see bench notes)
    shift: float = 0.5


CONFIG = PaperExperiment()
