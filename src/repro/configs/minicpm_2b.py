"""--arch minicpm-2b: full config (dry-run) + reduced smoke config."""

from repro.configs.registry import get_config, get_smoke_config

ARCH = "minicpm-2b"
CONFIG = get_config(ARCH)
SMOKE = get_smoke_config(ARCH)
