"""--arch mixtral-8x7b: full config (dry-run) + reduced smoke config."""

from repro.configs.registry import get_config, get_smoke_config

ARCH = "mixtral-8x7b"
CONFIG = get_config(ARCH)
SMOKE = get_smoke_config(ARCH)
