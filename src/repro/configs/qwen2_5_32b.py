"""--arch qwen2.5-32b: full config (dry-run) + reduced smoke config."""

from repro.configs.registry import get_config, get_smoke_config

ARCH = "qwen2.5-32b"
CONFIG = get_config(ARCH)
SMOKE = get_smoke_config(ARCH)
