"""Fault tolerance: heartbeat-based failure detection, straggler policy, and
elastic shrink-on-failure restart.

The paper's non-power-of-two support is the load-bearing piece here: losing
one worker from a 16-wide DP group leaves 15 — the MRD backward/forward
shifts keep every collective correct without waiting for a replacement or
regrouping to a power of two.  ``shrink_mesh`` + checkpoint reshard-restore
implement that path; ``test_fault_tolerance.py`` drives it end-to-end
(train -> kill -> shrink 4->3 -> restore -> keep training).

Straggler mitigation is in-protocol (per the paper): the ConvergenceMonitor's
staged reduction never blocks on a slow worker, and the bounded-staleness
engine keeps iterating while messages are in flight.  At the launcher level,
`StragglerPolicy` decides when a slow-but-alive worker should be treated as
failed (heartbeat percentile rule).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import numpy as np


@dataclasses.dataclass
class HeartbeatConfig:
    timeout_s: float = 60.0  # hard failure
    straggler_factor: float = 3.0  # x median step time => straggler
    evict_after_straggler_steps: int = 5


class FailureDetector:
    """Tracks per-worker heartbeats (host side).  Deterministic: the clock is
    injected, so tests drive it explicitly."""

    def __init__(self, workers: list[int], cfg: HeartbeatConfig,
                 now: float = 0.0):
        self.cfg = cfg
        self.last: dict[int, float] = {w: now for w in workers}
        self.step_times: dict[int, list[float]] = {w: [] for w in workers}
        self.straggler_strikes: dict[int, int] = {w: 0 for w in workers}

    def heartbeat(self, worker: int, now: float, step_time: Optional[float] = None):
        self.last[worker] = now
        if step_time is not None:
            self.step_times[worker].append(step_time)
            self.step_times[worker] = self.step_times[worker][-32:]

    def mark_dead(self, worker: int):
        """Fail-stop notification: the worker is known dead *now* (crash
        report, exit code), not merely silent — ``failed()`` reports it
        immediately instead of after ``timeout_s``."""
        if worker in self.last:
            self.last[worker] = float("-inf")

    def add_worker(self, worker: int, now: float):
        """Start tracking a joining worker (grow path)."""
        self.last.setdefault(worker, now)
        self.step_times.setdefault(worker, [])
        self.straggler_strikes.setdefault(worker, 0)

    def remove_worker(self, worker: int):
        """Stop tracking an evicted worker (shrink path)."""
        self.last.pop(worker, None)
        self.step_times.pop(worker, None)
        self.straggler_strikes.pop(worker, None)

    def failed(self, now: float) -> list[int]:
        return [w for w, t in self.last.items() if now - t > self.cfg.timeout_s]

    def stragglers(self) -> list[int]:
        med = np.median([np.mean(v) for v in self.step_times.values() if v] or [0.0])
        out = []
        for w, v in self.step_times.items():
            if v and med > 0 and np.mean(v[-5:]) > self.cfg.straggler_factor * med:
                self.straggler_strikes[w] += 1
                if self.straggler_strikes[w] >= self.cfg.evict_after_straggler_steps:
                    out.append(w)
            else:
                self.straggler_strikes[w] = 0
        return out


def shrink_mesh(mesh, failed_device_ids: set[int], dp_axis: str = "data"):
    """Rebuild the mesh without failed devices by shrinking the DP axis.

    Keeps the TP ("model") extent intact (a TP group with a dead member is
    unusable) and drops whole DP slices containing failed devices.  The
    resulting DP extent may be non-power-of-two — handled natively by the MRD
    collectives.  Returns (new_mesh, kept_dp_indices)."""
    axis_names = list(mesh.axis_names)
    dev_grid = np.asarray(mesh.devices)
    dp_idx = axis_names.index(dp_axis)
    # move dp axis to front
    grid = np.moveaxis(dev_grid, dp_idx, 0)
    keep = []
    for i in range(grid.shape[0]):
        ids = {d.id for d in np.ravel(grid[i])}
        if not (ids & failed_device_ids):
            keep.append(i)
    if not keep:
        raise RuntimeError("no healthy DP slices left")
    new_grid = np.moveaxis(grid[keep], 0, dp_idx)
    new_mesh = jax.sharding.Mesh(new_grid, axis_names)
    return new_mesh, keep


def grow_mesh(mesh, joining_device_ids, dp_axis: str = "data"):
    """Rebuild the mesh with joining devices appended along the DP axis.

    The inverse of :func:`shrink_mesh`: surviving DP slices keep their
    positions (ranks 0..dp_old-1), joiners form new trailing slices.  The
    joining device count must be a multiple of the per-slice device count
    (the product of the non-DP extents) so each new slice is complete.
    Any resulting extent — including non-power-of-two — is handled
    natively by the MRD collectives.  Returns (new_mesh, n_new_slices).
    """
    axis_names = list(mesh.axis_names)
    dev_grid = np.asarray(mesh.devices)
    dp_idx = axis_names.index(dp_axis)
    grid = np.moveaxis(dev_grid, dp_idx, 0)
    slice_shape = grid.shape[1:]
    per_slice = int(np.prod(slice_shape, dtype=np.int64)) if slice_shape else 1
    by_id = {d.id: d for d in jax.devices()}
    present = {d.id for d in np.ravel(dev_grid)}
    joiners = []
    for did in joining_device_ids:
        if did in present:
            raise ValueError(f"device {did} is already in the mesh")
        if did not in by_id:
            raise ValueError(f"no such device id {did}")
        joiners.append(by_id[did])
    if not joiners or len(joiners) % per_slice:
        raise ValueError(
            f"need a positive multiple of {per_slice} joining devices to "
            f"form whole DP slices, got {len(joiners)}"
        )
    new_slices = np.asarray(joiners, dtype=object).reshape((-1,) + slice_shape)
    new_grid = np.moveaxis(
        np.concatenate([grid, new_slices], axis=0), 0, dp_idx
    )
    new_mesh = jax.sharding.Mesh(new_grid, axis_names)
    return new_mesh, new_slices.shape[0]


class ReplicaSet:
    """Ordered live replica ids for a *simulated* DP extent (DESIGN.md S15).

    The serving engine's termination agreement runs over stacked replicas
    rather than mesh devices, so resizes need keep maps but no device grid:
    this is the 1-D analogue of ``flat_keep_for_shrink`` /
    ``flat_keep_for_grow``.  ``keep[i]`` = old rank now at new rank ``i``
    (None = joiner) — the exact contract the protocol ``migrate`` hooks and
    ``ServeEngine.resize`` consume."""

    def __init__(self, ids):
        self.ids = list(ids)
        if len(set(self.ids)) != len(self.ids):
            raise ValueError(f"duplicate replica ids: {self.ids}")

    @property
    def dp(self) -> int:
        return len(self.ids)

    def remove(self, dead) -> tuple:
        """Drop ``dead`` ids; survivors keep their order.  Returns
        ``(new_ids, keep)``."""
        dead = set(dead)
        keep = tuple(i for i, r in enumerate(self.ids) if r not in dead)
        if not keep:
            raise RuntimeError("no live replicas left")
        self.ids = [self.ids[i] for i in keep]
        return tuple(self.ids), keep

    def add(self, joiners) -> tuple:
        """Append ``joiners`` as new trailing ranks.  Returns
        ``(new_ids, keep)`` with None marking each joiner."""
        joiners = [j for j in joiners if j not in self.ids]
        keep = tuple(range(len(self.ids))) + (None,) * len(joiners)
        self.ids = self.ids + joiners
        return tuple(self.ids), keep


class StepClock:
    """Deterministic virtual clock: advances ``dt`` seconds per train step.

    The chaos harness injects this into the elastic controller so failure
    detection (heartbeat timeouts, straggler percentiles) is a pure
    function of the event script — no wall-clock nondeterminism."""

    def __init__(self, dt: float = 1.0, t0: float = 0.0):
        self.dt = dt
        self.t = t0

    def now(self) -> float:
        return self.t

    def advance(self) -> float:
        self.t += self.dt
        return self.t


@dataclasses.dataclass
class RestartReport:
    old_dp: int
    new_dp: int
    restored_step: int
    elapsed_s: float


def recover(
    checkpointer,
    template_state,
    new_shardings,
    *,
    old_dp: int,
    new_dp: int,
) -> tuple[object, RestartReport]:
    """Restore the latest checkpoint onto the shrunken mesh's shardings."""
    t0 = time.time()
    step = checkpointer.latest_step()
    if step is None:
        raise RuntimeError("no checkpoint to recover from")
    state = checkpointer.restore(step, template_state, new_shardings)
    return state, RestartReport(old_dp, new_dp, step, time.time() - t0)
