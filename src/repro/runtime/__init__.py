"""Runtime layer: fault tolerance + the elastic resize runtime (DESIGN.md
S12).  ``ELASTIC_POLICIES`` mirrors the repo's other registries — resolve
by name, extend with ``@register_policy``."""

from repro.runtime.elastic import (  # noqa: F401
    ElasticConfig,
    ElasticServeController,
    ElasticTrainer,
    ResizeEvent,
    mrd_broadcast,
)
from repro.runtime.fault_tolerance import (  # noqa: F401
    FailureDetector,
    HeartbeatConfig,
    ReplicaSet,
    StepClock,
    grow_mesh,
    shrink_mesh,
)
from repro.runtime.policies import (  # noqa: F401
    ELASTIC_POLICIES,
    ResizeDecision,
    available,
    clamp_min_extent,
    get_policy,
    register_policy,
)
