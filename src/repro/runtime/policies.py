"""Elastic resize policies (``ELASTIC_POLICIES``, DESIGN.md S12).

A policy turns the host-side health picture — heartbeat failures,
straggler percentiles, pending joins — into one :class:`ResizeDecision`
per step.  The :class:`repro.runtime.elastic.ElasticTrainer` executes the
decision: ``shrink``/``grow`` rebuild the mesh and migrate state in place
(no checkpoint round-trip when the survivors hold the data), ``abort``
raises, ``none`` trains.

Mirroring the collectives and asynchrony subsystems, policies live in a
registry keyed by name; adding one is a single ``@register_policy`` class
here (and nothing else — the trainer and the ``--elastic-policy`` CLI
flag resolve by name).

- ``static``: never resize; any confirmed failure aborts the run.  The
  baseline (and what non-elastic launchers implicitly do).
- ``shrink_on_failure``: drop the DP slices of failed workers and keep
  training at the (possibly non-power-of-two) smaller extent — the
  paper's modified recursive doubling makes every collective correct at
  any p, which is what makes this cheap.
- ``grow_on_join``: ``shrink_on_failure`` plus admission of pending
  joiners: new workers are appended as DP slices and receive the params
  via an MRD-plan broadcast at the new extent.
- ``drain_straggler``: ``shrink_on_failure`` plus eviction of workers
  whose step times exceed the heartbeat straggler rule — a slow-but-alive
  worker is drained instead of throttling the whole DP group.
- ``sla_autoscale``: ``shrink_on_failure`` plus load-driven grow/shrink
  for serving (DESIGN.md S17): the controller hands the policy a
  :class:`LoadSnapshot` (queue depth, TTFT-SLA pressure, free capacity)
  and the policy trades replica count against SLA risk with scale-up
  hysteresis, a post-resize cooldown, and min/max-extent clamps.
  Stateful — resolve via :meth:`ElasticPolicy.spawn` (as
  ``ElasticServeController`` does) so concurrent deployments never share
  hysteresis counters through the registry singleton.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

from repro.runtime.fault_tolerance import FailureDetector


@dataclasses.dataclass(frozen=True)
class ResizeDecision:
    """What the policy wants done before the next train step."""

    action: str = "none"  # 'none' | 'shrink' | 'grow' | 'abort'
    remove: frozenset = frozenset()  # device ids to drop (shrink/abort)
    admit: tuple = ()  # device ids to add (grow)
    reason: str = ""


@dataclasses.dataclass(frozen=True)
class LoadSnapshot:
    """Serving-load picture handed to autoscaling policies each step.

    Built by ``ElasticServeController`` from the engine: tick-domain and
    deterministic, so autoscaling decisions replay bit-identically for a
    given trace (what the bench gates rely on).
    """

    tick: int  # engine tick the snapshot was taken at
    queue_depth: int = 0  # pending requests (arrived, not admitted)
    sla_near: int = 0  # queued SLA requests past half their deadline
    sla_overdue: int = 0  # queued SLA requests past their deadline
    free_slots: int = 0  # usable slots with no active request
    usable_slots: int = 0  # min(slots, dp * slots_per_replica)
    dp: int = 1  # live replica extent


ELASTIC_POLICIES: Dict[str, "ElasticPolicy"] = {}


def register_policy(name: str):
    def deco(cls):
        ELASTIC_POLICIES[name] = cls()
        return cls

    return deco


def get_policy(name: str) -> "ElasticPolicy":
    if isinstance(name, ElasticPolicy):
        return name
    try:
        return ELASTIC_POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown elastic policy {name!r}; "
            f"registered: {sorted(ELASTIC_POLICIES)}"
        ) from None


def available() -> list[str]:
    return sorted(ELASTIC_POLICIES)


def clamp_min_extent(
    decision: ResizeDecision, live_ids, min_extent: int = 1
) -> ResizeDecision:
    """Serving guard: never shrink below ``min_extent`` replicas.

    A chaos script (or a real cascading failure) may remove every replica;
    training can abort and restore a checkpoint, but a serving pool must
    keep answering — so the lowest-id victims are spared until
    ``min_extent`` survivors remain.  Spared replicas stay in the mesh and
    keep being reported dead by the detector; they are dropped by a later
    decision once joiners restore headroom."""
    if decision.action != "shrink":
        return decision
    survivors = [w for w in live_ids if w not in decision.remove]
    if len(survivors) >= min_extent:
        return decision
    spared = sorted(decision.remove)[: min_extent - len(survivors)]
    remove = frozenset(w for w in decision.remove if w not in spared)
    if not remove:
        return ResizeDecision(
            reason=f"shrink suppressed: min extent {min_extent}"
        )
    return dataclasses.replace(
        decision, remove=remove,
        reason=f"{decision.reason} (clamped to min extent {min_extent})",
    )


class ElasticPolicy:
    """Base: no failures tolerated, no growth."""

    def decide(
        self,
        detector: FailureDetector,
        now: float,
        pending_joins: Sequence[int],
        mesh_device_ids: frozenset,
        load: Optional[LoadSnapshot] = None,
    ) -> ResizeDecision:
        raise NotImplementedError

    def spawn(self) -> "ElasticPolicy":
        """Per-deployment instance.  Stateless policies return themselves
        (the registry singleton is fine to share); stateful ones override
        to return a fresh copy so hysteresis never leaks across users."""
        return self

    def _confirmed_failures(self, detector, now, mesh_device_ids):
        return frozenset(w for w in detector.failed(now) if w in mesh_device_ids)


@register_policy("static")
class StaticPolicy(ElasticPolicy):
    def decide(self, detector, now, pending_joins, mesh_device_ids, load=None):
        failed = self._confirmed_failures(detector, now, mesh_device_ids)
        if failed:
            return ResizeDecision(
                "abort", remove=failed,
                reason=f"static policy: workers {sorted(failed)} failed",
            )
        return ResizeDecision()


@register_policy("shrink_on_failure")
class ShrinkOnFailurePolicy(ElasticPolicy):
    def decide(self, detector, now, pending_joins, mesh_device_ids, load=None):
        failed = self._confirmed_failures(detector, now, mesh_device_ids)
        if failed:
            return ResizeDecision(
                "shrink", remove=failed,
                reason=f"heartbeat failure: {sorted(failed)}",
            )
        return ResizeDecision()


@register_policy("grow_on_join")
class GrowOnJoinPolicy(ShrinkOnFailurePolicy):
    def decide(self, detector, now, pending_joins, mesh_device_ids, load=None):
        d = super().decide(detector, now, pending_joins, mesh_device_ids)
        if d.action != "none":
            return d
        joiners = tuple(w for w in pending_joins if w not in mesh_device_ids)
        if joiners:
            return ResizeDecision(
                "grow", admit=joiners, reason=f"join: {sorted(joiners)}"
            )
        return ResizeDecision()


@register_policy("drain_straggler")
class DrainStragglerPolicy(ShrinkOnFailurePolicy):
    def decide(self, detector, now, pending_joins, mesh_device_ids, load=None):
        d = super().decide(detector, now, pending_joins, mesh_device_ids)
        if d.action != "none":
            return d
        slow = frozenset(
            w for w in detector.stragglers() if w in mesh_device_ids
        )
        if slow:
            return ResizeDecision(
                "shrink", remove=slow, reason=f"straggler drain: {sorted(slow)}"
            )
        return ResizeDecision()


@register_policy("sla_autoscale")
class SlaAutoscalePolicy(ShrinkOnFailurePolicy):
    """SLA-pressure autoscaler for serving deployments (DESIGN.md S17).

    State machine per :meth:`decide` (after the inherited failure shrink,
    which always wins):

    - **pressure** = queued work the current capacity cannot absorb:
      overdue/near-deadline SLA requests, or queue depth beyond the free
      usable slots.  ``up_patience`` consecutive pressured steps outside
      the cooldown window grow by one replica (joiner id ``max(live)+1``
      — the controller admits synthesized ids).
    - **idle** = no queue, no SLA risk, and at least one replica's worth
      of free slots to spare.  ``down_patience`` consecutive idle steps
      shrink by one (the highest live id), never below ``min_extent``.
    - any resize arms ``cooldown`` ticks during which both counters are
      held at zero — scale-up hysteresis, so a single burst tick cannot
      thrash the extent.

    Thresholds are tick-domain integers off the injected clock, so a
    replayed trace autoscales identically every run.
    """

    def __init__(
        self,
        *,
        min_extent: int = 1,
        max_extent: int = 8,
        up_patience: int = 2,
        down_patience: int = 8,
        cooldown: int = 8,
        queue_per_replica: int = 0,
    ):
        if min_extent < 1 or max_extent < min_extent:
            raise ValueError(
                f"need 1 <= min_extent <= max_extent, got "
                f"{min_extent}..{max_extent}"
            )
        self.min_extent = min_extent
        self.max_extent = max_extent
        self.up_patience = up_patience
        self.down_patience = down_patience
        self.cooldown = cooldown
        # extra queue slack tolerated per live replica before it counts as
        # pressure (0 = any queue beyond the free slots is pressure)
        self.queue_per_replica = queue_per_replica
        self._up = 0
        self._down = 0
        self._cool_until = -1

    def spawn(self):
        return SlaAutoscalePolicy(
            min_extent=self.min_extent, max_extent=self.max_extent,
            up_patience=self.up_patience, down_patience=self.down_patience,
            cooldown=self.cooldown, queue_per_replica=self.queue_per_replica,
        )

    def _pressure(self, load: LoadSnapshot) -> bool:
        slack = self.queue_per_replica * load.dp
        return (
            load.sla_overdue > 0
            or load.sla_near > 0
            or load.queue_depth > load.free_slots + slack
        )

    def _idle(self, load: LoadSnapshot) -> bool:
        per_replica = max(1, load.usable_slots // max(1, load.dp))
        return (
            load.queue_depth == 0
            and load.sla_near == 0
            and load.sla_overdue == 0
            and load.free_slots >= per_replica
        )

    def decide(self, detector, now, pending_joins, mesh_device_ids, load=None):
        d = super().decide(detector, now, pending_joins, mesh_device_ids)
        if d.action != "none":
            self._up = self._down = 0
            self._cool_until = now + self.cooldown
            return d
        if load is None:  # not a serving controller: behave as the parent
            return d
        if load.tick < self._cool_until:
            self._up = self._down = 0
            return ResizeDecision(reason="autoscale: cooldown")
        live = sorted(mesh_device_ids)
        if self._pressure(load):
            self._down = 0
            self._up += 1
            if self._up >= self.up_patience and len(live) < self.max_extent:
                self._up = 0
                self._cool_until = load.tick + self.cooldown
                joiner = (max(live) + 1) if live else 0
                return ResizeDecision(
                    "grow", admit=(joiner,),
                    reason=(
                        f"autoscale up: queue={load.queue_depth} "
                        f"near={load.sla_near} overdue={load.sla_overdue} "
                        f"at dp={load.dp}"
                    ),
                )
        elif self._idle(load):
            self._up = 0
            self._down += 1
            if self._down >= self.down_patience and len(live) > self.min_extent:
                self._down = 0
                self._cool_until = load.tick + self.cooldown
                return ResizeDecision(
                    "shrink", remove=frozenset({max(live)}),
                    reason=f"autoscale down: idle at dp={load.dp}",
                )
        else:
            self._up = self._down = 0
        return ResizeDecision()
