"""Elastic resize policies (``ELASTIC_POLICIES``, DESIGN.md S12).

A policy turns the host-side health picture — heartbeat failures,
straggler percentiles, pending joins — into one :class:`ResizeDecision`
per step.  The :class:`repro.runtime.elastic.ElasticTrainer` executes the
decision: ``shrink``/``grow`` rebuild the mesh and migrate state in place
(no checkpoint round-trip when the survivors hold the data), ``abort``
raises, ``none`` trains.

Mirroring the collectives and asynchrony subsystems, policies live in a
registry keyed by name; adding one is a single ``@register_policy`` class
here (and nothing else — the trainer and the ``--elastic-policy`` CLI
flag resolve by name).

- ``static``: never resize; any confirmed failure aborts the run.  The
  baseline (and what non-elastic launchers implicitly do).
- ``shrink_on_failure``: drop the DP slices of failed workers and keep
  training at the (possibly non-power-of-two) smaller extent — the
  paper's modified recursive doubling makes every collective correct at
  any p, which is what makes this cheap.
- ``grow_on_join``: ``shrink_on_failure`` plus admission of pending
  joiners: new workers are appended as DP slices and receive the params
  via an MRD-plan broadcast at the new extent.
- ``drain_straggler``: ``shrink_on_failure`` plus eviction of workers
  whose step times exceed the heartbeat straggler rule — a slow-but-alive
  worker is drained instead of throttling the whole DP group.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

from repro.runtime.fault_tolerance import FailureDetector


@dataclasses.dataclass(frozen=True)
class ResizeDecision:
    """What the policy wants done before the next train step."""

    action: str = "none"  # 'none' | 'shrink' | 'grow' | 'abort'
    remove: frozenset = frozenset()  # device ids to drop (shrink/abort)
    admit: tuple = ()  # device ids to add (grow)
    reason: str = ""


ELASTIC_POLICIES: Dict[str, "ElasticPolicy"] = {}


def register_policy(name: str):
    def deco(cls):
        ELASTIC_POLICIES[name] = cls()
        return cls

    return deco


def get_policy(name: str) -> "ElasticPolicy":
    if isinstance(name, ElasticPolicy):
        return name
    try:
        return ELASTIC_POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown elastic policy {name!r}; "
            f"registered: {sorted(ELASTIC_POLICIES)}"
        ) from None


def available() -> list[str]:
    return sorted(ELASTIC_POLICIES)


def clamp_min_extent(
    decision: ResizeDecision, live_ids, min_extent: int = 1
) -> ResizeDecision:
    """Serving guard: never shrink below ``min_extent`` replicas.

    A chaos script (or a real cascading failure) may remove every replica;
    training can abort and restore a checkpoint, but a serving pool must
    keep answering — so the lowest-id victims are spared until
    ``min_extent`` survivors remain.  Spared replicas stay in the mesh and
    keep being reported dead by the detector; they are dropped by a later
    decision once joiners restore headroom."""
    if decision.action != "shrink":
        return decision
    survivors = [w for w in live_ids if w not in decision.remove]
    if len(survivors) >= min_extent:
        return decision
    spared = sorted(decision.remove)[: min_extent - len(survivors)]
    remove = frozenset(w for w in decision.remove if w not in spared)
    if not remove:
        return ResizeDecision(
            reason=f"shrink suppressed: min extent {min_extent}"
        )
    return dataclasses.replace(
        decision, remove=remove,
        reason=f"{decision.reason} (clamped to min extent {min_extent})",
    )


class ElasticPolicy:
    """Base: no failures tolerated, no growth."""

    def decide(
        self,
        detector: FailureDetector,
        now: float,
        pending_joins: Sequence[int],
        mesh_device_ids: frozenset,
    ) -> ResizeDecision:
        raise NotImplementedError

    def _confirmed_failures(self, detector, now, mesh_device_ids):
        return frozenset(w for w in detector.failed(now) if w in mesh_device_ids)


@register_policy("static")
class StaticPolicy(ElasticPolicy):
    def decide(self, detector, now, pending_joins, mesh_device_ids):
        failed = self._confirmed_failures(detector, now, mesh_device_ids)
        if failed:
            return ResizeDecision(
                "abort", remove=failed,
                reason=f"static policy: workers {sorted(failed)} failed",
            )
        return ResizeDecision()


@register_policy("shrink_on_failure")
class ShrinkOnFailurePolicy(ElasticPolicy):
    def decide(self, detector, now, pending_joins, mesh_device_ids):
        failed = self._confirmed_failures(detector, now, mesh_device_ids)
        if failed:
            return ResizeDecision(
                "shrink", remove=failed,
                reason=f"heartbeat failure: {sorted(failed)}",
            )
        return ResizeDecision()


@register_policy("grow_on_join")
class GrowOnJoinPolicy(ShrinkOnFailurePolicy):
    def decide(self, detector, now, pending_joins, mesh_device_ids):
        d = super().decide(detector, now, pending_joins, mesh_device_ids)
        if d.action != "none":
            return d
        joiners = tuple(w for w in pending_joins if w not in mesh_device_ids)
        if joiners:
            return ResizeDecision(
                "grow", admit=joiners, reason=f"join: {sorted(joiners)}"
            )
        return ResizeDecision()


@register_policy("drain_straggler")
class DrainStragglerPolicy(ShrinkOnFailurePolicy):
    def decide(self, detector, now, pending_joins, mesh_device_ids):
        d = super().decide(detector, now, pending_joins, mesh_device_ids)
        if d.action != "none":
            return d
        slow = frozenset(
            w for w in detector.stragglers() if w in mesh_device_ids
        )
        if slow:
            return ResizeDecision(
                "shrink", remove=slow, reason=f"straggler drain: {sorted(slow)}"
            )
        return ResizeDecision()
