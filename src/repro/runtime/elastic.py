"""Elastic training controller: the shrink-on-failure loop as a utility.

Ties together the pieces proven individually in tests:
heartbeat failure detection (`fault_tolerance.FailureDetector`) ->
mesh shrink (`shrink_mesh`, possibly to a non-power-of-two DP extent —
handled natively by the MRD collectives) -> checkpoint restore with
re-sharding -> training resume with the batch rounded to the new DP extent.

The controller is runtime-agnostic: `step_fn_factory(mesh)` rebuilds the
train step for whatever mesh survives, and the data pipeline's state
(deterministic, step-keyed) guarantees the token stream continues exactly
where it stopped regardless of the new topology.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.checkpoint.checkpointer import Checkpointer
from repro.runtime.fault_tolerance import (
    FailureDetector,
    HeartbeatConfig,
    shrink_mesh,
)


@dataclasses.dataclass
class ElasticConfig:
    ckpt_every: int = 50
    heartbeat: HeartbeatConfig = dataclasses.field(default_factory=HeartbeatConfig)
    max_restarts: int = 8
    dp_axis: str = "data"


class ElasticTrainer:
    """Drive training across failures.

    ``step_fn_factory(mesh) -> (train_step, init_state, state_specs, rules)``
    (what ``repro.distributed.gradsync.make_step_factory(model_cfg, tcfg)``
    returns — any mode in the ``GRAD_SYNC`` registry rebuilds cleanly on a
    shrunk, possibly non-power-of-two mesh because every strategy's
    collectives run through the MRD-native plan layer); alternatively pass
    ``(model_cfg, tcfg)`` directly and the factory is built from the
    registry.  ``pipe_factory(mesh)`` builds the data pipeline.
    """

    def __init__(
        self,
        mesh,
        step_fn_factory,
        pipe_factory: Callable,
        checkpointer: Checkpointer,
        cfg: ElasticConfig = ElasticConfig(),
    ):
        if isinstance(step_fn_factory, tuple):
            from repro.distributed import gradsync

            step_fn_factory = gradsync.make_step_factory(*step_fn_factory)
        self.mesh = mesh
        self.step_fn_factory = step_fn_factory
        self.pipe_factory = pipe_factory
        self.ck = checkpointer
        self.cfg = cfg
        self.restarts = 0
        self._build()

    def _build(self):
        (self.train_step, self.init_state, self.state_specs, self.rules) = (
            self.step_fn_factory(self.mesh)
        )
        self.pipe = self.pipe_factory(self.mesh)
        self._jit = jax.jit(self.train_step)
        self.detector = FailureDetector(
            [d.id for d in np.ravel(np.asarray(self.mesh.devices))],
            self.cfg.heartbeat,
        )

    def init_or_restore(self, key):
        with self.mesh:
            state = self.init_state(key)
            shardings = jax.tree.map(
                lambda s: NamedSharding(self.mesh, s), self.state_specs(state)
            )
            latest = self.ck.latest_step()
            if latest is not None:
                # params + step survive topology changes; optimizer moments
                # restart on reshard (safe default; see fault-tolerance test)
                tpl = {"params": state["params"], "step": state["step"]}
                restored = self.ck.restore(latest, jax.tree.map(
                    lambda x: np.zeros(x.shape, x.dtype), tpl))
                state["params"] = restored["params"]
                state["step"] = jnp.asarray(restored["step"])
                self.pipe.load_state_dict(self.ck.manifest(latest)["extra"]["data"])
            state = jax.device_put(state, shardings)
        return state

    def handle_failure(self, state, failed_device_ids: set[int]):
        """Shrink the mesh, rebuild, restore from the latest checkpoint."""
        if self.restarts >= self.cfg.max_restarts:
            raise RuntimeError("restart budget exhausted")
        self.restarts += 1
        self.ck.wait()
        new_mesh, kept = shrink_mesh(self.mesh, failed_device_ids, self.cfg.dp_axis)
        self.mesh = new_mesh
        self._build()
        return self.init_or_restore(jax.random.PRNGKey(0))

    def run(self, state, n_steps: int, *, fail_at: Optional[dict] = None):
        """Train; ``fail_at`` = {step: {device_ids}} injects failures (tests).
        Returns (state, losses)."""
        losses = []
        i = int(state["step"])
        target = i + n_steps
        while i < target:
            if fail_at and i in fail_at:
                ids = fail_at.pop(i)
                state = self.handle_failure(state, ids)
                i = int(state["step"])
                continue
            with self.mesh:
                state, metrics = self._jit(state, self.pipe.next_batch())
            losses.append(float(metrics["loss"]))
            i += 1
            for d in np.ravel(np.asarray(self.mesh.devices)):
                self.detector.heartbeat(d.id, now=time.time())
            if i % self.cfg.ckpt_every == 0:
                self.ck.save(i, state, extra={"data": self.pipe.state_dict()})
        self.ck.save(int(state["step"]), state,
                     extra={"data": self.pipe.state_dict()}, block=True)
        return state, losses
