"""Elastic training runtime: policy-driven shrink *and* grow with in-place
state migration (DESIGN.md S12).

The paper's modified recursive doubling makes every collective correct at
*any* process count, which is exactly what makes live elasticity cheap:
losing or admitting a worker changes the DP extent to an arbitrary —
usually non-power-of-two — value and the MRD plan layer keeps working.
This module turns that property into a runtime:

- an ``ELASTIC_POLICIES`` registry (``repro.runtime.policies``) decides
  per step whether to shrink (heartbeat failure, straggler drain), grow
  (pending join), abort (``static``), or keep training;
- a :class:`ResizeEvent` lifecycle executes the decision **without a
  checkpoint round-trip** when the survivors hold the data: the mesh is
  rebuilt (:func:`~repro.runtime.fault_tolerance.shrink_mesh` /
  :func:`~repro.runtime.fault_tolerance.grow_mesh`), live collective
  plans are invalidated (``repro.collectives.plans.invalidate_all_plans``),
  and the grad-sync strategy's registered resize hook
  (``repro.distributed.gradsync.migrate_state``) re-lays-out whatever it
  shards over DP — the ZeRO-1 master/moment segments, the EF-SGD residual
  carry, the detection-protocol monitor rows — onto the new extent;
- on grow, joiners receive the parameters through an MRD-plan *broadcast*
  at the new extent (:func:`mrd_broadcast`): the sum-allreduce of a
  source-masked tree is bit-exact (every other contribution is a true
  zero), so a 3→5 grow resumes with the survivors' params untouched.

Failure detection runs on the injected clock of
:class:`~repro.runtime.fault_tolerance.FailureDetector`; the chaos
harness (``tests/chaos.py``) scripts kill/join/stall events against a
:class:`~repro.runtime.fault_tolerance.StepClock`, which makes every
resize — and therefore the whole training trajectory — a deterministic
function of the event script.  The checkpointer remains the fallback for
the data-loss case (and for cold starts); ``ElasticTrainer.restores``
counts how often it was actually needed.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat, obs
from repro.checkpoint.checkpointer import Checkpointer
from repro.collectives import plans
from repro.runtime.fault_tolerance import (
    FailureDetector,
    HeartbeatConfig,
    ReplicaSet,
    StepClock,
    grow_mesh,
    shrink_mesh,
)
from repro.runtime.policies import (
    LoadSnapshot,
    ResizeDecision,
    clamp_min_extent,
    get_policy,
)


def _dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def mrd_broadcast(tree, mesh, dp_axes: Sequence[str], src: int = 0,
                  executor: str = "device"):
    """Broadcast ``tree`` from flattened-DP rank ``src`` to every rank via
    the paper's MRD sum-allreduce at the mesh's (possibly non-power-of-two)
    extent: every non-source rank contributes exact zeros, and ``x + 0``
    is bit-exact in every stage of the schedule, so the result equals the
    source's values on all ranks.  This is the grow path's param transfer —
    the protocol-level move a joining worker performs instead of a
    checkpoint restore."""
    plan = plans.allreduce_plan(
        schedule="mrd", axes=tuple(dp_axes), op="sum", executor=executor
    )

    def local(t):
        r = jnp.zeros((), jnp.int32)
        for ax in dp_axes:
            r = r * compat.axis_size(ax) + jax.lax.axis_index(ax)
        masked = jax.tree.map(
            lambda x: jnp.where(r == src, x, jnp.zeros_like(x)), t
        )
        return plan.run(masked)

    rep = jax.tree.map(lambda _: P(), tree)
    return jax.jit(
        compat.shard_map(
            local, mesh=mesh, in_specs=(rep,), out_specs=rep,
            axis_names=set(dp_axes), check_vma=False,
        )
    )(tree)


@dataclasses.dataclass(frozen=True)
class ResizeEvent:
    """One executed topology change — everything needed to replay it."""

    kind: str  # 'shrink' | 'grow'
    step: int  # global train step at which the resize took effect
    old_dp: int
    new_dp: int
    # new flattened-DP rank -> old flattened-DP rank (None = joined worker)
    keep: tuple
    device_ids: tuple  # device ids of the new mesh (row-major)
    reason: str = ""
    restored_from_checkpoint: bool = False


@dataclasses.dataclass
class ElasticConfig:
    ckpt_every: int = 50
    heartbeat: HeartbeatConfig = dataclasses.field(default_factory=HeartbeatConfig)
    max_restarts: int = 8  # resize budget (legacy name)
    dp_axis: str = "data"
    policy: str = "shrink_on_failure"  # any ELASTIC_POLICIES entry
    step_dt: float = 1.0  # virtual seconds per step (StepClock)
    base_step_time: float = 1.0  # healthy worker's reported step time


def flat_keep_for_shrink(old_mesh, dp_axes, axis: str, kept: Sequence[int]):
    """Flattened-DP keep map after dropping dp-``axis`` slices: new flat
    rank i held old flat rank keep[i]."""
    sizes_o = [old_mesh.shape[a] for a in dp_axes]
    ai = list(dp_axes).index(axis)
    sizes_n = list(sizes_o)
    sizes_n[ai] = len(kept)
    keep = []
    for new_flat in range(int(np.prod(sizes_n))):
        idx = list(np.unravel_index(new_flat, sizes_n))
        idx[ai] = kept[idx[ai]]
        keep.append(int(np.ravel_multi_index(idx, sizes_o)))
    return tuple(keep)


def flat_keep_for_grow(old_mesh, dp_axes, axis: str, n_new: int):
    """Flattened-DP keep map after appending ``n_new`` dp-``axis`` slices:
    survivors keep their positions, joiners map to None."""
    sizes_o = [old_mesh.shape[a] for a in dp_axes]
    ai = list(dp_axes).index(axis)
    old_extent = sizes_o[ai]
    sizes_n = list(sizes_o)
    sizes_n[ai] = old_extent + n_new
    keep = []
    for new_flat in range(int(np.prod(sizes_n))):
        idx = list(np.unravel_index(new_flat, sizes_n))
        if idx[ai] >= old_extent:
            keep.append(None)
        else:
            keep.append(int(np.ravel_multi_index(idx, sizes_o)))
    return tuple(keep)


class ElasticServeController:
    """Drive a :class:`repro.serving.ServeEngine` across replica changes —
    the serving analogue of :class:`ElasticTrainer` (DESIGN.md S15).

    Same harness surface (``kill`` / ``stall`` / ``unstall`` / ``join`` —
    chaos scripts fire against it unchanged), same policy/detector wiring
    on the injected :class:`StepClock`, but the "workers" are the engine's
    *simulated* termination-agreement replicas: a resize produces a
    :class:`ReplicaSet` keep map and calls :meth:`ServeEngine.resize`
    instead of resharding a device mesh.  Unlike training, serving never
    aborts on total failure — :func:`clamp_min_extent` pins the pool at
    ``min_extent`` replicas and spared replicas are pressed back into
    service until joiners restore headroom.

    One controller step = one policy pass + one engine step (which runs up
    to ``steps_per_dispatch`` device ticks); chaos events are matched
    against the engine's *tick* clock via ``apply_due``, so an event due at
    an intermediate tick of a fused dispatch fires at the next dispatch
    boundary — the first point a real control plane could act."""

    def __init__(
        self,
        engine,
        policy: str = "grow_on_join",
        *,
        heartbeat: Optional[HeartbeatConfig] = None,
        clock: Optional[StepClock] = None,
        replica_ids: Optional[Sequence[int]] = None,
        min_extent: int = 1,
        base_step_time: float = 1.0,
        max_resizes: int = 32,
    ):
        self.engine = engine
        ids = (
            list(replica_ids) if replica_ids is not None
            else list(range(engine.dp))
        )
        if len(ids) != engine.dp:
            raise ValueError(
                f"{len(ids)} replica ids for a dp={engine.dp} engine"
            )
        self.replicas = ReplicaSet(ids)
        # spawn(): stateful policies (sla_autoscale hysteresis) get a
        # per-controller instance instead of the shared registry singleton
        self.policy = get_policy(policy).spawn()
        self.clock = clock or StepClock()
        self.detector = FailureDetector(
            ids, heartbeat or HeartbeatConfig(), now=self.clock.now()
        )
        self.min_extent = min_extent
        self.base_step_time = base_step_time
        self.max_resizes = max_resizes
        self.health: dict[int, str] = {r: "ok" for r in ids}
        self.stall_factor: dict[int, float] = {}
        self.pending_joins: list[int] = []

    # -- harness surface (chaos scripts poke these, same as ElasticTrainer) --

    def kill(self, replica_id: int, *, silent: bool = False):
        self.health[replica_id] = "dead"
        if not silent:
            self.detector.mark_dead(replica_id)

    def stall(self, replica_id: int, factor: float = 10.0):
        self.health[replica_id] = "stalled"
        self.stall_factor[replica_id] = factor

    def unstall(self, replica_id: int):
        if self.health.get(replica_id) == "stalled":
            self.health[replica_id] = "ok"
        self.stall_factor.pop(replica_id, None)

    def join(self, replica_ids: Sequence[int]):
        for r in replica_ids:
            if r not in self.pending_joins and r not in self.replicas.ids:
                self.pending_joins.append(r)
                self.health[r] = "ok"

    def _heartbeat_all(self, now: float):
        for r in self.replicas.ids:
            status = self.health.get(r, "ok")
            if status == "dead":
                continue
            step_time = self.base_step_time * (
                self.stall_factor.get(r, 1.0) if status == "stalled" else 1.0
            )
            self.detector.heartbeat(r, now=now, step_time=step_time)

    def _load(self) -> LoadSnapshot:
        """Load picture for autoscaling policies — built by the *engine*
        (:meth:`ServeEngine.load_snapshot`), which also publishes the same
        numbers as telemetry gauges: the trace and the policy see one
        snapshot, never two divergent computations."""
        return self.engine.load_snapshot()

    # -- one controller step -------------------------------------------------

    def step(self, events=None) -> np.ndarray:
        """One policy pass + one engine step.  Returns the retired mask."""
        now = self.clock.advance()
        if events is not None:
            fire = getattr(events, "apply_due", None) or events.apply
            fire(self, self.engine.tick)
        self._heartbeat_all(now)
        decision = self.policy.decide(
            self.detector, now, self.pending_joins,
            frozenset(self.replicas.ids),
            load=self._load(),
        )
        clamped = clamp_min_extent(
            decision, self.replicas.ids, self.min_extent
        )
        if decision.action == "shrink" and clamped is not decision:
            # spared replicas are pressed back into service: clear their
            # failure evidence or the suppressed shrink re-fires forever
            # and blocks join admission
            for r in decision.remove - clamped.remove:
                self.health[r] = "ok"
                self.detector.heartbeat(r, now=now)
        decision = clamped
        if decision.action not in ("none", "abort"):
            obs.instant(
                "elastic.decision",
                action=decision.action,
                reason=decision.reason,
                tick=self.engine.tick,
                dp=self.replicas.dp,
            )
        if decision.action == "abort":
            raise RuntimeError(f"elastic policy abort: {decision.reason}")
        if decision.action == "shrink":
            if len(self.resizes) >= self.max_resizes:
                raise RuntimeError("resize budget exhausted")
            for r in decision.remove:
                self.detector.remove_worker(r)
                self.health[r] = "dead"
            _, keep = self.replicas.remove(decision.remove)
            self.engine.resize(
                self.replicas.dp, keep, reason=decision.reason
            )
        elif decision.action == "grow":
            if len(self.resizes) >= self.max_resizes:
                raise RuntimeError("resize budget exhausted")
            joiners = tuple(decision.admit)
            self.pending_joins = [
                r for r in self.pending_joins if r not in set(joiners)
            ]
            for r in joiners:
                self.detector.add_worker(r, now)
            _, keep = self.replicas.add(joiners)
            self.engine.resize(
                self.replicas.dp, keep, reason=decision.reason
            )
        return self.engine.step()

    @property
    def resizes(self) -> list[ResizeEvent]:
        return self.engine.resizes

    def run(self, requests=None, *, events=None, max_steps: Optional[int] = None):
        """Submit ``requests`` and step the engine under the policy until
        everything retires (the serving analogue of ``ElasticTrainer.run``,
        with chaos ``events`` applied on the engine's tick clock)."""
        eng = self.engine
        for r in requests or []:
            eng.submit(r)
        budget = max_steps or eng.cfg.max_ticks
        steps = 0
        while eng.queue or eng.pending or any(eng.slot_req):
            if steps >= budget:
                raise RuntimeError(
                    f"elastic serve loop did not drain within {budget} steps"
                )
            self.step(events)
            steps += 1
        return eng.results


class ElasticTrainer:
    """Drive training across topology changes.

    ``step_fn_factory(mesh) -> (train_step, init_state, state_specs,
    rules)`` (what ``repro.distributed.gradsync.make_step_factory(cfg,
    tcfg)`` returns); alternatively pass ``(model_cfg, tcfg)`` directly —
    then the factory is built from the ``GRAD_SYNC`` registry **and**
    resizes migrate state in place through the strategy's registered
    resize hook instead of restoring a checkpoint.  With an opaque
    factory the trainer falls back to the legacy checkpoint-restore path
    on every resize (``restores`` counts those).

    ``pipe_factory(mesh)`` builds the data pipeline; its state is
    deterministic and step-keyed, so the token stream continues exactly
    where it stopped regardless of the topology.
    """

    def __init__(
        self,
        mesh,
        step_fn_factory,
        pipe_factory: Callable,
        checkpointer: Optional[Checkpointer] = None,
        cfg: ElasticConfig = None,
        clock: Optional[StepClock] = None,
    ):
        cfg = cfg or ElasticConfig()
        self.train_cfgs = None
        if isinstance(step_fn_factory, tuple):
            from repro.distributed import gradsync

            self.train_cfgs = step_fn_factory
            step_fn_factory = gradsync.make_step_factory(*self.train_cfgs)
        self.mesh = mesh
        self.step_fn_factory = step_fn_factory
        self.pipe_factory = pipe_factory
        self.ck = checkpointer
        self.cfg = cfg
        self.policy = get_policy(cfg.policy)
        self.clock = clock or StepClock(dt=cfg.step_dt)
        self.resizes: list[ResizeEvent] = []
        self.restores = 0  # checkpoint restores actually performed on resize
        # harness-controlled cluster picture
        self.health: dict[int, str] = {}  # device id -> 'ok'|'dead'|'stalled'
        self.stall_factor: dict[int, float] = {}
        self.pending_joins: list[int] = []
        self._build()

    # -- wiring -------------------------------------------------------------

    @property
    def restarts(self) -> int:
        """Resize count (legacy name kept for the pre-S12 API)."""
        return len(self.resizes)

    def device_ids(self) -> tuple[int, ...]:
        return tuple(d.id for d in np.ravel(np.asarray(self.mesh.devices)))

    def _build(self):
        (self.train_step, self.init_state, self.state_specs, self.rules) = (
            self.step_fn_factory(self.mesh)
        )
        self.pipe = self.pipe_factory(self.mesh)
        self._jit = jax.jit(self.train_step)
        now = self.clock.now()
        ids = set(self.device_ids())
        if getattr(self, "detector", None) is None:
            self.detector = FailureDetector(
                list(self.device_ids()), self.cfg.heartbeat, now=now
            )
        else:
            # keep heartbeat history across resizes: a silently-partitioned
            # worker's stale-heartbeat evidence (and a straggler's strike
            # count) must survive unrelated topology changes, or detection
            # restarts from scratch on every resize
            for w in list(self.detector.last):
                if w not in ids:
                    self.detector.remove_worker(w)
            for w in ids:
                self.detector.add_worker(w, now)
        for d in self.device_ids():
            self.health.setdefault(d, "ok")

    def _shardings(self, state):
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), self.state_specs(state)
        )

    def init_or_restore(self, key):
        with self.mesh:
            state = self.init_state(key)
            shardings = self._shardings(state)
            latest = self.ck.latest_step() if self.ck else None
            if latest is not None:
                # params + step survive topology changes; optimizer moments
                # restart on reshard (safe default; see fault-tolerance test)
                tpl = {"params": state["params"], "step": state["step"]}
                restored = self.ck.restore(latest, jax.tree.map(
                    lambda x: np.zeros(x.shape, x.dtype), tpl))
                state["params"] = restored["params"]
                state["step"] = jnp.asarray(restored["step"])
                self.pipe.load_state_dict(self.ck.manifest(latest)["extra"]["data"])
            state = jax.device_put(state, shardings)
        return state

    # -- harness surface (chaos scripts poke these) -------------------------

    def kill(self, device_id: int, *, silent: bool = False):
        """Mark a worker dead.  ``silent=True`` models a network partition
        (detected only after the heartbeat timeout elapses on the injected
        clock); the default models a fail-stop crash report (detected on
        the next policy pass)."""
        self.health[device_id] = "dead"
        if not silent:
            self.detector.mark_dead(device_id)

    def stall(self, device_id: int, factor: float = 10.0):
        """Mark a worker as a straggler: it keeps heartbeating, but its
        reported step time is ``factor`` x the healthy baseline."""
        self.health[device_id] = "stalled"
        self.stall_factor[device_id] = factor

    def unstall(self, device_id: int):
        if self.health.get(device_id) == "stalled":
            self.health[device_id] = "ok"
        self.stall_factor.pop(device_id, None)

    def join(self, device_ids: Sequence[int]):
        """Queue workers for admission (policies that grow will admit them
        on their next decision)."""
        for d in device_ids:
            if d not in self.pending_joins:
                self.pending_joins.append(d)
                self.health[d] = "ok"

    def _heartbeat_all(self, now: float):
        for d in self.device_ids():
            status = self.health.get(d, "ok")
            if status == "dead":
                continue
            step_time = self.cfg.base_step_time * (
                self.stall_factor.get(d, 1.0) if status == "stalled" else 1.0
            )
            self.detector.heartbeat(d, now=now, step_time=step_time)

    # -- the ResizeEvent lifecycle ------------------------------------------

    def _clamp_grow(self, decision: ResizeDecision) -> ResizeDecision:
        """Admit only whole DP slices: with TP, a joiner set that is not a
        multiple of the per-slice device count stays pending (admitting it
        would make ``grow_mesh`` raise and kill the run) — the remainder
        is admitted once enough joiners accumulate."""
        per_slice = self.mesh.size // self.mesh.shape[self.cfg.dp_axis]
        n = (len(decision.admit) // per_slice) * per_slice
        if n == 0:
            return ResizeDecision()
        if n < len(decision.admit):
            return dataclasses.replace(decision, admit=decision.admit[:n])
        return decision

    def resize(self, state, decision: ResizeDecision):
        """Execute a policy decision: rebuild the mesh, migrate state in
        place (or restore from checkpoint when no migration path exists),
        rebuild the step functions, and record the :class:`ResizeEvent`."""
        with obs.span(
            "train.resize", action=decision.action, reason=decision.reason
        ) as sp:
            state = self._resize_impl(state, decision)
            if sp is not None:
                ev = self.resizes[-1]
                sp.update(
                    old_dp=ev.old_dp,
                    new_dp=ev.new_dp,
                    step=ev.step,
                    restored=ev.restored_from_checkpoint,
                )
        return state

    def _resize_impl(self, state, decision: ResizeDecision):
        if len(self.resizes) >= self.cfg.max_restarts:
            raise RuntimeError("resize budget exhausted")
        old_mesh = self.mesh
        dp_axes = _dp_axes(old_mesh)
        old_dp = int(np.prod([old_mesh.shape[a] for a in dp_axes]))
        step = int(state["step"]) if state is not None else 0

        if decision.action == "shrink":
            new_mesh, kept = shrink_mesh(
                old_mesh, set(decision.remove), self.cfg.dp_axis
            )
            keep = flat_keep_for_shrink(old_mesh, dp_axes, self.cfg.dp_axis, kept)
            for d in decision.remove:
                self.detector.remove_worker(d)
                self.health[d] = "dead"
        elif decision.action == "grow":
            new_mesh, n_new = grow_mesh(
                old_mesh, tuple(decision.admit), self.cfg.dp_axis
            )
            keep = flat_keep_for_grow(old_mesh, dp_axes, self.cfg.dp_axis, n_new)
            self.pending_joins = [
                d for d in self.pending_joins if d not in set(decision.admit)
            ]
        else:
            raise ValueError(f"resize cannot execute action {decision.action!r}")

        # stale extents invalidate every live plan's memoized derivations
        plans.invalidate_all_plans()

        restored = False
        if state is not None and self.train_cfgs is not None:
            from repro.distributed import gradsync

            cfg, tcfg = self.train_cfgs
            with obs.span("train.resize.migrate", action=decision.action):
                state = gradsync.migrate_state(
                    cfg, tcfg, old_mesh, new_mesh, state, keep
                )
            pipe_state = self.pipe.state_dict()
            self.mesh = new_mesh
            self._build()
            self.pipe.load_state_dict(pipe_state)
            with self.mesh:
                shardings = self._shardings(state)
                state = jax.device_put(state, shardings)
                if decision.action == "grow":
                    # protocol-level param transfer to the joiners: MRD
                    # broadcast at the new (non-power-of-two) extent —
                    # bit-exact, so survivors' params are untouched
                    with obs.span("train.resize.broadcast"):
                        state["params"] = jax.device_put(
                            mrd_broadcast(
                                state["params"], self.mesh,
                                _dp_axes(self.mesh), src=0,
                            ),
                            shardings["params"],
                        )
        else:
            # legacy path (opaque step factory): full checkpoint round-trip
            if self.ck is None:
                raise RuntimeError(
                    "cannot resize: no (model_cfg, tcfg) for in-place "
                    "migration and no checkpointer to restore from"
                )
            self.ck.wait()
            self.mesh = new_mesh
            self._build()
            state = self.init_or_restore(jax.random.PRNGKey(0))
            self.restores += 1
            restored = True

        new_dp = int(np.prod([new_mesh.shape[a] for a in _dp_axes(new_mesh)]))
        self.resizes.append(ResizeEvent(
            kind=decision.action, step=step, old_dp=old_dp, new_dp=new_dp,
            keep=tuple(keep), device_ids=self.device_ids(),
            reason=decision.reason, restored_from_checkpoint=restored,
        ))
        return state

    # -- training loop ------------------------------------------------------

    def handle_failure(self, state, failed_device_ids: set[int]):
        """Immediate shrink (legacy API): the named devices are gone."""
        for d in failed_device_ids:
            self.kill(d)
        return self.resize(
            state,
            ResizeDecision(
                "shrink", remove=frozenset(failed_device_ids),
                reason="handle_failure",
            ),
        )

    def run(self, state, n_steps: int, *, fail_at: Optional[dict] = None,
            events=None):
        """Train for ``n_steps``; returns (state, losses).

        ``fail_at`` = {step: {device_ids}} injects immediate failures
        (legacy test hook).  ``events`` is a chaos script — any object
        with ``apply(trainer, step)`` (see ``tests/chaos.py``) — applied
        before each step on the injected clock.
        """
        losses = []
        i = int(state["step"])
        target = i + n_steps
        while i < target:
            now = self.clock.advance()
            if fail_at and i in fail_at:
                for d in fail_at.pop(i):
                    self.kill(d)
            if events is not None:
                events.apply(self, i)
            self._heartbeat_all(now)
            decision = self.policy.decide(
                self.detector, now, self.pending_joins,
                frozenset(self.device_ids()),
            )
            if decision.action == "abort":
                raise RuntimeError(f"elastic policy abort: {decision.reason}")
            if decision.action == "grow":
                decision = self._clamp_grow(decision)
            if decision.action in ("shrink", "grow"):
                state = self.resize(state, decision)
                i = int(state["step"])
                if i >= target:
                    break
            with self.mesh:
                state, metrics = self._jit(state, self.pipe.next_batch())
            losses.append(float(metrics["loss"]))
            i += 1
            if self.ck is not None and i % self.cfg.ckpt_every == 0:
                self.ck.save(i, state, extra={"data": self.pipe.state_dict()})
        if self.ck is not None:
            self.ck.save(int(state["step"]), state,
                         extra={"data": self.pipe.state_dict()}, block=True)
        return state, losses
