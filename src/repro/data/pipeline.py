"""Synthetic-but-deterministic data pipeline with sharded device placement.

Produces LM batches (tokens/labels; plus frontend embeddings for vlm/audio)
keyed only on (seed, step) — so it is trivially checkpointable (resume = set
the step counter) and identical across restarts/elastic rescales, which the
fault-tolerance tests rely on.

The token stream is a mixture of Zipf-ish unigram draws and short repeated
motifs, giving models something learnable (loss decreases) without external
data dependencies.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig


@dataclasses.dataclass
class DataConfig:
    batch: int
    seq_len: int
    seed: int = 0
    motif_len: int = 16
    n_motifs: int = 64


class SyntheticPipeline:
    """Deterministic per-step batch generator; state = step counter."""

    def __init__(
        self,
        cfg: ModelConfig,
        dcfg: DataConfig,
        mesh: Optional[Mesh] = None,
        batch_sharding=None,
    ):
        self.cfg = cfg
        self.dcfg = dcfg
        self.mesh = mesh
        self.batch_sharding = batch_sharding
        self.step = 0
        key = jax.random.PRNGKey(dcfg.seed)
        # fixed motif bank (part of the 'dataset', not the per-step state)
        self._motifs = jax.random.randint(
            key, (dcfg.n_motifs, dcfg.motif_len), 0, cfg.vocab
        )

    # --- checkpointable state ---
    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.dcfg.seed}

    def load_state_dict(self, st: dict):
        assert st["seed"] == self.dcfg.seed, "data seed mismatch on restore"
        self.step = int(st["step"])

    def _tokens(self, key, B, S):
        k1, k2, k3 = jax.random.split(key, 3)
        n_chunks = -(-S // self.dcfg.motif_len)
        ids = jax.random.randint(k1, (B, n_chunks), 0, self.dcfg.n_motifs)
        stream = self._motifs[ids].reshape(B, -1)[:, :S]
        noise = jax.random.randint(k2, (B, S), 0, self.cfg.vocab)
        use_noise = jax.random.bernoulli(k3, 0.25, (B, S))
        return jnp.where(use_noise, noise, stream)

    def next_batch(self) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.dcfg.seed), self.step)
        self.step += 1
        B, S = self.dcfg.batch, self.dcfg.seq_len
        toks = self._tokens(key, B, S + 1)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.frontend == "vision":
            from repro.models.frontends import vision_patches

            batch["patches"] = vision_patches(
                jax.random.fold_in(key, 7), B, self.cfg.n_frontend_tokens, jnp.float32
            ).astype(jnp.bfloat16 if self.cfg.compute_dtype == "bfloat16" else jnp.float32)
            # labels align to the text region only
        if self.cfg.frontend == "audio":
            from repro.models.frontends import audio_frames

            frames = audio_frames(jax.random.fold_in(key, 9), B, S, jnp.float32)
            labels = jax.random.randint(jax.random.fold_in(key, 11), (B, S), 0, self.cfg.vocab)
            batch = {"frames": frames, "labels": labels}
        if self.batch_sharding is not None:
            batch = jax.device_put(batch, self.batch_sharding)
        elif self.mesh is not None:
            batch = jax.device_put(
                batch,
                jax.tree.map(
                    lambda x: NamedSharding(
                        self.mesh,
                        P(
                            tuple(a for a in self.mesh.axis_names if a != "model")
                            if x.shape[0] % _dp(self.mesh) == 0
                            else None
                        ),
                    ),
                    batch,
                ),
            )
        return batch


def _dp(mesh: Mesh) -> int:
    out = 1
    for a in mesh.axis_names:
        if a != "model":
            out *= mesh.shape[a]
    return out
