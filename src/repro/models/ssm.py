"""State-space blocks: Mamba-1 (falcon-mamba) and Mamba-2/SSD (zamba2).

TPU adaptation (see DESIGN.md): the CUDA selective-scan kernel is a
register-resident sequential scan; on TPU we use a **chunked** formulation —
sequence is processed in chunks with an intra-chunk associative scan (mamba1)
or the SSD matmul form (mamba2, MXU-friendly), carrying only chunk-boundary
states.  Memory per layer: O(B * chunk * d_inner * state) transient +
O(B * S/chunk * d_inner * state) boundaries, instead of O(B*S*d_inner*state).

The sequential-over-chunks loop is `lax.scan`; the Pallas kernel
(`repro.kernels.selective_scan`) implements the same chunking with the carry
held in VMEM scratch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


# ---------------------------------------------------------------------------
# Depthwise causal conv1d (kernel size cfg.ssm_conv, typically 4)
# ---------------------------------------------------------------------------


def causal_conv1d(x, w, b):
    """x: [B,S,C]; w: [K,C]; b: [C]."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    S = x.shape[1]
    for i in range(K):  # K is tiny (4): unrolled shifts beat conv_general here
        out = out + pad[:, i : i + S] * w[i]
    return out + b


def conv1d_step(x_tok, conv_state, w, b):
    """x_tok: [B,C]; conv_state: [B,K-1,C] (past inputs).  Returns (y, state)."""
    K = w.shape[0]
    window = jnp.concatenate([conv_state, x_tok[:, None]], axis=1)  # [B,K,C]
    y = jnp.einsum("bkc,kc->bc", window, w) + b
    return y, window[:, 1:]


# ---------------------------------------------------------------------------
# Mamba-1 (falcon-mamba-7b)
# ---------------------------------------------------------------------------


def mamba1_init(key, cfg, dtype):
    d, di, st, dtr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    ks = jax.random.split(key, 6)
    A = jnp.broadcast_to(jnp.arange(1, st + 1, dtype=jnp.float32), (di, st))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, di), dtype, fan_in=cfg.ssm_conv),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * st), dtype, fan_in=di),
        "dt_proj": dense_init(ks[3], (dtr, di), dtype, fan_in=dtr),
        "dt_bias": jnp.full((di,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(A),  # fp32
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], (di, d), dtype, fan_in=di),
    }


def _mamba1_ssm_inputs(p, x1, cfg):
    """x1: [B,S,di] post-conv activations -> (decay, Bx, Cs)."""
    st, dtr = cfg.ssm_state, cfg.dt_rank
    xdbc = x1 @ p["x_proj"]
    dt_r, Bs, Cs = jnp.split(xdbc, [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus(
        (dt_r @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # [B,S,di]
    A = -jnp.exp(p["A_log"])  # [di,st]
    decay = jnp.exp(dt[..., None] * A)  # [B,S,di,st]
    Bx = (dt * x1.astype(jnp.float32))[..., None] * Bs.astype(jnp.float32)[:, :, None, :]
    return decay, Bx, Cs.astype(jnp.float32)


def _chunk_scan(p, x1, cfg, h0, chunk: int):
    """Sequential-over-chunks selective scan.

    x1: [B,S,di] post-conv activations (compute dtype).  The f32 SSM inputs
    (decay, Bx) are computed *inside* the chunk body so only
    O(B*chunk*di*st) f32 is ever live (full-seq materialization is ~TB at 4k
    x d_inner 8k).  Returns (y [B,S,di] fp32, h_final)."""
    B, S, di = x1.shape
    st = cfg.ssm_state
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        x1 = jnp.pad(x1, ((0, 0), (0, pad), (0, 0)))
    xc = x1.reshape(B, nc, chunk, di).transpose(1, 0, 2, 3)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def body(h, x_i):  # x_i: [B,chunk,di]
        d_i, b_i, c_i = _mamba1_ssm_inputs(p, x_i, cfg)
        cumA, cumB = jax.lax.associative_scan(combine, (d_i, b_i), axis=1)
        h_t = cumA * h[:, None] + cumB  # [B,chunk,di,st]
        y = jnp.einsum("bqds,bqs->bqd", h_t, c_i)
        return h_t[:, -1], y

    h_f, ys = jax.lax.scan(body, h0, xc, unroll=cfg.scan_unroll)
    y = ys.transpose(1, 0, 2, 3).reshape(B, nc * chunk, di)
    return y[:, :S], h_f


def mamba1_apply(p, x, cfg, *, chunk: int = 256):
    """Full-sequence mamba1 block. x: [B,S,d] -> [B,S,d]."""
    B, S, _ = x.shape
    chunk = min(chunk, S)
    di = cfg.d_inner
    xz = x @ p["in_proj"]
    x1, z = jnp.split(xz, 2, axis=-1)
    x1 = jax.nn.silu(causal_conv1d(x1, p["conv_w"], p["conv_b"]))
    h0 = jnp.zeros((B, di, cfg.ssm_state), jnp.float32)
    y, _ = _chunk_scan(p, x1, cfg, h0, chunk)
    y = y + p["D"] * x1.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"]


def mamba1_init_state(cfg, batch, dtype):
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
    }


def mamba1_decode(p, x_tok, state, cfg):
    """One decode step. x_tok: [B,d] -> (y [B,d], new state)."""
    xz = x_tok @ p["in_proj"]
    x1, z = jnp.split(xz, 2, axis=-1)
    x1, conv_state = conv1d_step(x1, state["conv"], p["conv_w"], p["conv_b"])
    x1 = jax.nn.silu(x1)
    decay, Bx, Cs = _mamba1_ssm_inputs(p, x1[:, None], cfg)
    h = decay[:, 0] * state["h"] + Bx[:, 0]
    y = jnp.einsum("bds,bs->bd", h, Cs[:, 0]) + p["D"] * x1.astype(jnp.float32)
    y = y.astype(x_tok.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"], {"h": h, "conv": conv_state}


# ---------------------------------------------------------------------------
# Mamba-2 / SSD (zamba2)
# ---------------------------------------------------------------------------


def mamba2_init(key, cfg, dtype):
    """Projections kept separate (z / x / BC / dt) so each output dim can be
    TP-sharded cleanly (the fused HF layout's split boundaries don't align
    with shard boundaries — see DESIGN.md S4)."""
    d, di, st = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = cfg.ssm_nheads
    ks = jax.random.split(key, 6)
    conv_dim = di + 2 * st
    return {
        "in_z": dense_init(ks[0], (d, di), dtype),
        "in_x": dense_init(ks[1], (d, di), dtype),
        "in_bc": dense_init(ks[2], (d, 2 * st), dtype),
        "in_dt": dense_init(ks[4], (d, nh), dtype),
        "conv_w": dense_init(ks[3], (cfg.ssm_conv, conv_dim), dtype, fan_in=cfg.ssm_conv),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_w": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[5], (di, d), dtype, fan_in=di),
    }


def _mamba2_split(p, x, cfg):
    z = x @ p["in_z"]
    xbc = jnp.concatenate([x @ p["in_x"], x @ p["in_bc"]], axis=-1)
    dt = x @ p["in_dt"]
    return z, xbc, dt  # dt: [.., nh]


def _ssd_scan(xh, Bs, Cs, dt, A, h0, chunk: int, unroll: bool = False):
    """SSD chunked scan (sequential over chunks, matmul-form within chunk).

    xh: [B,S,nh,hp]; Bs, Cs: [B,S,st]; dt: [B,S,nh] (post-softplus, f32);
    A: [nh] (negative); h0: [B,nh,hp,st].  f32 casting happens per chunk.
    Returns (y [B,S,nh,hp] fp32, h_final)."""
    B, S, nh, hp = xh.shape
    st = Bs.shape[-1]
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bs = jnp.pad(Bs, ((0, 0), (0, pad), (0, 0)))
        Cs = jnp.pad(Cs, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))

    def to_chunks(a):
        return a.reshape(B, nc, chunk, *a.shape[2:]).transpose(1, 0, *range(2, a.ndim + 1))

    # chunk the *narrow* inputs; dA/dt*x are formed per-chunk in f32 inside
    # the body (full-seq f32 [B,S,nh,hp] is tens of GB at 4k x d_inner 5k)
    xc, bc, cc, dtc = to_chunks(xh), to_chunks(Bs), to_chunks(Cs), to_chunks(dt)

    def body(h, inp):
        xr_i, b_i, c_i, dt_i = inp  # [B,q,nh,hp], [B,q,st], [B,q,st], [B,q,nh]
        dt_i = dt_i.astype(jnp.float32)
        da_i = dt_i * A  # [B,q,nh] (negative)
        x_i = (dt_i[..., None] * xr_i.astype(jnp.float32))
        b_i = b_i.astype(jnp.float32)
        c_i = c_i.astype(jnp.float32)
        cum = jnp.cumsum(da_i, axis=1)  # [B,q,nh]
        # intra-chunk: attention-like matmul form
        cb = jnp.einsum("bqs,bks->bqk", c_i, b_i)  # [B,q,q]
        seg = cum[:, :, None, :] - cum[:, None, :, :]  # [B,q,k,nh]
        q = x_i.shape[1]
        causal = jnp.tril(jnp.ones((q, q), bool))
        L = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)  # [B,q,k,nh]
        y_intra = jnp.einsum("bqk,bqkh,bkhp->bqhp", cb, L, x_i)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("bqs,bhps,bqh->bqhp", c_i, h, jnp.exp(cum))
        # new chunk state
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)  # [B,q,nh]
        s_new = jnp.einsum("bqhp,bqs,bqh->bhps", x_i, b_i, decay_to_end)
        h_new = jnp.exp(cum[:, -1])[:, :, None, None] * h + s_new
        return h_new, y_intra + y_inter

    h_f, ys = jax.lax.scan(body, h0, (xc, bc, cc, dtc), unroll=unroll)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, nc * chunk, nh, hp)
    return y[:, :S], h_f


def mamba2_apply(p, x, cfg, *, chunk: int = 64):
    """Full-sequence mamba2 block. x: [B,S,d] -> [B,S,d]."""
    from repro.models.layers import rmsnorm

    B, S, _ = x.shape
    di, st, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    z, xbc, dt = _mamba2_split(p, x, cfg)
    xbc = jax.nn.silu(causal_conv1d(xbc, p["conv_w"], p["conv_b"]))
    x1, Bs, Cs = jnp.split(xbc, [di, di + st], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = x1.reshape(B, S, nh, hp)
    h0 = jnp.zeros((B, nh, hp, st), jnp.float32)
    y, _ = _ssd_scan(xh, Bs, Cs, dt, A, h0, chunk, unroll=cfg.scan_unroll)
    y = y + p["D"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"]


def mamba2_init_state(cfg, batch, dtype):
    return {
        "h": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner + 2 * cfg.ssm_state), dtype),
    }


def mamba2_decode(p, x_tok, state, cfg):
    """One decode step. x_tok: [B,d] -> (y [B,d], new state)."""
    from repro.models.layers import rmsnorm

    B = x_tok.shape[0]
    di, st, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    z, xbc, dt = _mamba2_split(p, x_tok, cfg)
    xbc, conv_state = conv1d_step(xbc, state["conv"], p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc)
    x1, Bs, Cs = jnp.split(xbc, [di, di + st], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,nh]
    A = -jnp.exp(p["A_log"])
    xh = x1.reshape(B, nh, hp).astype(jnp.float32)
    decay = jnp.exp(dt * A)  # [B,nh]
    h = decay[:, :, None, None] * state["h"] + jnp.einsum(
        "bhp,bs,bh->bhps", xh, Bs.astype(jnp.float32), dt
    )
    y = jnp.einsum("bhps,bs->bhp", h, Cs.astype(jnp.float32)) + p["D"][:, None] * xh
    y = y.reshape(B, di).astype(x_tok.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], {"h": h, "conv": conv_state}
