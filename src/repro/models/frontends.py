"""Modality frontend STUBS (per the assignment: ``[audio]``/``[vlm]`` specify
the transformer backbone only; ``input_specs()`` provides precomputed
frame/patch embeddings).

These generate synthetic-but-shaped frontend outputs for smoke tests and
examples; the dry-run uses ShapeDtypeStructs of the same shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

VISION_EMBED_DIM = 1024  # InternViT output width (projected to d_model)
AUDIO_FEAT_DIM = 80  # log-mel-like frame features


def vision_patches(key, batch: int, n_patches: int, dtype=jnp.bfloat16):
    """Stub InternViT: precomputed patch embeddings [B, P, 1024]."""
    return jax.random.normal(key, (batch, n_patches, VISION_EMBED_DIM), dtype)


def audio_frames(key, batch: int, n_frames: int, dtype=jnp.bfloat16):
    """Stub wav2vec2-style conv frontend: frame features [B, T, 80]."""
    return jax.random.normal(key, (batch, n_frames, AUDIO_FEAT_DIM), dtype)
