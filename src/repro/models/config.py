"""Model configuration: a single dataclass covering all assigned families
(dense / MoE / SSM / hybrid / VLM-backbone / audio-encoder)."""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # defaults to d_model // n_heads

    # attention
    rope_theta: float = 10000.0
    rope_theta_global: Optional[float] = None  # gemma3 global layers (1M)
    qkv_bias: bool = False
    sliding_window: Optional[int] = None  # SWA on every attn layer (mixtral)
    local_window: Optional[int] = None  # gemma3 local layers
    pattern_local: int = 0  # gemma3: local layers per group
    pattern_global: int = 0  # gemma3: global layers per group
    causal: bool = True  # False => encoder-only (hubert)

    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # ssm (mamba)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_version: int = 1  # 1 = mamba1 (falcon-mamba), 2 = mamba2 (zamba2)
    ssm_headdim: int = 64  # mamba2
    attn_every: int = 0  # hybrid: shared attn block applied every k ssm layers

    # modality frontend (stub: precomputed embeddings are model inputs)
    frontend: Optional[str] = None  # 'vision' | 'audio'
    n_frontend_tokens: int = 0

    # numerics / misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    attn_impl: str = "auto"  # auto | full | flash_scan | pallas
    # cost-calibration knobs (launch/calibrate.py): unroll scans so XLA's
    # HloCostAnalysis (which visits loop bodies once) counts true totals
    scan_unroll: bool = False
    attn_chunk: int = 1024
    ssm_chunk: int = 256
    moe_seq_chunk: int = 8192  # bound MoE dispatch transients at long seq
    kv_cache_dtype: str = "bf16"  # 'bf16' | 'int8' (blockwise-quantized cache)

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.family in ("ssm", "hybrid") and self.ssm_state <= 0:
            raise ValueError(f"{self.name}: ssm family needs ssm_state > 0")
        if self.family == "moe" and (self.n_experts <= 0 or self.top_k <= 0):
            raise ValueError(f"{self.name}: moe family needs experts/top_k")

    # --- derived ---
    @property
    def qk_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, -(-self.d_model // 16))  # ceil(d/16), mamba1 default

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim  # mamba2

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    def n_params(self) -> int:
        """Analytic total parameter count (for 6ND model-flops accounting)."""
        d, f, V, hd = self.d_model, self.d_ff, self.vocab, self.head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        n = emb
        if self.family in ("dense", "vlm", "audio"):
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * hd + self.qk_dim * d
            mlp = 3 * d * f if self.act == "silu" else 2 * d * f
            n += self.n_layers * (attn + mlp + 2 * d) + d
        elif self.family == "moe":
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * hd + self.qk_dim * d
            moe = self.n_experts * 3 * d * f + d * self.n_experts
            n += self.n_layers * (attn + moe + 2 * d) + d
        elif self.family == "ssm":
            di, st, dtr = self.d_inner, self.ssm_state, self.dt_rank
            per = (
                d * 2 * di + self.ssm_conv * di + di
                + di * (dtr + 2 * st) + dtr * di + di * st + di
                + di * d + d
            )
            n += self.n_layers * per + d
        elif self.family == "hybrid":
            di, st = self.d_inner, self.ssm_state
            nh = self.ssm_nheads
            per = (
                d * (2 * di + 2 * st + nh) + self.ssm_conv * (di + 2 * st)
                + nh + di + di * d + d
            )
            n += self.n_layers * per + d
            if self.attn_every:
                attn = d * (self.n_heads + 2 * self.n_kv_heads) * hd + self.qk_dim * d
                n += attn + 3 * d * f + 2 * d  # one shared block
        return n

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.family != "moe":
            return self.n_params()
        d, f = self.d_model, self.d_ff
        inactive = self.n_layers * (self.n_experts - self.top_k) * 3 * d * f
        return self.n_params() - inactive
