"""Mixture-of-Experts: GShard-style top-k routing with grouped dispatch.

Routing/capacity/dispatch are computed **per group** (group = sequence), so
under data parallelism every scatter/cumsum is shard-local: the dispatch
buffer is [G, E, C, d] with G sharded over the DP axes — no cross-shard
token-order dependency (a global cumsum would force GSPMD to replicate the
whole dispatch, ~20 GB/device at 32k prefill).

Implementations:
- ``scatter`` (default): sort-free positions via per-group cumsum over the
  one-hot routing matrix; tokens over capacity are dropped (capacity-factor
  semantics, applied per group as in GShard).
- ``dense``: every expert on every token, mixed by gate weight — O(E) flops
  oracle for tests.

Sharding: expert weights are [E, d, f]; the expert dim maps to the "data"
axis when divisible (EP, llama4 16e/16) else d_ff over "model" (TP within
expert).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def moe_init(key, cfg, dtype):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, E), jnp.float32),  # router in fp32
        "w1": dense_init(ks[1], (E, d, f), dtype, fan_in=d),
        "w3": dense_init(ks[2], (E, d, f), dtype, fan_in=d),
        "w2": dense_init(ks[3], (E, f, d), dtype, fan_in=f),
    }


def _routing(p, x, cfg):
    """x: [..., d] -> (expert_idx [..., k], gates [..., k], probs [..., E])."""
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return idx, gates.astype(x.dtype), probs


def moe_apply(p, x, cfg, *, impl: str = "scatter"):
    """x: [B, S, d] -> ([B, S, d], aux load-balance loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    idx, gates, probs = _routing(p, x, cfg)  # [B,S,k], [B,S,k], [B,S,E]

    # load-balance auxiliary loss (Switch-style): E * sum_e f_e * P_e
    counts = (
        jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    )
    frac_tokens = counts / (B * S * k)
    frac_probs = probs.mean((0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)

    if impl == "dense":
        h1 = jnp.einsum("bsd,edf->bsef", x, p["w1"])
        h3 = jnp.einsum("bsd,edf->bsef", x, p["w3"])
        h = jax.nn.silu(h1) * h3
        y_all = jnp.einsum("bsef,efd->bsed", h, p["w2"])
        onehot = jax.nn.one_hot(idx, E, dtype=x.dtype)  # [B,S,k,E]
        mix = jnp.einsum("bske,bsk->bse", onehot, gates)
        y = jnp.einsum("bsed,bse->bsd", y_all, mix)
        return y, aux

    # --- grouped scatter path (group = sequence slice) ---
    sub = min(cfg.moe_seq_chunk, S)
    if S % sub:
        sub = S
    if sub < S:  # scan over sequence chunks to bound dispatch transients
        nc = S // sub
        xc = x.reshape(B, nc, sub, d).transpose(1, 0, 2, 3)

        def body(_, xi):
            yi, auxi = _dispatch(p, xi, cfg)
            return None, (yi, auxi)

        _, (ys, auxs) = jax.lax.scan(body, None, xc, unroll=cfg.scan_unroll)
        return ys.transpose(1, 0, 2, 3).reshape(B, S, d), aux

    y, _ = _dispatch(p, x, cfg)
    return y, aux


def _dispatch(p, x, cfg):
    """Grouped capacity dispatch on [B, S, d] (one chunk)."""
    from repro.distributed.sharding import constrain

    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    idx, gates, probs = _routing(p, x, cfg)
    counts = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    aux = E * jnp.sum(counts / (B * S * k) * probs.mean((0, 1)))

    G, Tg = B, S
    C = max(1, int(cfg.capacity_factor * Tg * k / E))
    flat_e = idx.reshape(G, Tg * k)  # token-major within group
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # [G, Tg*k, E]
    pos = jnp.cumsum(onehot, axis=1) - onehot
    pos_in_e = jnp.sum(pos * onehot, axis=-1)  # [G, Tg*k]
    keep = pos_in_e < C
    safe_pos = jnp.where(keep, pos_in_e, 0)
    tok_id = jnp.repeat(jnp.arange(Tg), k)  # [Tg*k]

    g_ix = jnp.arange(G)[:, None]
    vals = jnp.where(keep[..., None], x[:, tok_id], 0)  # [G, Tg*k, d]
    buf = jnp.zeros((G, E, C, d), x.dtype)
    buf = constrain(buf.at[g_ix, flat_e, safe_pos].add(vals, mode="drop"), "expert_buf")

    # true EP when E divides the DP axis: the expert_buf -> expert_buf_ep
    # reshard is a token all_to_all; expert weights never leave their shard
    buf = constrain(buf, "expert_buf_ep")
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w1"])) * jnp.einsum(
        "gecd,edf->gecf", buf, p["w3"]
    )
    out_buf = constrain(jnp.einsum("gecf,efd->gecd", h, p["w2"]), "expert_buf_ep")
    out_buf = constrain(out_buf, "expert_buf")

    gathered = out_buf[g_ix, flat_e, safe_pos]  # [G, Tg*k, d]
    gathered = jnp.where(keep[..., None], gathered, 0)
    y = jnp.zeros((G, Tg, d), x.dtype).at[g_ix, tok_id[None, :]].add(
        gathered * gates.reshape(G, Tg * k)[..., None]
    )
    return y, aux
