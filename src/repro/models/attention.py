"""Attention: GQA with RoPE, causal/bidirectional, sliding-window and
local/global variants; three implementations:

- ``full``: materialized scores — smoke tests and short sequences.
- ``flash_scan``: pure-JAX online-softmax over KV chunks (differentiable,
  O(Sq * chunk) memory) — the default for long sequences and the dry-run.
- ``pallas``: the TPU flash kernel (``repro.kernels.flash_attention``) —
  forward hot path on real hardware; numerically validated against ``full``
  in interpret mode.

All variants share mask semantics via ``position-based`` predicates so the
same code path serves prefill (q_offset=0), chunked prefill, and decode
(Sq=1, q_offset=cache_len).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(q_pos, k_pos, *, causal: bool, window: int | None):
    """[Sq, Skv] boolean validity mask from absolute positions."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def _grouped_scores(q, k):
    """q: [B,Sq,KV,rep,hd]; k: [B,Skv,KV,hd] -> [B,KV,rep,Sq,Skv]."""
    return jnp.einsum("bqgrh,bkgh->bgrqk", q, k)


def attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset=0,
    impl: str = "auto",
    chunk: int = 1024,
    k_valid_len=None,
):
    """q: [B,Sq,H,hd]; k,v: [B,Skv,KV,hd] -> [B,Sq,H,hd].

    ``q_offset``: absolute position of q[0] (decode: cache length).
    ``k_valid_len``: optional number of valid cache entries (rest masked).
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    rep = H // KV
    scale = hd**-0.5

    if impl == "auto":
        impl = "full" if Skv <= 2048 else "flash_scan"

    qg = (q * scale).reshape(B, Sq, KV, rep, hd)
    q_pos = q_offset + jnp.arange(Sq)

    if impl == "full":
        k_pos = jnp.arange(Skv)
        s = _grouped_scores(qg, k).astype(jnp.float32)
        m = _mask(q_pos, k_pos, causal=causal, window=window)
        if k_valid_len is not None:
            m &= (k_pos < k_valid_len)[None, :]
        s = jnp.where(m[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        out = jnp.einsum("bgrqk,bkgh->bqgrh", p, v)
        return out.reshape(B, Sq, H, hd)

    if impl == "flash_scan":
        # Tiled in BOTH q (outer scan) and kv (inner scan): transient score
        # block is [B, H, q_chunk, chunk] regardless of sequence lengths.
        q_chunk = min(chunk, Sq) if Sq > 1 else 1
        nq = -(-Sq // q_chunk)
        qpad = nq * q_chunk - Sq
        if qpad:
            qg_p = jnp.pad(qg, ((0, 0), (0, qpad), (0, 0), (0, 0), (0, 0)))
        else:
            qg_p = qg
        qb = qg_p.reshape(B, nq, q_chunk, KV, rep, hd).transpose(1, 0, 2, 3, 4, 5)

        nchunk = -(-Skv // chunk)
        pad = nchunk * chunk - Skv
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kc = k.reshape(B, nchunk, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
        vc = v.reshape(B, nchunk, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
        valid = Skv if k_valid_len is None else k_valid_len

        def q_body(_, q_in):
            q_i, qi_idx = q_in  # [B, qc, KV, rep, hd]
            qpos_i = q_offset + qi_idx * q_chunk + jnp.arange(q_chunk)

            def kv_body(carry, inp):
                m_run, l_run, acc = carry
                ci, k_i, v_i = inp
                k_pos = ci * chunk + jnp.arange(chunk)
                s = _grouped_scores(q_i, k_i).astype(jnp.float32)
                msk = _mask(qpos_i, k_pos, causal=causal, window=window)
                msk &= (k_pos < valid)[None, :]
                s = jnp.where(msk[None, None, None], s, NEG_INF)
                m_new = jnp.maximum(m_run, s.max(-1))
                alpha = jnp.exp(m_run - m_new)
                pr = jnp.exp(s - m_new[..., None])
                l_new = l_run * alpha + pr.sum(-1)
                acc = acc * alpha[..., None] + jnp.einsum(
                    "bgrqk,bkgh->bgrqh", pr.astype(q.dtype), v_i
                ).astype(jnp.float32)
                return (m_new, l_new, acc), None

            m0 = jnp.full((B, KV, rep, q_chunk), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, KV, rep, q_chunk), jnp.float32)
            a0 = jnp.zeros((B, KV, rep, q_chunk, hd), jnp.float32)
            (m_f, l_f, acc), _ = jax.lax.scan(
                kv_body, (m0, l0, a0), (jnp.arange(nchunk), kc, vc)
            )
            out_i = acc / jnp.maximum(l_f, 1e-30)[..., None]
            return None, out_i.astype(q.dtype)  # [B, KV, rep, qc, hd]

        _, outs = jax.lax.scan(q_body, None, (qb, jnp.arange(nq)))
        # outs: [nq, B, KV, rep, qc, hd] -> [B, Sq, H, hd]
        out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * q_chunk, H, hd)
        return out[:, :Sq]

    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops

        return fa_ops.flash_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset
        )

    raise ValueError(f"unknown attention impl {impl!r}")


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int | None = None):
    """Single-token decode: q [B,1,H,hd] against cache [B,S,KV,hd].

    ``cache_len``: number of valid entries (scalar or [B]).  Window masking is
    positional, so a rolling (modular) cache layout is handled by the caller
    (`window`-sized caches store absolute positions implicitly: the caller
    passes positions via cache ordering; here validity+window suffice)."""
    # Always one-block ("full") attention for decode: Sq=1 so the score
    # tensor is [B,H,1,S] (tiny), and critically it PRESERVES the cache's
    # sequence sharding — the flash chunk reshape of a sequence-sharded
    # cache forces GSPMD to all-gather the whole cache every step
    # (measured: 2.7 s/step of ICI time on qwen decode_32k).  Softmax over
    # the sharded S reduces via psum'd stats instead.
    return attention(
        q,
        k_cache,
        v_cache,
        causal=False,  # decode: all valid cache entries precede the query
        window=None,
        q_offset=cache_len,  # not used when causal=False
        impl="full",
        k_valid_len=cache_len if window is None else None,
    )


# ---------------------------------------------------------------------------
# Projection block (init + apply) shared by all transformer layers
# ---------------------------------------------------------------------------


def attn_init(key, cfg, dtype):
    from repro.models.layers import dense_init

    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd), dtype),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * hd), dtype),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * hd), dtype),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, d), dtype, fan_in=cfg.n_heads * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


def qkv_project(p, x, cfg, positions, theta: float):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    if theta > 0:
        from repro.models.layers import apply_rope

        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    from repro.distributed.sharding import constrain

    return constrain(q, "q"), constrain(k, "k"), constrain(v, "v")
