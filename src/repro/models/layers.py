"""Shared functional layers: norms, embeddings, RoPE, MLPs, initializers.

Pure-functional style (param pytrees of jnp arrays); no framework deps.
Compute follows a mixed-precision policy: params in ``cfg.param_dtype``,
matmuls in ``cfg.compute_dtype``, normalization statistics in fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[
        name
    ]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, *, fan_in: int | None = None):
    fan_in = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(dt)


def rmsnorm_init(d, dtype):
    return jnp.zeros((d,), dtype)  # stored as (1 + w) convention


# ---------------------------------------------------------------------------
# Rotary position embeddings (llama-style half rotation)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [B, S, H, hd]; positions: [B, S] or [S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, hd/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(key, d, f, dtype, act: str):
    ks = jax.random.split(key, 3)
    if act == "silu":  # SwiGLU
        return {
            "w1": dense_init(ks[0], (d, f), dtype),
            "w3": dense_init(ks[1], (d, f), dtype),
            "w2": dense_init(ks[2], (f, d), dtype, fan_in=f),
        }
    return {
        "w1": dense_init(ks[0], (d, f), dtype),
        "w2": dense_init(ks[2], (f, d), dtype, fan_in=f),
    }


def mlp_apply(p, x, act: str):
    if act == "silu":
        h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    else:
        h = jax.nn.gelu(x @ p["w1"])
    return h @ p["w2"]


def mlp_flops(d, f, act: str, tokens: int) -> float:
    nmat = 3 if act == "silu" else 2
    return 2.0 * nmat * d * f * tokens
