"""Unified model builder for all assigned architectures.

Structural families (one code path each, params stacked for ``lax.scan``):

- ``dense`` / ``vlm`` / ``audio``: homogeneous decoder/encoder stack
  (llama3.2, minicpm, qwen2.5, internvl2-backbone, hubert).
- ``moe``: dense stack with MoE MLPs (mixtral, llama4-scout).
- ``gemma3``: grouped stack — G groups of (pattern_local local-attention
  layers + pattern_global global layers), dual RoPE theta, dual caches.
- ``ssm``: mamba1 stack (falcon-mamba).
- ``hybrid``: G groups of ``attn_every`` mamba2 layers + ONE weight-shared
  attention/MLP block applied after each group (zamba2).

Interfaces:
  init_params(cfg, key)                              -> params
  forward_train(params, batch, cfg, remat_policy)    -> (loss, metrics)
  init_cache(cfg, batch, max_len)                    -> cache
  forward_prefill(params, batch, cfg)                -> (last_logits, cache)
  forward_decode(params, tok, cache, cache_len, cfg) -> (logits, cache)
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import attention, attn_init, decode_attention, qkv_project
from repro.models.config import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers import (
    dense_init,
    dtype_of,
    embed_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
)

# ---------------------------------------------------------------------------
# Param init
# ---------------------------------------------------------------------------


def _attn_layer_init(key, cfg, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "attn": attn_init(k1, cfg, dtype),
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
    }
    if cfg.family == "moe":
        p["moe"] = moe_lib.moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_init(k3, cfg.d_model, cfg.d_ff, dtype, cfg.act)
    return p


def _stacked(init_one, keys):
    return jax.vmap(init_one)(keys)


def init_params(cfg: ModelConfig, key) -> dict[str, Any]:
    dtype = dtype_of(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": embed_init(keys[0], (cfg.vocab, cfg.d_model), dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab), dtype)

    if cfg.family in ("dense", "moe", "vlm", "audio") and not cfg.pattern_local:
        lkeys = jax.random.split(keys[2], cfg.n_layers)
        params["layers"] = _stacked(
            lambda k: _attn_layer_init(k, cfg, dtype), lkeys
        )
    elif cfg.pattern_local:  # gemma3 grouped local/global
        per = cfg.pattern_local + cfg.pattern_global
        G = cfg.n_layers // per
        lk = jax.random.split(keys[2], G * cfg.pattern_local).reshape(
            G, cfg.pattern_local, -1
        )
        gk = jax.random.split(keys[3], G)
        params["local_layers"] = jax.vmap(
            lambda ks: _stacked(lambda k: _attn_layer_init(k, cfg, dtype), ks)
        )(lk)
        params["global_layers"] = _stacked(
            lambda k: _attn_layer_init(k, cfg, dtype), gk
        )
    elif cfg.family == "ssm":
        lkeys = jax.random.split(keys[2], cfg.n_layers)
        params["layers"] = _stacked(
            lambda k: {
                "mamba": ssm_lib.mamba1_init(k, cfg, dtype),
                "ln": rmsnorm_init(cfg.d_model, dtype),
            },
            lkeys,
        )
    elif cfg.family == "hybrid":
        G = cfg.n_layers // cfg.attn_every
        lk = jax.random.split(keys[2], cfg.n_layers).reshape(G, cfg.attn_every, -1)
        params["mamba_groups"] = jax.vmap(
            lambda ks: _stacked(
                lambda k: {
                    "mamba": ssm_lib.mamba2_init(k, cfg, dtype),
                    "ln": rmsnorm_init(cfg.d_model, dtype),
                },
                ks,
            )
        )(lk)
        params["shared_attn"] = _attn_layer_init(keys[4], cfg, dtype)
    else:
        raise ValueError(f"unknown family {cfg.family}")

    if cfg.frontend == "vision":
        params["patch_proj"] = dense_init(keys[5], (1024, cfg.d_model), dtype)
    elif cfg.frontend == "audio":
        params["frame_proj"] = dense_init(keys[5], (80, cfg.d_model), dtype)
    return params


# ---------------------------------------------------------------------------
# Blocks (full-sequence)
# ---------------------------------------------------------------------------


def _attn_block(lp, x, cfg, positions, *, theta, window, impl):
    from repro.distributed.sharding import kv_repeat_factor

    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = qkv_project(lp["attn"], h, cfg, positions, theta)
    rep = kv_repeat_factor(cfg.n_heads, cfg.n_kv_heads)
    if rep > 1:  # make the kv head count TP-divisible (see sharding.py)
        k = constrain(jnp.repeat(k, rep, axis=2), "k")
        v = constrain(jnp.repeat(v, rep, axis=2), "v")
    o = attention(q, k, v, causal=cfg.causal, window=window, impl=impl,
                  chunk=min(cfg.attn_chunk, x.shape[1]))
    B, S = x.shape[:2]
    return constrain(x + o.reshape(B, S, -1) @ lp["attn"]["wo"], "tokens")


def _mlp_block(lp, x, cfg):
    h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = moe_lib.moe_apply(lp["moe"], h, cfg)
        return x + y, aux
    return x + mlp_apply(lp["mlp"], h, cfg.act), jnp.zeros((), jnp.float32)


def _decoder_layer(lp, x, cfg, positions, *, theta=None, window="default", impl=None):
    theta = cfg.rope_theta if theta is None else theta
    window = cfg.sliding_window if window == "default" else window
    impl = cfg.attn_impl if impl is None else impl
    x = _attn_block(lp, x, cfg, positions, theta=theta, window=window, impl=impl)
    return _mlp_block(lp, x, cfg)


def _embed_inputs(params, batch, cfg):
    """Token/frontend embedding. Returns (x [B,S,d], label_offset)."""
    if cfg.frontend == "audio":
        x = batch["frames"] @ params["frame_proj"]
        return x, 0
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.frontend == "vision" and "patches" in batch:
        vis = batch["patches"] @ params["patch_proj"]
        x = jnp.concatenate([vis, x], axis=1)
        return x, vis.shape[1]
    return x, 0


def _logits(params, x, cfg):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return constrain((x @ head).astype(jnp.float32), "logits")


# ---------------------------------------------------------------------------
# Full forward (train / prefill share the stack traversal)
# ---------------------------------------------------------------------------


def _run_stack(params, x, cfg, positions, remat_policy=None):
    """Run the layer stack. Returns (x, aux_loss)."""

    def maybe_remat(fn):
        if remat_policy is None:
            return fn
        return jax.checkpoint(fn, policy=remat_policy)

    aux0 = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "moe", "vlm", "audio") and not cfg.pattern_local:

        def body(carry, lp):
            h, aux = carry
            h = _attn_block(
                lp, h, cfg, positions,
                theta=cfg.rope_theta, window=cfg.sliding_window, impl=cfg.attn_impl,
            )
            h, aux_l = _mlp_block(lp, h, cfg)
            return (h, aux + aux_l), None

        (x, aux), _ = jax.lax.scan(maybe_remat(body), (x, aux0), params["layers"], unroll=cfg.scan_unroll)
        return x, aux

    if cfg.pattern_local:  # gemma3 grouped local/global
        theta_g = cfg.rope_theta_global or cfg.rope_theta

        def local_body(carry, lp):
            h, aux = carry
            h = _attn_block(
                lp, h, cfg, positions,
                theta=cfg.rope_theta, window=cfg.local_window, impl=cfg.attn_impl,
            )
            h, aux_l = _mlp_block(lp, h, cfg)
            return (h, aux + aux_l), None

        def global_block(carry, glp):
            h, aux = carry
            h = _attn_block(
                glp, h, cfg, positions,
                theta=theta_g, window=None, impl=cfg.attn_impl,
            )
            h, aux_g = _mlp_block(glp, h, cfg)
            return (h, aux + aux_g)

        def group_body(carry, gp):
            carry, _ = jax.lax.scan(maybe_remat(local_body), carry, gp["local"], unroll=cfg.scan_unroll)
            return maybe_remat(global_block)(carry, gp["global"]), None

        groups = {"local": params["local_layers"], "global": params["global_layers"]}
        (x, aux), _ = jax.lax.scan(group_body, (x, aux0), groups, unroll=cfg.scan_unroll)
        return x, aux

    if cfg.family == "ssm":

        def body(carry, lp):
            h = carry + ssm_lib.mamba1_apply(
                lp["mamba"], rmsnorm(carry, lp["ln"], cfg.norm_eps), cfg,
                chunk=cfg.ssm_chunk,
            )
            return h, None

        x, _ = jax.lax.scan(maybe_remat(body), x, params["layers"], unroll=cfg.scan_unroll)
        return x, aux0

    if cfg.family == "hybrid":
        shared = params["shared_attn"]

        def mamba_body(carry, lp):
            h = carry + ssm_lib.mamba2_apply(
                lp["mamba"], rmsnorm(carry, lp["ln"], cfg.norm_eps), cfg,
                chunk=min(cfg.ssm_chunk, carry.shape[1]),
            )
            return h, None

        def shared_block(h, aux):
            h = _attn_block(
                shared, h, cfg, positions,
                theta=cfg.rope_theta, window=None, impl=cfg.attn_impl,
            )
            h, aux_g = _mlp_block(shared, h, cfg)
            return (h, aux + aux_g)

        def group_body(carry, gp):
            h, aux = carry
            h, _ = jax.lax.scan(maybe_remat(mamba_body), h, gp, unroll=cfg.scan_unroll)
            return maybe_remat(shared_block)(h, aux), None

        (x, aux), _ = jax.lax.scan(group_body, (x, aux0), params["mamba_groups"], unroll=cfg.scan_unroll)
        return x, aux

    raise ValueError(cfg.family)


def _train_head(params, x, aux, batch, cfg: ModelConfig, label_offset: int = 0):
    """Final norm + logits + CE loss on the stack output ``x``.

    Reads only ``final_norm`` and the output head (``lm_head``, or
    ``embed`` when tied) from ``params``.  Split out of
    :func:`forward_train` so the ready-bucket overlap path can take its
    VJP separately from the stack and embedding segments (DESIGN.md S16)
    while both paths share the exact same ops.
    """
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if label_offset:
        x = x[:, label_offset:]
    logits = _logits(params, x, cfg)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    # CE via vocab-axis reductions only: take_along_axis would gather across
    # the vocab-sharded logits (an all-gather of the full fp32 logits under
    # GSPMD); max/sum reductions and the iota-match partition cleanly.
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    V = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    lab = jnp.sum(jnp.where(iota == safe[..., None], shifted, 0.0), axis=-1)
    ll = lab - lse
    ntok = jnp.maximum(mask.sum(), 1.0)
    loss = -(ll * mask).sum() / ntok
    per_example = -(ll * mask).sum(-1) / jnp.maximum(mask.sum(-1), 1.0)  # [B]
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux, "ntok": ntok, "per_example": per_example}


def forward_train(params, batch, cfg: ModelConfig, remat_policy=None):
    """Returns (loss, metrics)."""
    x, label_offset = _embed_inputs(params, batch, cfg)
    x = constrain(x.astype(dtype_of(cfg.compute_dtype)), "tokens")
    B, S = x.shape[:2]
    positions = jnp.arange(S)[None, :]
    x, aux = _run_stack(params, x, cfg, positions, remat_policy)
    return _train_head(params, x, aux, batch, cfg, label_offset)


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def _kv_store_dtype(cfg):
    return jnp.int8 if cfg.kv_cache_dtype == "int8" else dtype_of(cfg.compute_dtype)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dtype = _kv_store_dtype(cfg)
    hd, KV = cfg.head_dim, cfg.n_kv_heads
    if cfg.family in ("dense", "moe", "vlm") and not cfg.pattern_local:
        W = min(max_len, cfg.sliding_window or max_len)
        L = cfg.n_layers
        cache = {
            "k": jnp.zeros((L, batch, W, KV, hd), dtype),
            "v": jnp.zeros((L, batch, W, KV, hd), dtype),
        }
        if cfg.kv_cache_dtype == "int8":
            cache["k_scale"] = jnp.zeros((L, batch, W, KV), jnp.float32)
            cache["v_scale"] = jnp.zeros((L, batch, W, KV), jnp.float32)
        return cache
    if cfg.pattern_local:
        per = cfg.pattern_local + cfg.pattern_global
        G = cfg.n_layers // per
        Wl = min(max_len, cfg.local_window or max_len)
        return {
            "local_k": jnp.zeros((G, cfg.pattern_local, batch, Wl, KV, hd), dtype),
            "local_v": jnp.zeros((G, cfg.pattern_local, batch, Wl, KV, hd), dtype),
            "global_k": jnp.zeros((G, batch, max_len, KV, hd), dtype),
            "global_v": jnp.zeros((G, batch, max_len, KV, hd), dtype),
        }
    if cfg.family == "ssm":
        L = cfg.n_layers
        return {
            "h": jnp.zeros((L, batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        }
    if cfg.family == "hybrid":
        G = cfg.n_layers // cfg.attn_every
        k = cfg.attn_every
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        return {
            "m_h": jnp.zeros(
                (G, k, batch, cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32
            ),
            "m_conv": jnp.zeros((G, k, batch, cfg.ssm_conv - 1, conv_dim), dtype),
            "attn_k": jnp.zeros((G, batch, max_len, KV, hd), dtype),
            "attn_v": jnp.zeros((G, batch, max_len, KV, hd), dtype),
        }
    raise ValueError(f"no cache for family {cfg.family}")


def _quant_heads(x):
    """x: [B,1,KV,hd] -> (int8 [B,1,KV,hd], scale f32 [B,1,KV])."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def _write_cache(kc, vc, k_new, v_new, cache_len, window: int | None,
                 ks=None, vs=None):
    """kc/vc: [B,W,KV,hd]; k_new/v_new: [B,1,KV,hd]. Rolling write for windows.
    int8 caches also update the per-(token, head) scale planes (ks/vs)."""
    W = kc.shape[1]
    idx = cache_len % W if window is not None else cache_len
    if kc.dtype == jnp.int8:
        k_q, k_s = _quant_heads(k_new)
        v_q, v_s = _quant_heads(v_new)
        kc = jax.lax.dynamic_update_slice(kc, k_q, (0, idx, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v_q, (0, idx, 0, 0))
        ks = jax.lax.dynamic_update_slice(ks, k_s, (0, idx, 0))
        vs = jax.lax.dynamic_update_slice(vs, v_s, (0, idx, 0))
    else:
        kc = constrain(jax.lax.dynamic_update_slice(kc, k_new, (0, idx, 0, 0)), "cache_k")
        vc = constrain(jax.lax.dynamic_update_slice(vc, v_new, (0, idx, 0, 0)), "cache_v")
    valid = jnp.minimum(cache_len + 1, W)
    return kc, vc, ks, vs, valid


def _dequant_cache(c, s, out_dtype):
    """c: int8 [B,W,KV,hd]; s: f32 [B,W,KV] -> [B,W,KV,hd] out_dtype."""
    return (c.astype(jnp.float32) * s[..., None]).astype(out_dtype)


def _decode_attn_layer(lp, x_tok, kc, vc, cache_len, cfg, *, theta, window,
                       ks=None, vs=None):
    """x_tok: [B,d]. Returns (x, kc, vc[, ks, vs])."""
    B = x_tok.shape[0]
    h = rmsnorm(x_tok[:, None], lp["ln1"], cfg.norm_eps)
    pos = jnp.full((B, 1), cache_len, jnp.int32)
    q, k, v = qkv_project(lp["attn"], h, cfg, pos, theta)
    kc, vc, ks, vs, valid = _write_cache(kc, vc, k, v, cache_len, window, ks, vs)
    cdt = dtype_of(cfg.compute_dtype)
    if kc.dtype == jnp.int8:
        k_at = _dequant_cache(kc, ks, cdt)
        v_at = _dequant_cache(vc, vs, cdt)
    else:
        k_at, v_at = kc, vc
    o = decode_attention(q, k_at, v_at, valid)
    x = x_tok + (o.reshape(B, -1) @ lp["attn"]["wo"])
    if cfg.family == "moe":
        y, _ = moe_lib.moe_apply(lp["moe"], rmsnorm(x[:, None], lp["ln2"], cfg.norm_eps), cfg)
        x = x + y[:, 0]
    else:
        y = mlp_apply(lp["mlp"], rmsnorm(x[:, None], lp["ln2"], cfg.norm_eps), cfg.act)
        x = x + y[:, 0]
    if ks is not None:
        return x, kc, vc, ks, vs
    return x, kc, vc


def forward_decode(params, tokens, cache, cache_len, cfg: ModelConfig):
    """One decode step.  tokens: [B] int32.  Returns (logits [B,V], cache)."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype_of(cfg.compute_dtype))

    if cfg.family in ("dense", "moe", "vlm") and not cfg.pattern_local:
        int8 = "k_scale" in cache

        if int8:
            def body(carry, xs):
                lp, kc, vc, ks, vs = xs
                h, kc, vc, ks, vs = _decode_attn_layer(
                    lp, carry, kc, vc, cache_len, cfg,
                    theta=cfg.rope_theta, window=cfg.sliding_window,
                    ks=ks, vs=vs,
                )
                return h, (kc, vc, ks, vs)

            x, (knew, vnew, ksn, vsn) = jax.lax.scan(
                body, x,
                (params["layers"], cache["k"], cache["v"],
                 cache["k_scale"], cache["v_scale"]),
                unroll=cfg.scan_unroll,
            )
            cache = {"k": knew, "v": vnew, "k_scale": ksn, "v_scale": vsn}
        else:
            def body(carry, xs):
                lp, kc, vc = xs
                h, kc, vc = _decode_attn_layer(
                    lp, carry, kc, vc, cache_len, cfg,
                    theta=cfg.rope_theta, window=cfg.sliding_window,
                )
                return h, (kc, vc)

            x, (knew, vnew) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]), unroll=cfg.scan_unroll)
            cache = {"k": knew, "v": vnew}

    elif cfg.pattern_local:  # gemma3
        theta_g = cfg.rope_theta_global or cfg.rope_theta

        def local_body(carry, xs):
            lp, kc, vc = xs
            h, kc, vc = _decode_attn_layer(
                lp, carry, kc, vc, cache_len, cfg,
                theta=cfg.rope_theta, window=cfg.local_window,
            )
            return h, (kc, vc)

        def group_body(carry, xs):
            gp_local, lkc, lvc, gp_global, gkc, gvc = xs
            h, (lk, lv) = jax.lax.scan(local_body, carry, (gp_local, lkc, lvc), unroll=cfg.scan_unroll)
            h, gk, gv = _decode_attn_layer(
                gp_global, h, gkc, gvc, cache_len, cfg, theta=theta_g, window=None
            )
            return h, (lk, lv, gk, gv)

        x, (lk, lv, gk, gv) = jax.lax.scan(
            group_body,
            x,
            (
                params["local_layers"], cache["local_k"], cache["local_v"],
                params["global_layers"], cache["global_k"], cache["global_v"],
            ),
            unroll=cfg.scan_unroll,
        )
        cache = {"local_k": lk, "local_v": lv, "global_k": gk, "global_v": gv}

    elif cfg.family == "ssm":

        def body(carry, xs):
            lp, h_st, conv_st = xs
            y, new = ssm_lib.mamba1_decode(
                lp["mamba"], rmsnorm(carry[:, None], lp["ln"], cfg.norm_eps)[:, 0],
                {"h": h_st, "conv": conv_st}, cfg,
            )
            return carry + y, (new["h"], new["conv"])

        x, (h_new, conv_new) = jax.lax.scan(body, x, (params["layers"], cache["h"], cache["conv"])
        , unroll=cfg.scan_unroll)
        cache = {"h": h_new, "conv": conv_new}

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def mamba_body(carry, xs):
            lp, h_st, conv_st = xs
            y, new = ssm_lib.mamba2_decode(
                lp["mamba"], rmsnorm(carry[:, None], lp["ln"], cfg.norm_eps)[:, 0],
                {"h": h_st, "conv": conv_st}, cfg,
            )
            return carry + y, (new["h"], new["conv"])

        def group_body(carry, xs):
            gp, mh, mconv, akc, avc = xs
            h, (mh2, mc2) = jax.lax.scan(mamba_body, carry, (gp, mh, mconv), unroll=cfg.scan_unroll)
            h, ak2, av2 = _decode_attn_layer(
                shared, h, akc, avc, cache_len, cfg, theta=cfg.rope_theta, window=None
            )
            return h, (mh2, mc2, ak2, av2)

        x, (mh, mc, ak, av) = jax.lax.scan(
            group_body,
            x,
            (
                params["mamba_groups"], cache["m_h"], cache["m_conv"],
                cache["attn_k"], cache["attn_v"],
            ),
            unroll=cfg.scan_unroll,
        )
        cache = {"m_h": mh, "m_conv": mc, "attn_k": ak, "attn_v": av}
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(x[:, None], params["final_norm"], cfg.norm_eps)[:, 0]
    return _logits(params, x, cfg), cache


def _paged_attn_layer(lp, x_tok, kp, vp, tables, lengths, pb, off, cfg, *,
                      theta):
    """Decode attention layer over block-paged K/V pools.

    x_tok: [S, d]; kp/vp: [N, bs, KV, hd] physical pools (one layer);
    tables: [S, nb]; lengths: [S]; (pb, off): precomputed physical
    (block, offset) of each slot's write (trash-redirected for masked
    slots).  The Pallas paged-attention kernel reads K/V through the block
    table — no contiguous views are materialized.
    """
    from repro.kernels.flash_attention.ops import paged_attention

    S = x_tok.shape[0]
    h = rmsnorm(x_tok[:, None], lp["ln1"], cfg.norm_eps)
    pos = lengths[:, None]
    q, k, v = qkv_project(lp["attn"], h, cfg, pos, theta)
    kp = kp.at[pb, off].set(k[:, 0])
    vp = vp.at[pb, off].set(v[:, 0])
    o = paged_attention(q[:, 0], kp, vp, tables, lengths + 1)
    x = x_tok + (o.reshape(S, -1) @ lp["attn"]["wo"])
    if cfg.family == "moe":
        y, _ = moe_lib.moe_apply(
            lp["moe"], rmsnorm(x[:, None], lp["ln2"], cfg.norm_eps), cfg
        )
        x = x + y[:, 0]
    else:
        y = mlp_apply(
            lp["mlp"], rmsnorm(x[:, None], lp["ln2"], cfg.norm_eps), cfg.act
        )
        x = x + y[:, 0]
    return x, kp, vp


def forward_decode_paged(params, tokens, pages, tables, slot_state, lengths,
                         cfg: ModelConfig, *, block_size: int, write_ok=None):
    """One batched decode step over a block-paged cache (DESIGN.md S14).

    tokens: [S] int32; pages: paged cache pools (``k``/``v`` [L,N,bs,KV,hd]
    for dense/moe/vlm, ``attn_k``/``attn_v`` [G,N,bs,KV,hd] for hybrid);
    tables: [S, nb]; slot_state: per-slot leaves (hybrid ``m_h``/``m_conv``);
    lengths: [S]; write_ok: [S] bool (False redirects the slot's cache write
    to the trash block 0).  Returns (logits [S, V], pages, slot_state).

    Attention runs through the Pallas paged kernel per layer; all other math
    matches :func:`forward_decode`.  int8 KV quantization is served by the
    gather path instead (``make_paged_pool_decode_step(attn="gather")``).
    """
    if "k_scale" in pages:
        raise NotImplementedError(
            "int8 paged decode is served by the gather path (attn='gather')"
        )
    S = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype_of(cfg.compute_dtype))
    ok = jnp.ones((S,), bool) if write_ok is None else write_ok
    pb = jnp.take_along_axis(tables, (lengths // block_size)[:, None], axis=1)[:, 0]
    pb = jnp.where(ok, pb, 0)
    off = jnp.where(ok, lengths % block_size, 0)

    if cfg.family in ("dense", "moe", "vlm") and not cfg.pattern_local:

        def body(carry, xs):
            lp, kp, vp = xs
            h, kp, vp = _paged_attn_layer(
                lp, carry, kp, vp, tables, lengths, pb, off, cfg,
                theta=cfg.rope_theta,
            )
            return h, (kp, vp)

        x, (k2, v2) = jax.lax.scan(
            body, x, (params["layers"], pages["k"], pages["v"]),
            unroll=cfg.scan_unroll,
        )
        pages2 = {"k": k2, "v": v2}
        slot2 = {}

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def mamba_body(carry, xs):
            lp, h_st, conv_st = xs
            y, new = ssm_lib.mamba2_decode(
                lp["mamba"], rmsnorm(carry[:, None], lp["ln"], cfg.norm_eps)[:, 0],
                {"h": h_st, "conv": conv_st}, cfg,
            )
            return carry + y, (new["h"], new["conv"])

        def group_body(carry, xs):
            gp, mh, mconv, akp, avp = xs
            h, (mh2, mc2) = jax.lax.scan(
                mamba_body, carry, (gp, mh, mconv), unroll=cfg.scan_unroll
            )
            h, ak2, av2 = _paged_attn_layer(
                shared, h, akp, avp, tables, lengths, pb, off, cfg,
                theta=cfg.rope_theta,
            )
            return h, (mh2, mc2, ak2, av2)

        x, (mh, mc, ak, av) = jax.lax.scan(
            group_body, x,
            (params["mamba_groups"], slot_state["m_h"], slot_state["m_conv"],
             pages["attn_k"], pages["attn_v"]),
            unroll=cfg.scan_unroll,
        )
        pages2 = {"attn_k": ak, "attn_v": av}
        slot2 = {"m_h": mh, "m_conv": mc}
    else:
        raise ValueError(f"family {cfg.family!r} has no paged decode path")

    x = rmsnorm(x[:, None], params["final_norm"], cfg.norm_eps)[:, 0]
    return _logits(params, x, cfg), pages2, slot2
