import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines: jax locks the device count on first init.
# Only the dry-run sees 512 placeholder devices; tests/benches see 1.

"""Multi-pod dry-run: ``.lower().compile()`` every (arch x shape x mesh) cell
on the production meshes, record memory/cost analysis + collective bytes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --list
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import registry, shapes
from repro.distributed import sharding as shd
from repro.distributed import step as step_lib
from repro.launch import roofline
from repro.launch.mesh import make_mesh_by_name
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.layers import dtype_of
from repro.optim.optimizer import OptimizerConfig

# per-arch PER-DEVICE microbatch size (sequences) for train_4k: keeps saved
# residual-stream activations (L x B_mb_loc x S x d, bf16) <= ~4 GB/device.
# The microbatch COUNT is mesh-derived: mb = B / (dp * B_mb_loc), so the
# local working set is identical on single- and multi-pod meshes.
LOCAL_MICROBATCH_SEQS = {
    "mixtral-8x7b": 2,
    "llama4-scout-17b-a16e": 1,
    "llama3.2-1b": 8,
    "minicpm-2b": 2,
    "gemma3-12b": 2,
    "qwen2.5-32b": 1,
    "falcon-mamba-7b": 2,
    "zamba2-2.7b": 2,
    "internvl2-1b": 4,
    "hubert-xlarge": 8,
}


def microbatches_for(arch: str, global_batch: int, mesh) -> int:
    dp = 1
    for a in mesh.axis_names:
        if a != "model":
            dp *= mesh.shape[a]
    loc = LOCAL_MICROBATCH_SEQS.get(arch, 2)
    target = max(1, global_batch // (dp * loc))
    # snap to a divisor of the global batch; prefer per-microbatch batches
    # that stay DP-divisible (non-power-of-two DP groups fall back to the
    # largest plain divisor <= target)
    divisors = [m for m in range(1, global_batch + 1) if global_batch % m == 0]
    good = [m for m in divisors if m <= target and (global_batch // m) % dp == 0]
    if good:
        return max(good)
    ok = [m for m in divisors if m <= target]
    return max(ok) if ok else 1


def input_specs(cfg: ModelConfig, cell: shapes.ShapeCell):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    cdt = dtype_of(cfg.compute_dtype)
    sds = jax.ShapeDtypeStruct
    if cell.kind in ("train", "prefill"):
        if cfg.frontend == "audio":
            batch = {
                "frames": sds((B, S, 80), cdt),
                "labels": sds((B, S), i32),
            }
        elif cfg.frontend == "vision":
            s_text = S - cfg.n_frontend_tokens
            batch = {
                "tokens": sds((B, s_text), i32),
                "labels": sds((B, s_text), i32),
                "patches": sds((B, cfg.n_frontend_tokens, 1024), cdt),
            }
        else:
            batch = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
        if cell.kind == "prefill":
            batch.pop("labels")
        return batch
    # decode: one new token against a cache of length S
    cache = jax.eval_shape(lambda: transformer.init_cache(cfg, B, S))
    return {
        "tokens": sds((B,), i32),
        "cache": cache,
        "cache_len": sds((), i32),
    }


_F32CONV_RE = None


def estimate_bf16_upcast_bytes(hlo_text: str, param_shapes: set) -> int:
    """XLA *CPU* upcasts bf16 dot operands to f32, materializing f32 copies of
    whole stacked weight arrays (L-proportional temp).  TPU MXUs consume bf16
    natively, so these buffers don't exist on the target.  Sum the f32
    ``convert`` results whose dims exactly match a parameter shape — reported
    as ``bf16_upcast_weight_bytes`` and subtracted in
    ``temp_bytes_tpu_adjusted`` (see EXPERIMENTS.md methodology)."""
    import re as _re

    total = 0
    for m in _re.finditer(r"f32\[([\d,]+)\][^=]*? convert\(", hlo_text):
        dims = tuple(int(x) for x in m.group(1).split(","))
        if dims in param_shapes:
            n = 1
            for d_ in dims:
                n *= d_
            total += n * 4
    return total


def _cost(compiled):
    try:
        c = compiled.cost_analysis()
        if isinstance(c, (list, tuple)):
            c = c[0]
        return dict(c) if c else {}
    except Exception:
        return {}


def _memory(compiled):
    try:
        m = compiled.memory_analysis()
        if m is None:
            return {}
        return {
            "argument_bytes": getattr(m, "argument_size_in_bytes", None),
            "output_bytes": getattr(m, "output_size_in_bytes", None),
            "temp_bytes": getattr(m, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(m, "generated_code_size_in_bytes", None),
            "alias_bytes": getattr(m, "alias_size_in_bytes", None),
        }
    except Exception:
        return {}


def run_cell(
    arch: str,
    shape_name: str,
    mesh_name: str,
    *,
    grad_sync: str = "gspmd",
    microbatches: int | None = None,
    remat: str = "full",
    overrides: dict | None = None,
    verbose: bool = True,
) -> dict:
    import dataclasses as _dc

    cfg = registry.get_config(arch)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    cell = shapes.SHAPES[shape_name]
    skip = shapes.skip_reason(arch, shape_name)
    if skip:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name, "skipped": skip}

    mesh = make_mesh_by_name(mesh_name)
    # elastic/non-p2 meshes: round the global batch down to a DP multiple —
    # exactly what an elastic controller does after a shrink (the alternative
    # is replicating the whole batch on every device).
    import dataclasses as _dc2

    dp = 1
    for a in mesh.axis_names:
        if a != "model":
            dp *= mesh.shape[a]
    if cell.global_batch % dp:
        cell = _dc2.replace(cell, global_batch=(cell.global_batch // dp) * dp)
    t0 = time.time()
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "grad_sync": grad_sync,
        "chips": int(np.prod(list(mesh.shape.values()))),
        "global_batch": cell.global_batch,
    }

    with mesh:
        if cell.kind == "train":
            mb = microbatches or microbatches_for(arch, cell.global_batch, mesh)
            tcfg = step_lib.TrainConfig(
                microbatches=mb,
                remat=remat,
                grad_sync=grad_sync,
                monitor=True,
                optimizer=OptimizerConfig(),
            )
            result["microbatches"] = mb
            result["remat"] = remat
            train_step, init_state, state_specs, rules = step_lib.make_train_step(
                cfg, mesh, tcfg
            )
            state_sds = jax.eval_shape(init_state, jax.random.PRNGKey(0))
            specs = state_specs(state_sds)
            st_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
            batch_sds = input_specs(cfg, cell)
            b_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                step_lib.batch_specs(cfg, rules, batch_sds),
            )
            lowered = jax.jit(
                train_step,
                in_shardings=(st_sh, b_sh),
                out_shardings=(st_sh, None),
                donate_argnums=(0,),
            ).lower(state_sds, batch_sds)
        elif cell.kind == "prefill":
            prefill_step, rules = step_lib.make_prefill_step(cfg, mesh)
            params_sds = jax.eval_shape(
                lambda: transformer.init_params(cfg, jax.random.PRNGKey(0))
            )
            p_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                shd.param_specs(cfg, rules, params_sds),
            )
            batch_sds = input_specs(cfg, cell)
            b_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                step_lib.batch_specs(cfg, rules, batch_sds),
            )
            lowered = jax.jit(
                prefill_step, in_shardings=(p_sh, b_sh)
            ).lower(params_sds, batch_sds)
        else:  # decode
            serve_step, rules = step_lib.make_serve_step(cfg, mesh)
            params_sds = jax.eval_shape(
                lambda: transformer.init_params(cfg, jax.random.PRNGKey(0))
            )
            p_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                shd.param_specs(cfg, rules, params_sds),
            )
            ins = input_specs(cfg, cell)
            c_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                step_lib.cache_specs(cfg, rules, ins["cache"]),
            )
            tok_spec = NamedSharding(
                mesh, P(rules.batch_axes(cell.global_batch))
            )
            lowered = jax.jit(
                serve_step,
                in_shardings=(p_sh, tok_spec, c_sh, NamedSharding(mesh, P())),
                out_shardings=(None, c_sh),
                donate_argnums=(2,),
            ).lower(params_sds, ins["tokens"], ins["cache"], ins["cache_len"])

    result["lower_seconds"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    result["compile_seconds"] = round(time.time() - t1, 2)

    cost = _cost(compiled)
    result["cost"] = {
        "flops": cost.get("flops"),
        "bytes_accessed": cost.get("bytes accessed"),
    }
    result["memory"] = _memory(compiled)
    hlo = compiled.as_text()
    result["collective_bytes"] = roofline.parse_collective_bytes(hlo)
    # per-device (sharded) param shapes for the CPU-upcast adjustment
    pshapes = set()
    try:
        if cell.kind == "train":
            srcs = [(state_sds["params"], st_sh["params"])]
        elif cell.kind == "decode":
            srcs = [(params_sds, p_sh), (ins["cache"], c_sh)]
        else:
            srcs = [(params_sds, p_sh)]
        for src, shardings in srcs:
            for leaf, sh in zip(jax.tree.leaves(src), jax.tree.leaves(shardings)):
                pshapes.add(tuple(sh.shard_shape(leaf.shape)))
    except Exception:
        pass
    upcast = estimate_bf16_upcast_bytes(hlo, pshapes)
    result["bf16_upcast_weight_bytes"] = upcast
    tb = result["memory"].get("temp_bytes")
    if tb is not None:
        result["memory"]["temp_bytes_tpu_adjusted"] = tb - upcast
    result["hlo_collective_counts"] = {
        k: hlo.count(f" {k}(") + hlo.count(f" {k}-start(")
        for k in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
    }
    result["model_flops"] = roofline.model_flops_for(cfg, cell)
    if verbose:
        print(json.dumps({k: v for k, v in result.items() if k != "cost"}, indent=1))
        print("memory_analysis:", result["memory"])
        print("cost_analysis flops:", result["cost"])
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "nonp2"])
    ap.add_argument("--grad-sync", default="gspmd")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for a in registry.list_archs():
            print(a, "->", shapes.cells_for(a))
        return

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for a in registry.list_archs():
            for s in shapes.cells_for(a):
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape_name in cells:
        tag = f"{arch}__{shape_name}__{args.mesh}"
        if args.grad_sync != "gspmd":
            tag += f"__{args.grad_sync}"
        out_path = os.path.join(args.out, tag + ".json")
        if os.path.exists(out_path):
            print(f"[skip] {tag} (exists)")
            continue
        print(f"[run ] {tag}")
        try:
            res = run_cell(
                arch, shape_name, args.mesh,
                grad_sync=args.grad_sync,
                microbatches=args.microbatches,
                remat=args.remat,
            )
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append(tag)
            res = {"arch": arch, "shape": shape_name, "mesh": args.mesh,
                   "error": f"{type(e).__name__}: {e}"}
        with open(out_path, "w") as f:
            json.dump(res, f, indent=1)
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
