"""Training driver.

Usage (CPU demo sizes):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \\
      --steps 50 --batch 8 --seq 64 --grad-sync mrd_zero1

On a real cluster the same driver runs the full config on the production
mesh (remove --smoke); the dry-run (launch/dryrun.py) proves those programs
compile and fit.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro import compat, obs
from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import registry
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.distributed import gradsync
from repro.distributed import step as step_lib
from repro.optim.optimizer import OptimizerConfig


def build_mesh(dp: int, tp: int):
    axes = ("data", "model") if tp > 1 else ("data",)
    shape = (dp, tp) if tp > 1 else (dp,)
    n = dp * tp
    return compat.make_mesh(
        shape, axes, axis_types=compat.default_axis_types(len(axes)),
        devices=jax.devices()[:n],
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.list_archs())
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-sync", default="gspmd",
                    choices=gradsync.available())
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "full", "dots"])
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd", "const"])
    ap.add_argument("--monitor-threshold", type=float, default=0.0,
                    help="stop when the staged-MRD-certified loss < threshold")
    ap.add_argument("--monitor-mode", default="inexact",
                    choices=["inexact", "exact", "interval"])
    ap.add_argument("--no-error-feedback", action="store_true",
                    help="disable the EF-SGD residual carry of the "
                         "'compressed' grad-sync mode")
    ap.add_argument("--overlap", action="store_true", default=False,
                    help="ready-bucket grad-sync overlap (DESIGN.md S16): "
                         "issue each gradient bucket's MRD stages as its "
                         "backward segment completes; bit-identical to the "
                         "post-backward path (gradient-scale modes only)")
    ap.add_argument("--no-overlap", dest="overlap", action="store_false",
                    help="post-backward bucketed grad sync (the default)")
    ap.add_argument("--no-donate", action="store_true",
                    help="never donate the train state to jit (donation is "
                         "already skipped on CPU, where it deadlocks "
                         "shard_map strategies like mrd_leaf)")
    ap.add_argument("--elastic-policy", default=None,
                    help="drive training through the elastic runtime with "
                         "this resize policy (any ELASTIC_POLICIES entry: "
                         "static | shrink_on_failure | grow_on_join | "
                         "drain_straggler); default: plain train loop")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-every-seconds", type=float, default=None,
                    help="also snapshot whenever this much wall time has "
                         "passed since the last save (time-based policy; "
                         "combines with --ckpt-every, whichever fires first)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--bucket-bytes", type=int, default=32 * 2**20,
                    help="cap per gradient bucket for the pipelined "
                         "collective engine (0 = one bucket per dtype)")
    ap.add_argument("--telemetry", default=None, metavar="SINK[:PATH]",
                    help="enable the obs subsystem (DESIGN.md S18): "
                         "null | jsonl[:f.jsonl] | csv[:f.csv] | "
                         "chrome_trace[:trace.json] (load in Perfetto / "
                         "chrome://tracing)")
    args = ap.parse_args(argv)

    if args.telemetry:
        from repro import obs

        try:
            obs.configure(args.telemetry)
        except ValueError as e:
            raise SystemExit(f"--telemetry: {e}")
    try:
        return _main(args)
    finally:
        if args.telemetry:
            from repro import obs

            t = obs.shutdown()
            dest = getattr(obs.telemetry().sink, "path", None)
            print(f"# telemetry[{t['sink']}]: {t['spans']} spans, "
                  f"{t['instants']} instants, "
                  f"{t['events_dropped'] + t['metrics_dropped']} dropped"
                  + (f" -> {dest}" if dest else ""))


def _main(args):

    cfg = (
        registry.get_smoke_config(args.arch) if args.smoke else registry.get_config(args.arch)
    )
    mesh = build_mesh(args.dp, args.tp)
    tcfg = step_lib.TrainConfig(
        microbatches=args.microbatches,
        remat=args.remat,
        grad_sync=args.grad_sync,
        monitor=args.monitor_threshold > 0,
        monitor_mode=args.monitor_mode,
        monitor_threshold=args.monitor_threshold,
        error_feedback=not args.no_error_feedback,
        overlap=args.overlap,
        bucket_bytes=args.bucket_bytes or None,
        optimizer=OptimizerConfig(
            lr=args.lr, schedule=args.schedule,
            warmup_steps=min(20, args.steps // 10),
            total_steps=args.steps,
        ),
    )
    ck = (
        Checkpointer(
            args.ckpt_dir,
            save_every_steps=args.ckpt_every,
            save_every_seconds=args.ckpt_every_seconds,
        )
        if args.ckpt_dir
        else None
    )

    if args.elastic_policy is not None:
        # policy-driven elastic runtime (DESIGN.md S12): failures shrink the
        # DP extent in place, joiners grow it; the MRD collectives keep every
        # resulting (non-power-of-two) extent correct.
        from repro.data.pipeline import DataConfig as _DC
        from repro.runtime import ElasticConfig, ElasticTrainer, get_policy

        get_policy(args.elastic_policy)  # fail fast on unknown names
        trainer = ElasticTrainer(
            mesh, (cfg, tcfg),
            pipe_factory=lambda m: SyntheticPipeline(
                cfg, _DC(batch=args.batch, seq_len=args.seq, seed=args.seed), m
            ),
            checkpointer=ck,
            cfg=ElasticConfig(
                ckpt_every=args.ckpt_every, policy=args.elastic_policy
            ),
        )
        state = trainer.init_or_restore(jax.random.PRNGKey(args.seed))
        state, losses = trainer.run(state, args.steps)
        print(
            f"done ({len(trainer.resizes)} resizes, {trainer.restores} "
            f"checkpoint restores). final loss: {losses[-1]:.4f}"
        )
        return losses[-1]

    train_step, init_state, state_specs, rules = step_lib.make_train_step(cfg, mesh, tcfg)

    with mesh:
        state = init_state(jax.random.PRNGKey(args.seed))
        shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs(state))
        state = jax.device_put(state, shardings)
        pipe = SyntheticPipeline(
            cfg, DataConfig(batch=args.batch, seq_len=args.seq, seed=args.seed), mesh
        )
        if ck is not None and ck.latest_step() is not None:
            step0 = ck.latest_step()
            state = ck.restore(step0, jax.tree.map(np.asarray, jax.device_get(state)), shardings)
            pipe.load_state_dict(ck.manifest(step0)["extra"]["data"])
            print(f"resumed from checkpoint step {step0}")
        # Donating the state saves a copy on accelerators, but on multi-device
        # CPU the DP-replicated params of the shard_map strategies (mrd_leaf &
        # co) share one backing buffer across devices; donating it raises
        # "Attempt to donate the same buffer twice in Execute()" on one
        # replica while the others block forever at the collective-permute
        # rendezvous — the historical mrd_leaf "deadlock".  Donation buys
        # nothing on CPU anyway, so gate it on the backend.
        donate = (0,) if jax.default_backend() != "cpu" and not args.no_donate else ()
        jstep = jax.jit(train_step, donate_argnums=donate)
        # async snapshots: with donation on, the next jstep call deletes the
        # state's buffers, so the save must at least finish the d2h transfer
        # ('transfer'); without donation the buffers stay alive and the save
        # can be fully fire-and-forget
        save_block = "transfer" if donate else False

        t0 = time.time()
        for i in range(args.steps):
            with obs.span("train.step", step=i):
                state, metrics = jstep(state, pipe.next_batch())
            if obs.enabled():
                # the loss is a device array still in flight: the gauge
                # stores the reference, the writer thread materializes it
                # at drain — no dispatch fence on the train loop
                obs.gauge("train.loss").set(metrics["loss"])
                obs.counter("train.steps").add(1)
            if (i + 1) % args.log_every == 0 or i == 0:
                print(
                    f"step {int(state['step'])}: loss={float(metrics['loss']):.4f} "
                    f"gnorm={float(metrics['grad_norm']):.3f} "
                    f"({(time.time()-t0)/(i+1)*1e3:.0f} ms/step)"
                )
            if ck is not None and ck.should_save(i + 1):
                # pipe.state_dict() is captured *now*, in the same host
                # instant the state leaves are staged — snapshot and data
                # cursor stay consistent even though the write is async
                ck.save(
                    int(state["step"]), state,
                    extra={"data": pipe.state_dict()}, block=save_block,
                )
            if tcfg.monitor and bool(metrics["converged"]):
                obs.instant(
                    "monitor.certify",
                    mode=args.monitor_mode,
                    step=int(state["step"]),
                    value=float(metrics["monitor_value"]),
                )
                print(
                    f"ConvergenceMonitor ({args.monitor_mode}) certified "
                    f"loss {float(metrics['monitor_value']):.4f} < "
                    f"{args.monitor_threshold} at step {int(state['step'])} — stopping."
                )
                break
        if ck is not None:
            ck.save(int(state["step"]), state, extra={"data": pipe.state_dict()}, block=True)
    print("done. final loss:", float(metrics["loss"]))
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
