"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh), all in seconds:

  compute    = HLO_FLOPs / (chips * 197e12)          [bf16 MXU peak, v5e]
  memory     = HLO_bytes / (chips * 819e9)           [HBM bandwidth]
  collective = collective_bytes_per_chip / 50e9       [ICI per-link]

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes (already per-program =
per-device under SPMD); collective bytes parsed from the compiled HLO text
(sum of result-shape bytes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops — a per-device proxy, exact for
collective-permute, upper bound ~2x for ring-phased ops; consistent across
configs so deltas are meaningful).

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per training step
(x1/3 for forward-only serving steps);  MODEL/HLO flops ratio flags remat or
redundant compute.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

# --- hardware constants (TPU v5e) ---
PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s+(?:\([^)]*\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_TUPLE_ELEM_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result bytes per collective category from HLO text."""
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        if m.group(1):  # simple result shape
            b = _shape_bytes(m.group(1), m.group(2))
        else:  # tuple result: sum elements before the op name
            prefix = line.split(kind)[0]
            b = sum(_shape_bytes(d, s) for d, s in _TUPLE_ELEM_RE.findall(prefix))
        out[kind] = out.get(kind, 0) + b
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float  # per device
    hlo_bytes: float  # per device
    collective_bytes: dict[str, int]  # per device
    model_flops: float  # global, per step
    peak_memory_bytes: Optional[int] = None
    compile_seconds: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return sum(self.collective_bytes.values()) / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO flops): catches remat/redundancy waste."""
        total_hlo = self.hlo_flops * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / bound time: how close the step is to the
        best achievable given its dominant bound."""
        t_model = self.model_flops / self.chips / PEAK_FLOPS
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_model / t_bound if t_bound else 0.0

    def to_dict(self) -> dict:
        return {
            **dataclasses.asdict(self),
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(cfg, shape_cell, n_layers_tokens=None) -> float:
    """6*N*D training / 2*N*D forward-only, N = active params."""
    n_active = cfg.n_active_params()
    if shape_cell.kind == "train":
        tokens = shape_cell.global_batch * shape_cell.seq_len
        return 6.0 * n_active * tokens
    if shape_cell.kind == "prefill":
        tokens = shape_cell.global_batch * shape_cell.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape_cell.global_batch


def format_table(reports: list[RooflineReport]) -> str:
    hdr = (
        f"{'arch':<24}{'shape':<13}{'mesh':<8}{'t_comp(ms)':>11}{'t_mem(ms)':>11}"
        f"{'t_coll(ms)':>11}{'bound':>11}{'useful%':>9}{'roofline%':>10}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in reports:
        lines.append(
            f"{r.arch:<24}{r.shape:<13}{r.mesh:<8}"
            f"{r.t_compute*1e3:>11.2f}{r.t_memory*1e3:>11.2f}"
            f"{r.t_collective*1e3:>11.2f}{r.bottleneck:>11}"
            f"{r.useful_flops_ratio*100:>8.1f}%{r.roofline_fraction*100:>9.1f}%"
        )
    return "\n".join(lines)


def save_reports(reports: list[RooflineReport], path: str, extra: dict = None):
    payload = [r.to_dict() for r in reports]
    if extra:
        payload = {"reports": payload, **extra}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)


def load_reports(path: str) -> list[dict]:
    with open(path) as f:
        return json.load(f)
