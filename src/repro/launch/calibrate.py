import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Cost calibration for the roofline (see EXPERIMENTS.md §Roofline method).

XLA's HloCostAnalysis visits while-loop bodies ONCE, so a production compile
(layer stack under ``lax.scan``, microbatch loop, flash-attention chunk
loops) under-counts flops/bytes/collective-bytes.  Rather than hand-waving
analytic numbers, we *measure* compiled artifacts of reduced-depth fully
UNROLLED variants and fit the loop structure:

  train:   cost(L, mb) = a + b*L + c*mb + d*(L*mb)   -> 4 compiles
           (2u,1), (4u,1), (2u,2), (4u,2); u = the arch's structural unit
           (1 dense/moe/ssm layer; 6 for gemma3's 5:1 group / zamba2's
           mamba-group + shared block)
  serve:   cost(L) = a + b*L                          -> 2 compiles

Unrolling: cfg.scan_unroll=True (layer + microbatch scans), attn_chunk=seq
(single flash block), ssm_chunk=seq (single ssm chunk).  Shapes, sharding,
and mesh are the production ones — only loop *structure* changes, which the
fit then restores.  Memory analysis always comes from the production compile
(dryrun.py); this module only calibrates flops/bytes/collectives.
"""

import argparse
import dataclasses
import json
import time

import numpy as np

from repro.configs import registry, shapes


def structural_unit(cfg) -> int:
    if cfg.pattern_local:
        return cfg.pattern_local + cfg.pattern_global
    if cfg.family == "hybrid":
        return cfg.attn_every
    return 1


def _cal_config(cfg, n_layers: int, seq_len: int, overrides=None):
    # hybrid (mamba2/SSD): intra-chunk cost is O(chunk^2) per head — a full-
    # sequence chunk is uncompilable at 32k.  Cap the chunk and UNROLL the
    # chunk loop instead (scan_unroll covers it), so costs still count fully.
    ssm_chunk = max(seq_len, 1)
    if cfg.family == "hybrid":
        ssm_chunk = min(ssm_chunk, 1024)
    cfg = dataclasses.replace(
        cfg,
        n_layers=n_layers,
        scan_unroll=True,
        attn_chunk=max(seq_len, 1),
        ssm_chunk=ssm_chunk,
    )
    if overrides:
        safe = {k: v for k, v in overrides.items()
                if k not in ("scan_unroll", "attn_chunk", "ssm_chunk")}
        cfg = dataclasses.replace(cfg, **safe)
    return cfg


def _collect(arch, shape_name, mesh_name, cfg_override, *, grad_sync, microbatches, remat):
    """One calibration compile -> {'flops':, 'bytes':, 'coll': {kind: bytes}}."""
    from repro.launch import dryrun as dr  # after XLA_FLAGS

    # monkey-patch registry resolution with the reduced config
    orig = registry._FULL[arch]
    registry._FULL[arch] = cfg_override
    try:
        res = dr.run_cell(
            arch, shape_name, mesh_name,
            grad_sync=grad_sync, microbatches=microbatches, remat=remat,
            verbose=False,
        )
    finally:
        registry._FULL[arch] = orig
    return {
        "flops": float(res["cost"].get("flops") or 0.0),
        "bytes": float(res["cost"].get("bytes_accessed") or 0.0),
        "coll": dict(res.get("collective_bytes", {})),
        "compile_seconds": res.get("compile_seconds"),
    }


def _combine(points, weights):
    """Linear combination of cost dicts."""
    out = {"flops": 0.0, "bytes": 0.0, "coll": {}}
    for pt, w in zip(points, weights):
        out["flops"] += w * pt["flops"]
        out["bytes"] += w * pt["bytes"]
        for k, v in pt["coll"].items():
            out["coll"][k] = out["coll"].get(k, 0.0) + w * v
    return out


def calibrate_cell(
    arch: str,
    shape_name: str,
    mesh_name: str,
    *,
    grad_sync: str = "gspmd",
    microbatches: int | None = None,
    remat: str = "full",
    overrides: dict | None = None,
) -> dict:
    cfg = registry.get_config(arch)
    cell = shapes.SHAPES[shape_name]
    skip = shapes.skip_reason(arch, shape_name)
    if skip:
        return {"arch": arch, "shape": shape_name, "skipped": skip}
    u = structural_unit(cfg)
    L = cfg.n_layers
    # hybrid: unrolled SSD bodies dominate compile time — use half-depth
    # calibration points (u, 2u) instead of (2u, 4u); the fit is unchanged.
    lo, hi = (u, 2 * u) if cfg.family == "hybrid" else (2 * u, 4 * u)
    t0 = time.time()

    if cell.kind == "train":
        from repro.launch.dryrun import microbatches_for
        from repro.launch.mesh import make_mesh_by_name

        mb = microbatches or microbatches_for(
            arch, cell.global_batch, make_mesh_by_name(mesh_name)
        )
        pts = {}
        for (nl, m) in [(lo, 1), (hi, 1), (lo, 2), (hi, 2)]:
            pts[(nl, m)] = _collect(
                arch, shape_name, mesh_name,
                _cal_config(cfg, nl, cell.seq_len, overrides),
                grad_sync=grad_sync, microbatches=m, remat=remat,
            )
        # cost = a + b*L + c*mb + d*L*mb; solve from the 4 points
        c1, c2, c3, c4 = pts[(lo, 1)], pts[(hi, 1)], pts[(lo, 2)], pts[(hi, 2)]
        # cost = a + b*L + c*mb + d*L*mb from points at L in {lo, hi}
        span = hi - lo
        inv = 1.0 / span
        d = _combine([c4, c3, c2, c1], [inv, -inv, -inv, inv])
        b = _combine([c2, c1, d], [inv, -inv, -1.0])
        c = _combine([c3, c1, d], [1.0, -1.0, -lo])
        a = _combine([c1, b, c, d], [1.0, -lo, -1.0, -lo])
        total = _combine([a, b, c, d], [1.0, L, mb, L * mb])
        meta = {"points": {f"L{k[0]}_mb{k[1]}": v for k, v in pts.items()},
                "fit": f"bilinear(L in {{{lo},{hi}}}, mb)", "unit": u,
                "microbatches": mb}
    else:
        pts = {}
        for nl in (lo, hi):
            pts[nl] = _collect(
                arch, shape_name, mesh_name,
                _cal_config(cfg, nl, cell.seq_len, overrides),
                grad_sync=grad_sync, microbatches=1, remat=remat,
            )
        c1, c2 = pts[lo], pts[hi]
        inv = 1.0 / (hi - lo)
        b = _combine([c2, c1], [inv, -inv])
        a = _combine([c1, b], [1.0, -lo])
        total = _combine([a, b], [1.0, L])
        meta = {"points": {f"L{k}": v for k, v in pts.items()}, "fit": "linear(L)", "unit": u}

    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "grad_sync": grad_sync,
        "calibrated": total,
        "meta": meta,
        "wall_seconds": round(time.time() - t0, 1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--grad-sync", default="gspmd")
    ap.add_argument("--out", default="results/calibrate")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = (
        [(a, s) for a in registry.list_archs() for s in shapes.cells_for(a)]
        if args.all
        else [(args.arch, args.shape)]
    )
    failures = []
    for arch, shape_name in cells:
        tag = f"{arch}__{shape_name}__{args.mesh}"
        if args.grad_sync != "gspmd":
            tag += f"__{args.grad_sync}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            print(f"[skip] {tag}")
            continue
        print(f"[cal ] {tag}", flush=True)
        try:
            res = calibrate_cell(
                arch, shape_name, args.mesh, grad_sync=args.grad_sync
            )
        except Exception as e:  # noqa: BLE001
            import traceback

            traceback.print_exc()
            failures.append(tag)
            res = {"arch": arch, "shape": shape_name, "error": f"{type(e).__name__}: {e}"}
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("calibration complete")


if __name__ == "__main__":
    main()
