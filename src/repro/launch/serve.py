"""Serving driver: static batch or continuous batching (``--continuous``).

Static (the historical path, now with honest timing — ``block_until_ready``
fences around the timed regions, prefill and decode reported separately):

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \\
      --batch 4 --prompt-len 16 --gen 32

Continuous batching via the ``repro.serving`` subsystem (DESIGN.md S13):

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \\
      --continuous --slots 4 --requests 16 --arrival poisson:0.5 \\
      --scheduler fcfs --gen 24

  # block-paged cache with prefix sharing (DESIGN.md S14): same tokens,
  # more concurrent requests per byte of cache
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \\
      --continuous --workload llm_decode_paged --slots 8 --block-size 8

  # per-query fixed-point solves (D-iteration / personalized PageRank),
  # retired by the paper's detection protocol, agreement across --dp replicas
  PYTHONPATH=src python -m repro.launch.serve --continuous \\
      --workload fixedpoint_solve --termination residual_interval \\
      --requests 8 --dp 3 --gen 400

Elastic serving (DESIGN.md S15): kill/join termination-agreement replicas
under live traffic — no request lost, no slot re-prefilled:

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \\
      --continuous --slots 4 --requests 16 --arrival poisson:0.5 \\
      --dp 4 --elastic-policy grow_on_join --steps-per-dispatch 4 \\
      --kill 6:2 --join 16:4,5 --kill 26:0

Multi-tenant traffic + SLA autoscaling (DESIGN.md S17): named tenants with
TTFT SLAs / priorities / admission quotas, bursty or diurnal arrivals, and
an autoscaler trading replica-funded capacity against SLA pressure:

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \\
      --continuous --slots 8 --requests 32 --scheduler sla_edf \\
      --tenants "chat:3:sla=8:prio=2,batch:1:quota=4:gen=24" \\
      --arrival bursty:0.2,2.0 --dp 2 --slots-per-replica 4 \\
      --autoscale --max-extent 4 --steps-per-dispatch 4
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.distributed import step as step_lib
from repro.launch.train import build_mesh
from repro.models import transformer
from repro.serving import (
    TERMINATION,
    WORKLOADS,
    Request,
    ServeConfig,
    ServeEngine,
    get_scheduler,
    make_workload,
)
from repro.serving.tenants import (
    build_requests,
    make_arrival_ticks,
    parse_tenant_specs,
    quotas_of,
)


def _arrival_ticks(spec: str, n: int, seed: int) -> list[int]:
    """``none`` | ``poisson:RATE`` | ``bursty:BASE,PEAK[,RATE,LEN]`` |
    ``diurnal:PEAK,PERIOD[,FLOOR]`` | ``trace:FILE`` — see
    :mod:`repro.serving.tenants` (this wrapper maps spec errors to CLI
    exits and is what ``bench_serve.py`` imports)."""
    try:
        return make_arrival_ticks(spec, n, seed)
    except (ValueError, OSError) as e:
        raise SystemExit(f"--arrival {spec!r}: {e}")


class _CliChaosScript:
    """Chaos events parsed from ``--kill/--join/--stall/--unstall`` flags,
    fired against the :class:`repro.runtime.ElasticServeController` on its
    tick clock (same ``apply_due`` contract as ``tests/chaos.py``)."""

    def __init__(self, events):
        self.events = sorted(events, key=lambda e: e[0])
        self.fired = 0

    def apply_due(self, ctl, tick: int):
        while self.fired < len(self.events) and self.events[self.fired][0] <= tick:
            t, name, a, kw = self.events[self.fired]
            print(f"# chaos @tick {tick}: {name}{a}")
            getattr(ctl, name)(*a, **kw)
            self.fired += 1


def _parse_chaos(args) -> _CliChaosScript | None:
    events = []
    for spec in args.kill or []:
        parts = spec.split(":")
        events.append((int(parts[0]), "kill", (int(parts[1]),),
                       {"silent": len(parts) > 2 and parts[2] == "silent"}))
    for spec in args.join or []:
        tick, _, ids = spec.partition(":")
        events.append((int(tick), "join",
                       (tuple(int(i) for i in ids.split(",")),), {}))
    for spec in args.stall or []:
        parts = spec.split(":")
        factor = float(parts[2]) if len(parts) > 2 else 10.0
        events.append((int(parts[0]), "stall", (int(parts[1]), factor), {}))
    for spec in args.unstall or []:
        tick, _, rid = spec.partition(":")
        events.append((int(tick), "unstall", (int(rid),), {}))
    return _CliChaosScript(events) if events else None


def _static_main(args, cfg, mesh):
    serve_step, rules = step_lib.make_serve_step(cfg, mesh)
    prefill_step, _ = step_lib.make_cached_prefill_step(cfg, mesh)

    with mesh:
        params = transformer.init_params(cfg, jax.random.PRNGKey(args.seed))
        max_len = args.prompt_len + args.gen
        cache = transformer.init_cache(cfg, args.batch, max_len)
        prompt = jax.random.randint(
            jax.random.PRNGKey(args.seed + 1), (args.batch, args.prompt_len), 0, cfg.vocab
        )
        jstep = jax.jit(serve_step, donate_argnums=(2,))
        jprefill = jax.jit(prefill_step, donate_argnums=(2,))

        # fence before timing so we measure execution, not dispatch of the
        # param/cache initialization still in flight
        jax.block_until_ready((params, cache, prompt))

        # --- prefill phase (single scanned dispatch) ---
        t0 = time.perf_counter()
        logits, cache = jprefill(params, prompt, cache)
        jax.block_until_ready((logits, cache))
        dt_prefill = time.perf_counter() - t0

        # --- decode phase ---
        out = []
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(toks)
        t0 = time.perf_counter()
        for i in range(args.gen):
            out.append(np.asarray(toks))
            logits, cache = jstep(params, toks, cache, jnp.int32(args.prompt_len + i))
            toks = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(toks)
        dt_decode = time.perf_counter() - t0

        pre_tok = args.batch * args.prompt_len
        dec_tok = args.batch * args.gen
        print(f"prefill: {pre_tok} tokens in {dt_prefill * 1e3:.1f} ms "
              f"({pre_tok / dt_prefill:.1f} tok/s)")
        print(f"decode:  {dec_tok} tokens in {dt_decode * 1e3:.1f} ms "
              f"({dec_tok / dt_decode:.1f} tok/s, "
              f"{dt_decode / args.gen * 1e3:.2f} ms/step)")
        print("sample token ids:", np.stack(out, 1)[0][:16].tolist())


def _continuous_main(args, cfg, mesh):
    rng = np.random.default_rng(args.seed)

    if args.workload in ("llm_decode", "llm_decode_paged"):
        max_len = args.max_len or (args.prompt_len + args.gen + 4)
        kw = {}
        if args.workload == "llm_decode_paged":
            bs = args.block_size
            max_len = ((max_len + bs - 1) // bs) * bs  # whole blocks
            kw = {"block_size": bs}
            if args.num_blocks:
                kw["num_blocks"] = args.num_blocks
        wl = make_workload(
            args.workload, cfg=cfg, mesh=mesh, slots=args.slots,
            max_len=max_len, max_prompt_len=args.prompt_len, seed=args.seed,
            **kw,
        )
        termination = args.termination or "eos_maxlen"
        if not args.tenants:
            arrivals = _arrival_ticks(args.arrival, args.requests, args.seed + 7)
            reqs = [
                Request(
                    id=i, arrival=arrivals[i],
                    prompt=rng.integers(0, cfg.vocab, size=int(rng.integers(1, args.prompt_len + 1))),
                    max_new=int(rng.integers(max(1, args.gen // 2), args.gen + 1)),
                    priority=int(rng.integers(0, 3)),
                    sla=int(rng.integers(4, 64)),
                )
                for i in range(args.requests)
            ]
    else:
        n = ((args.n + args.dp - 1) // args.dp) * args.dp  # dp-block divisible
        if n != args.n:
            print(f"# rounding --n {args.n} up to {n} (divisible by dp={args.dp})")
        args.n = n
        wl = make_workload(
            "fixedpoint_solve", solver=args.solver, n=args.n,
            slots=args.slots, dp=args.dp,
        )
        termination = args.termination or "residual_interval"
        if not args.tenants:
            arrivals = _arrival_ticks(args.arrival, args.requests, args.seed + 7)
            reqs = []
            for i in range(args.requests):
                v = rng.random(args.n).astype(np.float32)
                reqs.append(Request(
                    id=i, arrival=arrivals[i], payload=v / v.sum(),
                    max_new=args.gen, priority=int(rng.integers(0, 3)),
                    sla=int(rng.integers(50, 500)),
                ))

    quotas = None
    if args.tenants:
        try:
            tenants = parse_tenant_specs(args.tenants)
        except ValueError as e:
            raise SystemExit(f"--tenants: {e}")
        # single-engine CLI: every tenant targets the deployed --workload
        # (mixed-workload scenarios live in TenantScenario / bench_scale)
        tenants = tuple(
            dataclasses.replace(t, workload=args.workload) for t in tenants
        )
        reqs = build_requests(
            tenants, {args.workload: wl}, args.requests,
            args.arrival, args.seed + 7,
        )[args.workload]
        quotas = quotas_of(tenants)

    eng = ServeEngine(wl, ServeConfig(
        scheduler=args.scheduler, termination=termination,
        dp=args.dp, eps=args.eps, max_retries=args.max_retries,
        steps_per_dispatch=args.steps_per_dispatch,
        quotas=quotas,
        slots_per_replica=args.slots_per_replica or None,
    ))
    script = _parse_chaos(args)
    if args.autoscale or args.elastic_policy or script is not None:
        from repro.runtime import ElasticServeController

        policy = args.elastic_policy or "grow_on_join"
        if args.autoscale:
            from repro.runtime.policies import SlaAutoscalePolicy

            policy = SlaAutoscalePolicy(
                min_extent=args.min_extent, max_extent=args.max_extent,
            )
        ctl = ElasticServeController(
            eng, policy=policy, min_extent=args.min_extent,
        )
        res = ctl.run(reqs, events=script)
        for ev in ctl.resizes:
            print(f"# resize: {ev.kind} {ev.old_dp} -> {ev.new_dp} "
                  f"@tick {ev.step} ({ev.reason})")
    else:
        res = eng.run(reqs)
    s = eng.summary()
    print(f"{args.workload} x {args.scheduler} x {termination} (dp={args.dp}): "
          f"{s['completed']} requests in {s['ticks']} ticks / {s['wall_s']:.2f} s")
    print(f"  throughput {s['throughput_tok_s']:.1f} tok/s | occupancy "
          f"{s['occupancy']:.2f} | converged {s['converged']}/{s['completed']}")
    print(f"  TTFT p50/p95 {s['ttft_p50_ms']:.1f}/{s['ttft_p95_ms']:.1f} ms | "
          f"TPOT p50/p95 {s['tpot_p50_ms']:.2f}/{s['tpot_p95_ms']:.2f} ms")
    if s["sla_total"]:
        print(f"  SLA {s['sla_met']}/{s['sla_total']} met | goodput "
              f"{s['goodput_ok']} ({s['goodput_per_ktick']:.1f}/ktick) | "
              f"replica-ticks {s['replica_ticks']}")
    for name, t in sorted(s["tenants"].items()):
        print(f"  tenant {name}: {t['completed']} done, {t['tokens_out']} tok "
              f"| sla {t['sla_met']}/{t['sla_total']} | "
              f"ttft p99 {t['ttft_p99_ticks']:.0f} ticks")
    if s["resizes"] or s["retried"]:
        print(f"  resizes {s['resizes']} | capacity retries {s['retried']} "
              f"| final dp {eng.dp}")
    if hasattr(wl, "cache_bytes"):
        extra = (f" | prefix blocks saved {wl.prefix_saved_blocks}"
                 if hasattr(wl, "prefix_saved_blocks") else "")
        print(f"  cache {wl.cache_bytes / 2**20:.2f} MiB | forced-at-capacity "
              f"{s['forced_at_capacity']}{extra}")
    first = res[min(res)]
    tail = (first.output[:8].tolist() if first.output.dtype.kind == "i"
            else np.round(first.output[:4], 5).tolist())
    print(f"  request {first.id}: {first.n_tokens} tokens, "
          f"admit@{first.admit_tick} retire@{first.retire_tick}, head {tail}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.list_archs(),
                    help="model arch (required unless --workload fixedpoint_solve)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4, help="static batch size")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    # continuous batching (repro.serving)
    ap.add_argument("--continuous", action="store_true",
                    help="serve through the continuous-batching ServeEngine")
    ap.add_argument("--slots", type=int, default=4, help="decode pool slots")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--scheduler", default="fcfs",
                    help="SCHEDULERS entry, optionally parameterized "
                         "(fcfs | priority | sla_edf | sla_edf:MAX_WAIT)")
    ap.add_argument("--workload", default="llm_decode", choices=sorted(WORKLOADS))
    ap.add_argument("--termination", default=None, choices=sorted(TERMINATION),
                    help="default: eos_maxlen (llm) / residual_interval (fixedpoint)")
    ap.add_argument("--arrival", default="none",
                    help="none | poisson:RATE (req/tick) | "
                         "bursty:BASE,PEAK[,RATE,LEN] | "
                         "diurnal:PEAK,PERIOD[,FLOOR] | trace:FILE (JSON ticks)")
    ap.add_argument("--tenants", default=None,
                    metavar="NAME:WEIGHT[:sla=..][:prio=..][:quota=..][:gen=..],...",
                    help="multi-tenant traffic model (serving/tenants.py); "
                         "requests are sampled per tenant instead of i.i.d.")
    ap.add_argument("--max-len", type=int, default=0,
                    help="pool cache length (0 = prompt+gen+margin)")
    ap.add_argument("--block-size", type=int, default=8,
                    help="llm_decode_paged: tokens per cache block")
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="llm_decode_paged: physical blocks "
                         "(0 = contiguous-capacity parity)")
    ap.add_argument("--solver", default="d_iteration",
                    help="fixedpoint_solve: SOLVERS entry (affine payload)")
    ap.add_argument("--n", type=int, default=64, help="fixedpoint problem size")
    ap.add_argument("--eps", type=float, default=1e-6)
    ap.add_argument("--steps-per-dispatch", type=int, default=16,
                    help="ticks per fused device dispatch; chaos events "
                         "fire at dispatch boundaries, so a finer value "
                         "lands --kill/--join closer to their nominal "
                         "ticks")
    ap.add_argument("--max-retries", type=int, default=0,
                    help="requeues granted to capacity-forced requests")
    # elastic serving (DESIGN.md S15): resize the agreement extent live
    ap.add_argument("--elastic-policy", default=None,
                    help="drive the engine through an ElasticServeController "
                         "(ELASTIC_POLICIES entry, e.g. grow_on_join)")
    ap.add_argument("--min-extent", type=int, default=1,
                    help="never shrink below this many replicas")
    # SLA autoscaling (DESIGN.md S17)
    ap.add_argument("--autoscale", action="store_true",
                    help="drive the engine with the sla_autoscale policy "
                         "(queue/SLA pressure grows, idle shrinks)")
    ap.add_argument("--max-extent", type=int, default=8,
                    help="autoscaler: never grow beyond this many replicas")
    ap.add_argument("--slots-per-replica", type=int, default=0,
                    help="capacity model: each replica funds this many pool "
                         "slots, so resizes change admission capacity "
                         "(0 = all slots usable at any extent)")
    ap.add_argument("--kill", action="append", metavar="TICK:REPLICA[:silent]",
                    help="kill a replica at TICK (repeatable); ':silent' "
                         "waits for the virtual heartbeat timeout")
    ap.add_argument("--join", action="append", metavar="TICK:ID[,ID...]",
                    help="replicas ask to join at TICK (repeatable)")
    ap.add_argument("--stall", action="append", metavar="TICK:REPLICA[:FACTOR]",
                    help="slow a replica's heartbeat step time (repeatable)")
    ap.add_argument("--unstall", action="append", metavar="TICK:REPLICA")
    ap.add_argument("--telemetry", default=None, metavar="SINK[:PATH]",
                    help="enable the obs subsystem (DESIGN.md S18): "
                         "null | jsonl[:f.jsonl] | csv[:f.csv] | "
                         "chrome_trace[:trace.json] (load in Perfetto / "
                         "chrome://tracing)")
    args = ap.parse_args(argv)

    if args.telemetry:
        from repro import obs

        try:
            obs.configure(args.telemetry)
        except ValueError as e:
            raise SystemExit(f"--telemetry: {e}")

    try:
        get_scheduler(args.scheduler)
    except ValueError as e:
        raise SystemExit(str(e))
    needs_model = not (args.continuous and args.workload == "fixedpoint_solve")
    cfg = None
    if needs_model:
        if not args.arch:
            raise SystemExit("--arch is required for LLM serving")
        cfg = (
            registry.get_smoke_config(args.arch) if args.smoke
            else registry.get_config(args.arch)
        )
        if cfg.is_encoder_only:
            raise SystemExit(f"{args.arch} is encoder-only: no decode serving")
    # continuous serving simulates the --dp agreement replicas (stacked
    # termination contributions), so the device mesh only needs the TP
    # extent; the static path shards the batch over real dp devices
    mesh_dp = 1 if args.continuous else args.dp
    mesh = build_mesh(mesh_dp, args.tp) if needs_model else None

    try:
        if args.continuous:
            _continuous_main(args, cfg, mesh)
        else:
            _static_main(args, cfg, mesh)
    finally:
        if args.telemetry:
            from repro import obs

            t = obs.shutdown()
            sink = obs.telemetry().sink
            dest = getattr(sink, "path", None)
            print(f"# telemetry[{t['sink']}]: {t['spans']} spans, "
                  f"{t['instants']} instants, "
                  f"{t['events_dropped'] + t['metrics_dropped']} dropped"
                  + (f" -> {dest}" if dest else ""))


if __name__ == "__main__":
    main()
