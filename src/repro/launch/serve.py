"""Serving driver: batched greedy decoding with a KV/state cache.

Usage (CPU demo):
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \\
      --batch 4 --prompt-len 16 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.distributed import step as step_lib
from repro.launch.train import build_mesh
from repro.models import transformer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (
        registry.get_smoke_config(args.arch) if args.smoke else registry.get_config(args.arch)
    )
    if cfg.is_encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode serving")
    mesh = build_mesh(args.dp, args.tp)
    serve_step, rules = step_lib.make_serve_step(cfg, mesh)
    prefill_step, _ = step_lib.make_cached_prefill_step(cfg, mesh)

    with mesh:
        params = transformer.init_params(cfg, jax.random.PRNGKey(args.seed))
        max_len = args.prompt_len + args.gen
        cache = transformer.init_cache(cfg, args.batch, max_len)
        prompt = jax.random.randint(
            jax.random.PRNGKey(args.seed + 1), (args.batch, args.prompt_len), 0, cfg.vocab
        )
        jstep = jax.jit(serve_step, donate_argnums=(2,))
        jprefill = jax.jit(prefill_step, donate_argnums=(2,))

        # single-dispatch prefill (scanned decode steps), then generate
        t0 = time.time()
        logits, cache = jprefill(params, prompt, cache)
        out = []
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(args.gen):
            out.append(np.asarray(toks))
            logits, cache = jstep(params, toks, cache, jnp.int32(args.prompt_len + i))
            toks = jnp.argmax(logits, -1).astype(jnp.int32)
        dt = time.time() - t0
        total = args.batch * (args.prompt_len + args.gen)
        print(f"decoded {args.gen} tokens x {args.batch} seqs "
              f"({total / dt:.1f} tok/s total on CPU demo)")
        print("sample token ids:", np.stack(out, 1)[0][:16].tolist())


if __name__ == "__main__":
    main()
