"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state.  The dry-run forces 512 host devices *before*
importing jax; tests and benches see the real single CPU device.
"""

from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(
        shape, axes, axis_types=compat.default_axis_types(len(axes)),
        devices=jax.devices()[: _prod(shape)],
    )


def make_nonp2_mesh():
    """Non-power-of-two demo mesh (the paper's headline case): 6 x 16 = 96
    chips — e.g. a 128-chip pod after 2 DP-slice failures, kept running by
    the MRD shifts instead of regrouping to 64."""
    return compat.make_mesh(
        (6, 16), ("data", "model"), axis_types=compat.default_axis_types(2),
        devices=jax.devices()[:96],
    )


def make_mesh_by_name(name: str):
    if name in ("single", "single_pod"):
        return make_production_mesh(multi_pod=False)
    if name in ("multi", "multi_pod"):
        return make_production_mesh(multi_pod=True)
    if name == "nonp2":
        return make_nonp2_mesh()
    raise ValueError(f"unknown mesh {name!r} (single|multi|nonp2)")


def _prod(t):
    out = 1
    for x in t:
        out *= x
    return out
