import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb harness: run one (arch x shape x mesh) *point* — a named
combination of knobs — and record its calibrated roofline terms + production
memory, for hypothesis → change → measure → validate cycles.

Usage:
  PYTHONPATH=src python -m repro.launch.perf --arch llama3.2-1b --shape train_4k \\
      --mesh single --label baseline
  PYTHONPATH=src python -m repro.launch.perf ... --label rab --grad-sync mrd_zero1
  PYTHONPATH=src python -m repro.launch.perf ... --label chunk512 --set attn_chunk=512

Results land in results/perf/<arch>__<shape>__<mesh>__<label>.json with the
three roofline terms precomputed for direct comparison.
"""

import argparse
import json

from repro.configs import registry, shapes
from repro.launch import roofline as R


def run_point(
    arch: str,
    shape_name: str,
    mesh_name: str,
    label: str,
    *,
    grad_sync: str = "gspmd",
    microbatches: int | None = None,
    remat: str = "full",
    overrides: dict | None = None,
    skip_memory: bool = False,
) -> dict:
    from repro.launch import calibrate as C
    from repro.launch import dryrun as D

    cal = C.calibrate_cell(
        arch, shape_name, mesh_name,
        grad_sync=grad_sync, microbatches=microbatches, remat=remat,
        overrides=overrides,
    )
    mem = {}
    if not skip_memory:
        prod = D.run_cell(
            arch, shape_name, mesh_name,
            grad_sync=grad_sync, microbatches=microbatches, remat=remat,
            overrides=overrides, verbose=False,
        )
        mem = prod.get("memory", {})

    cfg = registry.get_config(arch)
    cell = shapes.SHAPES[shape_name]
    chips = 512 if mesh_name == "multi" else (96 if mesh_name == "nonp2" else 256)
    cc = cal["calibrated"]
    rep = R.RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=cc["flops"], hlo_bytes=cc["bytes"],
        collective_bytes={k: int(v) for k, v in cc["coll"].items()},
        model_flops=R.model_flops_for(cfg, cell),
        peak_memory_bytes=(
            (mem.get("temp_bytes_tpu_adjusted") or 0) + (mem.get("argument_bytes") or 0)
        ) if mem else None,
    )
    out = {
        "label": label,
        "knobs": {
            "grad_sync": grad_sync, "microbatches": microbatches,
            "remat": remat, "overrides": overrides or {},
        },
        "roofline": rep.to_dict(),
        "memory": mem,
    }
    print(
        f"[{label}] t_comp={rep.t_compute*1e3:.2f}ms t_mem={rep.t_memory*1e3:.2f}ms "
        f"t_coll={rep.t_collective*1e3:.2f}ms bound={rep.bottleneck} "
        f"useful={rep.useful_flops_ratio*100:.1f}% roofline={rep.roofline_fraction*100:.1f}%"
    )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--label", required=True)
    ap.add_argument("--grad-sync", default="gspmd")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override field=value (int/float/str)")
    ap.add_argument("--skip-memory", action="store_true")
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    os.makedirs(args.out, exist_ok=True)
    res = run_point(
        args.arch, args.shape, args.mesh, args.label,
        grad_sync=args.grad_sync, microbatches=args.microbatches,
        remat=args.remat, overrides=overrides or None,
        skip_memory=args.skip_memory,
    )
    path = os.path.join(
        args.out, f"{args.arch}__{args.shape}__{args.mesh}__{args.label}.json"
    )
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    print("saved", path)


if __name__ == "__main__":
    main()
