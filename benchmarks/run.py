"""Benchmark harness: one module per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows from every bench.  The roofline
table (dry-run derived) is produced by ``benchmarks.roofline_table`` and reads
results/dryrun + results/calibrate.
"""

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (
        bench_async,
        bench_compression,
        bench_detection,
        bench_mrd,
        bench_train_step,
    )

    print("name,us_per_call,derived")
    for mod in (bench_mrd, bench_detection, bench_async, bench_compression,
                bench_train_step):
        print(f"# --- {mod.__name__} ---", file=sys.stderr)
        mod.main()


if __name__ == "__main__":
    main()
