"""Benchmark harness: one module per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows from every bench.  The roofline
table (dry-run derived) is produced by ``benchmarks.roofline_table`` and reads
results/dryrun + results/calibrate.

Each bench runs with the obs subsystem live and leaves a per-run trace
artifact ``TRACE_<bench>.json`` next to its ``BENCH_<bench>.json`` (load in
Perfetto / chrome://tracing) — a bench regression in a BENCH diff comes
with the trace that produced it.  ``--no-trace`` restores bare runs.
"""

from __future__ import annotations

import argparse
import sys


def run_bench(name: str, fn, trace: bool = True) -> None:
    """Run one bench entry point under a fresh telemetry instance and
    write ``TRACE_<name>.json`` at exit.  Fresh per bench: spans from one
    bench never bleed into the next bench's artifact."""
    if not trace:
        fn()
        return
    from repro import obs

    obs.reset()
    obs.configure("null", background=False)
    try:
        fn()
    finally:
        obs.telemetry().registry.flush()
        tr = obs.telemetry().tracer
        path = f"TRACE_{name}.json"
        tr.write_chrome_trace(path, process_name=f"bench_{name}")
        s = tr.summary()
        print(f"# trace: {s['spans']} spans, {s['instants']} instants "
              f"-> {path}", file=sys.stderr)
        obs.shutdown()
        obs.reset()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--no-trace", action="store_true",
                    help="skip the per-bench TRACE_<name>.json artifacts")
    args = ap.parse_args(argv)

    from benchmarks import (
        bench_async,
        bench_compression,
        bench_detection,
        bench_mrd,
        bench_train_step,
    )

    print("name,us_per_call,derived")
    for mod in (bench_mrd, bench_detection, bench_async, bench_compression,
                bench_train_step):
        print(f"# --- {mod.__name__} ---", file=sys.stderr)
        short = mod.__name__.rsplit(".", 1)[-1].removeprefix("bench_")
        run_bench(short, mod.main, trace=not args.no_trace)


if __name__ == "__main__":
    main()
