"""Continuous-batching serving sweep: scheduler x workload x arrival rate
(``repro.serving``, DESIGN.md S13), against the static-batch baseline.

The static baseline is the repo's historical serving shape: requests are
processed in waves of ``slots``, every wave decodes until its *longest*
request finishes, and finished requests idle their slot — the cost
continuous batching exists to remove.  Both paths serve the same
mixed-budget traffic and count the same *useful* tokens, so the
``speedup_vs_static`` column is an apples-to-apples occupancy win.

Rows (CSV on stdout: name,value,derived):

- ``serve_llm_<sched>_<arrival>`` — ServeEngine throughput (tok/s), TTFT /
  TPOT p50/p95 (ms), occupancy, speedup vs static.
- ``serve_static_baseline`` — the wave baseline's tok/s.
- ``serve_fixedpoint_<sched>`` — per-query D-iteration solves (requests/s)
  vs the barrier baseline (every wave iterates until its slowest query
  certifies — the global-barrier shape the paper's detection avoids).
- ``serve_llm_{contig,paged}_sysprefix`` — the block-paged cache
  (DESIGN.md S14) vs the contiguous pool on shared-system-prompt traffic:
  the paged pool runs *twice* the slots in the same cache byte budget
  (prefix blocks stored once + no per-slot worst-case reservation), with
  bit-exact tokens.  LLM rows carry ``cache_mib`` / ``bytes_per_slot`` /
  ``bytes_per_retired_token``.
- ``serve_{llm,fixedpoint}_elastic_killjoin`` — elastic serving (DESIGN.md
  S15): the same traffic with two replica kills and a two-replica join
  mid-run (agreement extent 4 -> 3 -> 5 -> 4) through the
  ElasticServeController, vs the uninterrupted steady-state run.

JSON: writes BENCH_serve.json ({"sweep": [...], "meta": {...}}).

``--quick`` shrinks the grid for CI smoke; ``--check`` asserts the
acceptance gates: every reported latency percentile is finite (a NaN —
the empty-run / single-token sentinel — or missing percentile is a hard
failure, never a pass); continuous token throughput within 0.7x of static at
the highest arrival rate (the wall-clock crossover is hardware-bound at
smoke scale — the reference ratio is ~0.97x — so the gate guards gross
regression); paged >= 1.5x concurrent requests per cache byte at >= 0.8x
contiguous token throughput, token-for-token identical to contiguous;
and the kill/join rows lose no request, re-prefill no slot, and recover
>= 0.8x steady-state throughput once the resize trajectory settles (the
post-resize segment).  Timed measurements are best-of-3 over identical
deterministic runs so the gates measure the code, not machine load.
"""

from __future__ import annotations

import argparse
import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.distributed import step as step_lib
from repro.launch.train import build_mesh
from repro.models import transformer
from repro.serving import Request, ServeConfig, ServeEngine, make_workload


def _traffic(n_req, prompt_len, gen_max, vocab, seed):
    """Mixed-budget traffic: uniform prompts, budgets in [gen_max/3, gen_max]."""
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, vocab, size=prompt_len) for _ in range(n_req)]
    budgets = [int(b) for b in rng.integers(max(2, gen_max // 3), gen_max + 1,
                                            size=n_req)]
    return prompts, budgets


def _system_traffic(n_req, vocab, seed, *, sys_len=24, user_len=4,
                    gen_lo=6, gen_hi=10):
    """Shared-system-prompt traffic: every request carries the same
    ``sys_len``-token system prefix plus a short unique user suffix — the
    shape prefix sharing exists for."""
    rng = np.random.default_rng(seed)
    sys_prefix = rng.integers(0, vocab, size=sys_len)
    prompts = [
        np.concatenate([sys_prefix, rng.integers(0, vocab, size=user_len)])
        for _ in range(n_req)
    ]
    budgets = [int(b) for b in rng.integers(gen_lo, gen_hi + 1, size=n_req)]
    return prompts, budgets


def _arrivals(kind, n_req, seed):
    """'burst' = everything queued at t=0 (peak load), else a Poisson rate
    (same generator the serve CLI uses)."""
    from repro.launch.serve import _arrival_ticks

    spec = "none" if kind == "burst" else f"poisson:{kind}"
    return _arrival_ticks(spec, n_req, seed)


def run_static_llm(cfg, mesh, params, prompts, budgets, slots):
    """Wave-of-``slots`` static batches; each wave decodes to its max budget."""
    serve_step, _ = step_lib.make_serve_step(cfg, mesh)
    prefill_step, _ = step_lib.make_cached_prefill_step(cfg, mesh)
    jstep = jax.jit(serve_step, donate_argnums=(2,))
    jprefill = jax.jit(prefill_step, donate_argnums=(2,))
    P = prompts[0].shape[0]
    gen_cap = max(budgets)
    max_len = P + gen_cap + 1

    def one_wave(wave_prompts, wave_budgets):
        B = slots
        batch = np.zeros((B, P), np.int64)
        for i, p in enumerate(wave_prompts):
            batch[i] = p
        with mesh:
            cache = transformer.init_cache(cfg, B, max_len)
            logits, cache = jprefill(params, jnp.asarray(batch), cache)
            toks = jnp.argmax(logits, -1).astype(jnp.int32)
            for k in range(max(wave_budgets) - 1):
                logits, cache = jstep(params, toks, cache, jnp.int32(P + k))
                toks = jnp.argmax(logits, -1).astype(jnp.int32)
            jax.block_until_ready(toks)

    waves = [
        (prompts[i : i + slots], budgets[i : i + slots])
        for i in range(0, len(prompts), slots)
    ]
    one_wave(*waves[0])  # warm the compile cache outside the timed region
    t0 = time.perf_counter()
    for wp, wb in waves:
        one_wave(wp, wb)
    dt = time.perf_counter() - t0
    useful = sum(budgets)
    return {"tok_s": useful / dt, "wall_s": dt, "useful_tokens": useful}


def run_continuous_llm(workload, prompts, budgets, arrivals, scheduler,
                       *, dp=1, steps_per_dispatch=16):
    workload.reset()
    eng = ServeEngine(workload, ServeConfig(
        scheduler=scheduler, termination="eos_maxlen", dp=dp,
        steps_per_dispatch=steps_per_dispatch,
    ))
    reqs = [
        Request(id=i, arrival=a, prompt=p, max_new=b)
        for i, (p, b, a) in enumerate(zip(prompts, budgets, arrivals))
    ]
    results = eng.run(reqs)
    return eng.summary(), results


def _best_of(run, key, n=3):
    """Re-run a (warmed, deterministic) timed measurement and keep the
    fastest repeat.  The check gates compare ratios of ~tens-of-ms walls,
    where a single scheduler preemption on a loaded box otherwise flips a
    CI gate; best-of-n measures the code, not the machine's mood."""
    best = None
    for _ in range(n):
        r = run()
        if best is None or key(r) > key(best):
            best = r
    return best


def _mem_fields(workload, summary):
    """Cache-memory accounting attached to every LLM sweep row."""
    cb = workload.cache_bytes
    return {
        "cache_mib": round(cb / 2**20, 3),
        "bytes_per_slot": cb // workload.slots,
        "bytes_per_retired_token": round(cb / max(1, summary["tokens_out"]), 1),
    }


def run_fixedpoint(n, dp, slots, n_req, eps, scheduler, seed):
    """Continuous residual-certified solves vs the barrier baseline."""
    workload = make_workload(
        "fixedpoint_solve", solver="d_iteration", n=n, dp=dp, slots=slots,
        damping=0.8, seed=seed,
    )
    rng = np.random.default_rng(seed)
    payloads = []
    for _ in range(n_req):
        v = rng.random(n).astype(np.float32)
        payloads.append(v / v.sum())

    # barrier baseline: waves of `slots` queries iterate until the *slowest*
    # certifies (true-residual oracle, free of charge — generous baseline)
    vmapped_map = jax.vmap(workload.pool.param_map)
    pm = jax.jit(vmapped_map)
    res_of = jax.jit(
        lambda x, v: jnp.max(jnp.abs(vmapped_map(x, v) - x), axis=1)
    )

    def one_wave(vs):
        V = jnp.asarray(np.stack(vs))
        x = jnp.zeros_like(V)
        iters = 0
        while True:
            x = pm(x, V)
            iters += 1
            if bool((np.asarray(res_of(x, V)) < eps).all()) or iters > 5000:
                break
        return iters

    waves = [payloads[i : i + slots] for i in range(0, n_req, slots)]
    one_wave(waves[0])
    t0 = time.perf_counter()
    total_iters = sum(one_wave(w) for w in waves)
    dt_static = time.perf_counter() - t0

    scfg = ServeConfig(
        scheduler=scheduler, termination="residual_interval", dp=dp, eps=eps,
    )
    # warm the fused-loop compile cache outside the timed run
    ServeEngine(workload, scfg).run(
        [Request(id=-1 - i, payload=p, max_new=5000)
         for i, p in enumerate(payloads[: slots + 1])]
    )
    workload.reset()
    eng = ServeEngine(workload, scfg)
    reqs = [Request(id=i, payload=p, max_new=5000)
            for i, p in enumerate(payloads)]
    eng.run(reqs)
    s = eng.summary()
    return {
        "req_s": len(payloads) / s["wall_s"],
        "static_req_s": len(payloads) / dt_static,
        "ticks": s["ticks"],
        "static_iters": total_iters,
        "converged": s["converged"],
        "occupancy": s["occupancy"],
    }


def main(json_path="BENCH_serve.json", quick=False, check=False):
    arch = "llama3.2-1b"
    slots = 2 if quick else 4
    n_req = 6 if quick else 16
    prompt_len = 6 if quick else 12
    gen_max = 24 if quick else 48
    schedulers = ("fcfs",) if quick else ("fcfs", "priority", "sla_edf")
    arrival_kinds = ("burst",) if quick else ("0.25", "1.0", "burst")
    seed = 0

    cfg = registry.get_smoke_config(arch)
    mesh = build_mesh(1, 1)
    prompts, budgets = _traffic(n_req, prompt_len, gen_max, cfg.vocab, seed)
    workload = make_workload(
        "llm_decode", cfg=cfg, mesh=mesh, slots=slots,
        max_len=prompt_len + gen_max + 2, max_prompt_len=prompt_len, seed=seed,
    )

    rows = []
    static = _best_of(
        lambda: run_static_llm(cfg, mesh, workload.params, prompts, budgets,
                               slots),
        lambda s: s["tok_s"])
    rows.append({
        "name": "serve_static_baseline", "workload": "llm_decode",
        "tok_s": round(static["tok_s"], 1),
        "useful_tokens": static["useful_tokens"],
        "wall_s": round(static["wall_s"], 3),
    })

    # warm the continuous path's compile cache outside the timed runs too
    # (slots+1 requests: the recycled-slot admission path compiles as well)
    w = slots + 1
    run_continuous_llm(workload, prompts[:w], budgets[:w], [0] * w, "fcfs")

    burst_tok_s = None
    for sched in schedulers:
        for akind in arrival_kinds:
            arrivals = _arrivals(akind, n_req, seed + 3)
            s = _best_of(
                lambda: run_continuous_llm(workload, prompts, budgets,
                                           arrivals, sched)[0],
                lambda s: s["throughput_tok_s"])
            row = {
                "name": f"serve_llm_{sched}_{akind}",
                "workload": "llm_decode", "scheduler": sched,
                "arrival": akind,
                "tok_s": round(s["throughput_tok_s"], 1),
                "ttft_p50_ms": round(s["ttft_p50_ms"], 2),
                "ttft_p95_ms": round(s["ttft_p95_ms"], 2),
                "tpot_p50_ms": round(s["tpot_p50_ms"], 3),
                "tpot_p95_ms": round(s["tpot_p95_ms"], 3),
                "occupancy": round(s["occupancy"], 3),
                "speedup_vs_static": round(
                    s["throughput_tok_s"] / static["tok_s"], 3),
                **_mem_fields(workload, s),
            }
            rows.append(row)
            if sched == "fcfs" and akind == "burst":
                burst_tok_s = s["throughput_tok_s"]

    # --- paged vs contiguous: same cache bytes, 2x the slots --------------
    # Shared-system-prompt burst traffic; the paged pool gets the same
    # number of cache *blocks* the contiguous pool reserves (+1 trash
    # block) but serves twice the slots out of them: the 3 system-prefix
    # blocks are stored once, and nothing reserves max_len for short
    # requests.  Tokens must match bit-for-bit (the paged step runs the
    # identical decode vmap over gathered block views).
    bs_blk = 8
    sys_len, user_len, gen_hi = 24, 4, 10
    p_prompt = sys_len + user_len
    p_max_len = -(-(p_prompt + gen_hi + 2) // bs_blk) * bs_blk
    sys_prompts, sys_budgets = _system_traffic(
        n_req, cfg.vocab, seed + 11, sys_len=sys_len, user_len=user_len,
        gen_hi=gen_hi,
    )
    burst = [0] * n_req
    wl_contig = make_workload(
        "llm_decode", cfg=cfg, mesh=mesh, slots=slots, max_len=p_max_len,
        max_prompt_len=p_prompt, seed=seed,
    )
    w = slots + 1
    run_continuous_llm(wl_contig, sys_prompts[:w], sys_budgets[:w],
                       [0] * w, "fcfs")  # warm
    sc, res_c = _best_of(
        lambda: run_continuous_llm(wl_contig, sys_prompts, sys_budgets,
                                   burst, "fcfs"),
        lambda t: t[0]["throughput_tok_s"])
    contig_row = {
        "name": "serve_llm_contig_sysprefix", "workload": "llm_decode",
        "slots": slots, "tok_s": round(sc["throughput_tok_s"], 1),
        "occupancy": round(sc["occupancy"], 3),
        **_mem_fields(wl_contig, sc),
    }
    rows.append(contig_row)

    blocks_per_slot = p_max_len // bs_blk
    wl_paged = make_workload(
        "llm_decode_paged", cfg=cfg, mesh=mesh, slots=2 * slots,
        max_len=p_max_len, max_prompt_len=p_prompt, seed=seed,
        block_size=bs_blk, num_blocks=slots * blocks_per_slot + 1,
    )
    run_continuous_llm(wl_paged, sys_prompts[:w], sys_budgets[:w],
                       [0] * w, "fcfs")  # warm
    sp, res_p = _best_of(
        lambda: run_continuous_llm(wl_paged, sys_prompts, sys_budgets,
                                   burst, "fcfs"),
        lambda t: t[0]["throughput_tok_s"])
    bit_exact = all(
        np.array_equal(res_c[i].output, res_p[i].output)
        for i in range(n_req)
    )
    pm = _mem_fields(wl_paged, sp)
    # concurrency each pool affords per MiB of cache
    conc_ratio = (2 * slots / (pm["cache_mib"] or 1)) / (
        slots / (contig_row["cache_mib"] or 1)
    )
    paged_row = {
        "name": "serve_llm_paged_sysprefix", "workload": "llm_decode_paged",
        "slots": 2 * slots, "num_blocks": slots * blocks_per_slot + 1,
        "block_size": bs_blk,
        "tok_s": round(sp["throughput_tok_s"], 1),
        "occupancy": round(sp["occupancy"], 3),
        "prefix_saved_blocks": wl_paged.prefix_saved_blocks,
        "forced_at_capacity": sp["forced_at_capacity"],
        "concurrency_per_byte_vs_contig": round(conc_ratio, 3),
        "tok_s_vs_contig": round(
            sp["throughput_tok_s"] / sc["throughput_tok_s"], 3),
        "bit_exact_vs_contig": bit_exact,
        **pm,
    }
    rows.append(paged_row)

    # --- elastic serving: kill/join under Poisson arrivals (DESIGN.md S15) --
    # The same mixed-budget traffic with two replica kills and a two-replica
    # join mid-run (agreement extent 4 -> 3 -> 5 -> 4), driven by the
    # ElasticServeController.  Gates: no request lost, no slot re-prefilled,
    # and elastic throughput >= 0.8x the uninterrupted steady-state run at
    # the starting extent.  Every visited extent is warmed outside the timed
    # region so the rows measure serving + migration, not XLA compiles.
    from repro.launch.serve import _CliChaosScript
    from repro.runtime import ElasticServeController

    el_dp, el_spd = 4, 4
    el_n = n_req * 3  # enough traffic to leave a settled tail post-chaos
    el_prompts, el_budgets = _traffic(
        el_n, prompt_len, gen_max, cfg.vocab, seed + 21)
    el_arrivals = _arrivals("0.5", el_n, seed + 5)
    el_events = [
        (6, "kill", (2,), {"silent": False}),
        (16, "join", ((4, 5),), {}),
        (26, "kill", (0,), {}),
    ]

    def _run_elastic(eng, reqs, tokens_of):
        """Drive the controller loop; also measure throughput of the
        *post-resize* segment — work retired after the trajectory settles
        back at the starting extent — which is what the >= 0.8x steady
        gate checks (a resize must not leave lasting degradation; the
        migration itself is bounded host work, not throughput)."""
        ctl = ElasticServeController(eng, policy="grow_on_join")
        script = _CliChaosScript(el_events)
        for r in reqs:
            eng.submit(r)
        t_post = w_post = None
        while eng.queue or eng.pending or any(
                s is not None for s in eng.slot_req):
            ctl.step(script)
            if t_post is None and len(eng.resizes) == len(el_events):
                t_post = time.perf_counter()
                w_post = tokens_of(eng)
        t_end = time.perf_counter()
        post_rate = None
        if t_post is not None and t_end > t_post:
            post_rate = (tokens_of(eng) - w_post) / (t_end - t_post)
        return eng.results, post_rate

    def _llm_tokens(eng):
        return sum(len(r.output) for r in eng.results.values())

    def _elastic_llm_run():
        workload.reset()
        eng = ServeEngine(workload, ServeConfig(
            dp=el_dp, steps_per_dispatch=el_spd,
        ))
        reqs = [Request(id=i, arrival=a, prompt=p, max_new=b)
                for i, (p, b, a) in enumerate(zip(el_prompts, el_budgets,
                                                  el_arrivals))]
        res, post = _run_elastic(eng, reqs, _llm_tokens)
        return eng, res, post

    # the run is a deterministic function of (traffic, script): run it once
    # to warm every visited extent's fused loop and the grow broadcast,
    # then time the identical second run
    _elastic_llm_run()
    run_continuous_llm(workload, el_prompts[:w], el_budgets[:w], [0] * w,
                       "fcfs", dp=el_dp, steps_per_dispatch=el_spd)
    ss = _best_of(
        lambda: run_continuous_llm(workload, el_prompts, el_budgets,
                                   el_arrivals, "fcfs", dp=el_dp,
                                   steps_per_dispatch=el_spd)[0],
        lambda s: s["throughput_tok_s"])
    eng, el_res, el_post = _best_of(
        _elastic_llm_run, lambda t: t[2] or 0.0)
    se = eng.summary()
    llm_elastic_row = {
        "name": "serve_llm_elastic_killjoin", "workload": "llm_decode",
        "trajectory": "4->3->5->4", "resizes": se["resizes"],
        "tok_s": round(se["throughput_tok_s"], 1),
        "ttft_p95_ms": round(se["ttft_p95_ms"], 2),
        "lost_requests": el_n - len(el_res),
        "reprefills": workload.prefills - el_n,
        "tok_s_vs_steady": round(
            se["throughput_tok_s"] / ss["throughput_tok_s"], 3),
        "tok_s_post_vs_steady": round(
            (el_post or 0.0) / ss["throughput_tok_s"], 3),
    }
    rows.append(llm_elastic_row)

    fp_n = 60  # divisible by every visited extent (3, 4, 5)
    fp_wl = make_workload(
        "fixedpoint_solve", solver="d_iteration", n=fp_n, dp=el_dp,
        slots=slots, damping=0.8, seed=seed,
    )
    rng = np.random.default_rng(seed + 13)
    fp_pay = []
    for _ in range(el_n):
        v = rng.random(fp_n).astype(np.float32)
        fp_pay.append(v / v.sum())
    fp_cfg = ServeConfig(
        termination="residual_interval", dp=el_dp, eps=1e-6,
        steps_per_dispatch=el_spd,
    )

    def _fp_reqs():
        return [Request(id=i, arrival=a, payload=p, max_new=5000)
                for i, (p, a) in enumerate(zip(fp_pay, el_arrivals))]

    def _elastic_fp_run():
        fp_wl.reset()
        eng = ServeEngine(fp_wl, fp_cfg)
        res, post = _run_elastic(eng, _fp_reqs(),
                                 lambda e: len(e.results))
        return eng, res, post

    _elastic_fp_run()  # warm all visited extents + the grow broadcast
    fp_wl.reset()
    ServeEngine(fp_wl, fp_cfg).run(_fp_reqs())  # warm the steady shape

    def _fp_steady_run():
        fp_wl.reset()
        eng = ServeEngine(fp_wl, fp_cfg)
        eng.run(_fp_reqs())
        return eng.summary()

    fp_steady = _best_of(_fp_steady_run, lambda s: -s["wall_s"])
    eng, fp_el_res, fp_post = _best_of(
        _elastic_fp_run, lambda t: t[2] or 0.0)
    fe = eng.summary()
    fp_steady_req_s = el_n / fp_steady["wall_s"]
    fp_elastic_row = {
        "name": "serve_fixedpoint_elastic_killjoin",
        "workload": "fixedpoint_solve", "trajectory": "4->3->5->4",
        "resizes": fe["resizes"],
        "req_s": round(len(fp_el_res) / fe["wall_s"], 2),
        "lost_requests": el_n - len(fp_el_res),
        "converged": fe["converged"],
        "req_s_vs_steady": round(
            (len(fp_el_res) / fe["wall_s"]) / fp_steady_req_s, 3),
        "req_s_post_vs_steady": round(
            (fp_post or 0.0) / fp_steady_req_s, 3),
    }
    rows.append(fp_elastic_row)

    fp = run_fixedpoint(
        n=48 if quick else 66, dp=2 if quick else 3, slots=slots,
        n_req=n_req, eps=1e-6, scheduler="fcfs", seed=seed,
    )
    rows.append({
        "name": "serve_fixedpoint_fcfs", "workload": "fixedpoint_solve",
        "scheduler": "fcfs",
        "req_s": round(fp["req_s"], 2),
        "static_req_s": round(fp["static_req_s"], 2),
        "speedup_vs_static": round(fp["req_s"] / fp["static_req_s"], 3),
        "occupancy": round(fp["occupancy"], 3),
        "converged": fp["converged"],
    })

    for r in rows:
        derived = r.get("speedup_vs_static", "")
        print(f"{r['name']},{r.get('tok_s', r.get('req_s'))},{derived}")
    payload = {
        "meta": {"arch": arch, "slots": slots, "requests": n_req,
                 "prompt_len": prompt_len, "gen_max": gen_max,
                 "quick": quick, "baseline": "static waves"},
        "sweep": rows,
    }
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {json_path}")

    if check:
        # percentile integrity first: ServeEngine.summary() reports NaN —
        # not a fake 0 ms — when nothing retired (or when TPOT has no
        # inter-token interval), so a missing or non-finite percentile in
        # any row is a hard failure, never a trivially-passing latency
        for r in rows:
            for k, v in r.items():
                if k.endswith("_ms"):
                    assert isinstance(v, float) and math.isfinite(v), (
                        f"{r['name']}: percentile {k}={v!r} is not finite "
                        f"(empty or single-token-only run leaked into a "
                        f"latency gate)"
                    )
            if r.get("scheduler") and r["workload"] == "llm_decode":
                for k in ("ttft_p50_ms", "ttft_p95_ms",
                          "tpot_p50_ms", "tpot_p95_ms"):
                    assert k in r, f"{r['name']} is missing percentile {k}"
        assert burst_tok_s is not None
        # The continuous-vs-static wall-clock crossover is hardware-bound
        # at smoke scale: the 64-dim model makes both loops host-limited,
        # and the reference full-bench numbers put fcfs/burst at ~0.97x
        # static — within scheduler noise.  The gate therefore guards
        # against gross scheduling regression (the structural wins show
        # up in TTFT, occupancy, and the priority/sla_edf burst rows).
        assert burst_tok_s >= 0.7 * static["tok_s"], (
            f"continuous batching ({burst_tok_s:.1f} tok/s) fell below "
            f"0.7x the static baseline ({static['tok_s']:.1f} tok/s) at "
            f"peak arrival"
        )
        for r in rows:
            if r["workload"] == "fixedpoint_solve":
                want = el_n if "elastic" in r["name"] else n_req
                assert r["converged"] == want, r
        assert paged_row["bit_exact_vs_contig"], (
            "paged decode diverged from contiguous decode"
        )
        assert paged_row["concurrency_per_byte_vs_contig"] >= 1.5, paged_row
        assert paged_row["tok_s_vs_contig"] >= 0.8, (
            f"paged throughput regressed: {paged_row['tok_s_vs_contig']:.3f}x "
            f"of contiguous (gate: >= 0.8x; the reference ratio is ~0.92 "
            f"and the measurement is host-bound at smoke scale)"
        )
        for r in (llm_elastic_row, fp_elastic_row):
            assert r["lost_requests"] == 0, f"elastic serving lost requests: {r}"
            assert r["resizes"] == 3, f"resize trajectory incomplete: {r}"
        assert llm_elastic_row["reprefills"] == 0, (
            f"elastic resize re-prefilled slots: {llm_elastic_row}"
        )
        assert llm_elastic_row["tok_s_post_vs_steady"] >= 0.8, (
            f"post-resize tok/s fell below 0.8x steady-state: "
            f"{llm_elastic_row}"
        )
        assert fp_elastic_row["converged"] == el_n, fp_elastic_row
        assert fp_elastic_row["req_s_post_vs_steady"] >= 0.8, (
            f"post-resize req/s fell below 0.8x steady-state: "
            f"{fp_elastic_row}"
        )
        print(f"# sanity OK: continuous {burst_tok_s:.1f} tok/s vs "
              f"static {static['tok_s']:.1f} tok/s; fixedpoint all certified; "
              f"paged bit-exact at "
              f"{paged_row['concurrency_per_byte_vs_contig']:.2f}x "
              f"concurrency/byte, {paged_row['tok_s_vs_contig']:.2f}x tok/s; "
              f"elastic kill/join lost 0 requests at "
              f"{llm_elastic_row['tok_s_post_vs_steady']:.2f}x steady "
              f"post-resize")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default="BENCH_serve.json")
    ap.add_argument("--quick", action="store_true",
                    help="reduced grid (CI smoke)")
    ap.add_argument("--check", action="store_true",
                    help="assert continuous >= static throughput at peak "
                         "arrival + fixedpoint certification (CI gate)")
    ap.add_argument("--telemetry", default=None, metavar="SINK[:PATH]",
                    help="run the whole sweep with the obs subsystem live "
                         "(null | jsonl[:f] | csv[:f] | chrome_trace[:f]); "
                         "benchmarks/bench_telemetry.py gates the overhead "
                         "of this against the disabled baseline")
    args = ap.parse_args()
    if args.telemetry:
        from repro import obs

        try:
            obs.configure(args.telemetry)
        except ValueError as e:
            raise SystemExit(f"--telemetry: {e}")
    try:
        main(json_path=args.json, quick=args.quick, check=args.check)
    finally:
        if args.telemetry:
            t = obs.shutdown()
            print(f"# telemetry[{t['sink']}]: {t['spans']} spans, "
                  f"{t['instants']} instants, "
                  f"{t['events_dropped'] + t['metrics_dropped']} dropped")
