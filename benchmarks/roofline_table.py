"""Roofline table: joins the production dry-run (memory, structure) with the
loop-calibrated cost fits (flops / bytes / collective bytes) and prints the
three-term roofline per (arch x shape) — EXPERIMENTS.md §Roofline reads this.

Also prints the *collective message model*: per-(schedule, p) message and
step counts regenerated from the live ``CollectivePlan`` stage tables (the
single accounting the bucketed/paged engines execute) and emitted through
the obs metrics registry, checked against the paper's closed forms.  The
table predated the bucketed paths and had drifted from a hand-maintained
copy of the counts; it now cannot drift — it reads the same
``bound_stage_table()`` the executors run.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline_table \\
      [--dryrun results/dryrun] [--cal results/calibrate] [--mesh single] \\
      [--json out.json] [--extents 2,3,5,8,17]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import registry, shapes
from repro.core import topology
from repro.launch import roofline as R
from repro.obs import MetricsRegistry


def message_model(ps, schedules=("mrd", "rabenseifner")):
    """Regenerate per-(schedule, p) collective message accounting from the
    live plan layer, routed through a :class:`MetricsRegistry` (the same
    instruments ``--telemetry`` uses) and read back from its snapshot —
    so the printed numbers are exactly what the obs plane would report.

    Returns (rows, drift): drift lists any (schedule, p) where the plan's
    stage table disagrees with the paper's closed form (mrd only — the
    other schedules have no paper closed form to pin)."""
    from repro.collectives.plans import CollectivePlan

    reg = MetricsRegistry()
    meta = {}
    for sched in schedules:
        for p in ps:
            plan = CollectivePlan(schedule=sched, executor="sim", p=p)
            msgs = steps = 0
            shift = 0  # the paper's 2*(p - 2^floor(log2 p)) extra messages
            for st, _coll, _ai, sp in plan.bound_stage_table():
                msgs += len(st.pairs)
                steps += 1
                if st.kind in ("bshift", "fshift"):
                    shift += len(st.pairs)
            reg.counter("coll.model.messages", schedule=sched, p=str(p)).add(msgs)
            reg.counter("coll.model.steps", schedule=sched, p=str(p)).add(steps)
            reg.counter("coll.model.extra_msgs", schedule=sched, p=str(p)).add(shift)
            meta[(sched, p)] = (msgs, steps, shift)
    counters = reg.snapshot()["counters"]

    rows, drift = [], []
    for (sched, p), _ in meta.items():
        key = f"[p={p},schedule={sched}]"
        msgs = int(counters["coll.model.messages" + key])
        steps = int(counters["coll.model.steps" + key])
        shift = int(counters["coll.model.extra_msgs" + key])
        row = {"schedule": sched, "p": p, "messages": msgs, "steps": steps,
               "extra_msgs": shift}
        if sched == "mrd":
            want_m = topology.paper_message_count(p)
            want_s = topology.paper_step_count(p)
            want_x = 2 * topology.pivot(p)[2]
            row["paper_messages"] = want_m
            if (msgs, steps, shift) != (want_m, want_s, want_x):
                drift.append(row)
        rows.append(row)
    return rows, drift


def format_message_model(rows):
    head = f"{'schedule':<14}{'p':>4}{'steps':>7}{'messages':>10}{'extra':>7}  paper"
    lines = [head, "-" * len(head)]
    for r in rows:
        paper = r.get("paper_messages")
        ok = "" if paper is None else ("  ok" if paper == r["messages"]
                                       else f"  DRIFT(want {paper})")
        lines.append(
            f"{r['schedule']:<14}{r['p']:>4}{r['steps']:>7}"
            f"{r['messages']:>10}{r['extra_msgs']:>7}{ok}"
        )
    return "\n".join(lines)


def load(dryrun_dir, cal_dir, mesh):
    reports = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, f"*__{mesh}.json"))):
        prod = json.load(open(f))
        if "error" in prod or "skipped" in prod:
            continue
        arch, shape = prod["arch"], prod["shape"]
        cal_path = os.path.join(cal_dir, f"{arch}__{shape}__{mesh}.json")
        cal = None
        if os.path.exists(cal_path):
            c = json.load(open(cal_path))
            cal = c.get("calibrated")
        cfg = registry.get_config(arch)
        cell = shapes.SHAPES[shape]
        if cal:
            flops, byts, coll = cal["flops"], cal["bytes"], cal["coll"]
            calibrated = True
        else:  # fall back to raw (loop-undercounted) production numbers
            flops = prod["cost"].get("flops") or 0.0
            byts = prod["cost"].get("bytes_accessed") or 0.0
            coll = prod.get("collective_bytes", {})
            calibrated = False
        rep = R.RooflineReport(
            arch=arch,
            shape=shape,
            mesh=mesh,
            chips=prod.get("chips", 256),
            hlo_flops=flops,
            hlo_bytes=byts,
            collective_bytes={k: int(v) for k, v in coll.items()},
            model_flops=R.model_flops_for(cfg, cell),
            peak_memory_bytes=(
                (prod.get("memory", {}).get("temp_bytes_tpu_adjusted") or 0)
                + (prod.get("memory", {}).get("argument_bytes") or 0)
            ),
            compile_seconds=prod.get("compile_seconds"),
        )
        rep._calibrated = calibrated  # annotate
        reports.append(rep)
    return reports


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun")
    ap.add_argument("--cal", default="results/calibrate")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json", default=None)
    ap.add_argument("--extents", default="2,3,5,8,17",
                    help="comma-separated p values for the message model")
    args = ap.parse_args()

    ps = [int(x) for x in args.extents.split(",") if x]
    msg_rows, drift = message_model(ps)
    print("collective message model (regenerated from CollectivePlan):")
    print(format_message_model(msg_rows))
    if drift:
        raise SystemExit(
            f"message-model drift vs paper closed form: {drift}"
        )

    reports = load(args.dryrun, args.cal, args.mesh)
    if not reports:
        print(f"\n(no dry-run results under {args.dryrun!r}/{args.cal!r} — "
              f"roofline section skipped; run launch/dryrun.py + "
              f"benchmarks/calibrate to populate)")
    else:
        print()
        print(R.format_table(reports))
        ncal = sum(1 for r in reports if getattr(r, "_calibrated", False))
        print(f"\n({ncal}/{len(reports)} cells loop-calibrated; HBM fit uses "
              f"temp_bytes_tpu_adjusted + args, v5e budget 16 GB/chip)")
        over = [
            r for r in reports
            if r.peak_memory_bytes and r.peak_memory_bytes > 16e9
        ]
        for r in over:
            print(f"  OVER-BUDGET: {r.arch}/{r.shape}: {r.peak_memory_bytes/1e9:.1f} GB")
    if args.json:
        R.save_reports(reports, args.json, extra={"message_model": msg_rows})


if __name__ == "__main__":
    main()
