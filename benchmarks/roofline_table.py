"""Roofline table: joins the production dry-run (memory, structure) with the
loop-calibrated cost fits (flops / bytes / collective bytes) and prints the
three-term roofline per (arch x shape) — EXPERIMENTS.md §Roofline reads this.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline_table \\
      [--dryrun results/dryrun] [--cal results/calibrate] [--mesh single] [--json out.json]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import registry, shapes
from repro.launch import roofline as R


def load(dryrun_dir, cal_dir, mesh):
    reports = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, f"*__{mesh}.json"))):
        prod = json.load(open(f))
        if "error" in prod or "skipped" in prod:
            continue
        arch, shape = prod["arch"], prod["shape"]
        cal_path = os.path.join(cal_dir, f"{arch}__{shape}__{mesh}.json")
        cal = None
        if os.path.exists(cal_path):
            c = json.load(open(cal_path))
            cal = c.get("calibrated")
        cfg = registry.get_config(arch)
        cell = shapes.SHAPES[shape]
        if cal:
            flops, byts, coll = cal["flops"], cal["bytes"], cal["coll"]
            calibrated = True
        else:  # fall back to raw (loop-undercounted) production numbers
            flops = prod["cost"].get("flops") or 0.0
            byts = prod["cost"].get("bytes_accessed") or 0.0
            coll = prod.get("collective_bytes", {})
            calibrated = False
        rep = R.RooflineReport(
            arch=arch,
            shape=shape,
            mesh=mesh,
            chips=prod.get("chips", 256),
            hlo_flops=flops,
            hlo_bytes=byts,
            collective_bytes={k: int(v) for k, v in coll.items()},
            model_flops=R.model_flops_for(cfg, cell),
            peak_memory_bytes=(
                (prod.get("memory", {}).get("temp_bytes_tpu_adjusted") or 0)
                + (prod.get("memory", {}).get("argument_bytes") or 0)
            ),
            compile_seconds=prod.get("compile_seconds"),
        )
        rep._calibrated = calibrated  # annotate
        reports.append(rep)
    return reports


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun")
    ap.add_argument("--cal", default="results/calibrate")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    reports = load(args.dryrun, args.cal, args.mesh)
    print(R.format_table(reports))
    ncal = sum(1 for r in reports if getattr(r, "_calibrated", False))
    print(f"\n({ncal}/{len(reports)} cells loop-calibrated; HBM fit uses "
          f"temp_bytes_tpu_adjusted + args, v5e budget 16 GB/chip)")
    over = [
        r for r in reports
        if r.peak_memory_bytes and r.peak_memory_bytes > 16e9
    ]
    for r in over:
        print(f"  OVER-BUDGET: {r.arch}/{r.shape}: {r.peak_memory_bytes/1e9:.1f} GB")
    if args.json:
        R.save_reports(reports, args.json)


if __name__ == "__main__":
    main()
