"""Telemetry overhead gate (DESIGN.md S18): the obs subsystem must be
cheap enough to leave on in production serving.

Runs the quick continuous-batching serve measurement from
``benchmarks.bench_serve`` three times over identical burst traffic:

- ``off``   — obs disabled (every hook is one attribute load + branch);
- ``null``  — obs enabled with the null sink (record + drain, no I/O);
- ``jsonl`` — obs enabled with the jsonl sink (the production default:
  record + drain + line-buffered writes from the background thread).

Each cell is best-of-n over deterministic runs (the PR-7 noise treatment:
these are tens-of-ms walls where one scheduler preemption flips a ratio).
``--check`` asserts the CI gate from ISSUE 10: **jsonl throughput within
5% of null** (and null within 5% of off, so "enabled at all" can't hide
a regression either).  The jsonl cell must also have actually recorded
spans — a gate that passes because telemetry silently never turned on is
no gate.

CSV on stdout: name,tok_s,ratio_vs_off
JSON: writes BENCH_telemetry.json ({"sweep": [...], "meta": {...}}).
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile

from repro import obs
from repro.configs import registry
from repro.launch.train import build_mesh
from repro.serving import make_workload

from benchmarks.bench_serve import _best_of, _traffic, run_continuous_llm

GATE = 0.95  # enabled sinks must keep >= 95% of the baseline tok/s


def _measure(workload, prompts, budgets, spec, n):
    """Best-of-n tok/s for one telemetry configuration (None = disabled).
    Each repeat configures, runs, and tears down — the measurement includes
    the background writer thread, exactly what production pays."""
    arrivals = [0] * len(prompts)  # burst: peak load, the worst case

    def once():
        obs.reset()
        telem = None
        if spec is not None:
            obs.configure(spec)
        try:
            s, _ = run_continuous_llm(workload, prompts, budgets, arrivals,
                                      "fcfs")
            if spec is not None:
                telem = obs.summary()
            return s["throughput_tok_s"], telem
        finally:
            if spec is not None:
                obs.shutdown()
            obs.reset()

    return _best_of(once, lambda r: r[0], n=n)


def main(json_path="BENCH_telemetry.json", check=False, repeats=5):
    arch = "llama3.2-1b"
    slots, n_req, prompt_len, gen_max, seed = 2, 6, 6, 24, 0

    cfg = registry.get_smoke_config(arch)
    mesh = build_mesh(1, 1)
    prompts, budgets = _traffic(n_req, prompt_len, gen_max, cfg.vocab, seed)
    workload = make_workload(
        "llm_decode", cfg=cfg, mesh=mesh, slots=slots,
        max_len=prompt_len + gen_max + 2, max_prompt_len=prompt_len, seed=seed,
    )
    # warm the compile cache (and the recycled-slot admission path) before
    # any timed cell, under telemetry so the instrumented trace is warm too
    w = slots + 1
    obs.configure("null")
    run_continuous_llm(workload, prompts[:w], budgets[:w], [0] * w, "fcfs")
    obs.shutdown()
    obs.reset()

    jsonl_path = os.path.join(tempfile.mkdtemp(prefix="obs_bench_"),
                              "serve.jsonl")
    cells = [
        ("off", None),
        ("null", "null"),
        ("jsonl", f"jsonl:{jsonl_path}"),
    ]
    rows = []
    by_name = {}
    for name, spec in cells:
        tok_s, telem = _measure(workload, prompts, budgets, spec, repeats)
        row = {"name": f"telemetry_{name}", "sink": name,
               "tok_s": round(tok_s, 1)}
        if telem is not None:
            row["spans"] = telem["spans"]
            row["events_dropped"] = telem["events_dropped"]
            row["metrics_dropped"] = telem["metrics_dropped"]
        rows.append(row)
        by_name[name] = row

    off = by_name["off"]["tok_s"]
    for row in rows:
        row["ratio_vs_off"] = round(row["tok_s"] / off, 3) if off else None
        print(f"{row['name']},{row['tok_s']},{row['ratio_vs_off']}")

    payload = {
        "meta": {"arch": arch, "slots": slots, "requests": n_req,
                 "repeats": repeats, "gate": GATE},
        "sweep": rows,
    }
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {json_path}")

    if check:
        null_r, jsonl_r = by_name["null"], by_name["jsonl"]
        assert jsonl_r["spans"] > 0, (
            "jsonl cell recorded no spans — telemetry never enabled, the "
            "overhead gate measured nothing"
        )
        ratio = jsonl_r["tok_s"] / null_r["tok_s"]
        assert ratio >= GATE, (
            f"jsonl telemetry overhead over gate: {jsonl_r['tok_s']:.1f} "
            f"tok/s vs null {null_r['tok_s']:.1f} tok/s "
            f"({ratio:.3f}x < {GATE}x)"
        )
        assert null_r["tok_s"] >= GATE * off, (
            f"enabling telemetry (null sink) costs more than "
            f"{(1-GATE):.0%}: {null_r['tok_s']:.1f} vs disabled {off:.1f}"
        )
        print(f"# sanity OK: jsonl {ratio:.3f}x of null "
              f"(gate >= {GATE}), {jsonl_r['spans']} spans recorded, "
              f"{jsonl_r['events_dropped']} dropped")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default="BENCH_telemetry.json")
    ap.add_argument("--check", action="store_true",
                    help="assert the 5%% overhead gate (CI)")
    ap.add_argument("--repeats", type=int, default=5,
                    help="best-of-n repeats per cell")
    args = ap.parse_args()
    main(json_path=args.json, check=args.check, repeats=args.repeats)
