"""Paper S2 table: MRD cost model — steps, messages, volume vs p, and the
alpha-beta time comparison against ring/tree/Rabenseifner schedules.

CSV: name,us_per_call,derived
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mrd, topology as T


def rows():
    out = []
    # --- closed-form validation: messages & steps per cycle (E1/E2) ---
    for p in (2, 3, 4, 5, 7, 8, 12, 16, 24, 32, 64, 100, 256):
        sched = T.allreduce_schedule(p)
        msgs = T.schedule_messages(sched)
        assert msgs == T.paper_message_count(p)
        assert len(sched) == T.paper_step_count(p)
        out.append((f"mrd_messages_p{p}", 0.0, msgs))
        out.append((f"mrd_steps_p{p}", 0.0, len(sched)))

    # --- alpha-beta modeled time (v5e ICI), 100MB gradient buffer ---
    link = T.LinkModel.tpu_v5e_ici()
    n_bytes = 100 * 2**20
    for p in (8, 16, 64, 256):
        t_mrd = T.schedule_time(T.allreduce_schedule(p), n_bytes, link)
        t_rab = T.schedule_time(T.rabenseifner_schedule(p), n_bytes, link)
        t_ring = T.ring_allreduce_time(p, n_bytes, link)
        t_tree = T.tree_allreduce_time(p, n_bytes, link)
        out.append((f"model_mrd_100MB_p{p}", t_mrd * 1e6, round(t_mrd * 1e3, 3)))
        out.append((f"model_rabenseifner_100MB_p{p}", t_rab * 1e6, round(t_rab * 1e3, 3)))
        out.append((f"model_ring_100MB_p{p}", t_ring * 1e6, round(t_ring * 1e3, 3)))
        out.append((f"model_tree_100MB_p{p}", t_tree * 1e6, round(t_tree * 1e3, 3)))

    # --- latency regime (8-byte residual scalar, the paper's case) ---
    for p in (16, 256):
        t_mrd = T.schedule_time(T.allreduce_schedule(p), 8, link)
        t_ring = T.ring_allreduce_time(p, 8, link)
        out.append((f"model_mrd_scalar_p{p}", t_mrd * 1e6, round(t_mrd * 1e6, 2)))
        out.append((f"model_ring_scalar_p{p}", t_ring * 1e6, round(t_ring * 1e6, 2)))

    # --- measured wall time of the sim executor (CPU, correctness path) ---
    for p in (8, 16, 32):
        x = jnp.asarray(np.random.default_rng(0).standard_normal((p, 4096)), jnp.float32)
        f = jax.jit(lambda v: mrd.sim_allreduce(v, op="sum"))
        f(x).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(20):
            f(x).block_until_ready()
        us = (time.perf_counter() - t0) / 20 * 1e6
        out.append((f"sim_allreduce_p{p}_n4096", round(us, 1), p))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us},{derived}")


if __name__ == "__main__":
    main()
