"""Paper S2 table: MRD cost model — steps, messages, volume vs p, and the
alpha-beta time comparison against ring/tree/Rabenseifner schedules — plus
measured sweeps of the plan layer on the sim executor: the registry sweep
(schedule x transform) and the bucketed-vs-flat-vs-per-leaf gradient sweep
(many-leaf tree through the DESIGN.md S10 pipelined engine).

CSV on stdout: name,us_per_call,derived
JSON: writes BENCH_mrd.json (schema: {"model": [...], "measured": [...]}) so
the perf trajectory is machine-readable across PRs.

``--quick`` runs a reduced sweep (fewer p values, fewer timing iterations)
for CI smoke; the row names it emits are a subset of the full run's.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.collectives import SCHEDULES, TRANSFORMS, plans
from repro.collectives import schedules as T
from repro.core import mrd


def model_rows():
    out = []
    # --- closed-form validation: messages & steps per cycle (E1/E2) ---
    for p in (2, 3, 4, 5, 7, 8, 12, 16, 24, 32, 64, 100, 256):
        sched = T.allreduce_schedule(p)
        msgs = T.schedule_messages(sched)
        assert msgs == T.paper_message_count(p)
        assert len(sched) == T.paper_step_count(p)
        out.append((f"mrd_messages_p{p}", 0.0, msgs))
        out.append((f"mrd_steps_p{p}", 0.0, len(sched)))

    # --- alpha-beta modeled time (v5e ICI), 100MB gradient buffer ---
    link = T.LinkModel.tpu_v5e_ici()
    n_bytes = 100 * 2**20
    for p in (8, 16, 64, 256):
        t_mrd = T.schedule_time(T.allreduce_schedule(p), n_bytes, link)
        t_rab = T.schedule_time(T.rabenseifner_schedule(p), n_bytes, link)
        t_ring = T.ring_allreduce_time(p, n_bytes, link)
        t_tree = T.tree_allreduce_time(p, n_bytes, link)
        out.append((f"model_mrd_100MB_p{p}", t_mrd * 1e6, round(t_mrd * 1e3, 3)))
        out.append((f"model_rabenseifner_100MB_p{p}", t_rab * 1e6, round(t_rab * 1e3, 3)))
        out.append((f"model_ring_100MB_p{p}", t_ring * 1e6, round(t_ring * 1e3, 3)))
        out.append((f"model_tree_100MB_p{p}", t_tree * 1e6, round(t_tree * 1e3, 3)))

    # --- latency regime (8-byte residual scalar, the paper's case) ---
    for p in (16, 256):
        t_mrd = T.schedule_time(T.allreduce_schedule(p), 8, link)
        t_ring = T.ring_allreduce_time(p, 8, link)
        out.append((f"model_mrd_scalar_p{p}", t_mrd * 1e6, round(t_mrd * 1e6, 2)))
        out.append((f"model_ring_scalar_p{p}", t_ring * 1e6, round(t_ring * 1e6, 2)))
    return out


def _time_call(f, *args, iters: int = 20) -> float:
    f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        f(*args).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bucketed_rows(quick: bool = False):
    """Gradient-scale sweep: a many-leaf (>= 64) fp32 tree allreduced three
    ways — per-leaf (one schedule cycle per tensor), flat (single ravel
    vector, the pre-bucketing path), and bucketed/pipelined
    (``run_bucketed``, DESIGN.md S10).

    Two regimes per variant:

    - ``..._jit_..``: steady-state inside one fused XLA computation.  On
      the CPU sim every stage fuses, so there is *no* per-message launch
      cost and the three paths land close together — this row set tracks
      regressions, not the alpha win.
    - ``..._dispatch_..``: op-by-op (eager) execution, where every stage
      of every tensor pays a real launch overhead — the CPU analog of the
      per-message alpha cost that the per-leaf path pays once per tensor
      on device interconnects.  This is the regime the bucketed engine
      targets; the bucketed row carries ``speedup_vs_perleaf``
      (acceptance: >= 1.3x on the >= 64-leaf tree).
    """
    out = []
    rng = np.random.default_rng(0)
    n_leaves = 64
    for p in ((8,) if quick else (8, 12)):
        sizes = [int(s) for s in rng.integers(64, 2048, n_leaves)]
        tree = {
            f"g{i:02d}": jnp.asarray(
                rng.standard_normal((p, s)), jnp.float32
            )
            for i, s in enumerate(sizes)
        }
        total = sum(sizes)
        plan = plans.allreduce_plan(schedule="mrd", p=p, op="sum")
        bucket_bytes = (total * 4) // 6  # ~6 buckets of the tree

        def flat_fn(t):
            vec = jnp.concatenate(
                [l.reshape(p, -1) for l in jax.tree.leaves(t)], axis=1
            )
            pad = (-vec.shape[1]) % plan.pad_quantum()
            red = plan.run(jnp.pad(vec, ((0, 0), (0, pad))))
            return red[:, : vec.shape[1]]

        def bucketed_fn(t):
            return plan.run_bucketed(t, bucket_bytes=bucket_bytes)

        variants = {"perleaf": plan.run, "flat": flat_fn, "bucketed": bucketed_fn}

        def _sync(o):
            for leaf in jax.tree.leaves(o):
                leaf.block_until_ready()

        def _time(f, iters, reps=3):
            _sync(f(tree))  # warmup (compile in the jit regime)
            best = float("inf")  # best-of-reps: robust to scheduler noise
            for _ in range(reps):
                t0 = time.perf_counter()
                for _ in range(iters):
                    _sync(f(tree))
                best = min(best, (time.perf_counter() - t0) / iters * 1e6)
            return best

        for regime, wrap, iters in (
            ("jit", jax.jit, 5 if quick else 20),
            ("dispatch", lambda f: f, 2 if quick else 4),
        ):
            times = {n: _time(wrap(f), iters) for n, f in variants.items()}
            for name, us in times.items():
                row = {
                    "name": f"sim_grad{n_leaves}_{name}_{regime}_p{p}",
                    "schedule": "mrd",
                    "transform": "identity",
                    "p": p,
                    "n": total,
                    "n_leaves": n_leaves,
                    "us_per_call": round(us, 1),
                }
                if name == "bucketed":
                    row["speedup_vs_perleaf"] = round(times["perleaf"] / us, 2)
                    row["speedup_vs_flat"] = round(times["flat"] / us, 2)
                out.append(row)
    return out


def measured_rows(quick: bool = False):
    """Registry sweep: every (schedule x transform) pair the plan layer can
    bind, measured on the sim executor (CPU correctness path)."""
    out = []
    rng = np.random.default_rng(0)
    for p in ((8, 12) if quick else (8, 12, 16, 32)):
        p0, _, _ = T.pivot(p)
        n = max(4096, p0 * 256)
        x = jnp.asarray(rng.standard_normal((p, n)), jnp.float32)
        for sched_name, fam in sorted(SCHEDULES.items()):
            if fam.min_axes > 1:
                continue  # hierarchical needs two mesh axes (device-only)
            for tf_name in sorted(TRANSFORMS):
                if tf_name != "identity" and sched_name == "mrd":
                    # int8 butterfly requantizes the full buffer every stage;
                    # it is wire-valid but never the fast choice — skip.
                    continue
                plan = plans.allreduce_plan(
                    schedule=sched_name, p=p, op="sum", transform=tf_name
                )
                pad = (-n) % plan.pad_quantum()
                xp = jnp.pad(x, ((0, 0), (0, pad)))
                f = jax.jit(plan.run)
                us = _time_call(f, xp)
                out.append(
                    {
                        "name": f"sim_{sched_name}_{tf_name}_p{p}_n{xp.shape[1]}",
                        "schedule": sched_name,
                        "transform": tf_name,
                        "p": p,
                        "n": int(xp.shape[1]),
                        "us_per_call": round(us, 1),
                    }
                )

    # legacy row set (kept so old trend lines keep their names)
    for p in ((8,) if quick else (8, 16, 32)):
        x = jnp.asarray(np.random.default_rng(0).standard_normal((p, 4096)), jnp.float32)
        f = jax.jit(lambda v: mrd.sim_allreduce(v, op="sum"))
        us = _time_call(f, x)
        out.append(
            {
                "name": f"sim_allreduce_p{p}_n4096",
                "schedule": "mrd",
                "transform": "identity",
                "p": p,
                "n": 4096,
                "us_per_call": round(us, 1),
            }
        )
    return out


def main(json_path: str = "BENCH_mrd.json", quick: bool = False):
    model = model_rows()
    measured = measured_rows(quick) + bucketed_rows(quick)
    for name, us, derived in model:
        print(f"{name},{us},{derived}")
    for r in measured:
        print(f"{r['name']},{r['us_per_call']},{r['p']}")
    payload = {
        "model": [
            {"name": n, "us_per_call": us, "derived": d} for n, us, d in model
        ],
        "measured": measured,
    }
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {json_path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default="BENCH_mrd.json", help="output JSON path")
    ap.add_argument(
        "--quick", action="store_true",
        help="reduced sweep (CI smoke): fewer p values and iterations",
    )
    args = ap.parse_args()
    main(json_path=args.json, quick=args.quick)
