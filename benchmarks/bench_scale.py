"""Trace-driven multi-tenant serving at scale (``repro.serving.tenants`` +
``sla_autoscale``, DESIGN.md S17): goodput-under-SLA, per-tenant tail
latency, and occupancy-vs-replica-count curves over pools of hundreds of
slots.

The scale rows run the ``fixedpoint_solve`` workload (per-query
D-iteration solves certified by the paper's detection protocol): its
per-tick device work is a cheap vmapped operator apply, so a 256-slot
pool is tractable on CPU CI while exercising exactly the same engine /
scheduler / termination / autoscaler control plane as LLM decode.  One
mixed-workload :class:`~repro.serving.TenantScenario` row serves LLM
decode and fixed-point tenants side by side and merges their per-tenant
summaries.

Rows (CSV on stdout: name,value,derived):

- ``scale_sched_<sched>`` — a three-tenant mix (interactive ``chat`` with
  a tight TTFT SLA, ``api`` with a looser one, quota'd no-SLA ``batch``)
  under correlated burst arrivals at a big pool, per scheduler.  Carries
  goodput-under-SLA and per-tenant p99 TTFT (ticks + ms).
- ``scale_replicas_dp<k>`` — the occupancy-vs-replica-count curve: the
  same traffic at fixed ``slots_per_replica`` and growing replica count
  (capacity model: ``usable = min(slots, dp * slots_per_replica)``).
- ``scale_static_peak`` / ``scale_autoscale`` — the ``sla_autoscale``
  policy against a static deployment pinned at the autoscaler's
  ``max_extent`` (equal peak replicas), on diurnal arrivals.  The
  autoscaler must match the static goodput while spending strictly fewer
  replica-ticks (the deterministic cost integral ``sum_t dp(t)``).
- ``scale_mixed_scenario`` — LLM + fixed-point tenants through
  :class:`TenantScenario`, merged per-tenant p99 TTFT/TPOT.

Every gate compares tick-domain quantities (``sla_met`` counts TTFT
deadlines in *ticks*, ``replica_ticks`` integrates the extent over the
tick clock), so ``--check`` is a deterministic function of (tenants,
arrival spec, seed) — wall-clock fields are reported but never gated.

``--quick`` shrinks the pool/grid for CI smoke; ``--check`` asserts:
sla_edf >= fcfs on SLA-met and goodput under burst; autoscale >= static
goodput at equal peak replicas with fewer replica-ticks; more replicas
never slow the drain (the replica curve's tick counts are monotone);
no request lost anywhere; and every latency percentile reported by a
non-empty run is finite — while an *empty* engine must report NaN
percentiles, never a fake 0 ms (the summary bugfix this bench guards).
"""

from __future__ import annotations

import argparse
import json
import math

import numpy as np

from repro.serving import (
    ServeConfig,
    ServeEngine,
    TenantScenario,
    build_requests,
    make_workload,
    parse_tenant_specs,
    quotas_of,
)

FP = "fixedpoint_solve"


def make_fp(slots, dp, n, seed=0):
    """Cheap, fast-converging solver pool (damping 0.6 -> ~20-tick solves
    at eps 1e-3): the per-tick cost stays small at hundreds of slots."""
    return make_workload(
        FP, solver="d_iteration", n=n, dp=dp, slots=slots,
        damping=0.6, seed=seed,
    )


def run_engine(wl, reqs, *, scheduler="sla_edf", dp=1, quotas=None,
               spr=None, spd=4, eps=1e-3, autoscale=None):
    """One deterministic serve run -> (engine, summary).

    ``autoscale=(min_extent, max_extent)`` drives the engine through an
    ElasticServeController under the ``sla_autoscale`` policy instead of
    serving at a fixed extent.
    """
    wl.reset()
    eng = ServeEngine(wl, ServeConfig(
        scheduler=scheduler, termination="residual_interval", dp=dp,
        eps=eps, quotas=quotas, slots_per_replica=spr,
        steps_per_dispatch=spd,
    ))
    if autoscale is not None:
        from repro.runtime import ElasticServeController
        from repro.runtime.policies import SlaAutoscalePolicy

        lo, hi = autoscale
        ctl = ElasticServeController(
            eng,
            policy=SlaAutoscalePolicy(
                min_extent=lo, max_extent=hi,
                up_patience=1, down_patience=6, cooldown=4,
            ),
            min_extent=lo,
        )
        ctl.run(reqs)
    else:
        eng.run(reqs)
    return eng, eng.summary()


def max_wait(eng) -> int:
    """Largest queue wait (ticks) any retired request experienced."""
    return max(
        (r.admit_tick - r.arrival for r in eng.results.values()), default=0
    )


def tenant_fields(s) -> dict:
    out = {}
    for name, t in sorted(s["tenants"].items()):
        out[f"{name}_p99_ttft_ticks"] = round(t["ttft_p99_ticks"], 1)
        out[f"{name}_p99_ttft_ms"] = round(t["ttft_p99_ms"], 2)
        out[f"{name}_sla_met"] = t["sla_met"]
        out[f"{name}_sla_total"] = t["sla_total"]
    return out


def goodput_fields(s) -> dict:
    return {
        "sla_met": s["sla_met"], "sla_total": s["sla_total"],
        "goodput_ok": s["goodput_ok"],
        "goodput_per_ktick": round(s["goodput_per_ktick"], 2),
        "replica_ticks": s["replica_ticks"],
        "goodput_per_replica_ktick": round(
            s["goodput_ok"] / s["replica_ticks"] * 1000.0
            if s["replica_ticks"] else 0.0, 3),
    }


def main(json_path="BENCH_scale.json", quick=False, check=False):
    if quick:
        slots, spr, n_fp = 32, 8, 120
        dps, peak = (1, 2, 4), 4
        n_mix, llm_slots = 20, 4
    else:
        slots, spr, n_fp = 256, 32, 240
        dps, peak = (1, 2, 4, 8), 8
        n_mix, llm_slots = 48, 8
    n_req = 3 * slots  # three pool-fills of traffic: queues must form
    seed = 0
    rows = []

    # --- empty-summary guard: NaN percentiles, never a fake 0 ms ----------
    wl = make_fp(slots, dps[-1], n_fp, seed)
    empty = ServeEngine(wl, ServeConfig(termination="residual_interval",
                                        dp=dps[-1])).summary()
    empty_nan = all(
        math.isnan(empty[k])
        for k in ("ttft_p50_ms", "ttft_p99_ms", "tpot_p50_ms", "tpot_p99_ms")
    )
    rows.append({
        "name": "scale_empty_summary_nan", "value": int(empty_nan),
        "note": "no-retirement percentiles are NaN, not 0 ms",
    })

    # --- scheduler sweep: tenant mix under correlated bursts --------------
    # chat: interactive, tight TTFT SLA, high priority; api: looser SLA;
    # batch: no SLA, admission quota (an eighth of the pool) so bursts of
    # batch traffic cannot crowd out the interactive tenants.
    sla_chat, sla_api = 8, 24  # ticks; solves run ~14, so waves 2+ contend
    tenants = parse_tenant_specs(
        f"chat:4:sla={sla_chat}:prio=2:gen=2000,"
        f"api:2:sla={sla_api}:prio=1:gen=2000,"
        f"batch:2:quota={slots // 8}:gen=2000"
    )
    for t in tenants:
        assert t.workload == "llm_decode"  # default; retarget to fixedpoint
    import dataclasses as _dc
    tenants = tuple(_dc.replace(t, workload=FP) for t in tenants)
    quotas = quotas_of(tenants)
    # peak dumps a full pool of arrivals per tick for ~40 ticks: deep
    # queues form, so which order the scheduler admits in decides how many
    # TTFT deadlines survive — the regime the gate discriminates in
    burst = f"bursty:{slots / 100:.2f},{slots:.2f},0.05,40"
    reqs = build_requests(tenants, {FP: wl}, n_req, burst, seed + 7)[FP]

    sched_sum = {}
    sched_eng = {}
    for sched in ("fcfs", "priority", "sla_edf"):
        eng, s = run_engine(
            wl, reqs, scheduler=sched, dp=peak // 2, quotas=quotas,
        )
        sched_sum[sched], sched_eng[sched] = s, eng
        rows.append({
            "name": f"scale_sched_{sched}", "workload": FP,
            "scheduler": sched, "arrival": burst, "slots": slots,
            "requests": n_req, "completed": s["completed"],
            "ticks": s["ticks"], "occupancy": round(s["occupancy"], 3),
            "max_wait_ticks": max_wait(eng),
            "wall_s": round(s["wall_s"], 3),
            **goodput_fields(s), **tenant_fields(s),
        })

    # --- occupancy vs replica count (capacity model) ----------------------
    # Fixed slots_per_replica: each extent funds dp*spr usable slots out of
    # the same physical pool, so the curve shows how replica count buys
    # drain time and SLA headroom on identical traffic.
    curve = {}
    for dp in dps:
        eng, s = run_engine(
            wl, reqs, scheduler="sla_edf", dp=dp, quotas=quotas, spr=spr,
        )
        curve[dp] = s
        rows.append({
            "name": f"scale_replicas_dp{dp}", "workload": FP,
            "dp": dp, "usable_slots": min(slots, dp * spr),
            "slots": slots, "requests": n_req,
            "completed": s["completed"], "ticks": s["ticks"],
            "occupancy": round(s["occupancy"], 3),
            "wall_s": round(s["wall_s"], 3),
            **goodput_fields(s),
        })

    # --- autoscale vs static at equal peak replicas -----------------------
    # Diurnal arrivals (two periods, valley start): the static deployment
    # pays peak capacity all day; the autoscaler rides the wave.
    period = 160 if quick else 400
    peak_rate = (dps[-1] * spr) / 24.0
    diurnal = f"diurnal:{peak_rate:.3f},{period},{peak_rate / 8:.3f}"
    as_reqs = build_requests(tenants, {FP: wl}, n_req, diurnal, seed + 13)[FP]

    eng_st, s_static = run_engine(
        wl, as_reqs, scheduler="sla_edf", dp=peak, quotas=quotas,
        spr=spr, spd=2,
    )
    rows.append({
        "name": "scale_static_peak", "workload": FP, "dp": peak,
        "arrival": diurnal, "requests": n_req,
        "completed": s_static["completed"], "ticks": s_static["ticks"],
        "occupancy": round(s_static["occupancy"], 3),
        "wall_s": round(s_static["wall_s"], 3),
        **goodput_fields(s_static), **tenant_fields(s_static),
    })
    eng_as, s_auto = run_engine(
        wl, as_reqs, scheduler="sla_edf", dp=1, quotas=quotas,
        spr=spr, spd=2, autoscale=(1, peak),
    )
    extents = [ev.new_dp for ev in eng_as.resizes]
    rows.append({
        "name": "scale_autoscale", "workload": FP,
        "policy": "sla_autoscale", "arrival": diurnal,
        "requests": n_req, "completed": s_auto["completed"],
        "ticks": s_auto["ticks"], "resizes": s_auto["resizes"],
        "peak_dp": max(extents, default=1), "final_dp": eng_as.dp,
        "occupancy": round(s_auto["occupancy"], 3),
        "wall_s": round(s_auto["wall_s"], 3),
        **goodput_fields(s_auto), **tenant_fields(s_auto),
    })

    # --- mixed-workload TenantScenario (LLM decode + fixed-point) ---------
    from repro.configs import registry
    from repro.launch.train import build_mesh

    cfg = registry.get_smoke_config("llama3.2-1b")
    mesh = build_mesh(1, 1)
    mix = parse_tenant_specs(
        "chat:3:sla=8:prio=2:prompt=6:gen=10,"
        "solve:2:sla=60:workload=fixedpoint_solve:gen=2000,"
        "batch:1:quota=2:prompt=6:gen=16"
    )
    wl_llm = make_workload(
        "llm_decode", cfg=cfg, mesh=mesh, slots=llm_slots, max_len=32,
        max_prompt_len=8, seed=seed,
    )
    wl_fp2 = make_fp(16, 2, n_fp, seed)
    mix_reqs = build_requests(
        mix, {"llm_decode": wl_llm, FP: wl_fp2}, n_mix,
        "bursty:0.3,2.0", seed + 29,
    )
    scenario = TenantScenario({
        "llm_decode": ServeEngine(wl_llm, ServeConfig(
            scheduler="sla_edf", termination="eos_maxlen",
            quotas=quotas_of(mix),
        )),
        FP: ServeEngine(wl_fp2, ServeConfig(
            scheduler="sla_edf", termination="residual_interval", dp=2,
            eps=1e-3, quotas=quotas_of(mix),
        )),
    })
    scenario.run(mix_reqs)
    s_mix = scenario.summary()
    mix_row = {
        "name": "scale_mixed_scenario",
        "workloads": "llm_decode+fixedpoint_solve",
        "requests": n_mix, "completed": s_mix["completed"],
        "ticks": s_mix["ticks"], "wall_s": round(s_mix["wall_s"], 3),
        "ttft_p99_ms": round(s_mix["ttft_p99_ms"], 2),
        "tpot_p99_ms": round(s_mix["tpot_p99_ms"], 3),
        **goodput_fields(s_mix), **tenant_fields(s_mix),
    }
    rows.append(mix_row)

    for r in rows:
        val = r.get("goodput_per_ktick", r.get("value", ""))
        print(f"{r['name']},{val},{r.get('sla_met', '')}")
    payload = {
        "meta": {
            "workload": FP, "slots": slots, "slots_per_replica": spr,
            "fp_n": n_fp, "requests": n_req, "peak_dp": peak,
            "quick": quick,
            "tenants": [t.name for t in tenants],
            "gates": "tick-domain (sla_met / goodput_ok / replica_ticks)",
        },
        "sweep": rows,
    }
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {json_path}")

    if check:
        assert empty_nan, (
            f"empty-engine summary reported non-NaN percentiles: "
            f"{ {k: empty[k] for k in ('ttft_p50_ms', 'tpot_p50_ms')} }"
        )
        # nothing lost anywhere
        for s in (*sched_sum.values(), *curve.values(), s_static, s_auto):
            assert s["completed"] == n_req, s
        assert s_mix["completed"] == n_mix, s_mix
        # finite percentiles on every non-empty run (NaN = hard failure)
        for s in (*sched_sum.values(), *curve.values(), s_static, s_auto,
                  s_mix):
            for k in ("ttft_p50_ms", "ttft_p95_ms", "ttft_p99_ms"):
                assert math.isfinite(s[k]), f"{k} is not finite: {s[k]}"
        assert math.isfinite(s_mix["tpot_p99_ms"]), s_mix
        # scheduler gate: EDF meets >= fcfs deadlines under burst, at no
        # goodput cost, and its anti-starvation bound holds for batch
        edf, fcfs = sched_sum["sla_edf"], sched_sum["fcfs"]
        assert edf["sla_met"] >= fcfs["sla_met"], (
            f"sla_edf met {edf['sla_met']} < fcfs {fcfs['sla_met']}"
        )
        assert edf["goodput_ok"] >= fcfs["goodput_ok"], (edf, fcfs)
        bound = 64 + slots  # scheduler max_wait + one pool drain of slack
        assert max_wait(sched_eng["sla_edf"]) <= bound, (
            f"starvation: a request waited "
            f"{max_wait(sched_eng['sla_edf'])} ticks (bound {bound})"
        )
        # replica curve: capacity must buy SLA headroom monotonically, and
        # the full extent must drain the pool faster than the minimum one.
        # (Adjacent tick counts need not be monotone: the termination
        # agreement cycle lengthens with dp — more MRD stages per agreed
        # retirement — which can offset one doubling's worth of slots.)
        met = [curve[dp]["sla_met"] for dp in dps]
        assert all(a <= b for a, b in zip(met, met[1:])), (
            f"SLA-met not monotone over replica counts: {dict(zip(dps, met))}"
        )
        ticks = [curve[dp]["ticks"] for dp in dps]
        assert ticks[0] > ticks[-1], (
            f"dp={dps[-1]} did not drain faster than dp={dps[0]}: "
            f"{dict(zip(dps, ticks))}"
        )
        # autoscale gate: >= static goodput at equal peak replicas, for
        # strictly fewer replica-ticks
        assert s_auto["goodput_ok"] >= s_static["goodput_ok"], (
            f"autoscale goodput {s_auto['goodput_ok']} < static "
            f"{s_static['goodput_ok']} at equal peak replicas"
        )
        assert s_auto["replica_ticks"] < s_static["replica_ticks"], (
            f"autoscale spent {s_auto['replica_ticks']} replica-ticks vs "
            f"static {s_static['replica_ticks']}"
        )
        assert max(extents, default=1) <= peak, extents
        print(f"# sanity OK: sla_edf {edf['sla_met']}/{edf['sla_total']} "
              f"vs fcfs {fcfs['sla_met']}/{fcfs['sla_total']} SLA under "
              f"burst; autoscale {s_auto['goodput_ok']} goodput @ "
              f"{s_auto['replica_ticks']} replica-ticks vs static "
              f"{s_static['goodput_ok']} @ {s_static['replica_ticks']}; "
              f"mixed scenario p99 TTFT {s_mix['ttft_p99_ms']:.1f} ms")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default="BENCH_scale.json")
    ap.add_argument("--quick", action="store_true",
                    help="reduced pool/grid (CI smoke)")
    ap.add_argument("--check", action="store_true",
                    help="assert the tick-domain scale gates (CI)")
    args = ap.parse_args()
    main(json_path=args.json, quick=args.quick, check=args.check)
