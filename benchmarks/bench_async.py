"""Asynchrony-runtime sweep: delay model x detection protocol on the
paper's 1-D BVP relaxation (``repro.asynchrony``, DESIGN.md S11).

For every registered delay model, every realizable protocol is compared
against the ``oracle`` baseline (the physically-unrealizable true residual
of the live iterate) on the same seeds, via ONE vmapped ``sweep()``
dispatch per (model, protocol) pair:

- **detection delay**: mean extra ticks past the oracle's stopping tick —
  the price of a realizable protocol in that environment;
- **message counts**: point-to-point + collective (paper S2 accounting);
- **soundness**: worst certified-vs-true residual across the seeds.

CSV on stdout: name,us_per_call,derived
JSON: writes BENCH_async.json (schema: {"sweep": [...], "meta": {...}}) so
the detection-delay trajectory is machine-readable across PRs.

``--quick`` reduces seeds/models for the CI smoke (row names are a subset
of the full run's).
"""

from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from repro.asynchrony import (
    DELAY_MODELS,
    AsyncConfig,
    make_solver,
    sweep,
)
from repro.asynchrony.engine import record_detection_delay
from repro.configs.paper_poisson1d import CONFIG as PAPER

PROTOCOLS = ("sync", "inexact", "exact", "interval")  # vs 'oracle' baseline


def run_sweeps(n: int, p: int, seeds, models, eps: float):
    fp = make_solver("poisson1d", n=n, omega=1.0, shift=PAPER.shift, seed=0)
    rows = []
    for model in models:
        def cfg_for(det):
            return AsyncConfig(
                p=p, detection=det, delay_model=model, eps=eps,
                max_ticks=60000, max_delay=PAPER.max_delay,
                activity=PAPER.activity,
            )

        t0 = time.perf_counter()
        oracle = sweep(fp, cfg_for("oracle"), seeds)
        oracle_us = (time.perf_counter() - t0) / len(seeds) * 1e6
        if not oracle.detected.all():
            # budget-capped baseline: delay deltas would be meaningless
            rows.append({
                "name": f"async_{model}_oracle_ticks_p{p}",
                "model": model, "protocol": "oracle", "p": p,
                "us_per_call": round(oracle_us, 1), "undetected": True,
            })
            continue
        base_ticks = oracle.ticks.astype(np.float64)
        rows.append({
            "name": f"async_{model}_oracle_ticks_p{p}",
            "model": model, "protocol": "oracle", "p": p,
            "us_per_call": round(oracle_us, 1),
            "mean_ticks": round(float(base_ticks.mean()), 1),
            "detection_delay_ticks": 0.0,
            "messages_p2p": int(oracle.messages_p2p.mean()),
            "messages_coll": int(oracle.messages_coll.mean()),
            "worst_true_res": float(oracle.true_res.max()),
        })
        for det in PROTOCOLS:
            t0 = time.perf_counter()
            r = sweep(fp, cfg_for(det), seeds)
            us = (time.perf_counter() - t0) / len(seeds) * 1e6
            if not r.detected.all():
                rows.append({
                    "name": f"async_{model}_{det}_ticks_p{p}",
                    "model": model, "protocol": det, "p": p,
                    "us_per_call": round(us, 1), "undetected": True,
                })
                continue
            delay = float((r.ticks.astype(np.float64) - base_ticks).mean())
            # gauge async.detect.delay_vs_oracle[protocol=...] when the obs
            # subsystem is live (benchmarks/run.py --telemetry); no-op here
            record_detection_delay(det, r.ticks, oracle.ticks)
            rows.append({
                "name": f"async_{model}_{det}_ticks_p{p}",
                "model": model, "protocol": det, "p": p,
                "us_per_call": round(us, 1),
                "mean_ticks": round(float(r.ticks.mean()), 1),
                # 'sync' runs a different (delay-free) environment, so its
                # delta vs the async oracle is an environment gap, not a
                # detection delay — still the paper's Fig. 5 comparison
                "detection_delay_ticks": round(delay, 1),
                "messages_p2p": int(r.messages_p2p.mean()),
                "messages_coll": int(r.messages_coll.mean()),
                "worst_true_res": float(r.true_res.max()),
            })
    return rows


def check_rows(rows) -> int:
    """CI sanity (wired behind ``--check``): every *detected* realizable
    async protocol must stop at or after the oracle — a negative detection
    delay would mean certifying convergence before the ground truth reached
    eps, i.e. an unsound detector.  ``sync`` is excluded (it runs a
    different, delay-free environment, so its delta is an environment gap,
    not a detection delay).  Returns the number of rows checked."""
    checked = 0
    for r in rows:
        if r.get("undetected") or r["protocol"] in ("oracle", "sync"):
            continue
        assert r["detection_delay_ticks"] >= 0, (
            f"unsound: {r['name']} stopped {-r['detection_delay_ticks']} "
            f"ticks before the oracle"
        )
        assert np.isfinite(r["worst_true_res"]), r
        checked += 1
    assert checked > 0, "no detected protocol rows to sanity-check"
    return checked


def main(json_path: str = "BENCH_async.json", quick: bool = False,
         check: bool = False):
    n = 256 if quick else 512
    p = 4 if quick else 8
    n_seeds = 4 if quick else 16
    models = ("bernoulli", "straggler") if quick else tuple(sorted(DELAY_MODELS))
    eps = PAPER.eps
    seeds = jnp.arange(n_seeds)

    rows = run_sweeps(n, p, seeds, models, eps)
    for r in rows:
        derived = r.get("detection_delay_ticks", "undetected")
        print(f"{r['name']},{r['us_per_call']},{derived}")
    payload = {
        "meta": {"n": n, "p": p, "seeds": n_seeds, "eps": eps,
                 "quick": quick, "baseline": "oracle"},
        "sweep": rows,
    }
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {json_path}")
    if check:
        n = check_rows(rows)
        print(f"# sanity OK: detection delay >= oracle on {n} rows")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default="BENCH_async.json", help="output JSON path")
    ap.add_argument(
        "--quick", action="store_true",
        help="reduced sweep (CI smoke): fewer models, seeds, smaller problem",
    )
    ap.add_argument(
        "--check", action="store_true",
        help="sanity-assert the sweep (every detected realizable protocol "
             "stops at or after the oracle) — wired into CI",
    )
    args = ap.parse_args()
    main(json_path=args.json, quick=args.quick, check=args.check)
