"""Train-step wall time on CPU (reduced configs): gspmd vs mrd_zero1 vs
compressed grad sync, and the monitor's overhead.

CSV: name,us_per_call,derived
"""

from __future__ import annotations

import time

import jax

from repro.configs import registry
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.distributed import step as step_lib
from repro.optim.optimizer import OptimizerConfig


def time_mode(grad_sync, monitor, steps=5):
    cfg = registry.get_smoke_config("llama3.2-1b")
    mesh = jax.make_mesh(
        (1,), ("data",), axis_types=(jax.sharding.AxisType.Auto,)
    )
    tcfg = step_lib.TrainConfig(
        microbatches=1, remat="none", grad_sync=grad_sync, monitor=monitor,
        optimizer=OptimizerConfig(lr=1e-3, schedule="const", warmup_steps=0),
    )
    train_step, init_state, state_specs, _ = step_lib.make_train_step(cfg, mesh, tcfg)
    with mesh:
        state = init_state(jax.random.PRNGKey(0))
        pipe = SyntheticPipeline(cfg, DataConfig(batch=4, seq_len=64, seed=0))
        js = jax.jit(train_step)
        batch = pipe.next_batch()
        state, _ = js(state, batch)  # compile
        jax.block_until_ready(state)
        t0 = time.perf_counter()
        for _ in range(steps):
            state, m = js(state, pipe.next_batch())
        jax.block_until_ready(state)
        us = (time.perf_counter() - t0) / steps * 1e6
    return us, float(m["loss"])


def main():
    rows = []
    for gs in ("gspmd", "mrd_zero1", "compressed"):
        us, loss = time_mode(gs, monitor=True)
        rows.append((f"train_step_{gs}_mon", round(us, 0), round(loss, 3)))
    us_nomon, _ = time_mode("gspmd", monitor=False)
    us_mon, _ = time_mode("gspmd", monitor=True)
    rows.append(("monitor_overhead_us", round(us_mon - us_nomon, 0), "staged, non-blocking"))
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")


if __name__ == "__main__":
    main()
