"""Train-step benchmarks: ready-bucket grad-sync overlap (DESIGN.md S16)
and async device->host checkpointing.

JSON: writes BENCH_train.json ({"measured": [...], "meta": {...}}).
CSV on stdout: name,us_per_call[,ratio]

Three row families:

- ``train_step_{mode}_{variant}_jit_dp{dp}``: the real jitted train step
  on a multi-device CPU mesh, overlap vs no-overlap.  Inside one fused
  XLA computation the CPU backend schedules ops itself, so the two
  variants are expected to land at *parity* — these rows gate the
  bit-identical-loss contract and act as a regression tripwire
  (overlap must not be slower than baseline beyond JIT_NOISE_FLOOR).

- ``gradsync_{mode}_{variant}_dispatch_p{p}``: the dispatch regime —
  host-driven op-by-op execution where bucket *issue order* is
  observable.  A single-core CPU host has no async interconnect, so the
  wire is modeled: every stage of the real
  :class:`repro.collectives.plans.BucketPipeline` additionally occupies
  a discrete-event NIC for its alpha-beta time (the same LinkModel
  framing as BENCH_mrd's model rows), while the *real* jitted backward
  segments (the same 3-segment VJP split as ``gradsync/overlap.py``)
  burn wall-clock.  A pump thread advances in-flight buckets as their
  modeled transfers land, so wire time genuinely elapses concurrently
  with segment compute.  Baseline admits every bucket after the full
  backward; overlap admits each readiness group as its segment
  finishes — the measured delta is the comm hidden under compute, the
  latency-hiding the paper's non-blocking reduction targets.  Stage
  math, packing, and admission policy are the real engine; both
  variants run identical compute and identical stage ops, and the
  reduced buffers must be bit-identical across admission orders.

- ``ckpt_save_*``: Checkpointer.save call time by blocking mode —
  ``block=True`` (full write), ``block='transfer'`` (device->host
  materialize only; the pre-S16 synchronous-snapshot stall), and
  ``block=False`` (async staging; the call must return without waiting
  on the transfer).

``--quick`` shrinks the grid for CI smoke.  ``--check`` asserts:
losses bit-identical overlap vs baseline (jit rows, microbatches=1);
jit overlap <= baseline x JIT_NOISE_FLOOR; dispatch overlap <=
baseline x DISPATCH_GATE (i.e. overlap *reduces* dispatch-regime step
time); reduced buffers bit-identical across admission orders; async
checkpoint save call <= max(0.5 x transfer stall, CKPT_FLOOR_US).
"""

from __future__ import annotations

import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    # the jit rows need a real DP extent; must be set before importing jax
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()

import argparse
import json
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro import compat
from repro.checkpoint.checkpointer import Checkpointer
from repro.collectives import buckets, plans
from repro.configs import registry
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.distributed import step as step_lib
from repro.distributed.gradsync import overlap as overlap_lib
from repro.models import transformer
from repro.models.layers import dtype_of
from repro.optim.optimizer import OptimizerConfig

# Inside one jitted step XLA:CPU schedules both variants itself, so overlap
# is parity-by-construction there; the floor only absorbs walltime noise.
JIT_NOISE_FLOOR = 1.30
# Dispatch regime: overlap must actually reduce step time.
DISPATCH_GATE = 0.95
# Fraction of measured segment-compute time the modeled wire is calibrated
# to (comm-bound-ish, the regime where overlap matters).
COMM_RATIO = 0.8
ALPHA_S = 50e-6  # per-stage dispatch/launch latency of the modeled NIC
CKPT_FLOOR_US = 2000.0


# ---------------------------------------------------------------------------
# jit regime: the real train step, overlap vs baseline
# ---------------------------------------------------------------------------


def _jit_step_run(mode: str, dp: int, overlap: bool, steps: int, reps: int):
    """Best-of-``reps`` us/step of the real jitted train step, plus the
    per-step losses (for the bitwise gate)."""
    cfg = registry.get_smoke_config("llama3.2-1b")
    mesh = compat.make_mesh(
        (dp,), ("data",), axis_types=compat.default_axis_types(1),
        devices=jax.devices()[:dp],
    )
    tcfg = step_lib.TrainConfig(
        microbatches=1, remat="none", grad_sync=mode, monitor=True,
        bucket_bytes=1 << 15, overlap=overlap,
        optimizer=OptimizerConfig(lr=1e-3, schedule="const", warmup_steps=0),
    )
    train_step, init_state, state_specs, _ = step_lib.make_train_step(cfg, mesh, tcfg)
    with mesh:
        state0 = init_state(jax.random.PRNGKey(0))
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs(state0))
        state0 = jax.device_put(state0, sh)
        jstep = jax.jit(train_step)
        warm = SyntheticPipeline(cfg, DataConfig(batch=2 * dp, seq_len=32, seed=0), mesh)
        jax.block_until_ready(jstep(state0, warm.next_batch())[0])  # compile
        best, losses = float("inf"), []
        for _ in range(reps):
            # every rep replays the same trajectory, so the loss list is
            # deterministic and the timing work identical across reps
            pipe = SyntheticPipeline(
                cfg, DataConfig(batch=2 * dp, seq_len=32, seed=1), mesh
            )
            state, rl = state0, []
            t0 = time.perf_counter()
            for _ in range(steps):
                state, m = jstep(state, pipe.next_batch())
                rl.append(m["loss"])
            jax.block_until_ready(state)
            best = min(best, (time.perf_counter() - t0) / steps)
            losses = [float(v) for v in rl]
        return best * 1e6, losses


def jit_rows(modes, quick: bool):
    dp = 4
    steps, reps = (3, 2) if quick else (5, 3)
    out = []
    for mode in modes:
        t_base, l_base = _jit_step_run(mode, dp, False, steps, reps)
        t_ovl, l_ovl = _jit_step_run(mode, dp, True, steps, reps)
        bitwise = l_base == l_ovl
        for variant, us in (("baseline", t_base), ("overlap", t_ovl)):
            row = {
                "name": f"train_step_{mode}_{variant}_jit_dp{dp}",
                "mode": mode, "regime": "jit", "dp": dp,
                "us_per_call": round(us, 1),
            }
            if variant == "overlap":
                row["ratio_vs_baseline"] = round(t_ovl / t_base, 3)
                row["losses_bitwise"] = bitwise
            out.append(row)
    return out


# ---------------------------------------------------------------------------
# dispatch regime: real BucketPipeline + segment VJPs over a modeled NIC
# ---------------------------------------------------------------------------


class _LinkSim:
    """Discrete-event model of one rank's NIC: transfers serialize on the
    link; each costs ``alpha + bytes*beta`` (the repo's alpha-beta model)."""

    def __init__(self, alpha_s: float, beta_s_per_byte: float):
        self.alpha = alpha_s
        self.beta = beta_s_per_byte
        self.free_at = 0.0

    def occupy(self, nbytes: float, now: float) -> float:
        start = max(now, self.free_at)
        self.free_at = start + self.alpha + nbytes * self.beta
        return self.free_at


class _DispatchRun:
    """One timed reduction: real per-bucket BucketPipelines advanced by a
    pump thread as their modeled stage transfers land."""

    def __init__(self, plan, layout, elt_bytes: int, link: _LinkSim):
        self.plan = plan
        self.layout = layout
        self.elt_bytes = elt_bytes
        self.link = link
        self.fractions = [
            st.payload_fraction for st, _, _, _ in plan.bound_stage_table()
        ]
        self.n_stages = len(self.fractions)
        self.lock = threading.Lock()
        self.pipes: dict = {}
        self.rem: dict = {}       # stages left to finish per in-flight bucket
        self.ready_at: dict = {}  # modeled arrival of the in-flight stage
        self.done: dict = {}
        self.all_done = threading.Event()
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def _stage_bytes(self, bi: int, si: int) -> float:
        return self.layout.buckets[bi].length * self.elt_bytes * self.fractions[si]

    def admit(self, bi: int, buf) -> None:
        with self.lock:
            pipe = self.plan.pipeline()
            pipe.admit(bi, buf)  # issues stage 0
            if self.n_stages == 0:
                self.done[bi] = pipe.drain()[bi]
            else:
                self.pipes[bi] = pipe
                self.rem[bi] = self.n_stages
                self.ready_at[bi] = self.link.occupy(
                    self._stage_bytes(bi, 0), self.now()
                )
            if len(self.done) == len(self.layout.buckets):
                self.all_done.set()

    def pump(self):
        """Advance every bucket whose modeled transfer has arrived; returns
        the next deadline (or None if nothing is in flight)."""
        with self.lock:
            progressed = True
            while progressed:
                progressed = False
                now = self.now()
                for bi in list(self.pipes):
                    if now < self.ready_at[bi]:
                        continue
                    pipe = self.pipes[bi]
                    pipe.advance()  # finish the arrived stage, issue the next
                    self.rem[bi] -= 1
                    if self.rem[bi] == 0:
                        self.done[bi] = pipe.drain()[bi]
                        del self.pipes[bi], self.rem[bi], self.ready_at[bi]
                    else:
                        si = self.n_stages - self.rem[bi]
                        self.ready_at[bi] = self.link.occupy(
                            self._stage_bytes(bi, si), self.now()
                        )
                    progressed = True
            if len(self.done) == len(self.layout.buckets):
                self.all_done.set()
            return min(self.ready_at.values(), default=None)


def _pump_loop(run: _DispatchRun, stop: threading.Event):
    while not (stop.is_set() or run.all_done.is_set()):
        nxt = run.pump()
        if nxt is None:
            time.sleep(1e-4)  # nothing admitted yet
        else:
            dt = nxt - run.now()
            if dt > 0:
                time.sleep(min(dt, 1e-3))


def _dispatch_ctx(p: int):
    """The model + jitted segment functions for the dispatch rows: the
    same 3-segment VJP split as gradsync/overlap.py, untied so the output
    head is a real early-readiness gradient group."""
    cfg = registry.override(
        registry.get_smoke_config("llama3.2-1b"),
        tie_embeddings=False, vocab=4096, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, n_layers=4,
    )
    cdt = dtype_of(cfg.compute_dtype)
    fp32 = lambda t: jax.tree.map(lambda g: g.astype(jnp.float32), t)

    def embed_fn(pe, batch):
        x, _ = transformer._embed_inputs(pe, batch, cfg)
        return x.astype(cdt)

    @jax.jit
    def fwd(params, batch):
        _, ps, pe = overlap_lib._split_params(params)
        x0 = embed_fn(pe, batch)
        positions = jnp.arange(x0.shape[1])[None, :]
        x1, aux = transformer._run_stack(ps, x0, cfg, positions, None)
        return x0, x1, aux

    @jax.jit
    def head_bwd(params, x1, aux, batch):
        ph, _, _ = overlap_lib._split_params(params)

        def f(ph_, x, a):
            return transformer._train_head(ph_, x, a, batch, cfg, 0)

        loss, vjp, _metrics = jax.vjp(f, ph, x1, aux, has_aux=True)
        gh, ct_x1, ct_aux = vjp(jnp.ones_like(loss))
        return loss, fp32(gh), ct_x1, ct_aux

    @jax.jit
    def stack_bwd(params, x0, ct_x1, ct_aux):
        _, ps, _ = overlap_lib._split_params(params)
        positions = jnp.arange(x0.shape[1])[None, :]
        (_x1, _aux), vjp = jax.vjp(
            lambda ps_, x: transformer._run_stack(ps_, x, cfg, positions, None),
            ps, x0,
        )
        gs, ct_x0 = vjp((ct_x1, ct_aux))
        return fp32(gs), ct_x0

    @jax.jit
    def embed_bwd(params, ct_x0, batch):
        _, _, pe = overlap_lib._split_params(params)
        (ge,) = jax.vjp(lambda pe_: embed_fn(pe_, batch), pe)[1](ct_x0)
        return fp32(ge)

    @jax.jit
    def finish(red):
        # stands in for the (admission-order-independent) optimizer tail
        return sum(jnp.sum(r.astype(jnp.float32) ** 2) for r in red)

    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    batch = SyntheticPipeline(
        cfg, DataConfig(batch=8, seq_len=128, seed=0)
    ).next_batch()
    pshape = jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0))
    )
    return {
        "cfg": cfg, "params": params, "batch": batch, "pshape": pshape,
        "fwd": fwd, "head_bwd": head_bwd, "stack_bwd": stack_bwd,
        "embed_bwd": embed_bwd, "finish": finish, "p": p,
    }


_DISPATCH_PLANS = {
    # the plan each gradsync mode drives at gradient scale, on the sim
    # executor (stacked [p, n] buffers)
    "mrd_zero1": lambda p: plans.reduce_scatter_plan(p=p, op="sum", executor="sim"),
    "compressed": lambda p: plans.reduce_scatter_plan(
        p=p, op="sum", transform="int8", executor="sim"
    ),
    "mrd_paper": lambda p: plans.allreduce_plan(
        schedule="mrd", p=p, op="sum", executor="sim"
    ),
    "mrd_leaf": lambda p: plans.allreduce_plan(
        schedule="mrd", p=p, op="sum", executor="sim"
    ),
}
_WIRE_ELT_BYTES = {"mrd_zero1": 4, "compressed": 1, "mrd_paper": 4, "mrd_leaf": 4}


def _dispatch_once(ctx, plan, layout, koffs, bgroups, elt_bytes, link, overlap: bool):
    """One timed step: segments + reduction.  Returns (seconds, loss, red)."""
    p = ctx["p"]
    params, batch = ctx["params"], ctx["batch"]
    leaves: list = [None] * layout.n_leaves

    def scatter(piece):
        for k in sorted(piece.keys()):
            base = koffs[k]
            for j, leaf in enumerate(jax.tree.leaves(piece[k])):
                leaves[base + j] = jnp.broadcast_to(leaf[None], (p,) + leaf.shape)

    def admit_group(run, gi):
        for bi, bg in enumerate(bgroups):
            if bg == gi:
                run.admit(bi, buckets.pack_bucket(leaves, layout, bi))

    run = _DispatchRun(plan, layout, elt_bytes, link)
    stop = threading.Event()
    th = threading.Thread(target=_pump_loop, args=(run, stop), daemon=True)
    th.start()
    t0 = time.perf_counter()
    x0, x1, aux = jax.block_until_ready(ctx["fwd"](params, batch))
    loss, gh, ct_x1, ct_aux = jax.block_until_ready(
        ctx["head_bwd"](params, x1, aux, batch)
    )
    scatter(gh)
    if overlap:
        admit_group(run, 0)
    gs, ct_x0 = jax.block_until_ready(ctx["stack_bwd"](params, x0, ct_x1, ct_aux))
    scatter(gs)
    if overlap:
        admit_group(run, 1)
    ge = jax.block_until_ready(ctx["embed_bwd"](params, ct_x0, batch))
    scatter(ge)
    if overlap:
        admit_group(run, 2)
    else:
        for gi in range(overlap_lib.N_GROUPS):
            admit_group(run, gi)
    run.all_done.wait()
    stop.set()
    th.join()
    red = [run.done[i] for i in range(len(layout.buckets))]
    jax.block_until_ready(ctx["finish"](red))
    dt = time.perf_counter() - t0
    return dt, float(loss), red


def dispatch_rows(modes, quick: bool):
    p = 8
    reps = 2 if quick else 3
    ctx = _dispatch_ctx(p)
    pshape = ctx["pshape"]
    koffs = overlap_lib.key_offsets(pshape)
    lgroups = overlap_lib.leaf_groups(pshape)

    # calibrate the modeled wire against measured segment compute: one
    # compute-only pass (after compiling) gives C; beta is set so each
    # mode's total wire time is COMM_RATIO x C
    def compute_only():
        t0 = time.perf_counter()
        x0, x1, aux = jax.block_until_ready(ctx["fwd"](ctx["params"], ctx["batch"]))
        _, _, ct_x1, ct_aux = jax.block_until_ready(
            ctx["head_bwd"](ctx["params"], x1, aux, ctx["batch"])
        )
        _, ct_x0 = jax.block_until_ready(
            ctx["stack_bwd"](ctx["params"], x0, ct_x1, ct_aux)
        )
        jax.block_until_ready(ctx["embed_bwd"](ctx["params"], ct_x0, ctx["batch"]))
        return time.perf_counter() - t0

    compute_only()  # compile
    c_seconds = min(compute_only() for _ in range(3))

    out = []
    for mode in modes:
        plan = _DISPATCH_PLANS[mode](p)
        elt_bytes = _WIRE_ELT_BYTES[mode]
        fp32_stacked = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((p,) + s.shape, jnp.float32), pshape
        )
        layout = buckets.build_layout(
            fp32_stacked, bucket_bytes=1 << 20,
            quantum=plan.pad_quantum(), stacked=p,
        )
        bgroups = overlap_lib.bucket_groups(layout, lgroups)
        fractions = [
            st.payload_fraction for st, _, _, _ in plan.bound_stage_table()
        ]
        total_bytes = sum(
            b.length * elt_bytes * f for b in layout.buckets for f in fractions
        )
        beta = COMM_RATIO * c_seconds / total_bytes
        times, reds = {}, {}
        for variant, overlap in (("baseline", False), ("overlap", True)):
            best = float("inf")
            for rep in range(reps + 1):  # rep 0 warms the jit caches
                link = _LinkSim(ALPHA_S, beta)
                dt, _loss, red = _dispatch_once(
                    ctx, plan, layout, koffs, bgroups, elt_bytes, link, overlap
                )
                if rep > 0:
                    best = min(best, dt)
            times[variant], reds[variant] = best, red
        bitwise = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(reds["baseline"], reds["overlap"])
        )
        for variant in ("baseline", "overlap"):
            row = {
                "name": f"gradsync_{mode}_{variant}_dispatch_p{p}",
                "mode": mode, "regime": "dispatch", "p": p,
                "n_buckets": len(layout.buckets),
                "us_per_call": round(times[variant] * 1e6, 1),
                "beta_s_per_byte": beta,
            }
            if variant == "overlap":
                row["ratio_vs_baseline"] = round(
                    times["overlap"] / times["baseline"], 3
                )
                row["reduced_bitwise"] = bitwise
            out.append(row)
    return out


# ---------------------------------------------------------------------------
# checkpoint stall rows
# ---------------------------------------------------------------------------


def ckpt_rows(quick: bool):
    rng = np.random.default_rng(0)
    n = 32_768 if quick else 262_144  # x32 leaves: 4MB quick, 32MB full
    state = {
        "params": {
            f"w{i:02d}": jnp.asarray(rng.standard_normal(n), jnp.float32)
            for i in range(32)
        },
        "step": jnp.zeros((), jnp.int32),
    }
    jax.block_until_ready(state)
    out, step = [], 0
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        for name, block in (
            ("ckpt_save_blocking", True),
            ("ckpt_save_transfer_stall", "transfer"),
            ("ckpt_save_async_call", False),
        ):
            best = float("inf")
            for _ in range(3):
                ck.wait()  # the timed call must not pay the previous write
                step += 1
                t0 = time.perf_counter()
                ck.save(step, state, block=block)
                best = min(best, time.perf_counter() - t0)
            ck.wait()
            out.append({
                "name": name, "regime": "ckpt",
                "block": str(block),
                "state_mb": round(n * 32 * 4 / 2**20, 1),
                "us_per_call": round(best * 1e6, 1),
            })
    return out


# ---------------------------------------------------------------------------


def main(json_path: str = "BENCH_train.json", quick: bool = False, check: bool = False):
    jit_modes = ["mrd_zero1", "compressed"] if quick else [
        "mrd_zero1", "compressed", "mrd_paper", "mrd_leaf"
    ]
    disp_modes = ["mrd_zero1", "compressed"] if quick else list(_DISPATCH_PLANS)

    measured = (
        jit_rows(jit_modes, quick)
        + dispatch_rows(disp_modes, quick)
        + ckpt_rows(quick)
    )
    for r in measured:
        print(f"{r['name']},{r['us_per_call']},{r.get('ratio_vs_baseline', '')}")

    meta = {
        "backend": jax.default_backend(),
        "n_devices": jax.device_count(),
        "jit_noise_floor": JIT_NOISE_FLOOR,
        "dispatch_gate": DISPATCH_GATE,
        "dispatch_gate_modes": ["mrd_zero1", "compressed"],
        "comm_ratio": COMM_RATIO,
        "alpha_s": ALPHA_S,
        "notes": [
            "jit rows: real jitted train step; XLA:CPU schedules the fused "
            "program itself, so overlap==baseline at parity is the expected "
            "result — the rows gate bit-identical losses and regressions.",
            "dispatch rows: host-driven op-by-op regime; wire time is the "
            "alpha-beta LinkModel as a discrete-event NIC (calibrated to "
            "comm_ratio x measured segment compute) because a single-core "
            "CPU host has no async interconnect; stage math, packing, and "
            "admission policy are the real BucketPipeline engine.",
            "ckpt rows: Checkpointer.save call time by blocking mode on a "
            "synthetic state; block=False must not wait on device->host.",
        ],
    }
    with open(json_path, "w") as f:
        json.dump({"measured": measured, "meta": meta}, f, indent=2)
    print(f"# wrote {json_path}")

    if check:
        by_name = {r["name"]: r for r in measured}
        for r in measured:
            if r.get("regime") == "jit" and "ratio_vs_baseline" in r:
                assert r["losses_bitwise"], (
                    f"{r['name']}: overlap losses differ bitwise from baseline"
                )
                assert r["ratio_vs_baseline"] <= JIT_NOISE_FLOOR, (
                    f"{r['name']}: jit overlap regressed "
                    f"{r['ratio_vs_baseline']}x > {JIT_NOISE_FLOOR}x floor"
                )
            if r.get("regime") == "dispatch" and "ratio_vs_baseline" in r:
                assert r["reduced_bitwise"], (
                    f"{r['name']}: reduced buffers differ across admission orders"
                )
                # Hard speedup gate on the acceptance modes; the AR modes
                # (mrd_paper/mrd_leaf) run log2(p) full-payload butterfly
                # stages per bucket, so the last bucket's wire time dominates
                # both variants and the overlap win is smaller — gate those
                # at no-regression only.
                gated = any(m in r["name"] for m in ("mrd_zero1", "compressed"))
                gate = DISPATCH_GATE if gated else JIT_NOISE_FLOOR
                assert r["ratio_vs_baseline"] <= gate, (
                    f"{r['name']}: overlap dispatch ratio "
                    f"{r['ratio_vs_baseline']}x > {gate}x gate"
                )
        stall = by_name["ckpt_save_transfer_stall"]["us_per_call"]
        async_us = by_name["ckpt_save_async_call"]["us_per_call"]
        assert async_us <= max(0.5 * stall, CKPT_FLOOR_US), (
            f"async save call {async_us}us blocks vs transfer stall {stall}us"
        )
        print("# all checks passed")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default="BENCH_train.json", help="output JSON path")
    ap.add_argument("--quick", action="store_true",
                    help="reduced grid (CI smoke): fewer modes/steps/reps")
    ap.add_argument("--check", action="store_true",
                    help="assert the overlap/bitwise/checkpoint gates")
    args = ap.parse_args()
    main(json_path=args.json, quick=args.quick, check=args.check)
