"""Paper Fig. 5 analogue on the registry runtime (``repro.asynchrony``):
asynchronous vs synchronous iterations of the 1-D two-point BVP relaxation
in a 'concentrated' environment, with the paper's detection protocols.

Reports per p: ticks to detection, per-worker iteration counts, messages
(point-to-point + collective), certified vs true residual, and the premature-
stop behavior of the inexact detector.  The paper's qualitative claims:
(1) in a concentrated (low-delay) cluster, async iteration counts track the
synchronous ones (Fig. 5's 'synchronous behavior'); (2) async generates more
messages; (3) the exact detector certifies a genuine solution, the inexact
one may stop early but within acceptable precision.

(The delay-model x protocol grid with the oracle baseline lives in
``benchmarks/bench_async.py``; this file keeps the historical Fig. 5 row
names for trend lines.)

CSV: name,us_per_call,derived
"""

from __future__ import annotations

import time

import numpy as np

from repro.asynchrony import AsyncConfig, make_solver, run
from repro.configs.paper_poisson1d import CONFIG as PAPER


def run_one(p, mode, n=1024, eps=1e-5, seed=0):
    fp = make_solver("poisson1d", n=n, omega=1.0, shift=PAPER.shift, seed=seed)
    cfg = AsyncConfig(
        p=p, detection=mode, eps=eps, max_ticks=60000, seed=seed,
        max_delay=PAPER.max_delay, activity=PAPER.activity,
    )
    t0 = time.perf_counter()
    res = run(fp, cfg)
    wall = (time.perf_counter() - t0) * 1e6
    return res, wall


def main():
    rows = []
    for p in (2, 4, 8, 16):
        r_sync, w_sync = run_one(p, "sync")
        r_exact, w_exact = run_one(p, "exact")
        r_inex, w_inex = run_one(p, "inexact")
        r_orac, _ = run_one(p, "oracle")
        rows.append((f"fig5_sync_ticks_p{p}", w_sync, r_sync.ticks))
        rows.append((f"fig5_async_exact_ticks_p{p}", w_exact, r_exact.ticks))
        rows.append((f"fig5_async_inexact_ticks_p{p}", w_inex, r_inex.ticks))
        rows.append((f"fig5_oracle_ticks_p{p}", 0.0, r_orac.ticks))
        rows.append((f"fig5_sync_msgs_p{p}", 0.0, r_sync.messages_p2p + r_sync.messages_coll))
        rows.append((f"fig5_async_msgs_p{p}", 0.0, r_exact.messages_p2p + r_exact.messages_coll))
        rows.append((f"fig5_exact_true_res_p{p}", 0.0, f"{r_exact.true_res:.2e}"))
        rows.append((f"fig5_inexact_true_res_p{p}", 0.0, f"{r_inex.true_res:.2e}"))
        rows.append((
            f"fig5_async_iter_spread_p{p}", 0.0,
            f"{r_exact.kiter.min()}..{r_exact.kiter.max()}",
        ))
    # paper-scale problem (n = 10000): rate snapshot with capped ticks
    fp = make_solver("poisson1d", n=10000, omega=1.0, shift=0.0, seed=0)
    cfg = AsyncConfig(p=16, detection="oracle", eps=1e-30, max_ticks=300)
    res = run(fp, cfg)
    rows.append(("paper_n10000_res_after_300_ticks", 0.0, f"{res.res_glb:.4e}"))
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")


if __name__ == "__main__":
    main()
