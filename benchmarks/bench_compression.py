"""Compression table: wire-bytes factor and quantization error of the int8
blockwise scheme used by the compressed MRD reduce-scatter.

CSV: name,us_per_call,derived
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.collectives import compression as C
from repro.core import mrd


def main():
    rows = []
    rng = np.random.default_rng(0)
    for n in (2**16, 2**20):
        x = jnp.asarray(rng.standard_normal(n), jnp.float32)
        q, s = C.quantize(x)
        err = float(jnp.max(jnp.abs(C.dequantize(q, s) - x)))
        amax = float(jnp.max(jnp.abs(x)))
        rows.append((f"quant_maxerr_rel_n{n}", 0.0, f"{err / amax:.2e}"))
    rows.append(
        ("wire_bytes_factor_vs_f32", 0.0, f"{C.wire_bytes_factor(4):.4f}")
    )
    rows.append(
        ("wire_bytes_factor_vs_bf16", 0.0, f"{C.wire_bytes_factor(2):.4f}")
    )

    # compressed vs plain sim reduce-scatter numerical agreement
    p, n = 8, 8 * 256 * 4
    x = jnp.asarray(rng.standard_normal((p, n)), jnp.float32)
    ref = np.asarray(mrd.sim_reduce_scatter(x))
    # (compressed path is device-executor only; measure plain here)
    f = jax.jit(lambda v: mrd.sim_reduce_scatter(v))
    f(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        f(x).block_until_ready()
    rows.append(("sim_reduce_scatter_p8", round((time.perf_counter() - t0) / 10 * 1e6, 1), n))

    for name, us, derived in rows:
        print(f"{name},{us},{derived}")


if __name__ == "__main__":
    main()
