"""Serving chaos suite (DESIGN.md S15): scripted kill/join/stall against
the :class:`repro.runtime.ElasticServeController` under Poisson arrivals.

The acceptance bar for elastic serving, crossing non-power-of-two
termination-agreement extents (4 → 3 → 5 → 4) with traffic live the whole
time:

- **zero lost requests** — every submitted request retires with a result;
- **zero re-prefills** — the LLM pool's slot state is replica-independent,
  so a resize migrates the control plane only (``workload.prefills`` counts
  exactly one admission per request);
- **bit-identical tokens** — each request's retired stream equals the
  uninterrupted oracle run of the same traffic, for both the contiguous
  and the paged (block-table + allocator broadcast) cache layouts;
- fixed-point traffic stays *certified*: every retirement across the
  resize trajectory still satisfies its true residual bound.

Events are matched against the engine's tick clock via
``ChaosScript.apply_due`` — the engine's tick jumps by up to
``steps_per_dispatch`` per fused call, so an event due mid-dispatch fires
at the next dispatch boundary, the first point a real control plane could
act.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from chaos import ChaosScript, Join, Kill, Stall, Unstall
from repro import compat
from repro.configs import registry
from repro.runtime import ElasticServeController, HeartbeatConfig, StepClock
from repro.serving import Request, ServeConfig, ServeEngine, make_workload

import jax


def _mesh():
    return compat.make_mesh(
        (1,), ("data",), devices=jax.devices()[:1],
        axis_types=compat.default_axis_types(1),
    )


def _poisson_arrivals(rng, n, mean_gap=3.0):
    """Arrival ticks with exponential inter-arrival gaps (Poisson process)."""
    gaps = rng.exponential(mean_gap, size=n)
    return np.floor(np.cumsum(gaps)).astype(int)


def _llm_requests(cfg, n=8, seed=3):
    rng = np.random.default_rng(seed)
    arrivals = _poisson_arrivals(rng, n)
    lens = rng.integers(3, 9, size=n)
    max_new = rng.integers(4, 8, size=n)
    return [
        Request(
            id=i, arrival=int(arrivals[i]),
            prompt=rng.integers(0, cfg.vocab, size=int(lens[i])),
            max_new=int(max_new[i]),
        )
        for i in range(n)
    ]


# the scripted trajectory: 4 -> 3 (fail-stop kill) -> 5 (two joiners)
# -> 4 (second kill), with a stall/unstall riding along (grow_on_join
# drains no stragglers — the stall only exercises the heartbeat path)
def _script():
    return ChaosScript([
        Kill(step=4, device=2),
        Stall(step=8, device=1, factor=10.0),
        Join(step=14, devices=(4, 5)),
        Unstall(step=20, device=1),
        Kill(step=24, device=0),
    ])


def _assert_trajectory(resizes):
    assert [(e.kind, e.old_dp, e.new_dp) for e in resizes] == [
        ("shrink", 4, 3), ("grow", 3, 5), ("shrink", 5, 4),
    ], resizes


# ---------------------------------------------------------------------------
# LLM decode (contiguous and paged) under chaos == oracle, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("workload", ["llm_decode", "llm_decode_paged"])
def test_llm_chaos_matches_oracle_no_reprefill(workload):
    cfg = registry.get_smoke_config("llama3.2-1b")
    mesh = _mesh()
    kw = {"block_size": 8} if workload == "llm_decode_paged" else {}
    wl = make_workload(
        workload, cfg=cfg, mesh=mesh, slots=2, max_len=24,
        max_prompt_len=12, seed=0, **kw,
    )
    n = 8

    # oracle: the same Poisson traffic, uninterrupted at dp=4
    oracle = ServeEngine(wl, ServeConfig(dp=4)).run(_llm_requests(cfg, n))
    assert len(oracle) == n
    assert wl.prefills == n

    wl.reset()
    eng = ServeEngine(wl, ServeConfig(dp=4, steps_per_dispatch=3))
    ctl = ElasticServeController(eng, policy="grow_on_join")
    script = _script()
    res = ctl.run(_llm_requests(cfg, n), events=script)

    assert len(script.fired) == 5, "chaos script did not fully fire"
    _assert_trajectory(ctl.resizes)
    assert eng.dp == 4
    assert len(res) == n, "request lost across kill/join"
    assert wl.prefills == n, "a resize re-prefilled a slot"
    assert eng.summary()["resizes"] == 3
    for i in range(n):
        np.testing.assert_array_equal(
            res[i].output, oracle[i].output,
            err_msg=f"{workload} request {i}: chaotic run != oracle",
        )
    if workload == "llm_decode_paged":
        # every retired request's blocks came back through the chaos
        assert wl.pool.allocator.used_blocks == 0
        wl.pool.allocator.check()


# ---------------------------------------------------------------------------
# Fixed-point traffic: certification survives the same trajectory
# ---------------------------------------------------------------------------


def test_fixedpoint_chaos_stays_certified():
    eps = 1e-6
    n_dim = 60  # divisible by every visited extent (4, 3, 5)
    wl = make_workload(
        "fixedpoint_solve", solver="d_iteration", n=n_dim, dp=4, slots=3,
        damping=0.7, seed=1,
    )
    eng = ServeEngine(wl, ServeConfig(
        termination="residual_interval", dp=4, eps=eps,
        steps_per_dispatch=3,
    ))
    ctl = ElasticServeController(eng, policy="grow_on_join")
    rng = np.random.default_rng(7)
    n = 8
    arrivals = _poisson_arrivals(rng, n, mean_gap=4.0)
    reqs = []
    for i in range(n):
        v = rng.random(n_dim).astype(np.float32)
        reqs.append(Request(id=i, arrival=int(arrivals[i]),
                            payload=v / v.sum(), max_new=800))
    script = _script()
    res = ctl.run(reqs, events=script)

    assert len(script.fired) == 5
    _assert_trajectory(ctl.resizes)
    assert len(res) == n
    for i, r in sorted(res.items()):
        assert r.converged, f"request {i} not certified under chaos"
        assert r.certified < eps
        v = jnp.asarray(reqs[i].payload)
        x = jnp.asarray(r.output)
        true_res = float(jnp.max(jnp.abs(wl.pool.param_map(x, v) - x)))
        assert true_res < eps, (i, true_res)


# ---------------------------------------------------------------------------
# Silent kill: detection waits for the virtual heartbeat timeout
# ---------------------------------------------------------------------------


def test_silent_kill_detected_on_virtual_clock():
    wl = make_workload(
        "fixedpoint_solve", solver="d_iteration", n=60, dp=3, slots=2,
        damping=0.7, seed=1,
    )
    eng = ServeEngine(wl, ServeConfig(
        termination="residual_inexact", dp=3, eps=1e-5,
        steps_per_dispatch=2,
    ))
    ctl = ElasticServeController(
        eng, policy="shrink_on_failure",
        heartbeat=HeartbeatConfig(timeout_s=5.0),
        clock=StepClock(dt=1.0),
    )
    ctl.kill(1, silent=True)  # partition: no crash report
    res = ctl.run([
        Request(id=i, arrival=3 * i, max_new=500) for i in range(4)
    ])
    assert len(res) == 4 and all(r.converged for r in res.values())
    assert [(e.kind, e.old_dp, e.new_dp) for e in ctl.resizes] == [
        ("shrink", 3, 2)
    ]
    # the shrink waited for the timeout on the *virtual* clock
    assert ctl.resizes[0].step > 0


# ---------------------------------------------------------------------------
# ChaosScript.apply_due fires events the coarse tick clock jumped over
# ---------------------------------------------------------------------------


def test_apply_due_fires_skipped_steps():
    fired = []

    class T:
        def kill(self, d, silent=False):
            fired.append(("kill", d))

        def join(self, ds):
            fired.append(("join", ds))

    s = ChaosScript([Kill(step=3, device=0), Join(step=7, devices=(9,))])
    s.apply_due(T(), 2)
    assert fired == []
    s.apply_due(T(), 5)  # tick jumped 2 -> 5: the step-3 event is due
    assert fired == [("kill", 0)]
    s.apply_due(T(), 50)
    assert fired == [("kill", 0), ("join", (9,))]
    s.apply_due(T(), 51)  # never re-fires
    assert len(s.fired) == 2
