"""Deterministic chaos-testing harness for the elastic runtime (DESIGN.md
S12).

Two pieces:

- a seeded **event-script DSL** (:class:`Kill` / :class:`Join` /
  :class:`Stall` / :class:`Unstall` composed into a :class:`ChaosScript`)
  that the :class:`repro.runtime.ElasticTrainer` applies before each train
  step.  Everything flows through the *injected clock* of the
  ``FailureDetector`` — a silent kill is detected exactly when the virtual
  heartbeat timeout elapses, a straggler is drained after exactly
  ``evict_after_straggler_steps`` slow steps — so a script determines the
  full resize trajectory bit-for-bit, with no wall-clock nondeterminism.
  :meth:`ChaosScript.random` generates *legal* seeded sequences (never
  killing the last worker, only joining devices that exist and are
  currently outside the mesh).

- an **oracle replay** (:func:`oracle_replay`): the same model/config
  trained with plain ``jax.jit`` steps — no policies, no detector, no
  harness — as a chain of uninterrupted runs at each intermediate extent,
  stitched with the same ``gradsync.migrate_state`` calls the trainer's
  recorded :class:`ResizeEvent` s describe.  The chaos suite asserts the
  chaotic run's params are **bit-identical** to this straight-line
  replay: the entire elastic machinery (detection, policies, plan
  invalidation, MRD param broadcast on grow) adds nothing to the math
  beyond the migrations themselves.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Event DSL
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Kill:
    """Worker ``device`` dies before step ``step``.  ``silent=True`` models
    a network partition: detection waits for the heartbeat timeout on the
    virtual clock instead of a fail-stop crash report."""

    step: int
    device: int
    silent: bool = False

    def fire(self, trainer):
        trainer.kill(self.device, silent=self.silent)


@dataclasses.dataclass(frozen=True)
class Join:
    """Workers ``devices`` ask to join before step ``step`` (admitted by
    growth-capable policies on their next decision)."""

    step: int
    devices: tuple

    def fire(self, trainer):
        trainer.join(tuple(self.devices))


@dataclasses.dataclass(frozen=True)
class Stall:
    """Worker ``device`` slows to ``factor`` x the healthy step time."""

    step: int
    device: int
    factor: float = 10.0

    def fire(self, trainer):
        trainer.stall(self.device, self.factor)


@dataclasses.dataclass(frozen=True)
class Unstall:
    step: int
    device: int

    def fire(self, trainer):
        trainer.unstall(self.device)


class ChaosScript:
    """An ordered event script; ``apply`` is the hook
    :meth:`repro.runtime.ElasticTrainer.run` calls before each step."""

    def __init__(self, events: Sequence):
        self.events = sorted(events, key=lambda e: e.step)
        self.fired: list = []

    def apply(self, trainer, step: int):
        for ev in self.events:
            if ev.step == step and ev not in self.fired:
                ev.fire(trainer)
                self.fired.append(ev)

    def apply_due(self, trainer, step: int):
        """Fire every not-yet-fired event with ``ev.step <= step``.

        The serving engine's tick jumps by ``steps_per_dispatch`` per fused
        call, so exact-step matching (``apply``) would skip events that land
        inside a dispatch window; controllers that observe a coarse clock
        use this hook instead."""
        for ev in self.events:
            if ev.step <= step and ev not in self.fired:
                ev.fire(trainer)
                self.fired.append(ev)

    @staticmethod
    def random(
        seed: int,
        n_steps: int,
        initial_devices: Sequence[int],
        spare_devices: Sequence[int] = (),
        min_extent: int = 2,
        max_events: int = 4,
        event_steps: Optional[Sequence[int]] = None,
    ) -> "ChaosScript":
        """Seeded *legal* kill/join sequence: tracks the live worker set so
        it never kills below ``min_extent`` and only joins devices that are
        currently outside the mesh."""
        rng = np.random.default_rng(seed)
        live = list(initial_devices)
        outside = list(spare_devices)
        steps = (
            sorted(rng.choice(np.arange(1, n_steps), size=max_events, replace=False))
            if event_steps is None
            else list(event_steps)
        )
        events: list = []
        for s in steps[:max_events]:
            can_kill = len(live) > min_extent
            can_join = len(outside) > 0
            if not (can_kill or can_join):
                break
            if can_kill and (not can_join or rng.random() < 0.5):
                victim = live[int(rng.integers(len(live)))]
                events.append(Kill(int(s), victim))
                live.remove(victim)
                outside.append(victim)
            else:
                n = int(rng.integers(1, min(2, len(outside)) + 1))
                joiners = [outside.pop(int(rng.integers(len(outside))))
                           for _ in range(n)]
                events.append(Join(int(s), tuple(sorted(joiners))))
                live.extend(joiners)
        return ChaosScript(events)


# ---------------------------------------------------------------------------
# Oracle replay: uninterrupted runs at each intermediate extent
# ---------------------------------------------------------------------------


def _mesh_from_ids(device_ids):
    from repro import compat

    by_id = {d.id: d for d in jax.devices()}
    devs = [by_id[i] for i in device_ids]
    return compat.make_mesh(
        (len(devs),), ("data",), devices=devs,
        axis_types=compat.default_axis_types(1),
    )


def oracle_replay(
    cfg,
    tcfg,
    dcfg,
    initial_device_ids: Sequence[int],
    resizes: Sequence,
    n_steps: int,
    *,
    key=None,
):
    """Replay a recorded resize trajectory with plain jitted train steps.

    Each segment is an *uninterrupted oracle run at that extent* — built
    straight from ``step_lib.make_train_step`` with none of the elastic
    machinery — and segments are stitched with the same
    ``gradsync.migrate_state`` calls the recorded :class:`ResizeEvent` s
    name.  Returns ``(state, losses)``; DP-only (1-D ``("data",)``)
    meshes.
    """
    from jax.sharding import NamedSharding

    from repro.data.pipeline import SyntheticPipeline
    from repro.distributed import gradsync
    from repro.distributed import step as step_lib

    key = jax.random.PRNGKey(0) if key is None else key
    by_step: dict[int, list] = {}
    for ev in resizes:
        by_step.setdefault(int(ev.step), []).append(ev)

    mesh = _mesh_from_ids(initial_device_ids)
    train_step, init_state, state_specs, _ = step_lib.make_train_step(cfg, mesh, tcfg)
    with mesh:
        state = init_state(key)
        state = jax.device_put(
            state,
            jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs(state)),
        )
    pipe = SyntheticPipeline(cfg, dcfg, mesh)
    jit_step = jax.jit(train_step)
    losses = []
    for i in range(n_steps):
        for ev in by_step.get(i, []):
            old_mesh, new_mesh = mesh, _mesh_from_ids(ev.device_ids)
            state = gradsync.migrate_state(
                cfg, tcfg, old_mesh, new_mesh, state, ev.keep
            )
            mesh = new_mesh
            train_step, init_state, state_specs, _ = step_lib.make_train_step(
                cfg, mesh, tcfg
            )
            jit_step = jax.jit(train_step)
            pipe_state = pipe.state_dict()
            pipe = SyntheticPipeline(cfg, dcfg, mesh)
            pipe.load_state_dict(pipe_state)
            with mesh:
                state = jax.device_put(
                    state,
                    jax.tree.map(
                        lambda s: NamedSharding(mesh, s), state_specs(state)
                    ),
                )
        with mesh:
            state, metrics = jit_step(state, pipe.next_batch())
        losses.append(float(metrics["loss"]))
    return state, losses


def assert_params_bit_identical(a, b, context: str = ""):
    """Bitwise equality of two param pytrees (elementwise, every leaf)."""
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb, f"param tree structures differ {context}"
    for x, y in zip(la, lb):
        xa = np.asarray(jax.device_get(x))
        ya = np.asarray(jax.device_get(y))
        assert xa.dtype == ya.dtype and xa.shape == ya.shape, context
        if not np.array_equal(
            xa.view(np.uint8) if xa.dtype == jnp.bfloat16 else xa,
            ya.view(np.uint8) if ya.dtype == jnp.bfloat16 else ya,
        ):
            bad = np.abs(xa.astype(np.float64) - ya.astype(np.float64)).max()
            raise AssertionError(
                f"params not bit-identical {context}: max abs diff {bad}"
            )
