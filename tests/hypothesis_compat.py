"""Degrade gracefully when ``hypothesis`` is not installed.

The property-based tests use hypothesis (declared in requirements-dev.txt /
``pip install -e .[dev]``), but a bare environment should still run the
example-based tests instead of erroring at collection.  Import the
hypothesis surface from here::

    from hypothesis_compat import given, settings, st, requires_hypothesis

With hypothesis present this is a pass-through.  Without it, ``@given``
turns the test into a skip, and ``st``/``settings`` become inert stubs so
module-level strategy expressions still evaluate.

Modules that are *entirely* property-based can instead call
``pytest.importorskip("hypothesis")`` directly.
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade: skip property tests, keep the rest
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):  # noqa: D103
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):  # noqa: D103
        return lambda f: f

    class _InertStrategy:
        """Absorbs any strategy construction/combination at module scope."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

        def map(self, *a, **k):
            return self

        def filter(self, *a, **k):
            return self

    class _St:
        def __getattr__(self, name):
            return _InertStrategy()

    st = _St()


requires_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed"
)
