"""The unified collectives plan layer: schedule x executor x transform x op.

Sim-executor coverage runs in-process for every p (non-powers-of-two are the
paper's headline case).  Device-executor bit-agreement runs in a subprocess
with XLA_FLAGS=--xla_force_host_platform_device_count=17 so the main test
process keeps seeing exactly one device.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.collectives import (
    EXECUTORS,
    SCHEDULES,
    TRANSFORMS,
    plans,
)
from repro.collectives.schedules import pivot
from repro.collectives.transforms import dequantize, quantize

PS = [2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 16, 17]


def _stack(p, n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((p, n)).astype(np.float32))


# ---------------------------------------------------------------------------
# Registries
# ---------------------------------------------------------------------------


def test_registries_are_populated():
    assert {"mrd", "rabenseifner", "hierarchical"} <= set(SCHEDULES)
    assert {"device", "device_fused", "sim"} <= set(EXECUTORS)
    assert {"identity", "int8"} <= set(TRANSFORMS)


def test_unknown_names_raise_with_known_lists():
    with pytest.raises(ValueError, match="mrd"):
        plans.allreduce_plan(schedule="nope", p=4).run(_stack(4, 8))
    with pytest.raises(ValueError, match="sim"):
        plans.CollectivePlan(executor="warp", p=4).run(_stack(4, 8))
    with pytest.raises(ValueError, match="identity"):
        plans.allreduce_plan(transform="zstd", p=4)


def test_reduce_scatter_rejects_indivisible_lengths():
    """Mis-sized buffers must raise, not silently corrupt (old-API parity)."""
    with pytest.raises(ValueError, match="len % 4"):
        plans.reduce_scatter_plan(p=4).run(_stack(4, 6))
    with pytest.raises(ValueError, match="len % 4"):
        plans.allreduce_plan(schedule="rabenseifner", p=4).run(_stack(4, 6))
    with pytest.raises(ValueError, match="len % 1024"):
        plans.reduce_scatter_plan(p=4, transform="int8").run(_stack(4, 512))


def test_plan_binding_validation():
    with pytest.raises(ValueError, match="exactly one"):
        plans.CollectivePlan(axes=("data",), p=4)
    with pytest.raises(ValueError, match="exactly one"):
        plans.CollectivePlan()
    with pytest.raises(ValueError, match="sum"):
        plans.allreduce_plan(p=4, transform="int8", op="max")
    with pytest.raises(ValueError, match=">= 2 axes"):
        plans.allreduce_plan(schedule="hierarchical", p=4).run(_stack(4, 8))


# ---------------------------------------------------------------------------
# Sim executor: p sweep x schedule x op (identity transform)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("op", ["sum", "max", "min"])
@pytest.mark.parametrize("schedule", ["mrd", "rabenseifner"])
def test_sim_allreduce_matches_reference(p, op, schedule):
    if schedule == "rabenseifner" and op != "sum":
        pytest.skip("one op suffices for the RS+AG composition")
    plan = plans.allreduce_plan(schedule=schedule, p=p, op=op)
    n = 4 * plan.pad_quantum()
    x = _stack(p, n, seed=p)
    out = np.asarray(plan.run(x))
    ref = {"sum": x.sum(0), "max": x.max(0), "min": x.min(0)}[op]
    np.testing.assert_allclose(
        out, np.broadcast_to(np.asarray(ref), (p, n)), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("p", PS)
def test_sim_reduce_scatter_and_allgather_roundtrip(p):
    p0, _, _ = pivot(p)
    n = p0 * 3
    x = _stack(p, n, seed=p + 100)
    seg = plans.reduce_scatter_plan(p=p).run(x)
    ref = np.asarray(x.sum(0))
    for i in range(p0):
        np.testing.assert_allclose(
            np.asarray(seg)[i], ref[i * 3 : (i + 1) * 3], rtol=1e-5, atol=1e-4
        )
    full = plans.allgather_plan(p=p).run(seg)
    np.testing.assert_allclose(
        np.asarray(full), np.broadcast_to(ref, (p, n)), rtol=1e-5, atol=1e-4
    )


@pytest.mark.parametrize("p", [2, 3, 5, 6, 8, 12, 13, 17])
def test_sim_int8_transform_reduce_scatter(p):
    """int8 wire format: result within per-stage quantization bounds."""
    p0, _, _ = pivot(p)
    plan = plans.reduce_scatter_plan(p=p, transform="int8")
    n = plan.pad_quantum()
    assert n == p0 * 256
    x = _stack(p, n, seed=p + 200)
    out = np.asarray(plan.run(x))
    ref = np.asarray(x.sum(0))
    m = n // p0
    for i in range(p0):
        np.testing.assert_allclose(
            out[i], ref[i * m : (i + 1) * m], rtol=0.1, atol=0.3
        )


@pytest.mark.parametrize("p", [3, 5, 8, 12])
def test_sim_int8_allreduce_blocking(p):
    plan = plans.allreduce_plan(schedule="mrd", p=p, transform="int8", op="sum")
    n = plan.pad_quantum()
    x = _stack(p, n, seed=p + 300)
    out = np.asarray(plan.run(x))
    ref = np.broadcast_to(np.asarray(x.sum(0)), (p, n))
    np.testing.assert_allclose(out, ref, rtol=0.1, atol=0.4)
    # the allreduce contract: every rank ends with the *same* value, even
    # though the wire format is lossy (butterfly combines canonical views)
    np.testing.assert_array_equal(out, np.broadcast_to(out[:1], out.shape))


# ---------------------------------------------------------------------------
# Non-blocking step() == blocking run() after one cycle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("op", ["sum", "max", "min"])
def test_nonblocking_equals_blocking_identity(p, op):
    plan = plans.allreduce_plan(schedule="mrd", p=p, op=op)
    x = _stack(p, 8, seed=p + 400)
    staged = np.asarray(plan.run_blocking(x))
    blocking = np.asarray(plan.run(x))
    np.testing.assert_array_equal(staged, blocking)  # bit-exact
    # flag fires exactly on the completing call
    st = plan.init(x)
    for i in range(plan.cycle_length()):
        st = plan.step(st, x)
        assert bool(st["flag"]) == (i == plan.cycle_length() - 1)
    assert int(st["cycles"]) == 1


@pytest.mark.parametrize("p", [3, 5, 8, 13])
def test_nonblocking_equals_blocking_int8(p):
    plan = plans.allreduce_plan(schedule="mrd", p=p, transform="int8", op="sum")
    x = _stack(p, plan.pad_quantum(), seed=p + 500)
    staged = np.asarray(plan.run_blocking(x))
    blocking = np.asarray(plan.run(x))
    # identical math; lax.switch may re-associate fp ops vs the unrolled loop
    np.testing.assert_allclose(staged, blocking, rtol=1e-5, atol=1e-5)


def test_nonblocking_rejects_non_allreduce_plans():
    with pytest.raises(ValueError, match="allreduce-only"):
        plans.reduce_scatter_plan(p=4).cycle_length()


def test_cycle_length_matches_paper():
    for p, expect in [(1, 1), (2, 1), (4, 2), (5, 4), (8, 3), (12, 5), (16, 4)]:
        assert plans.allreduce_plan(schedule="mrd", p=p).cycle_length() == expect


# ---------------------------------------------------------------------------
# Fused (Pallas mrd_combine) executor combine == unfused math
# ---------------------------------------------------------------------------


def test_fused_combine_matches_unfused():
    from repro.collectives.executors import DeviceBackend, FusedDeviceBackend

    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal(1024), jnp.float32)
    g = jnp.asarray(rng.standard_normal(1024), jnp.float32)
    q, s = quantize(g)
    plain = DeviceBackend("r").combine_quantized(x, q, s, 256)
    ref = x + dequantize(q, s)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(ref), rtol=1e-6)
    fused = FusedDeviceBackend("r").combine_quantized(x, q, s, 256)
    np.testing.assert_allclose(
        np.asarray(fused), np.asarray(ref), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# Grad-sync registry
# ---------------------------------------------------------------------------


def test_grad_sync_registry():
    from repro.distributed import gradsync

    assert {
        "gspmd", "mrd_paper", "mrd_leaf", "mrd_zero1", "compressed", "local_sgd"
    } <= set(gradsync.GRAD_SYNC)
    with pytest.raises(ValueError, match="mrd_zero1"):
        gradsync.get("adamw_ring")


# ---------------------------------------------------------------------------
# Device executor: bit-agreement with sim (subprocess, 17 host devices)
# ---------------------------------------------------------------------------

_DEVICE_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=17"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.collectives import plans
    from repro.collectives.schedules import pivot

    rng = np.random.default_rng(0)

    def run_device(plan_dev, x, mesh):
        def local(v):
            return plan_dev.run(v[0])[None]
        return jax.jit(compat.shard_map(
            local, mesh=mesh, in_specs=P("r"), out_specs=P("r")))(x)

    for p in [2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 16, 17]:
        mesh = compat.make_mesh((p,), ("r",), devices=jax.devices()[:p])
        for schedule in ["mrd", "rabenseifner"]:
            for op in ["sum", "max", "min"]:
                if schedule == "rabenseifner" and op != "sum":
                    continue
                sim = plans.allreduce_plan(schedule=schedule, p=p, op=op)
                dev = plans.allreduce_plan(schedule=schedule, axes=("r",), op=op)
                n = 2 * sim.pad_quantum()
                x = jnp.asarray(rng.standard_normal((p, n)).astype(np.float32))
                out_d = np.asarray(run_device(dev, x, mesh))
                out_s = np.asarray(sim.run(x))
                assert np.array_equal(out_d, out_s), (
                    f"device/sim mismatch p={p} {schedule} {op}: "
                    f"{np.abs(out_d - out_s).max()}")
        print(f"p={p} identity OK")

    # int8 transform parity on a subset (wire format must be identical too)
    for p in [3, 6, 8, 13]:
        mesh = compat.make_mesh((p,), ("r",), devices=jax.devices()[:p])
        sim = plans.reduce_scatter_plan(p=p, transform="int8")
        dev = plans.reduce_scatter_plan(axes=("r",), transform="int8")
        n = sim.pad_quantum()
        x = jnp.asarray(rng.standard_normal((p, n)).astype(np.float32))
        out_d = np.asarray(run_device(dev, x, mesh))
        out_s = np.asarray(sim.run(x))
        p0, _, _ = pivot(p)
        assert np.allclose(out_d[:p0], out_s[:p0], rtol=1e-6, atol=1e-6), (
            f"int8 device/sim mismatch p={p}")
        print(f"p={p} int8 OK")

    print("DEVICE-PARITY-PASSED")
    """
)


@pytest.mark.slow
def test_device_sim_bit_agreement():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _DEVICE_SCRIPT],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "DEVICE-PARITY-PASSED" in proc.stdout
