"""Property tests on the asynchrony registries: every delay model respects
the paper's two fairness conditions by construction, and every certifying
protocol is sound against a model-derived residual bound."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.asynchrony import (
    DELAY_MODELS,
    AsyncConfig,
    get_delay_model,
    make_solver,
    run,
)

MODEL_NAMES = sorted(DELAY_MODELS)


def _drive_model(name, p, max_delay, force_every, seed, ticks=48):
    """Sample `ticks` ticks of a model, carrying last_active like the engine."""
    cfg = AsyncConfig(
        p=p, max_delay=max_delay, force_every=force_every,
        activity=0.5, seed=seed,
    )
    model = get_delay_model(name)
    params = model.default_params(cfg, p)
    state = model.init_state(p)
    base = jax.random.PRNGKey(seed)
    last_active = jnp.zeros((p,), jnp.int32)
    out = []
    for t in range(1, ticks + 1):
        k_model, _ = jax.random.split(jax.random.fold_in(base, t))
        active, delays, state = model.sample(
            params, state, jnp.int32(t), k_model, last_active,
            p=p, max_delay=max_delay, force_every=force_every,
        )
        out.append((t, np.asarray(active), np.asarray(delays), np.asarray(last_active)))
        last_active = jnp.where(active, t, last_active)
    return out


def _check_fairness(rows, max_delay, force_every):
    for t, active, delays, last_active in rows:
        assert delays.dtype == np.int32
        assert (delays >= 0).all() and (delays <= max_delay).all(), (
            f"tick {t}: delay out of [0, {max_delay}]"
        )
        starved = (t - last_active) >= force_every
        assert active[starved].all(), (
            f"tick {t}: starved worker not forced active"
        )


@pytest.mark.parametrize("name", MODEL_NAMES)
def test_delay_model_fairness_example(name):
    """Example-based floor (runs even without hypothesis): bounds + forced
    activity hold for every registered model."""
    rows = _drive_model(name, p=6, max_delay=3, force_every=4, seed=0)
    _check_fairness(rows, max_delay=3, force_every=4)
    # every worker iterates infinitely often: implied count lower bound
    total_active = sum(a.astype(int) for _, a, _, _ in rows)
    assert (total_active >= len(rows) // 4 - 1).all()


@given(
    name=st.sampled_from(MODEL_NAMES),
    p=st.integers(2, 9),
    max_delay=st.integers(1, 5),
    force_every=st.integers(2, 7),
    seed=st.integers(0, 1000),
)
@settings(max_examples=25, deadline=None)
def test_delay_model_fairness_property(name, p, max_delay, force_every, seed):
    """Hypothesis-hardened: across random shapes/bounds/seeds, every model's
    emissions respect max_delay and forced-activity fairness."""
    rows = _drive_model(name, p, max_delay, force_every, seed, ticks=32)
    _check_fairness(rows, max_delay, force_every)


# ---------------------------------------------------------------------------
# Protocol soundness (paper S3, hardened): certification => residual bound
# ---------------------------------------------------------------------------

# n divisible by every p in the sweep (incl. non-powers-of-two 3 and 5)
_N = 120
_SHIFT = 0.5  # contraction rho(|T|) <= 2/(2+shift) = 0.8


def _bound(fp, protocol, eps):
    """Model-derived certified-residual bound.

    ``exact`` certifies ``||f(x̄)-x̄|| < eps`` on the frozen snapshot —
    the bound is eps itself.  ``inexact``/``interval`` certify that update
    magnitudes cleared eps; for a contraction with factor rho, an update
    magnitude d at a point x bounds the residual by d·(1+rho)/(1-rho)
    (standard fixed-point perturbation: ||f(x)-x|| <= ||x_new - x||·(1+rho)
    /(1-rho) along the iteration).
    """
    if protocol == "exact":
        return eps
    rho = fp.contraction
    assert rho is not None and rho < 1
    return eps * (1 + rho) / (1 - rho)


@pytest.mark.parametrize("protocol", ["inexact", "exact", "interval"])
@pytest.mark.parametrize("p", [2, 3, 4, 5, 8])
def test_protocol_soundness(protocol, p):
    fp = make_solver("poisson1d", n=_N, shift=_SHIFT, seed=0)
    eps = 1e-5
    for seed in (0, 3):
        cfg = AsyncConfig(
            p=p, detection=protocol, eps=eps, max_ticks=80000,
            seed=seed, max_delay=3, activity=0.6,
        )
        r = run(fp, cfg)
        assert r.detected, f"{protocol} never fired (p={p}, seed={seed})"
        bound = _bound(fp, protocol, eps)
        assert r.true_res < bound, (
            f"{protocol} certified a bad solution: true_res={r.true_res:.3e} "
            f">= bound={bound:.3e} (p={p}, seed={seed})"
        )


@pytest.mark.parametrize("protocol", ["inexact", "exact", "interval"])
def test_protocol_soundness_under_stragglers(protocol):
    """Soundness must survive adversarial environments, not just iid ones."""
    fp = make_solver("poisson1d", n=_N, shift=_SHIFT, seed=0)
    eps = 1e-5
    cfg = AsyncConfig(
        p=4, detection=protocol, eps=eps, max_ticks=80000,
        seed=0, max_delay=4, activity=0.6, delay_model="straggler",
    )
    r = run(fp, cfg)
    assert r.detected
    assert r.true_res < _bound(fp, protocol, eps)
