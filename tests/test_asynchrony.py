"""The registry-backed asynchrony runtime (DESIGN.md S11): registry
contents, sweep()/run() bit-identity, delay-model behavior, the new
solvers, and the import-compat shims."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.asynchrony import (
    DELAY_MODELS,
    DETECTION_PROTOCOLS,
    RES_INIT,
    SOLVERS,
    AsyncConfig,
    make_solver,
    resolve_delay_params,
    run,
    sweep,
)


def _cfg(**kw):
    base = dict(p=4, detection="exact", eps=1e-5, max_ticks=50000, seed=1)
    base.update(kw)
    return AsyncConfig(**base)


def test_registries_minimum_entries():
    assert {"bernoulli", "straggler", "heterogeneous", "bursty", "trace"} <= set(
        DELAY_MODELS
    )
    assert {"inexact", "exact", "oracle", "sync", "interval"} <= set(
        DETECTION_PROTOCOLS
    )
    assert {"poisson1d", "poisson2d", "jacobi_dense", "richardson", "d_iteration"} <= set(
        SOLVERS
    )
    assert len(DELAY_MODELS) >= 5 and len(DETECTION_PROTOCOLS) >= 5
    assert len(SOLVERS) >= 5


def test_sweep_bit_identical_to_run():
    """Acceptance: one vmapped dispatch == a Python loop of run() calls,
    bit for bit (bernoulli model)."""
    fp = make_solver("poisson1d", n=96, shift=0.5, seed=0)
    cfg = _cfg()
    seeds = [0, 1, 2, 5]
    sw = sweep(fp, cfg, seeds)
    for i, s in enumerate(seeds):
        r = run(fp, dataclasses.replace(cfg, seed=s))
        assert sw.detected[i] == r.detected
        assert sw.ticks[i] == r.ticks
        assert sw.res_glb[i] == np.float32(r.res_glb)
        assert sw.true_res[i] == np.float32(r.true_res)
        np.testing.assert_array_equal(sw.kiter[i], r.kiter)
        assert sw.messages_p2p[i] == r.messages_p2p
        assert sw.messages_coll[i] == r.messages_coll
        np.testing.assert_array_equal(sw.x[i], r.x)


def test_sweep_param_grid():
    """vmap over seeds x delay-model params in one dispatch: [G, S] axes."""
    fp = make_solver("poisson1d", n=64, shift=0.5, seed=0)
    cfg = _cfg(p=4)
    grid = {"activity": jnp.asarray([0.3, 0.6, 0.9], jnp.float32)}
    sw = sweep(fp, cfg, [0, 1], delay_params=grid)
    assert sw.ticks.shape == (3, 2)
    assert sw.x.shape == (3, 2, 64)
    assert sw.detected.all()
    assert (sw.true_res < cfg.eps).all()
    # lower activity -> no lane finishes faster than the high-activity one
    assert sw.ticks[0].mean() >= sw.ticks[2].mean()


@pytest.mark.parametrize("model", sorted(DELAY_MODELS))
def test_every_delay_model_converges_with_exact_detection(model):
    fp = make_solver("poisson1d", n=96, shift=0.5, seed=0)
    r = run(fp, _cfg(delay_model=model))
    assert r.detected, f"exact detector never fired under {model}"
    assert r.true_res < 1e-5


def test_trace_replays_its_source_model():
    """The default trace records bernoulli under the same seed stream, so
    replaying it must reproduce the bernoulli run exactly."""
    fp = make_solver("poisson1d", n=96, shift=0.5, seed=0)
    r_b = run(fp, _cfg(delay_model="bernoulli"))
    r_t = run(fp, _cfg(delay_model="trace"))
    assert r_b.ticks == r_t.ticks
    np.testing.assert_array_equal(r_b.x, r_t.x)
    np.testing.assert_array_equal(r_b.kiter, r_t.kiter)


def test_straggler_model_actually_lags():
    """The slow subset iterates measurably less than the fast one."""
    fp = make_solver("poisson1d", n=96, shift=0.5, seed=0)
    cfg = _cfg(delay_model="straggler", detection="oracle", force_every=10)
    params = resolve_delay_params(fp, cfg)
    n_slow = int(params["n_slow"])
    r = run(fp, cfg)
    assert r.kiter[:n_slow].mean() < 0.6 * r.kiter[n_slow:].mean()


def test_poisson2d_and_d_iteration_solve():
    fp2 = make_solver("poisson2d", nx=8, ny=8, shift=0.5)
    r = run(fp2, _cfg(eps=1e-6))
    assert r.detected and r.true_res < 1e-6

    # damped diffusion: the fixed point is a probability vector (sum 1)
    fpd = make_solver("d_iteration", n=64, damping=0.85)
    r = run(fpd, _cfg(eps=1e-7))
    assert r.detected and r.true_res < 1e-7
    assert abs(float(np.sum(r.x)) - 1.0) < 1e-3
    assert (r.x >= -1e-6).all()  # nonnegative mass


def test_d_iteration_contraction_matches_damping():
    """The residual map is r -> damping * P r; P column-stochastic preserves
    the 1-norm of nonnegative vectors, so the residual's 1-norm contracts by
    exactly the damping factor each application (rho(|T|) = damping)."""
    fp = make_solver("d_iteration", n=32, damping=0.7)
    assert fp.contraction == 0.7
    x = jnp.zeros((32,))
    r0 = jnp.sum(jnp.abs(fp.full_map(x) - x))
    y = fp.full_map(x)
    r1 = jnp.sum(jnp.abs(fp.full_map(y) - y))
    np.testing.assert_allclose(float(r1), 0.7 * float(r0), rtol=1e-5)


# ---------------------------------------------------------------------------
# Import-compat shims (acceptance criterion)
# ---------------------------------------------------------------------------


def test_core_shims_import_compat():
    from repro.core import async_engine as ae
    from repro.core import detection, solvers

    assert ae.AsyncConfig is AsyncConfig
    assert ae.run is run and ae.sweep is sweep
    fp = solvers.poisson_1d(64, omega=1.0, shift=0.5, seed=0)
    r = ae.run(fp, ae.AsyncConfig(p=4, detection="exact", eps=1e-5, max_ticks=50000))
    assert r.detected
    assert r.det_tick == r.ticks  # deprecated alias, no duplicated state
    assert detection._BIG == RES_INIT
    assert detection.ConvergenceMonitor is not None
    assert solvers.FixedPoint is not None
    assert "poisson2d" in solvers.SOLVERS


def test_detection_shim_tick_functions_still_drive():
    """Old-style inexact_init/inexact_tick calls (pre-registry surface)."""
    from repro.core import detection

    p = 4
    st = detection.inexact_init(p)
    mags = jnp.full((p,), 1e-9, jnp.float32)
    fired = False
    for _ in range(16):
        st = detection.inexact_tick(st, mags, p=p, eps=1e-6)
        fired = fired or bool(st["detected"])
    assert fired


def test_interval_protocol_needs_a_full_quiet_window():
    """interval == inexact hardened: a single small instantaneous update
    cannot certify; the window max must clear eps."""
    from repro.asynchrony.protocols import Obs, get_protocol

    p = 4
    proto = get_protocol("interval")
    cfg = _cfg(p=p, max_delay=2, window=0)  # window -> max_delay + 2 = 4
    st = proto.init(p, 16, cfg)
    big = jnp.full((p,), 1.0, jnp.float32)
    small = jnp.full((p,), 1e-9, jnp.float32)

    def obs(t, mags):
        return Obs(
            x=None, update_mag=mags, tick=jnp.int32(t), key=None, fp=None,
            eps=1e-6, max_delay=2, msg_table=jnp.zeros((1,), jnp.int32),
            coll_cycle_msgs=jnp.zeros((), jnp.int32),
        )

    t = 1
    # big updates fill the window
    for _ in range(6):
        st, _ = proto.tick(st, obs(t, big))
        t += 1
    # one small tick: the window still contains big values -> no certify
    st, _ = proto.tick(st, obs(t, small))
    t += 1
    assert not bool(st["detected"])
    # a full quiet window (+ reduction cycles) -> certify
    for _ in range(16):
        st, _ = proto.tick(st, obs(t, small))
        t += 1
    assert bool(st["detected"])
