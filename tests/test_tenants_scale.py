"""Multi-tenant traffic model + SLA autoscaling (DESIGN.md S17).

Covers the scale layer end to end on the cheap fixed-point workload:
arrival generators (seeded, tick-domain), tenant-spec parsing, request
materialization through workload ``sample_request`` hooks, quota-aware
admission (a tenant at its in-flight quota is passed over, never
wedged), the ``sla_edf`` anti-starvation bound under a starvation-shaped
trace, the summary bugfixes (NaN percentiles on empty runs, NaN TPOT for
single-token completions, excluded from percentiles), the
``sla_autoscale`` policy state machine (hysteresis, cooldown, min/max
clamps, per-controller ``spawn``), the ``slots_per_replica`` capacity
model, and the merged :class:`TenantScenario` summary.
"""

import json
import math

import numpy as np
import pytest

from repro.runtime.fault_tolerance import FailureDetector, HeartbeatConfig
from repro.runtime.policies import LoadSnapshot, SlaAutoscalePolicy, get_policy
from repro.serving import (
    ARRIVALS,
    Request,
    ServeConfig,
    ServeEngine,
    TenantScenario,
    TenantSpec,
    build_requests,
    make_arrival_ticks,
    make_workload,
    parse_tenant_specs,
    quotas_of,
)

FP = "fixedpoint_solve"


def fp_workload(slots=4, dp=1, n=16, **kw):
    return make_workload(FP, solver="d_iteration", n=n, dp=dp, slots=slots,
                         damping=0.6, **kw)


# ---------------------------------------------------------------------------
# arrival generators
# ---------------------------------------------------------------------------


def test_arrival_registry_floor():
    assert {"none", "poisson", "bursty", "diurnal", "trace"} <= set(ARRIVALS)


def test_arrivals_are_seeded_sorted_and_sized():
    for spec in ("none", "poisson:0.5", "bursty:0.2,2.0", "bursty:0.2,2.0,0.1,10",
                 "diurnal:1.0,40", "diurnal:1.0,40,0.2"):
        a = make_arrival_ticks(spec, 30, seed=3)
        b = make_arrival_ticks(spec, 30, seed=3)
        assert a == b and len(a) == 30 and a == sorted(a)
        assert all(isinstance(t, int) and t >= 0 for t in a)
    assert make_arrival_ticks("poisson:0.5", 30, 3) != make_arrival_ticks(
        "poisson:0.5", 30, 4
    )


def test_bursty_concentrates_arrivals_vs_base_rate():
    ticks = make_arrival_ticks("bursty:0.05,5.0,0.05,20", 60, seed=1)
    # a burst window dumps many arrivals on few distinct ticks; a pure
    # 0.05/tick base process would spread 60 arrivals over ~1200 ticks
    assert len(set(ticks)) < len(ticks) / 2


def test_diurnal_peaks_mid_period():
    ticks = make_arrival_ticks("diurnal:2.0,100,0.01", 100, seed=0)
    phase = [t % 100 for t in ticks]
    # valley start: the first quarter-period carries far fewer arrivals
    # than the mid-period crest
    assert sum(1 for p in phase if p < 25) < sum(1 for p in phase if 25 <= p < 75)


def test_trace_arrivals_replay_file(tmp_path):
    f = tmp_path / "trace.json"
    f.write_text(json.dumps({"arrivals": [5, 1, 9, 9]}))
    assert make_arrival_ticks(f"trace:{f}", 4, 0) == [1, 5, 9, 9]
    with pytest.raises(ValueError, match="need 9"):
        make_arrival_ticks(f"trace:{f}", 9, 0)


def test_unknown_arrival_kind_raises():
    with pytest.raises(ValueError, match="unknown arrival"):
        make_arrival_ticks("pareto:1.0", 4, 0)


def test_too_low_rate_raises_not_hangs():
    with pytest.raises(ValueError, match="rate too low"):
        make_arrival_ticks("diurnal:0.0,10,0.0", 5, 0)


# ---------------------------------------------------------------------------
# tenant specs + request materialization
# ---------------------------------------------------------------------------


def test_parse_tenant_specs():
    chat, batch = parse_tenant_specs(
        "chat:3:sla=8:prio=2:gen=12,batch:quota=4:workload=fixedpoint_solve"
    )
    assert chat == TenantSpec("chat", weight=3.0, sla=8, priority=2, max_new=12)
    assert batch.weight == 1.0 and batch.quota == 4 and batch.workload == FP
    assert batch.sla is None
    assert quotas_of((chat, batch)) == {"batch": 4}


@pytest.mark.parametrize("bad", ["chat:1:deadline=3", "a:1,a:2", ""])
def test_parse_tenant_specs_rejects(bad):
    with pytest.raises(ValueError):
        parse_tenant_specs(bad)


def test_build_requests_routes_by_workload_with_unique_ids():
    wl = fp_workload()
    tenants = parse_tenant_specs(
        f"solve:2:sla=50:workload={FP}:gen=500,bulk:1:workload={FP}:gen=500"
    )
    out = build_requests(tenants, {FP: wl}, 20, "poisson:1.0", seed=5)
    reqs = out[FP]
    assert len(reqs) == 20
    assert sorted(r.id for r in reqs) == list(range(20))
    assert {r.tenant for r in reqs} == {"solve", "bulk"}
    for r in reqs:
        assert r.payload is not None and r.payload.shape == (16,)
        assert (r.sla == 50) == (r.tenant == "solve")
    # deterministic: same (tenants, spec, seed) -> same stream
    again = build_requests(tenants, {FP: wl}, 20, "poisson:1.0", seed=5)[FP]
    assert [(r.tenant, r.arrival) for r in reqs] == [
        (r.tenant, r.arrival) for r in again
    ]


def test_build_requests_rejects_undeployed_workload():
    tenants = (TenantSpec("chat"),)  # targets llm_decode
    with pytest.raises(ValueError, match="llm_decode"):
        build_requests(tenants, {FP: fp_workload()}, 4, "none", 0)


def test_weights_must_be_positive():
    tenants = (TenantSpec("a", weight=0.0, workload=FP),)
    with pytest.raises(ValueError, match="positive"):
        build_requests(tenants, {FP: fp_workload()}, 4, "none", 0)


# ---------------------------------------------------------------------------
# quota-aware admission
# ---------------------------------------------------------------------------


def test_quota_limits_inflight_and_passes_slot_over():
    wl = fp_workload(slots=4)
    eng = ServeEngine(wl, ServeConfig(
        termination="residual_interval", eps=1e-2,
        quotas={"bulk": 1},
    ))
    reqs = [Request(id=i, arrival=0, max_new=500,
                    tenant="bulk" if i < 3 else "free")
            for i in range(6)]
    eng.run(reqs)
    assert len(eng.results) == 6
    bulk = sorted((r for r in eng.results.values() if r.tenant == "bulk"),
                  key=lambda r: r.admit_tick)
    # quota=1: bulk's in-flight intervals never overlap
    for a, b in zip(bulk, bulk[1:]):
        assert b.admit_tick >= a.retire_tick
    # the passed-over slots served the unquota'd tenant immediately
    free = [r for r in eng.results.values() if r.tenant == "free"]
    assert all(r.admit_tick == 0 for r in free)


# ---------------------------------------------------------------------------
# starvation bound under a starvation-shaped trace (bugfix)
# ---------------------------------------------------------------------------


def test_no_request_waits_past_promotion_bound():
    wl = fp_workload(slots=2)
    eng = ServeEngine(wl, ServeConfig(
        scheduler="sla_edf:8", termination="residual_interval", eps=1e-2,
    ))
    # a sustained stream of tight-deadline requests + one batch request at
    # t=0: pure EDF would starve the batch request for the whole run
    reqs = [Request(id=0, arrival=0, max_new=500, tenant="batch")]
    # two tight-deadline arrivals per tick saturate both slots from t=0
    reqs += [Request(id=1 + i, arrival=i // 2, max_new=500, sla=4,
                     tenant="chat")
             for i in range(40)]
    eng.run(reqs)
    batch = eng.results[0]
    assert batch.admit_tick > 0  # it did contend with the stream
    # promoted after max_wait=8 ticks; it still has to wait for a slot to
    # free (one in-flight solve), hence the slack
    solve_ticks = max(r.retire_tick - r.admit_tick for r in eng.results.values())
    assert batch.admit_tick - batch.arrival <= 8 + solve_ticks
    # and it genuinely bypassed the deadline stream: chat requests that
    # arrived before the batch admission were still waiting behind it
    bypassed = [r for r in eng.results.values()
                if r.tenant == "chat" and r.arrival < batch.admit_tick
                and r.admit_tick > batch.admit_tick]
    assert bypassed


# ---------------------------------------------------------------------------
# summary bugfixes: NaN, never fake zeros
# ---------------------------------------------------------------------------


def test_empty_summary_reports_nan_percentiles():
    eng = ServeEngine(fp_workload(), ServeConfig(
        termination="residual_interval",
    ))
    s = eng.summary()
    for k in ("ttft_p50_ms", "ttft_p95_ms", "ttft_p99_ms",
              "tpot_p50_ms", "tpot_p95_ms", "tpot_p99_ms"):
        assert math.isnan(s[k]), f"{k} should be NaN on an empty run, got {s[k]}"
    assert s["completed"] == 0 and s["tenants"] == {}


def test_single_token_completion_tpot_nan_and_excluded():
    wl = fp_workload(slots=2)
    eng = ServeEngine(wl, ServeConfig(termination="residual_interval",
                                      eps=1e-2))
    # max_new=1: budget-forced after a single iteration -> n_tokens == 1,
    # which has no inter-token interval
    eng.run([Request(id=0, max_new=1), Request(id=1, max_new=500)])
    assert eng.results[0].n_tokens == 1
    assert math.isnan(eng.results[0].tpot_s)
    s = eng.summary()
    # the percentile ranks only the multi-token request - finite, not
    # dragged toward 0.0 by the single-token completion
    assert math.isfinite(s["tpot_p50_ms"]) and s["tpot_p50_ms"] > 0.0


def test_sla_met_is_tick_domain():
    wl = fp_workload(slots=1)
    eng = ServeEngine(wl, ServeConfig(termination="residual_interval",
                                      eps=1e-2))
    eng.run([Request(id=0, max_new=500, sla=0),
             Request(id=1, max_new=500, sla=0)])
    # slot 1 is busy until the first solve retires: request 1 must miss a
    # zero-tick TTFT deadline, request 0 meets it
    assert eng.results[0].sla_met is True
    assert eng.results[1].sla_met is False
    s = eng.summary()
    assert s["sla_total"] == 2 and s["sla_met"] == 1 and s["goodput_ok"] == 1


def test_per_tenant_summary_breakdown():
    wl = fp_workload(slots=4)
    eng = ServeEngine(wl, ServeConfig(termination="residual_interval",
                                      eps=1e-2))
    eng.run([Request(id=0, max_new=500, sla=50, tenant="chat"),
             Request(id=1, max_new=500, tenant="batch")])
    t = eng.summary()["tenants"]
    assert set(t) == {"chat", "batch"}
    assert t["chat"]["sla_total"] == 1 and t["chat"]["sla_met"] == 1
    assert t["batch"]["sla_total"] == 0 and t["batch"]["goodput_ok"] == 1
    assert math.isfinite(t["chat"]["ttft_p99_ticks"])
    assert math.isfinite(t["chat"]["ttft_p99_ms"])


# ---------------------------------------------------------------------------
# sla_autoscale policy state machine
# ---------------------------------------------------------------------------


def mk_detector(ids=(0,)):
    return FailureDetector(list(ids), HeartbeatConfig(), now=0.0)


def load(tick, *, queue=0, near=0, overdue=0, free=0, usable=8, dp=1):
    return LoadSnapshot(tick=tick, queue_depth=queue, sla_near=near,
                        sla_overdue=overdue, free_slots=free,
                        usable_slots=usable, dp=dp)


def test_autoscale_grows_after_up_patience_with_synthesized_joiner():
    p = SlaAutoscalePolicy(max_extent=4, up_patience=2, cooldown=3)
    det = mk_detector((0, 1))
    ids = frozenset({0, 1})
    # first pressured step arms the counter, second fires the grow
    assert p.decide(det, 1.0, [], ids, load=load(1, queue=9)).action == "none"
    d = p.decide(det, 2.0, [], ids, load=load(2, queue=9))
    assert d.action == "grow" and d.admit == (2,)  # max(live)+1


def test_autoscale_cooldown_suppresses_thrash():
    p = SlaAutoscalePolicy(max_extent=4, up_patience=1, cooldown=5)
    det = mk_detector((0,))
    d = p.decide(det, 1.0, [], frozenset({0}), load=load(10, queue=9))
    assert d.action == "grow"
    # inside the cooldown window nothing fires, however hard the pressure
    d2 = p.decide(det, 2.0, [], frozenset({0, 1}), load=load(12, queue=99))
    assert d2.action == "none" and "cooldown" in d2.reason
    d3 = p.decide(det, 3.0, [], frozenset({0, 1}), load=load(15, queue=99))
    assert d3.action == "grow"


def test_autoscale_shrinks_idle_to_min_extent_only():
    p = SlaAutoscalePolicy(min_extent=2, max_extent=4, down_patience=2,
                           cooldown=0)
    det = mk_detector((0, 1, 2))
    ids = frozenset({0, 1, 2})
    idle = dict(free=8, usable=8)
    assert p.decide(det, 1.0, [], ids, load=load(1, **idle)).action == "none"
    d = p.decide(det, 2.0, [], ids, load=load(2, **idle))
    assert d.action == "shrink" and d.remove == frozenset({2})  # max(live)
    # at min_extent the shrink never fires
    p2 = SlaAutoscalePolicy(min_extent=2, max_extent=4, down_patience=1,
                            cooldown=0)
    for t in range(1, 5):
        d = p2.decide(det, float(t), [], frozenset({0, 1}),
                      load=load(t, **idle))
        assert d.action == "none"


def test_autoscale_respects_max_extent():
    p = SlaAutoscalePolicy(max_extent=2, up_patience=1, cooldown=0)
    det = mk_detector((0, 1))
    for t in range(1, 5):
        d = p.decide(det, float(t), [], frozenset({0, 1}),
                     load=load(t, queue=50))
        assert d.action == "none"


def test_autoscale_mixed_load_resets_both_counters():
    p = SlaAutoscalePolicy(up_patience=2, down_patience=2, cooldown=0)
    det = mk_detector((0, 1))
    ids = frozenset({0, 1})
    p.decide(det, 1.0, [], ids, load=load(1, queue=9))  # arms up
    # neither pressured nor idle: busy steady state resets the counters
    p.decide(det, 2.0, [], ids, load=load(2, queue=0, free=0))
    d = p.decide(det, 3.0, [], ids, load=load(3, queue=9))
    assert d.action == "none"  # up-counter restarted


def test_autoscale_spawn_isolates_state_and_registry_passthrough():
    reg = get_policy("sla_autoscale")
    a, b = reg.spawn(), reg.spawn()
    assert a is not reg and a is not b
    det = mk_detector((0,))
    a._up = 99
    assert b._up == 0
    # stateless policies spawn themselves
    static = get_policy("static")
    assert static.spawn() is static
    # without a load snapshot the policy degrades to shrink_on_failure
    assert a.decide(det, 1.0, [], frozenset({0})).action == "none"


def test_autoscale_invalid_extents_raise():
    with pytest.raises(ValueError):
        SlaAutoscalePolicy(min_extent=0)
    with pytest.raises(ValueError):
        SlaAutoscalePolicy(min_extent=4, max_extent=2)


# ---------------------------------------------------------------------------
# capacity model + end-to-end autoscaling
# ---------------------------------------------------------------------------


def test_slots_per_replica_masks_admission_capacity():
    wl = fp_workload(slots=4)
    eng = ServeEngine(wl, ServeConfig(
        termination="residual_interval", eps=1e-2, dp=1,
        slots_per_replica=2,
    ))
    assert eng.usable_slots == 2
    eng.run([Request(id=i, max_new=500) for i in range(4)])
    # only 2 slots ever admit at dp=1: the other two requests queue
    assert len(eng.results) == 4
    assert sum(1 for r in eng.results.values() if r.admit_tick == 0) == 2
    assert max(r.admit_tick for r in eng.results.values()) > 0


def test_autoscale_end_to_end_grows_under_burst_and_completes():
    from repro.runtime import ElasticServeController

    wl = fp_workload(slots=6, n=24)
    eng = ServeEngine(wl, ServeConfig(
        scheduler="sla_edf", termination="residual_interval", eps=1e-2,
        dp=1, slots_per_replica=2, steps_per_dispatch=2,
    ))
    ctl = ElasticServeController(
        eng,
        policy=SlaAutoscalePolicy(max_extent=3, up_patience=1, cooldown=2),
    )
    reqs = [Request(id=i, arrival=0, max_new=500, sla=10)
            for i in range(12)]
    res = ctl.run(reqs)
    assert len(res) == 12
    grows = [ev for ev in eng.resizes if ev.kind == "grow"]
    assert grows, "burst pressure should have grown the extent"
    assert max(ev.new_dp for ev in eng.resizes) <= 3
    assert eng.usable_slots == min(6, eng.dp * 2)
    s = eng.summary()
    assert s["replica_ticks"] > 0
    # a static dp=1 run of the same traffic meets strictly fewer deadlines
    wl2 = fp_workload(slots=6, n=24)
    eng2 = ServeEngine(wl2, ServeConfig(
        scheduler="sla_edf", termination="residual_interval", eps=1e-2,
        dp=1, slots_per_replica=2, steps_per_dispatch=2,
    ))
    eng2.run([Request(id=i, arrival=0, max_new=500, sla=10)
              for i in range(12)])
    assert s["sla_met"] > eng2.summary()["sla_met"]


# ---------------------------------------------------------------------------
# TenantScenario merged summary
# ---------------------------------------------------------------------------


def test_tenant_scenario_merges_engines_and_tenants():
    wl_a, wl_b = fp_workload(slots=2), fp_workload(slots=2, n=24)
    tenants = (
        TenantSpec("alpha", weight=2.0, workload="fp_a", sla=40, max_new=500),
        TenantSpec("beta", weight=1.0, workload="fp_b", max_new=500),
    )
    reqs = build_requests(tenants, {"fp_a": wl_a, "fp_b": wl_b}, 10,
                          "poisson:0.5", seed=2)
    scen = TenantScenario({
        "fp_a": ServeEngine(wl_a, ServeConfig(termination="residual_interval",
                                              eps=1e-2)),
        "fp_b": ServeEngine(wl_b, ServeConfig(termination="residual_interval",
                                              eps=1e-2)),
    })
    out = scen.run(reqs)
    assert len(out["fp_a"]) + len(out["fp_b"]) == 10
    s = scen.summary()
    assert s["completed"] == 10
    assert set(s["tenants"]) == {"alpha", "beta"}
    assert s["ticks"] == sum(e["ticks"] for e in s["engines"].values())
    assert s["replica_ticks"] == sum(
        e["replica_ticks"] for e in s["engines"].values()
    )
    assert math.isfinite(s["ttft_p99_ms"])
    assert s["goodput_ok"] == s["completed"] - s["sla_total"] + s["sla_met"]
