"""Pallas flash-attention kernel vs pure-jnp oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref


def _qkv(seed, B, Sq, Skv, H, KV, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (
        jax.random.normal(ks[0], (B, Sq, H, hd), dtype),
        jax.random.normal(ks[1], (B, Skv, KV, hd), dtype),
        jax.random.normal(ks[2], (B, Skv, KV, hd), dtype),
    )


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Skv,H,KV,hd",
    [
        (1, 128, 128, 4, 4, 64),     # MHA, square
        (2, 128, 256, 8, 2, 64),     # GQA rep=4, rectangular
        (1, 256, 256, 4, 1, 128),    # MQA, hd=128
        (1, 64, 192, 2, 2, 80),      # hubert/zamba2-like hd=80
    ],
)
def test_kernel_matches_ref_shapes(dtype, B, Sq, Skv, H, KV, hd):
    q, k, v = _qkv(0, B, Sq, Skv, H, KV, hd, dtype)
    out = flash_attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **TOL[dtype]
    )


@pytest.mark.parametrize("window", [None, 32, 128])
def test_kernel_sliding_window(window):
    q, k, v = _qkv(1, 1, 128, 128, 4, 2, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, bq=64, bk=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_kernel_bidirectional():
    q, k, v = _qkv(2, 2, 128, 128, 4, 4, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=False, bq=64, bk=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_kernel_decode_offset():
    """Sq=1 with q_offset = cache length (decode step)."""
    q, k, v = _qkv(3, 2, 1, 256, 8, 8, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=True, q_offset=255, bq=1, bk=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, q_offset=255)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_kernel_unaligned_lengths():
    """Sq/Skv not multiples of the block sizes (padding paths)."""
    q, k, v = _qkv(4, 1, 100, 150, 4, 2, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
