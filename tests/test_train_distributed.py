"""Distributed training integration: gspmd vs MRD-ZeRO-1 equivalence,
non-power-of-two DP groups, monitor detection — on an 8-device subprocess."""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import compat
    import dataclasses

    from repro.configs import registry
    from repro.data.pipeline import DataConfig, SyntheticPipeline
    from repro.distributed import step as step_lib
    from repro.optim.optimizer import OptimizerConfig

    cfg = registry.get_smoke_config("llama3.2-1b")

    def run_mode(mesh_shape, axis_names, grad_sync, steps=6, monitor=True, ndev=8,
                 bucket_bytes=32 * 2**20):
        mesh = compat.make_mesh(mesh_shape, axis_names,
                                devices=jax.devices()[:ndev],
                                axis_types=compat.default_axis_types(len(axis_names)))
        tcfg = step_lib.TrainConfig(
            microbatches=2, remat="none", grad_sync=grad_sync, monitor=monitor,
            monitor_threshold=1e-6, bucket_bytes=bucket_bytes,
            optimizer=OptimizerConfig(lr=1e-2, schedule="const", warmup_steps=0,
                                      grad_clip=1.0),
        )
        train_step, init_state, state_specs, rules = step_lib.make_train_step(cfg, mesh, tcfg)
        with mesh:
            state = init_state(jax.random.PRNGKey(0))
            specs = state_specs(state)
            shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
            state = jax.device_put(state, shardings)
            pipe = SyntheticPipeline(cfg, DataConfig(batch=8, seq_len=32, seed=0), mesh)
            jstep = jax.jit(train_step)
            losses = []
            for _ in range(steps):
                batch = pipe.next_batch()
                state, metrics = jstep(state, batch)
                losses.append(float(metrics["loss"]))
        return losses, state, metrics

    # --- 1. gspmd baseline: loss decreases ---
    l_gspmd, st_g, _ = run_mode((4, 2), ("data", "model"), "gspmd")
    assert l_gspmd[-1] < l_gspmd[0], f"gspmd loss: {l_gspmd}"
    print("gspmd OK", [round(x,3) for x in l_gspmd])

    # --- 2. MRD-ZeRO-1: matches gspmd step-for-step (same math).  A small
    # bucket cap forces the multi-bucket pipelined RS/AG path. ---
    l_mrd, st_m, _ = run_mode((4, 2), ("data", "model"), "mrd_zero1",
                              bucket_bytes=1 << 15)
    np.testing.assert_allclose(l_gspmd, l_mrd, rtol=2e-2, atol=2e-2)
    print("mrd_zero1 == gspmd OK", [round(x,3) for x in l_mrd])

    # --- params agreement after N steps ---
    pg = jax.tree.leaves(st_g["params"]); pm = jax.tree.leaves(st_m["params"])
    for a, b in zip(pg, pm):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-2)
    print("param agreement OK")

    # --- 3. non-power-of-two DP (p=6: the paper's headline case) ---
    l_np2, _, _ = run_mode((6,), ("data",), "mrd_zero1", ndev=6)
    assert l_np2[-1] < l_np2[0], f"non-p2 loss: {l_np2}"
    print("non-p2 dp=6 OK", [round(x,3) for x in l_np2])

    # --- 4. compressed grad sync: converges (within quantization noise) ---
    l_cmp, _, _ = run_mode((4, 2), ("data", "model"), "compressed")
    assert l_cmp[-1] < l_cmp[0] + 0.05, f"compressed loss: {l_cmp}"
    print("compressed OK", [round(x,3) for x in l_cmp])

    # --- 5. monitor fires when threshold is lenient ---
    _, _, metrics = run_mode((4, 2), ("data", "model"), "gspmd", steps=8)
    # threshold 1e-6 won't fire in 8 steps; re-run with a huge threshold
    mesh = compat.make_mesh((4, 2), ("data", "model"),
                            axis_types=compat.default_axis_types(2))
    tcfg = step_lib.TrainConfig(
        microbatches=1, remat="none", grad_sync="gspmd", monitor=True,
        monitor_threshold=100.0,
        optimizer=OptimizerConfig(lr=1e-3, schedule="const", warmup_steps=0))
    train_step, init_state, state_specs, rules = step_lib.make_train_step(cfg, mesh, tcfg)
    with mesh:
        state = jax.device_put(init_state(jax.random.PRNGKey(0)),
            jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs(state := init_state(jax.random.PRNGKey(0)))))
        pipe = SyntheticPipeline(cfg, DataConfig(batch=8, seq_len=32, seed=0), mesh)
        jstep = jax.jit(train_step)
        fired = False
        from repro.core.nonblocking import cycle_length
        need = cycle_length(4) + 2
        for i in range(need + 2):
            state, metrics = jstep(state, pipe.next_batch())
            if bool(metrics["converged"]):
                fired = True
                break
    assert fired, "monitor never fired with lenient threshold"
    print(f"monitor fired at step {i} (cycle length {need-2}) OK")
    print("ALL-TRAIN-DIST-PASSED")
    """
)


@pytest.mark.slow
def test_distributed_training_modes():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env, timeout=1800,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout[-4000:]}\nSTDERR:\n{proc.stderr[-6000:]}"
    assert "ALL-TRAIN-DIST-PASSED" in proc.stdout
