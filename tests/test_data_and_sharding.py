"""Data pipeline determinism/resume + sharding-rule properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import registry
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.distributed import sharding as shd
from repro.models import transformer


def test_pipeline_deterministic_and_resumable():
    cfg = registry.get_smoke_config("llama3.2-1b")
    d = DataConfig(batch=4, seq_len=16, seed=7)
    p1 = SyntheticPipeline(cfg, d)
    batches = [p1.next_batch() for _ in range(5)]
    st_ = p1.state_dict()

    # resume from step 3 reproduces batches 3, 4
    p2 = SyntheticPipeline(cfg, d)
    p2.load_state_dict({"step": 3, "seed": 7})
    for i in (3, 4):
        b = p2.next_batch()
        np.testing.assert_array_equal(np.asarray(b["tokens"]), np.asarray(batches[i]["tokens"]))
    assert st_["step"] == 5


def test_pipeline_labels_are_shifted_tokens():
    cfg = registry.get_smoke_config("llama3.2-1b")
    p = SyntheticPipeline(cfg, DataConfig(batch=2, seq_len=12, seed=0))
    b = p.next_batch()
    np.testing.assert_array_equal(
        np.asarray(b["tokens"][:, 1:]), np.asarray(b["labels"][:, :-1])
    )


def _mesh(dp, tp):
    n = dp * tp
    if n > 1:
        pytest.skip("single-device test process")
    return jax.make_mesh((dp, tp), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


@pytest.mark.parametrize("arch", registry.list_archs())
def test_param_specs_cover_every_leaf(arch):
    """Every param leaf gets a spec whose sharded dims divide evenly on the
    production mesh extents (16, 16) — checked abstractly, no devices."""
    cfg = registry.get_config(arch)
    params_sds = jax.eval_shape(lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    rules = shd.ShardingRules(
        mesh=FakeMesh(), dp_axes=("data",), fsdp_axis="data", tp_axis="model",
        attn_heads_sharded=cfg.n_heads > 0 and cfg.n_heads % 16 == 0,
        kv_heads_sharded=cfg.n_kv_heads > 0 and cfg.n_kv_heads % 16 == 0,
        ep=cfg.n_experts > 0 and cfg.n_experts % 16 == 0,
    )
    specs = shd.param_specs(cfg, rules, params_sds)
    sizes = {"data": 16, "model": 16}
    for (path, leaf), (_, spec) in zip(
        jax.tree_util.tree_flatten_with_path(params_sds)[0],
        jax.tree_util.tree_flatten_with_path(specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))[0],
    ):
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            total = 1
            for a in axes:
                total *= sizes[a]
            assert dim % total == 0, f"{path}: dim {dim} not divisible by {axes}"


@given(
    h=st.sampled_from([8, 16, 32, 40, 48, 64]),
    kv=st.sampled_from([1, 2, 4, 8, 16, 32]),
)
@settings(max_examples=30, deadline=None)
def test_kv_repeat_factor_properties(h, kv):
    """Outside a context the factor is 1; algebraic properties hold."""
    if h % kv:
        return
    assert shd.kv_repeat_factor(h, kv) == 1  # no active context


def test_manual_region_disables_dp_constraints():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 2}

    r = shd.ShardingRules(
        mesh=FakeMesh(), dp_axes=("data",), fsdp_axis="data", tp_axis="model",
        attn_heads_sharded=True, kv_heads_sharded=True, ep=False,
    )
    inner = r.manual_region()
    assert inner.batch_axes(8) is None
    assert inner.fsdp_axis is None
    assert inner.tp_axis == "model"  # TP constraints still active
