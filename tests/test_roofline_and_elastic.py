"""Roofline HLO parser units + elastic-trainer end-to-end (subprocess)."""

import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch import roofline as R


def test_parse_collective_bytes_simple():
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={}
  %ar = f32[1024]{0} all-reduce(%y), to_apply=%sum
  %cp = bf16[64,64]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %rs = f32[256]{0} reduce-scatter(%w), dimensions={0}
  %a2a.1 = s8[32]{0} all-to-all(%v), dimensions={0}
"""
    out = R.parse_collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 2
    assert out["all-reduce"] == 1024 * 4
    assert out["collective-permute"] == 64 * 64 * 2
    assert out["reduce-scatter"] == 256 * 4
    assert out["all-to-all"] == 32 * 1


def test_parse_collective_start_variants():
    hlo = "%s = f32[100]{0} all-reduce-start(%x), to_apply=%sum"
    out = R.parse_collective_bytes(hlo)
    assert out["all-reduce"] == 400


def test_roofline_report_terms():
    rep = R.RooflineReport(
        arch="x", shape="train_4k", mesh="single", chips=256,
        hlo_flops=197e12, hlo_bytes=819e9, collective_bytes={"all-reduce": 50_000_000_000},
        model_flops=197e12 * 256 * 0.5,
    )
    assert abs(rep.t_compute - 1.0) < 1e-9
    assert abs(rep.t_memory - 1.0) < 1e-9
    assert abs(rep.t_collective - 1.0) < 1e-9
    assert abs(rep.useful_flops_ratio - 0.5) < 1e-9
    assert abs(rep.roofline_fraction - 0.5) < 1e-9


_ELASTIC = textwrap.dedent(
    """
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.configs import registry
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.data.pipeline import DataConfig, SyntheticPipeline
    from repro.distributed import step as step_lib
    from repro.optim.optimizer import OptimizerConfig
    from repro.runtime.elastic import ElasticConfig, ElasticTrainer
    from repro import compat

    cfg = registry.get_smoke_config("llama3.2-1b")
    tcfg = step_lib.TrainConfig(
        microbatches=1, remat="none", grad_sync="mrd_leaf", monitor=False,
        optimizer=OptimizerConfig(lr=5e-3, schedule="const", warmup_steps=0))

    mesh = compat.make_mesh((4,), ("data",), devices=jax.devices()[:4],
                         axis_types=compat.default_axis_types(1))
    trainer = ElasticTrainer(
        mesh,
        step_fn_factory=lambda m: step_lib.make_train_step(cfg, m, tcfg),
        pipe_factory=lambda m: SyntheticPipeline(
            cfg, DataConfig(batch=12, seq_len=32, seed=0), m),
        checkpointer=Checkpointer(tempfile.mkdtemp()),
        cfg=ElasticConfig(ckpt_every=3),
    )
    state = trainer.init_or_restore(jax.random.PRNGKey(0))
    # fail device 0 at step 5: shrink 4 -> 3 (non-power-of-two, MRD handles it)
    state, losses = trainer.run(state, 10, fail_at={5: {0}})
    assert trainer.mesh.shape["data"] == 3, trainer.mesh.shape
    assert trainer.restarts == 1
    assert len(losses) >= 8
    assert np.mean(losses[-3:]) < np.mean(losses[:3]) + 0.05, losses
    print("ELASTIC-TRAINER-PASSED", [round(x, 3) for x in losses])
    """
)


@pytest.mark.slow
def test_elastic_trainer_end_to_end():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _ELASTIC],
        capture_output=True, text=True, env=env, timeout=1200,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout[-3000:]}\nSTDERR:\n{proc.stderr[-5000:]}"
    assert "ELASTIC-TRAINER-PASSED" in proc.stdout
