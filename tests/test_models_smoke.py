"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + one decode step on CPU; asserts shapes + finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry, shapes
from repro.models import frontends, transformer
from repro.models.config import ModelConfig

ARCHS = registry.list_archs()


def _batch_for(cfg: ModelConfig, key, B=2, S=32):
    ks = jax.random.split(key, 3)
    if cfg.family == "audio":
        return {
            "frames": frontends.audio_frames(ks[0], B, S, jnp.float32),
            "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
        }
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
    }
    if cfg.frontend == "vision":
        batch["patches"] = frontends.vision_patches(
            ks[2], B, cfg.n_frontend_tokens, jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_train_smoke(arch):
    cfg = registry.get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    batch = _batch_for(cfg, jax.random.PRNGKey(1))
    loss, metrics = jax.jit(
        lambda p, b: transformer.forward_train(p, b, cfg)
    )(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert float(loss) > 0
    # sanity: loss ~ log(vocab) at init
    assert float(metrics["loss"]) < np.log(cfg.vocab) + 2.0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss(arch):
    """Two plain-SGD steps on one batch must reduce the loss."""
    cfg = registry.get_smoke_config(arch)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, jax.random.PRNGKey(1))

    @jax.jit
    def step(p):
        (loss, _), g = jax.value_and_grad(
            lambda q: transformer.forward_train(q, batch, cfg), has_aux=True
        )(p)
        p = jax.tree.map(lambda w, gw: w - 0.1 * gw.astype(w.dtype), p, g)
        return p, loss

    losses = []
    for _ in range(3):
        params, loss = step(params)
        losses.append(float(loss))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], f"{arch}: loss did not decrease: {losses}"


@pytest.mark.parametrize(
    "arch", [a for a in ARCHS if a not in shapes.ENCODER_ONLY]
)
def test_decode_smoke(arch):
    cfg = registry.get_smoke_config(arch)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    B, max_len = 2, 64
    cache = transformer.init_cache(cfg, B, max_len)
    toks = jnp.array([1, 2], jnp.int32)
    decode = jax.jit(
        lambda p, t, c, n: transformer.forward_decode(p, t, c, n, cfg)
    )
    for i in range(4):
        logits, cache = decode(params, toks, cache, jnp.int32(i))
        assert logits.shape == (B, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(logits))), f"{arch}: step {i} NaN"
        toks = jnp.argmax(logits, -1).astype(jnp.int32)


def test_decode_matches_forward_dense():
    """Greedy decode logits == full-forward logits at the same positions
    (cache correctness), for a small dense config."""
    cfg = registry.get_smoke_config("llama3.2-1b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)

    # full forward logits at each position
    batch = {"tokens": toks, "labels": jnp.zeros((B, S), jnp.int32)}
    x, _ = transformer._embed_inputs(params, batch, cfg)
    pos = jnp.arange(S)[None, :]
    h, _ = transformer._run_stack(params, x, cfg, pos)
    from repro.models.layers import rmsnorm

    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    full_logits = transformer._logits(params, h, cfg)

    # token-by-token decode
    cache = transformer.init_cache(cfg, B, S)
    for i in range(S):
        logits, cache = transformer.forward_decode(
            params, toks[:, i], cache, jnp.int32(i), cfg
        )
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, i]), rtol=2e-4, atol=2e-4
        )


def test_decode_matches_forward_ssm():
    """Same cache-correctness check for the mamba1 path (recurrent state)."""
    cfg = registry.get_smoke_config("falcon-mamba-7b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.zeros((B, S), jnp.int32)}
    x, _ = transformer._embed_inputs(params, batch, cfg)
    h, _ = transformer._run_stack(params, x, cfg, jnp.arange(S)[None, :])
    from repro.models.layers import rmsnorm

    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    full_logits = transformer._logits(params, h, cfg)

    cache = transformer.init_cache(cfg, B, S)
    for i in range(S):
        logits, cache = transformer.forward_decode(
            params, toks[:, i], cache, jnp.int32(i), cfg
        )
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, i]), rtol=5e-4, atol=5e-4
        )


def test_param_counts_match_analytic():
    """init_params totals ~= ModelConfig.n_params (within embed/frontend slack)."""
    for arch in ["llama3.2-1b", "qwen2.5-32b", "mixtral-8x7b", "falcon-mamba-7b"]:
        cfg = registry.get_smoke_config(arch)
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        analytic = cfg.n_params()
        assert abs(actual - analytic) / analytic < 0.1, (
            f"{arch}: actual {actual} vs analytic {analytic}"
        )
