"""Paper S3: detection protocol properties (E3) on the async engine."""

import numpy as np
import pytest

from repro.core import async_engine as ae
from repro.core import solvers


def _fp(n=96, seed=0, shift=0.5):
    return solvers.poisson_1d(n, omega=1.0, shift=shift, seed=seed)


@pytest.mark.parametrize("p", [2, 3, 4, 6, 8])
def test_exact_detection_is_certified(p):
    """E3: whenever the exact (snapshot) detector fires, the returned x̄
    genuinely satisfies ||f(x̄) - x̄||_inf < eps. Zero tolerance."""
    fp = _fp(n=96)
    cfg = ae.AsyncConfig(p=p, detection="exact", eps=1e-5, max_ticks=50000, seed=p)
    res = ae.run(fp, cfg)
    assert res.detected, f"exact detector did not converge (p={p})"
    assert res.true_res < cfg.eps, (
        f"exact detector certified a bad solution: true_res={res.true_res}"
    )


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_exact_detection_many_seeds(seed):
    fp = _fp(n=64, seed=seed)
    cfg = ae.AsyncConfig(
        p=4, detection="exact", eps=1e-5, max_ticks=50000,
        seed=seed, max_delay=4, activity=0.5,
    )
    res = ae.run(fp, cfg)
    assert res.detected and res.true_res < cfg.eps


@pytest.mark.parametrize("p", [2, 4, 8])
def test_inexact_detection_terminates_near_solution(p):
    """Algorithm 1 is inexact but 'still has an acceptable precision' (paper):
    at detection the true residual should be within a modest factor of eps."""
    fp = _fp(n=96)
    cfg = ae.AsyncConfig(p=p, detection="inexact", eps=1e-6, max_ticks=50000, seed=p)
    res = ae.run(fp, cfg)
    assert res.detected
    # not exact — but the paper's claim is bounded inexactness, not failure
    assert res.true_res < 1e-2


def test_oracle_baseline_converges():
    fp = _fp(n=64)
    res = ae.run(fp, ae.AsyncConfig(p=4, detection="oracle", eps=1e-6, max_ticks=50000))
    assert res.detected and res.true_res < 1e-6


def test_sync_mode_matches_jacobi_iteration_count():
    """Synchronous mode = classical Jacobi: no staleness, all workers active."""
    fp = _fp(n=64)
    res = ae.run(fp, ae.AsyncConfig(p=4, detection="sync", eps=1e-6, max_ticks=50000))
    assert res.detected
    assert np.all(res.kiter == res.kiter[0])  # all workers iterate in lockstep
    assert res.true_res < 1e-4  # update-magnitude criterion ~ residual scale


def test_async_solution_agrees_with_sync():
    # eps bounded below by the fp32 floor (update magnitudes ~ eps_mach * |x|)
    fp = _fp(n=64)
    r_sync = ae.run(fp, ae.AsyncConfig(p=4, detection="sync", eps=2e-6, max_ticks=60000))
    r_async = ae.run(fp, ae.AsyncConfig(p=4, detection="exact", eps=2e-6, max_ticks=60000))
    assert r_sync.detected and r_async.detected
    np.testing.assert_allclose(r_sync.x, r_async.x, atol=1e-4)


def test_fairness_forced_activity():
    """No worker starves: per-worker iteration counts stay within the forced
    activity bound (paper's first fairness condition)."""
    fp = _fp(n=64)
    cfg = ae.AsyncConfig(
        p=8, detection="oracle", eps=1e-6, max_ticks=50000, activity=0.3, force_every=4
    )
    res = ae.run(fp, cfg)
    assert res.detected
    assert res.kiter.min() >= res.ticks // cfg.force_every - 1


def test_messages_accounting_sync_vs_async():
    """Fig. 5 discussion: in a 'concentrated' setting async generates at least
    as many point-to-point messages while needing similar iteration counts."""
    fp = _fp(n=64)
    r_sync = ae.run(fp, ae.AsyncConfig(p=4, detection="sync", eps=1e-6, max_ticks=60000))
    r_async = ae.run(
        fp,
        ae.AsyncConfig(
            p=4, detection="exact", eps=1e-6, max_ticks=60000,
            activity=1.0, max_delay=1,
        ),
    )
    assert r_sync.detected and r_async.detected
    per_tick_sync = r_sync.messages_p2p / r_sync.ticks
    per_tick_async = r_async.messages_p2p / r_async.ticks
    assert per_tick_async >= per_tick_sync * 0.99
