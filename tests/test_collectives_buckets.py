"""The bucketed, pipelined collective execution engine (DESIGN.md S10):
bucketizer layout invariants + pack/unpack round-trips (property-based),
bucketed == flat == per-leaf bit-agreement on the sim executor, and the
mixed-dtype preservation contract of ``tree_allreduce``.
"""

import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.collectives import buckets, plans

# ---------------------------------------------------------------------------
# Layout invariants + pack/unpack round-trip (property-based)
# ---------------------------------------------------------------------------

_DTYPES = ["float32", "bfloat16", "int32", "float16"]

_leaf_spec = st.tuples(
    st.lists(st.integers(1, 7), min_size=0, max_size=3).map(tuple),  # shape
    st.sampled_from(_DTYPES),
)


def _make_tree(leaf_specs, stacked=None):
    """Deterministic, exactly-representable values (small ints) so round-
    trips can be checked bit-exactly in every dtype."""
    tree = {}
    for i, (shape, dtype) in enumerate(leaf_specs):
        full = ((stacked,) if stacked else ()) + shape
        n = int(np.prod(full)) if full else 1
        vals = (np.arange(n) % 120).reshape(full)
        tree[f"leaf{i}"] = jnp.asarray(vals).astype(dtype)
    return tree


def _check_layout(layout, leaf_specs, bucket_bytes, quantum):
    slots_seen = sorted(s.index for b in layout.buckets for s in b.slots)
    assert slots_seen == list(range(len(leaf_specs)))  # partition, no dupes
    for b in layout.buckets:
        assert all(s.dtype == b.dtype for s in b.slots)  # dtype-homogeneous
        assert b.length % quantum == 0  # padded to the plan quantum
        assert b.length >= b.used
        offsets = [(s.offset, s.size) for s in b.slots]
        pos = 0
        for off, size in offsets:  # slots tile the bucket contiguously
            assert off == pos
            pos += size
        if bucket_bytes is not None and len(b.slots) > 1:
            # cap respected whenever the bucket holds more than one leaf
            # (a single over-cap leaf legitimately gets its own bucket)
            itemsize = jnp.dtype(b.dtype).itemsize
            assert b.used * itemsize <= bucket_bytes


@given(
    leaf_specs=st.lists(_leaf_spec, min_size=1, max_size=8),
    bucket_bytes=st.sampled_from([None, 64, 256, 4096]),
    quantum=st.sampled_from([1, 4, 256]),
)
@settings(max_examples=40, deadline=None)
def test_pack_unpack_roundtrip_property(leaf_specs, bucket_bytes, quantum):
    tree = _make_tree(leaf_specs)
    layout = buckets.build_layout(
        tree, bucket_bytes=bucket_bytes, quantum=quantum
    )
    _check_layout(layout, leaf_specs, bucket_bytes, quantum)
    bufs = buckets.pack(tree, layout)
    assert [b.shape for b in bufs] == [(bk.length,) for bk in layout.buckets]
    assert [b.dtype for b in bufs] == [
        jnp.dtype(bk.dtype) for bk in layout.buckets
    ]
    out = buckets.unpack(bufs, layout)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    for k in tree:
        assert out[k].dtype == tree[k].dtype
        assert out[k].shape == tree[k].shape
        np.testing.assert_array_equal(
            np.asarray(out[k], np.float64), np.asarray(tree[k], np.float64)
        )


@given(
    leaf_specs=st.lists(_leaf_spec, min_size=1, max_size=5),
    p=st.sampled_from([2, 3, 5]),
    bucket_bytes=st.sampled_from([None, 128]),
)
@settings(max_examples=20, deadline=None)
def test_pack_unpack_roundtrip_stacked_property(leaf_specs, p, bucket_bytes):
    """Sim trees carry a leading [p, ...] rank axis; buffers become [p, n]."""
    tree = _make_tree(leaf_specs, stacked=p)
    layout = buckets.build_layout(
        tree, bucket_bytes=bucket_bytes, quantum=2, stacked=p
    )
    bufs = buckets.pack(tree, layout)
    assert all(b.shape[0] == p for b in bufs)
    out = buckets.unpack(bufs, layout)
    for k in tree:
        assert out[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(
            np.asarray(out[k], np.float64), np.asarray(tree[k], np.float64)
        )


def test_layout_is_deterministic_and_reusable():
    tree = {"a": jnp.zeros((3, 4)), "b": jnp.zeros((17,)), "c": jnp.zeros((2,))}
    l1 = buckets.build_layout(tree, bucket_bytes=64, quantum=4)
    l2 = buckets.build_layout(
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree),
        bucket_bytes=64,
        quantum=4,
    )
    assert l1.buckets == l2.buckets  # arrays vs shape-structs: same layout
    assert l1.total_padded == sum(l1.bucket_lengths)


def test_pack_rejects_mismatched_dtype_and_structure():
    tree = {"a": jnp.zeros((4,), jnp.float32)}
    layout = buckets.build_layout(tree)
    with pytest.raises(ValueError, match="never promote"):
        buckets.pack({"a": jnp.zeros((4,), jnp.bfloat16)}, layout)
    with pytest.raises(ValueError, match="structure"):
        buckets.pack({"zz": jnp.zeros((4,), jnp.float32)}, layout)


def test_build_layout_rejects_bad_stacked_and_quantum():
    with pytest.raises(ValueError, match="rank axis"):
        buckets.build_layout({"a": jnp.zeros((3, 2))}, stacked=4)
    with pytest.raises(ValueError, match="quantum"):
        buckets.build_layout({"a": jnp.zeros((3,))}, quantum=0)


# ---------------------------------------------------------------------------
# Bucketed == flat == per-leaf bit-agreement (sim executor, identity)
# ---------------------------------------------------------------------------


def _grad_tree(p, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "wq": jnp.asarray(rng.standard_normal((p, 7, 3)), jnp.float32),
        "wk": jnp.asarray(rng.standard_normal((p, 11)), jnp.float32),
        "mlp": [
            jnp.asarray(rng.standard_normal((p, 5)), jnp.float32),
            jnp.asarray(rng.standard_normal((p, 64)), jnp.float32),
        ],
    }


def _flat_rows(tree, p):
    return jnp.concatenate(
        [l.reshape(p, -1) for l in jax.tree.leaves(tree)], axis=1
    )


@pytest.mark.parametrize("p", [2, 3, 4, 5, 6, 7, 8, 9])
@pytest.mark.parametrize("schedule", ["mrd", "rabenseifner"])
def test_bucketed_equals_flat_equals_per_leaf(p, schedule):
    """The acceptance contract: run_bucketed is bit-identical to run() on
    the flat vector (identity transform), for non-power-of-two p too, at
    every bucket granularity; the per-leaf path agrees bit-for-bit."""
    tree = _grad_tree(p, seed=p)
    plan = plans.allreduce_plan(schedule=schedule, p=p, op="sum")
    flat = _flat_rows(tree, p)
    pad = (-flat.shape[1]) % plan.pad_quantum()
    ref = plan.run(jnp.pad(flat, ((0, 0), (0, pad))))[:, : flat.shape[1]]
    for bucket_bytes in [None, 4, 40 * 4, 10**9]:
        out = plan.run_bucketed(tree, bucket_bytes=bucket_bytes)
        np.testing.assert_array_equal(
            np.asarray(_flat_rows(out, p)), np.asarray(ref)
        )
    if schedule == "mrd":  # per-leaf path: plan.run tree-maps over leaves
        per_leaf = plan.run(tree)
        np.testing.assert_array_equal(
            np.asarray(_flat_rows(per_leaf, p)), np.asarray(ref)
        )


@pytest.mark.parametrize("p", [3, 5, 8])
def test_run_buffers_matches_run_per_buffer(p):
    """run_buffers pipelines across buffers but must equal per-buffer run()
    bit-for-bit (identity transform), including RS/AG phase plans."""
    rng = np.random.default_rng(p)
    for factory, kw in [
        (plans.allreduce_plan, {"schedule": "mrd"}),
        (plans.allreduce_plan, {"schedule": "rabenseifner"}),
        (plans.reduce_scatter_plan, {}),
    ]:
        plan = factory(p=p, op="sum", **kw)
        q = plan.pad_quantum()
        bufs = [
            jnp.asarray(rng.standard_normal((p, q * k)), jnp.float32)
            for k in (1, 3, 2)
        ]
        out = plan.run_buffers(bufs)
        for b_in, b_out in zip(bufs, out):
            np.testing.assert_array_equal(
                np.asarray(b_out), np.asarray(plan.run(b_in))
            )


def test_run_buffers_validates_rs_divisibility():
    plan = plans.allreduce_plan(schedule="rabenseifner", p=4)
    with pytest.raises(ValueError, match="pad_quantum"):
        plan.run_buffers([jnp.zeros((4, 6), jnp.float32)])


def test_run_bucketed_rejects_primitive_plans():
    with pytest.raises(ValueError, match="allreduce-schedule"):
        plans.reduce_scatter_plan(p=4).run_bucketed({"a": jnp.zeros((4, 8))})


# ---------------------------------------------------------------------------
# Mixed-dtype preservation (the tree_allreduce promotion hazard, fixed)
# ---------------------------------------------------------------------------


def test_tree_allreduce_preserves_mixed_dtypes():
    """A bf16+fp32 tree must round-trip with original dtypes end-to-end —
    the old flat-ravel path promoted bf16 leaves to fp32 on the wire."""
    p = 6
    rng = np.random.default_rng(0)
    # small-integer payloads are exactly representable in every dtype, so
    # the reduced values can be compared bit-exactly regardless of the
    # schedule's reduction order
    tree = {
        "bf16": jnp.asarray(rng.integers(-8, 8, (p, 24)), jnp.bfloat16),
        "fp32": jnp.asarray(rng.integers(-64, 64, (p, 10)), jnp.float32),
        "fp16": jnp.asarray(rng.integers(-8, 8, (p, 5)), jnp.float16),
    }
    for bucket_bytes in [None, 64]:
        out = plans.tree_allreduce(tree, p=p, bucket_bytes=bucket_bytes)
        assert out["bf16"].dtype == jnp.bfloat16
        assert out["fp32"].dtype == jnp.float32
        assert out["fp16"].dtype == jnp.float16
        # small-integer payloads are exact in every dtype: check the sums
        for k in tree:
            np.testing.assert_array_equal(
                np.asarray(out[k], np.float64),
                np.broadcast_to(
                    np.asarray(tree[k], np.float64).sum(0), tree[k].shape
                ),
            )


def test_tree_allreduce_single_rank_is_noop():
    """p=1 (degenerate domain): bucketed round-trip is the identity."""
    tree = {"a": jnp.arange(6.0, dtype=jnp.float32).reshape(1, 6)}
    out = plans.tree_allreduce(tree, p=1)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))


# ---------------------------------------------------------------------------
# ZeRO-1 bucketed shard layout helpers
# ---------------------------------------------------------------------------


def test_zero1_masters_match_bucketed_layout():
    """Master rows = per-bucket owned segments concatenated in bucket
    order; non-pivot ranks of a non-power-of-two domain hold zeros."""
    from repro.distributed.gradsync.mrd_zero1 import (
        zero1_layout,
        zero1_masters_from_params,
        zero1_owner_segments,
    )

    mesh = types.SimpleNamespace(shape={"data": 3})  # dp=3, p0=2 (non-p2)
    rng = np.random.default_rng(1)
    params = {
        "a": jnp.asarray(rng.standard_normal((40, 7)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal((33,)), jnp.float32),
    }
    bb = 512  # tiny cap -> several buckets
    layout, prod_p0 = zero1_layout(params, mesh, ("data",), bucket_bytes=bb)
    assert prod_p0 == 2 and len(layout.buckets) > 1
    masters = zero1_masters_from_params(params, mesh, ("data",), bucket_bytes=bb)
    assert masters.shape == (3, layout.total_padded // prod_p0)
    from repro.collectives import buckets as B

    bufs = B.pack(params, layout)
    owners = zero1_owner_segments(mesh, ("data",))
    for rank, o in enumerate(owners):
        if o is None:
            np.testing.assert_array_equal(np.asarray(masters[rank]), 0.0)
        else:
            expect = np.concatenate(
                [np.asarray(b.reshape(prod_p0, -1)[o]) for b in bufs]
            )
            np.testing.assert_array_equal(np.asarray(masters[rank]), expect)
    # paper mode: every rank replicates the concatenated padded buckets
    rep = zero1_masters_from_params(
        params, mesh, ("data",), bucket_bytes=bb, paper_mode=True
    )
    np.testing.assert_array_equal(
        np.asarray(rep[2]), np.concatenate([np.asarray(b) for b in bufs])
    )
