"""Versioned checkpoint layout migration (DESIGN.md S12 satellite).

PR 3 broke restore of older checkpoints twice: compressed runs gained an
``opt/ef`` leaf, and the ConvergenceMonitor's per-protocol policy state
moved under ``m/`` (``monitor/latched`` -> ``monitor/m/latched``).  The
checkpointer now stamps ``layout_version`` in the manifest and migrates
older layouts on restore; both breaks are covered here against a *real*
compressed+monitored train state.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import (
    LAYOUT_VERSION,
    Checkpointer,
    migrate_layout,
)
from repro.configs import registry
from repro.distributed import step as step_lib
from repro.optim.optimizer import OptimizerConfig


def _real_state():
    """A genuine compressed + exact-monitor train state (dp=1, in-process):
    has the 'opt/ef' leaf and the 'monitor/m/latched' key — exactly the two
    PR-3 layout breaks."""
    cfg = registry.get_smoke_config("llama3.2-1b")
    tcfg = step_lib.TrainConfig(
        microbatches=1, remat="none", grad_sync="compressed",
        monitor=True, monitor_mode="exact", monitor_threshold=1e-6,
        optimizer=OptimizerConfig(lr=1e-3, schedule="const", warmup_steps=0),
    )
    from repro import compat

    mesh = compat.make_mesh(
        (1,), ("data",), devices=jax.devices()[:1],
        axis_types=compat.default_axis_types(1),
    )
    _, init_state, _, _ = step_lib.make_train_step(cfg, mesh, tcfg)
    with mesh:
        state = init_state(jax.random.PRNGKey(0))
    # make the migrated-through values recognizably non-default
    state["opt"]["ef"] = state["opt"]["ef"] + 0.0  # exists (compressed + EF)
    state["monitor"]["m"]["latched"] = jnp.full((1,), 7.5, jnp.float32)
    state["step"] = jnp.asarray(11, jnp.int32)
    return state


def _downgrade_to_v1(ckdir: str, step: int):
    """Rewrite a fresh checkpoint as a pre-PR-3 (v1) one: drop 'opt/ef',
    move 'monitor/m/*' keys to the old top-level spot, stamp no version."""
    d = os.path.join(ckdir, f"step_{step}")
    with np.load(os.path.join(d, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    old = {}
    for k, v in flat.items():
        if k.startswith("opt/ef"):
            continue  # pre-PR-3 compressed runs carried no residual
        parts = k.split("/")
        if "m" in parts:
            i = parts.index("m")
            k = "/".join(parts[:i] + parts[i + 1 :])
        old[k] = v
    np.savez(os.path.join(d, "arrays.npz"), **old)
    mpath = os.path.join(d, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    del manifest["layout_version"]  # v1 predates the field entirely
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    return old


@pytest.fixture(scope="module")
def state():
    return _real_state()


def test_current_layout_roundtrips_and_is_stamped(tmp_path, state):
    ck = Checkpointer(str(tmp_path))
    ck.save(11, state, block=True)
    assert ck.manifest(11)["layout_version"] == LAYOUT_VERSION
    out = ck.restore(11, jax.device_get(state))
    np.testing.assert_array_equal(
        np.asarray(out["monitor"]["m"]["latched"]), [7.5]
    )
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_v1_checkpoint_migrates_both_breaks(tmp_path, state):
    ck = Checkpointer(str(tmp_path))
    ck.save(11, state, block=True)
    _downgrade_to_v1(str(tmp_path), 11)

    out = ck.restore(11, jax.device_get(state))
    # break 1: the missing EF residual is synthesized as a fresh (zero) carry
    np.testing.assert_array_equal(
        np.asarray(out["opt"]["ef"]), np.zeros_like(np.asarray(state["opt"]["ef"]))
    )
    # break 2: the old top-level 'monitor/latched' lands under 'm/'
    np.testing.assert_array_equal(
        np.asarray(out["monitor"]["m"]["latched"]), [7.5]
    )
    # everything else restores bit-identically
    np.testing.assert_array_equal(
        np.asarray(out["opt"]["master"]), np.asarray(state["opt"]["master"])
    )
    assert int(out["step"]) == 11


def test_migrate_layout_reports_missing_keys():
    template = {"a": np.zeros((2,), np.float32), "b": np.zeros((3,), np.float32)}
    with pytest.raises(ValueError, match="missing 1 leaves.*'b'"):
        migrate_layout({"a": np.zeros((2,), np.float32)}, template, 1)


def test_migrate_layout_rejects_future_versions():
    with pytest.raises(ValueError, match="newer than this code"):
        migrate_layout({}, {}, LAYOUT_VERSION + 1)


def test_unknown_intermediate_version_raises():
    with pytest.raises(ValueError, match="no layout migration"):
        migrate_layout({}, {}, 0)
