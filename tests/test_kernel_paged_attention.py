"""Paged-gather decode attention kernel vs oracles (DESIGN.md S14).

The Pallas kernel (``kernels/flash_attention/paged_kernel.py``) reads K/V
through a per-sequence block table; its contract is checked three ways:

1. against the pure-jnp paged oracle (``paged_attention_ref``) across head
   sizes, block sizes, GQA ratios, and ragged lengths (incl. 1 and full);
2. against the *contiguous* flash-attention oracle through an identity
   block table — paging is pure bookkeeping, the math must not move;
3. under a random permutation of physical blocks — outputs depend only on
   the logical (table-ordered) view, never on physical placement.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import paged_attention
from repro.kernels.flash_attention.ref import (
    flash_attention_ref,
    paged_attention_ref,
)


def _mk(seed, *, S, H, KV, hd, nb, bs, num_blocks=None):
    """Random q + physical pools + a valid (disjoint per-row) block table."""
    rng = np.random.default_rng(seed)
    N = num_blocks or (S * nb + 1)
    q = rng.standard_normal((S, H, hd)).astype(np.float32)
    k = rng.standard_normal((N, bs, KV, hd)).astype(np.float32)
    v = rng.standard_normal((N, bs, KV, hd)).astype(np.float32)
    perm = rng.permutation(np.arange(1, N))[: S * nb]
    tables = perm.reshape(S, nb).astype(np.int32)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(tables)


@pytest.mark.parametrize(
    "S,H,KV,hd,nb,bs",
    [
        (3, 4, 4, 64, 3, 8),  # MHA
        (4, 8, 2, 64, 2, 8),  # GQA 4:1
        (2, 6, 3, 80, 4, 16),  # odd head dim, bigger blocks
        (1, 2, 1, 32, 2, 4),  # single sequence, tiny blocks
    ],
)
def test_kernel_matches_paged_ref(S, H, KV, hd, nb, bs):
    q, k, v, tables = _mk(0, S=S, H=H, KV=KV, hd=hd, nb=nb, bs=bs)
    rng = np.random.default_rng(1)
    # ragged: always include a length-1 and a full-capacity row when S allows
    lengths = rng.integers(1, nb * bs + 1, size=S).astype(np.int32)
    lengths[0] = nb * bs
    if S > 1:
        lengths[-1] = 1
    lengths = jnp.asarray(lengths)
    out = paged_attention(q, k, v, tables, lengths, interpret=True)
    ref = paged_attention_ref(q, k, v, tables, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5,
                               rtol=1e-5)


def test_kernel_matches_contiguous_flash_ref():
    """Identity block table == contiguous decode attention, per sequence."""
    S, H, KV, hd, nb, bs = 3, 4, 2, 64, 4, 8
    rng = np.random.default_rng(2)
    W = nb * bs
    kc = rng.standard_normal((S, W, KV, hd)).astype(np.float32)
    vc = rng.standard_normal((S, W, KV, hd)).astype(np.float32)
    q = jnp.asarray(rng.standard_normal((S, H, hd)).astype(np.float32))
    lengths = np.array([W, 9, 1], np.int32)
    # pack each sequence's contiguous cache into its own blocks (row s owns
    # physical blocks [1 + s*nb, 1 + (s+1)*nb))
    pools_k = np.zeros((S * nb + 1, bs, KV, hd), np.float32)
    pools_v = np.zeros_like(pools_k)
    tables = np.zeros((S, nb), np.int32)
    for s in range(S):
        for j in range(nb):
            b = 1 + s * nb + j
            pools_k[b] = kc[s, j * bs : (j + 1) * bs]
            pools_v[b] = vc[s, j * bs : (j + 1) * bs]
            tables[s, j] = b
    out = paged_attention(
        q, jnp.asarray(pools_k), jnp.asarray(pools_v), jnp.asarray(tables),
        jnp.asarray(lengths), interpret=True,
    )
    for s in range(S):
        L = int(lengths[s])
        # the decode query sits at position L-1: causal over the first L keys
        ref = flash_attention_ref(
            q[s][None, None], jnp.asarray(kc[s, :L][None]),
            jnp.asarray(vc[s, :L][None]), causal=True, q_offset=L - 1,
        )[0, 0]
        np.testing.assert_allclose(
            np.asarray(out[s]), np.asarray(ref), atol=1e-5, rtol=1e-5
        )


def test_kernel_invariant_under_physical_permutation():
    """Only the table-ordered logical view matters, not physical placement."""
    S, H, KV, hd, nb, bs = 2, 4, 2, 32, 3, 8
    q, k, v, tables = _mk(3, S=S, H=H, KV=KV, hd=hd, nb=nb, bs=bs)
    lengths = jnp.asarray(np.array([20, 7], np.int32))
    out0 = paged_attention(q, k, v, tables, lengths, interpret=True)

    rng = np.random.default_rng(4)
    N = k.shape[0]
    perm = np.concatenate([[0], rng.permutation(np.arange(1, N))])
    inv = np.argsort(perm)
    k2 = jnp.asarray(np.asarray(k)[perm])
    v2 = jnp.asarray(np.asarray(v)[perm])
    tables2 = jnp.asarray(inv[np.asarray(tables)].astype(np.int32))
    out1 = paged_attention(q, k2, v2, tables2, lengths, interpret=True)
    np.testing.assert_allclose(np.asarray(out0), np.asarray(out1), atol=1e-6,
                               rtol=1e-6)


def test_garbage_beyond_length_is_ignored():
    """Huge (finite) junk past ``length`` must not leak into the output —
    the kernel masks by position, so a masked key contributes an exact-zero
    softmax weight and the junk value multiplies out to 0.  (NaN garbage is
    excluded: 0*NaN propagates through any flash-style accumulator.)"""
    S, H, KV, hd, nb, bs = 2, 2, 2, 32, 2, 8
    q, k, v, tables = _mk(5, S=S, H=H, KV=KV, hd=hd, nb=nb, bs=bs)
    lengths = jnp.asarray(np.array([5, 12], np.int32))
    out0 = paged_attention(q, k, v, tables, lengths, interpret=True)

    k2, v2 = np.asarray(k).copy(), np.asarray(v).copy()
    t = np.asarray(tables)
    # poison everything beyond each row's length inside its own blocks
    for s, L in enumerate([5, 12]):
        for j in range(nb):
            lo, hi = j * bs, (j + 1) * bs
            for p in range(lo, hi):
                if p >= L:
                    k2[t[s, j], p - lo] = 1e9
                    v2[t[s, j], p - lo] = -1e9
    out1 = paged_attention(q, jnp.asarray(k2), jnp.asarray(v2), tables,
                           lengths, interpret=True)
    np.testing.assert_allclose(np.asarray(out0), np.asarray(out1), atol=1e-6,
                               rtol=1e-6)


def test_kernel_runs_jitted():
    """The op must stay jit-stable (it runs inside the fused serve tick)."""
    q, k, v, tables = _mk(6, S=2, H=4, KV=2, hd=32, nb=2, bs=8)
    lengths = jnp.asarray(np.array([3, 16], np.int32))

    @jax.jit
    def step(q, k, v, t, ln):
        return paged_attention(q, k, v, t, ln, interpret=True)

    out = step(q, k, v, tables, lengths)
    ref = paged_attention_ref(q, k, v, tables, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5,
                               rtol=1e-5)
