# NOTE: do NOT set XLA_FLAGS / forced device counts here — unit tests and
# benches must see the real single CPU device.  Only launch/dryrun.py forces
# 512 host devices, and device-executor tests spawn subprocesses.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
