"""Protocol message accounting vs the paper's closed-form cost model.

The paper's Table-1 counts for the modified recursive doubling Allreduce
over p processes (p0 = 2^mu0 <= p, extra = p - p0):

    messages per cycle: p0 * mu0 + 2 * extra
    steps per cycle:    mu0 (+ 2 when p is not a power of two)

``asynchrony/engine.py`` attributes collective messages tick-by-tick from
``msg_table`` (per-stage counts out of the schedule) and protocols charge
``coll_cycle_msgs`` per completed cycle — both must agree with the closed
forms at power-of-two and modified non-p2 extents, or every
messages_coll number the benches report is fiction.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.asynchrony.engine import AsyncConfig, _stage_message_table, run
from repro.asynchrony.protocols import _stage_msgs
from repro.asynchrony.solvers import make_solver
from repro.core import topology

PS = [2, 3, 5, 8, 17]


@pytest.mark.parametrize("p", PS)
def test_stage_table_sums_to_paper_count(p):
    table = np.asarray(_stage_message_table(p))
    assert int(table.sum()) == topology.paper_message_count(p)


@pytest.mark.parametrize("p", PS)
def test_stage_table_length_is_paper_step_count(p):
    table = _stage_message_table(p)
    assert table.shape[0] == topology.paper_step_count(p)


@pytest.mark.parametrize("p", PS)
def test_closed_form_matches_pivot(p):
    p0, mu0, extra = topology.pivot(p)
    assert topology.paper_message_count(p) == p0 * mu0 + 2 * extra
    assert topology.paper_step_count(p) == mu0 + (2 if extra else 0)


@pytest.mark.parametrize("p", PS)
def test_stage_kinds_account_for_extra_messages(p):
    """The (p - 2^floor(log2 p)) prediction: each of the `extra` ranks
    costs exactly one backward-shift and one forward-shift message."""
    _p0, _mu0, extra = topology.pivot(p)
    sched = topology.allreduce_schedule(p)
    shift = sum(
        len(st.pairs) for st in sched if st.kind in ("bshift", "fshift")
    )
    assert shift == 2 * extra


@pytest.mark.parametrize("p", PS)
def test_stage_msgs_attribution_covers_cycle(p):
    """Summing the per-tick attribution over one cycle = the cycle charge."""
    table = _stage_message_table(p)
    S = table.shape[0]
    per_tick = [int(_stage_msgs(table, jnp.int32(s))) for s in range(S)]
    assert sum(per_tick) == topology.paper_message_count(p)
    # the clamp used for ticks past the final stage repeats the last entry
    assert int(_stage_msgs(table, jnp.int32(S + 3))) == per_tick[-1]


@pytest.mark.parametrize("p", [2, 3, 5])
def test_sync_protocol_charges_paper_count_per_cycle(p):
    """The synchronous baseline completes one blocking cycle per tick, so
    messages_coll must be exactly ticks x paper_message_count(p)."""
    fp = make_solver("poisson1d", n=24 * p, shift=0.5, seed=0)
    cfg = AsyncConfig(p=p, detection="sync", max_ticks=50000, eps=1e-5)
    res = run(fp, cfg)
    assert res.detected
    assert res.messages_coll == res.ticks * topology.paper_message_count(p)


@pytest.mark.parametrize("p", [3, 5, 8])
def test_inexact_protocol_bills_one_stage_per_tick(p):
    """The inexact protocol advances the non-blocking reduction exactly
    one stage per tick and bills that stage's schedule count — so the run
    total is bracketed by ticks x min/max per-stage messages (and equal
    for power-of-two p, where every butterfly stage costs p messages)."""
    table = np.asarray(_stage_message_table(p))
    fp = make_solver("poisson1d", n=24 * p, shift=0.5, seed=0)
    cfg = AsyncConfig(p=p, detection="inexact", max_ticks=50000, eps=1e-5)
    res = run(fp, cfg)
    assert res.detected
    assert res.ticks * int(table.min()) <= res.messages_coll
    assert res.messages_coll <= res.ticks * int(table.max())
    if topology.is_power_of_two(p):
        assert res.messages_coll == res.ticks * p
